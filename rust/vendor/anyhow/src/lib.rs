//! Offline stand-in for the `anyhow` crate (DESIGN.md §5: the build
//! environment has no crates.io access, so external deps are vendored as
//! minimal API-compatible subsets).
//!
//! Implements the slice of the real API this workspace uses:
//!
//! * [`Error`]: an erased error with a context chain. `{e}` prints the
//!   outermost message, `{e:#}` the full `outer: inner: ...` chain.
//! * [`Result<T>`] alias.
//! * `?` conversion from any `std::error::Error + Send + Sync + 'static`
//!   (and, reflexively, from `Error` itself — `Error` deliberately does
//!   NOT implement `std::error::Error`, exactly like the real crate).
//! * [`Context`]: `.context(..)` / `.with_context(..)` on both `Result`
//!   and `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.

use std::fmt;

/// An erased error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self { msg: c.to_string(), cause: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        out.into_iter()
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// `Error` intentionally does not implement `std::error::Error`, so this
// blanket conversion cannot overlap with the reflexive `From<Error>`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error { msg, cause: err.map(Box::new) });
        }
        err.expect("non-empty chain")
    }
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error arm of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_reflexive() {
        fn a() -> Result<()> {
            bail!("boom {}", 7)
        }
        fn b() -> Result<()> {
            a()?;
            Ok(())
        }
        assert_eq!(b().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing field {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing field x");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn ensure_and_root_cause() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 2, "x too small: {x}");
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert_eq!(f(1).unwrap_err().to_string(), "x too small: 1");
        let chained = Err::<(), _>(io_err()).context("outer").unwrap_err();
        assert_eq!(chained.root_cause(), "missing");
    }
}
