//! Request-serving loop: a continuous scheduler in front of the PIM-GPT
//! engine.
//!
//! Timing-only systems are served by the interleaved multi-stream engine
//! (`sim::sched::MultiSim`): the worker admits up to
//! `cfg.sched.max_streams` requests into concurrent decode streams,
//! interleaves their instructions on the shared simulated hardware, and
//! backfills each freed slot from the queue — so one request's ASIC ops
//! overlap another's bank-level VMMs instead of serializing whole
//! requests FIFO. New requests are ingested (without blocking) at every
//! completion boundary. Setting `max_streams = 1` reproduces the seed's
//! FIFO behavior exactly.
//!
//! Requests carry a real prompt/generation split: the prompt runs as
//! batched prefill chunks (`sim::prefill`, `cfg.sched.prefill_chunk`)
//! whose matrix-matrix programs amortize DRAM row activations over the
//! prompt, and the reported TTFT is the first *generated* token — the
//! prompt's prefill completion — with the prefill/decode service split
//! surfaced per response (`Response::sim_prefill_seconds`) and in
//! aggregate (`ServerMetrics::{sim_prefill_seconds, sim_decode_seconds}`).
//!
//! Scheduling is policy-driven (`sim::policy`, `cfg.sched.policy`):
//! `fcfs` (default), `srf`, `fair` or `slo` — the latter sheds requests
//! whose predicted TTFT (the chunked-prefill cost of the request's own
//! prompt length) busts `cfg.sched.slo_ttft_cycles`. A shed request is
//! served a first-class response with `rejected = true` (no tokens, no
//! error) and counts in `ServerMetrics::rejected`.
//!
//! Requests carry a simulated `arrival_cycle` (open-loop serving): the
//! scheduler holds each request pending until simulated time reaches
//! its arrival, and the shutdown metrics report p50/p95/p99 of queue,
//! TTFT and end-to-end latency measured from those arrivals
//! (`ServerMetrics::latency`). Arrival traces come from
//! `sim::arrivals` (batch / fixed / Poisson / JSON replay). Note that
//! ingestion itself is wall-clock: a request ingested after simulated
//! time has already passed its `arrival_cycle` is admitted as soon as
//! possible but keeps its (now past) arrival stamp, so its queue time
//! includes the ingestion lag. For deterministic percentiles submit
//! the whole trace before serving starts, as `pim-gpt serve` does (it
//! gates the worker's factory on a barrier until every request is in
//! the channel), so every stamp derives from simulated time alone.
//!
//! Systems with a functional PJRT artifact still serve FIFO: the
//! functional decode is inherently one-token-at-a-time against a single
//! KV cache, so it co-simulates sequentially as before.
//!
//! (std threads + mpsc stand in for tokio, unavailable offline —
//! DESIGN.md §5.) The PJRT client types are not `Send`, so the worker
//! *constructs* the system inside its own thread from a factory closure.
//!
//! `shutdown` closes the queue and joins the worker but keeps the
//! response channel alive: late `recv()` callers drain any remaining
//! buffered responses and then get a clean "server shut down" error
//! instead of blocking on a channel that can never deliver.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::generation::PimGptSystem;
use crate::sim::stats::Percentiles;
use crate::sim::{LatencyReport, MultiSim, StreamOutcome, StreamSpec};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_new: usize,
    /// Simulated cycle the request arrives (open-loop replay; 0 =
    /// present at start). Queue/TTFT/end-to-end latencies are measured
    /// from this stamp, and the scheduler holds the request pending
    /// until simulated time reaches it. A request ingested after the
    /// sim has already passed this cycle keeps the stamp (its queue
    /// time then includes the ingestion lag) — submit whole traces up
    /// front for deterministic replays. Ignored by FIFO (functional
    /// artifact) serving, which runs on wall-clock ingestion order.
    pub arrival_cycle: u64,
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Simulated PIM-GPT service time for this request, seconds
    /// (admission to last token; excludes queueing).
    pub sim_seconds: f64,
    /// Prefill share of the service, seconds: admission to prompt
    /// completion — the moment the first generated token existed
    /// (`sim_seconds - sim_prefill_seconds` is the decode share). 0
    /// for rejected/errored requests and FIFO (functional) serving,
    /// which runs token-by-token.
    pub sim_prefill_seconds: f64,
    /// Wall-clock time from ingestion to completion, seconds.
    pub wall_seconds: f64,
    /// Queueing delay in *simulated* seconds (time the request waited
    /// for a free stream slot behind earlier requests). For a rejected
    /// request: the wait up to the rejection decision.
    pub sim_queue_seconds: f64,
    /// The admission policy shed this request (`sim::policy`,
    /// `StreamOutcome::Rejected`) — a first-class serving outcome, not
    /// an error: `error` stays `None` and no tokens are produced.
    pub rejected: bool,
    pub error: Option<String>,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    pub requests: u64,
    pub failed: u64,
    pub tokens: u64,
    /// Sum of per-request simulated service times.
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    /// Simulated wall time of the whole run (last completion cycle).
    /// For interleaved serving this is < `sim_seconds`: streams overlap.
    pub sim_makespan_seconds: f64,
    /// Makespan minus idle arrival-gap warp time (`SimStats::busy_cycles`)
    /// in seconds: the time the engine actually had work. Under open-loop
    /// arrivals the makespan includes offered-load gaps, so
    /// [`ServerMetrics::sim_tokens_per_s`] conflates load with capacity;
    /// [`ServerMetrics::sim_tokens_per_busy_s`] divides by this instead.
    pub sim_busy_seconds: f64,
    /// Fused decode sweeps (`sched.batch_decode`; 0 when off).
    pub fused_sweeps: u64,
    /// Mean streams per fused sweep (0 when nothing fused).
    pub mean_decode_batch: f64,
    /// Most streams ever fused into one sweep.
    pub max_decode_batch: u64,
    /// Decode steps that ran solo (unfused).
    pub solo_decode_steps: u64,
    /// Prefill share of the summed service times (admission to prompt
    /// completion, per request). Together with `sim_decode_seconds`
    /// this splits `sim_seconds` into the compute-dense prompt phase
    /// and the memory-bound generation phase.
    pub sim_prefill_seconds: f64,
    /// Decode share of the summed service times.
    pub sim_decode_seconds: f64,
    /// Disjoint per-stream KV contexts the mapping reserved (the real
    /// admission capacity; may be below the configured `max_streams`
    /// when DRAM rows ran out). 1 for FIFO/functional serving.
    pub kv_slots: u64,
    /// Most KV slots ever occupied at once during the run.
    pub peak_slots_in_use: u64,
    /// Arrived requests found waiting with every KV slot occupied,
    /// summed over admission attempts (queue-depth-weighted KV-capacity
    /// pressure — see `SimStats::admission_blocked`).
    pub admission_blocked: u64,
    /// KV frames in the paged pool (`sched.kv_paging`; 0 when the slot
    /// engine served the run).
    pub kv_pages: u64,
    /// Most frames ever in use at once under paging.
    pub peak_pages_in_use: u64,
    /// Decode steps that needed a KV frame with the free list empty
    /// (each fault resolves by preempting a victim stream).
    pub page_faults: u64,
    /// Streams preempted (evicted, context written back, re-queued for
    /// re-admission) to resolve page faults.
    pub preemptions: u64,
    /// Context tokens written back by those evictions (restore cost is
    /// symmetric, so this measures the oversubscription swap traffic).
    pub evicted_tokens: u64,
    /// Requests shed by the configured admission policy
    /// (`sched.policy = slo`; always 0 under admit-always policies).
    /// Rejected requests count in `requests` but not in `failed`,
    /// `tokens` or the latency percentiles.
    pub rejected: u64,
    /// Devices the model was partitioned across (`sched.devices`; 1 for
    /// the single-package engine).
    pub devices: u64,
    /// Modeled interconnect cycles (pipeline stage hops, tensor-parallel
    /// all-reduces and LM-head gathers — `SimStats::link_transfer_cycles`;
    /// 0 at `devices = 1`).
    pub link_transfer_cycles: u64,
    /// Tail-latency percentiles (queue/TTFT/end-to-end, in simulated
    /// cycles, measured from each request's arrival). TTFT is the
    /// first *generated* token — the request's prompt-prefill
    /// completion — not the first prefill position
    /// (`StreamResult::ttft_cycles`). `None` for FIFO/functional
    /// serving and runs that completed no stream.
    pub latency: Option<LatencyReport>,
    /// Rendered trace artifact `(path, contents)` when the run was
    /// traced (`sched.trace` / `serve --trace`); the engine never does
    /// IO, so the caller writes the file. `None` with tracing off and
    /// for FIFO/functional serving.
    pub trace: Option<(String, String)>,
    /// Rendered profile artifact `(path, contents)` when the run was
    /// profiled (`sched.profile` / `serve --profile`); same IO contract
    /// as `trace`.
    pub profile: Option<(String, String)>,
    /// Trace-vs-stats reconciliation failure surfaced by
    /// `sched.strict_reconcile` (`SimStats::reconcile_error`). `None`
    /// when the run reconciled clean or the check was off.
    pub reconcile_error: Option<String>,
}

impl ServerMetrics {
    /// Delivered simulated throughput. Uses the makespan (wall time of
    /// the simulated hardware); falls back to summed service time for
    /// runs that never recorded one.
    pub fn sim_tokens_per_s(&self) -> f64 {
        let denom = if self.sim_makespan_seconds > 0.0 {
            self.sim_makespan_seconds
        } else {
            self.sim_seconds
        };
        if denom == 0.0 {
            return 0.0;
        }
        self.tokens as f64 / denom
    }

    /// Engine-capacity throughput: tokens over *busy* time (makespan
    /// minus idle arrival-gap warps). Equals `sim_tokens_per_s` for
    /// closed-loop batch-at-zero runs; strictly higher under sparse
    /// open-loop arrivals, where the makespan counts waiting-for-work
    /// time the engine never spent. Falls back to the makespan basis
    /// for runs that recorded no busy time.
    pub fn sim_tokens_per_busy_s(&self) -> f64 {
        if self.sim_busy_seconds > 0.0 {
            self.tokens as f64 / self.sim_busy_seconds
        } else {
            self.sim_tokens_per_s()
        }
    }

    /// The full metrics as machine-readable JSON (`serve
    /// --metrics-json`): every aggregate counter, the derived
    /// throughputs, and the latency percentiles (`null` when the run
    /// recorded none). The trace artifact itself is not embedded —
    /// only its output path, when tracing was on.
    pub fn to_json(&self) -> Json {
        let pct = |p: &Percentiles| {
            Json::obj(vec![
                ("p50", p.p50.into()),
                ("p95", p.p95.into()),
                ("p99", p.p99.into()),
                ("max", p.max.into()),
            ])
        };
        let latency = match &self.latency {
            Some(l) => Json::obj(vec![
                ("queue", pct(&l.queue)),
                ("ttft", pct(&l.ttft)),
                ("e2e", pct(&l.e2e)),
            ]),
            None => Json::Null,
        };
        let trace_path = match &self.trace {
            Some((path, _)) => Json::from(path.clone()),
            None => Json::Null,
        };
        let profile_path = match &self.profile {
            Some((path, _)) => Json::from(path.clone()),
            None => Json::Null,
        };
        let reconcile_error = match &self.reconcile_error {
            Some(e) => Json::from(e.clone()),
            None => Json::Null,
        };
        Json::obj(vec![
            ("requests", self.requests.into()),
            ("failed", self.failed.into()),
            ("tokens", self.tokens.into()),
            ("sim_seconds", self.sim_seconds.into()),
            ("wall_seconds", self.wall_seconds.into()),
            ("sim_makespan_seconds", self.sim_makespan_seconds.into()),
            ("sim_busy_seconds", self.sim_busy_seconds.into()),
            ("sim_prefill_seconds", self.sim_prefill_seconds.into()),
            ("sim_decode_seconds", self.sim_decode_seconds.into()),
            ("sim_tokens_per_s", self.sim_tokens_per_s().into()),
            ("sim_tokens_per_busy_s", self.sim_tokens_per_busy_s().into()),
            ("fused_sweeps", self.fused_sweeps.into()),
            ("mean_decode_batch", self.mean_decode_batch.into()),
            ("max_decode_batch", self.max_decode_batch.into()),
            ("solo_decode_steps", self.solo_decode_steps.into()),
            ("kv_slots", self.kv_slots.into()),
            ("peak_slots_in_use", self.peak_slots_in_use.into()),
            ("admission_blocked", self.admission_blocked.into()),
            ("kv_pages", self.kv_pages.into()),
            ("peak_pages_in_use", self.peak_pages_in_use.into()),
            ("page_faults", self.page_faults.into()),
            ("preemptions", self.preemptions.into()),
            ("evicted_tokens", self.evicted_tokens.into()),
            ("rejected", self.rejected.into()),
            ("devices", self.devices.into()),
            ("link_transfer_cycles", self.link_transfer_cycles.into()),
            ("latency", latency),
            ("trace_path", trace_path),
            ("profile_path", profile_path),
            ("reconcile_error", reconcile_error),
        ])
    }
}

/// Serving loop around a `PimGptSystem` (interleaved for timing-only,
/// FIFO for functional artifacts).
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    rx_resp: mpsc::Receiver<Response>,
    worker: Option<JoinHandle<ServerMetrics>>,
    done: Option<ServerMetrics>,
}

impl Server {
    /// Spawn the worker thread; `factory` builds the `PimGptSystem`
    /// inside the thread (PJRT handles are not `Send`). The scheduler
    /// reads `cfg.sched.max_streams` from the system's config.
    pub fn start<F>(factory: F) -> Self
    where
        F: FnOnce() -> anyhow::Result<PimGptSystem> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (tx_resp, rx_resp) = mpsc::channel::<Response>();
        let worker = std::thread::spawn(move || worker_loop(factory, rx, tx_resp));
        Self { tx: Some(tx), rx_resp, worker: Some(worker), done: None }
    }

    /// Enqueue a request. Fails cleanly after `shutdown`.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server shut down"))?
            .send(req)
            .map_err(|e| anyhow!("submit failed: {e}"))
    }

    /// Block for the next response. After `shutdown` (or if the worker
    /// died), drains any remaining buffered responses, then returns a
    /// clean error instead of blocking forever.
    pub fn recv(&self) -> Result<Response> {
        self.rx_resp
            .recv()
            .map_err(|_| anyhow!("server shut down (or worker exited): no more responses"))
    }

    /// Close the queue, let the worker finish every request already
    /// submitted, and join it. Idempotent; responses not yet consumed
    /// stay available via `recv()`. A panicked worker is reported on
    /// stderr and yields default (all-zero) metrics.
    pub fn shutdown(&mut self) -> ServerMetrics {
        if let Some(m) = &self.done {
            return m.clone();
        }
        drop(self.tx.take());
        let m = match self.worker.take().map(|w| w.join()) {
            Some(Ok(m)) => m,
            Some(Err(_)) => {
                eprintln!("pim-gpt server: worker thread panicked; metrics lost");
                ServerMetrics::default()
            }
            None => ServerMetrics::default(),
        };
        self.done = Some(m.clone());
        m
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn error_response(id: u64, err: String) -> Response {
    Response {
        id,
        tokens: vec![],
        sim_seconds: 0.0,
        sim_prefill_seconds: 0.0,
        wall_seconds: 0.0,
        sim_queue_seconds: 0.0,
        rejected: false,
        error: Some(err),
    }
}

fn worker_loop<F>(
    factory: F,
    rx: mpsc::Receiver<Request>,
    tx_resp: mpsc::Sender<Response>,
) -> ServerMetrics
where
    F: FnOnce() -> anyhow::Result<PimGptSystem>,
{
    let mut metrics = ServerMetrics::default();
    let mut system = match factory() {
        Ok(s) => s,
        Err(e) => {
            // Fail every request with the construction error.
            while let Ok(req) = rx.recv() {
                metrics.requests += 1;
                metrics.failed += 1;
                let _ = tx_resp.send(error_response(req.id, format!("system init failed: {e}")));
            }
            return metrics;
        }
    };
    if system.has_artifact() {
        fifo_loop(&mut system, &rx, &tx_resp, &mut metrics);
    } else if let Err(e) = interleaved_loop(&system, &rx, &tx_resp, &mut metrics) {
        // Scheduler construction/stepping failed: fail remaining requests.
        while let Ok(req) = rx.recv() {
            metrics.requests += 1;
            metrics.failed += 1;
            let _ = tx_resp.send(error_response(req.id, format!("scheduler failed: {e}")));
        }
    }
    metrics
}

/// FIFO serving for functional (artifact) systems: one request at a
/// time, co-simulating timing alongside the PJRT decode.
fn fifo_loop(
    system: &mut PimGptSystem,
    rx: &mpsc::Receiver<Request>,
    tx_resp: &mpsc::Sender<Response>,
    metrics: &mut ServerMetrics,
) {
    let mut sim_busy_until = 0.0f64;
    // One request at a time against a single KV cache: one slot, always
    // fully occupied while serving.
    metrics.kv_slots = 1;
    while let Ok(req) = rx.recv() {
        metrics.peak_slots_in_use = 1;
        let wall0 = Instant::now();
        metrics.requests += 1;
        match system.generate(&req.prompt, req.n_new) {
            Ok(r) => {
                let wall = wall0.elapsed().as_secs_f64();
                metrics.tokens += r.tokens.len() as u64;
                metrics.sim_seconds += r.sim_seconds;
                metrics.wall_seconds += wall;
                let resp = Response {
                    id: req.id,
                    tokens: r.tokens,
                    sim_seconds: r.sim_seconds,
                    sim_prefill_seconds: 0.0,
                    wall_seconds: wall,
                    sim_queue_seconds: sim_busy_until,
                    rejected: false,
                    error: None,
                };
                sim_busy_until += r.sim_seconds;
                metrics.sim_makespan_seconds = sim_busy_until;
                let _ = tx_resp.send(resp);
            }
            Err(e) => {
                metrics.failed += 1;
                let _ = tx_resp.send(Response {
                    id: req.id,
                    tokens: vec![],
                    sim_seconds: 0.0,
                    sim_prefill_seconds: 0.0,
                    wall_seconds: wall0.elapsed().as_secs_f64(),
                    sim_queue_seconds: sim_busy_until,
                    rejected: false,
                    error: Some(e.to_string()),
                });
            }
        }
    }
}

/// Bookkeeping for a request in flight inside the interleaved engine.
struct InFlight {
    id: u64,
    tokens: Vec<i32>,
    wall0: Instant,
}

/// Validate and enqueue one request into the interleaved engine;
/// invalid requests are rejected immediately with an error response.
fn ingest(
    req: Request,
    msim: &mut MultiSim,
    inflight: &mut Vec<InFlight>,
    metrics: &mut ServerMetrics,
    tx_resp: &mpsc::Sender<Response>,
) {
    metrics.requests += 1;
    let total = (req.prompt.len() + req.n_new) as u64;
    if total == 0 {
        // Degenerate empty request: served successfully with no tokens
        // and zero simulated time, matching the seed's FIFO behavior.
        let _ = tx_resp.send(Response {
            id: req.id,
            tokens: vec![],
            sim_seconds: 0.0,
            sim_prefill_seconds: 0.0,
            wall_seconds: 0.0,
            sim_queue_seconds: 0.0,
            rejected: false,
            error: None,
        });
        return;
    }
    // The request's prompt maps to the prefill phase (batched into
    // `sched.prefill_chunk`-sized chunk programs); an empty prompt
    // still prefills its first position, like the seed's decode.
    let spec = StreamSpec {
        id: req.id,
        n_tokens: total,
        prompt_tokens: (req.prompt.len() as u64).max(1),
        arrival_cycle: req.arrival_cycle,
    };
    match msim.submit(spec) {
        Ok(()) => {
            // Timing-only: tokens are synthetic, as in the seed.
            let tokens = super::generation::synthetic_tokens(&req.prompt, req.n_new);
            inflight.push(InFlight { id: req.id, tokens, wall0: Instant::now() });
        }
        Err(e) => {
            metrics.failed += 1;
            let _ = tx_resp.send(error_response(req.id, e.to_string()));
        }
    }
}

/// Continuous interleaved serving for timing-only systems.
fn interleaved_loop(
    system: &PimGptSystem,
    rx: &mpsc::Receiver<Request>,
    tx_resp: &mpsc::Sender<Response>,
    metrics: &mut ServerMetrics,
) -> Result<()> {
    let cfg = &system.sim.cfg;
    let freq_hz = cfg.gddr6.freq_ghz * 1e9;
    // Reuse the system's Algorithm-3 placement instead of re-mapping.
    if let Some(report) = &system.sim.mapping.kv_shortfall {
        // Degraded-capacity serving: fewer concurrent streams than
        // configured. Not an error — admission simply blocks earlier.
        eprintln!("pim-gpt server: {report}");
    }
    let mut msim = MultiSim::from_mapping(&system.model, cfg, system.sim.mapping.clone());
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut open = true;

    while open
        || msim.active_streams() > 0
        || msim.queued_streams() > 0
        || msim.undelivered_rejections() > 0
        || msim.undelivered_completions() > 0
    {
        // Idle with an open queue and no undelivered outcomes: block
        // for the next request. (Undelivered rejections and buffered
        // completions — a fused sweep can retire several streams at
        // once — must drain first: blocking here would deadlock a
        // client that waits for every response before shutting down.)
        if open
            && msim.active_streams() == 0
            && msim.queued_streams() == 0
            && msim.undelivered_rejections() == 0
            && msim.undelivered_completions() == 0
        {
            match rx.recv() {
                Ok(req) => ingest(req, &mut msim, &mut inflight, metrics, tx_resp),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // Ingest whatever else has arrived, without blocking.
        while open {
            match rx.try_recv() {
                Ok(req) => ingest(req, &mut msim, &mut inflight, metrics, tx_resp),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => open = false,
            }
        }
        // Advance the simulation to the next request completion. A
        // scheduler error mid-run fails every in-flight request (they
        // would otherwise never receive a response) before surfacing.
        let stepped = match msim.step() {
            Ok(s) => s,
            Err(e) => {
                for m in inflight.drain(..) {
                    metrics.failed += 1;
                    let _ = tx_resp.send(error_response(m.id, format!("scheduler failed: {e}")));
                }
                return Err(e);
            }
        };
        if let Some(outcome) = stepped {
            let idx = inflight
                .iter()
                .position(|m| m.id == outcome.id())
                .ok_or_else(|| anyhow!("stream {} has no request record", outcome.id()))?;
            let m = inflight.remove(idx);
            let wall = m.wall0.elapsed().as_secs_f64();
            match outcome {
                StreamOutcome::Completed(done) => {
                    let service_s = done.service_cycles() as f64 / freq_hz;
                    let prefill_s = done.prefill_cycles() as f64 / freq_hz;
                    let queue_s = done.queue_cycles() as f64 / freq_hz;
                    metrics.tokens += done.tokens;
                    metrics.sim_seconds += service_s;
                    metrics.sim_prefill_seconds += prefill_s;
                    metrics.sim_decode_seconds += service_s - prefill_s;
                    metrics.wall_seconds += wall;
                    metrics.sim_makespan_seconds = msim.clock() as f64 / freq_hz;
                    let _ = tx_resp.send(Response {
                        id: m.id,
                        tokens: m.tokens,
                        sim_seconds: service_s,
                        sim_prefill_seconds: prefill_s,
                        wall_seconds: wall,
                        sim_queue_seconds: queue_s,
                        rejected: false,
                        error: None,
                    });
                }
                // An admission-policy shed: a first-class response (no
                // tokens, no error) so the client learns its fate
                // promptly.
                StreamOutcome::Rejected(rej) => {
                    metrics.rejected += 1;
                    let _ = tx_resp.send(Response {
                        id: m.id,
                        tokens: vec![],
                        sim_seconds: 0.0,
                        sim_prefill_seconds: 0.0,
                        wall_seconds: wall,
                        sim_queue_seconds: rej.waited_cycles() as f64 / freq_hz,
                        rejected: true,
                        error: None,
                    });
                }
            }
        }
    }
    // Queue/occupancy/latency stats of the whole run.
    msim.finalize_stats();
    metrics.kv_slots = msim.stats.kv_slots;
    metrics.peak_slots_in_use = msim.stats.peak_slots_in_use;
    metrics.admission_blocked = msim.stats.admission_blocked;
    metrics.kv_pages = msim.stats.kv_pages;
    metrics.peak_pages_in_use = msim.stats.peak_pages_in_use;
    metrics.page_faults = msim.stats.page_faults;
    metrics.preemptions = msim.stats.preemptions;
    metrics.evicted_tokens = msim.stats.evicted_tokens;
    metrics.sim_busy_seconds = msim.stats.busy_seconds(cfg.gddr6.freq_ghz);
    metrics.fused_sweeps = msim.stats.fused_sweeps;
    metrics.mean_decode_batch = msim.stats.mean_decode_batch();
    metrics.max_decode_batch = msim.stats.max_decode_batch;
    metrics.solo_decode_steps = msim.stats.solo_decode_steps;
    metrics.devices = msim.stats.devices.max(1);
    metrics.link_transfer_cycles = msim.stats.link_transfer_cycles;
    metrics.latency = msim.stats.latency_report();
    metrics.trace = msim.render_trace();
    metrics.profile = msim.render_profile();
    metrics.reconcile_error = msim.stats.reconcile_error.clone();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::model::gpt::by_name;

    fn server_k(model: &str, k: usize) -> Server {
        let name = model.to_string();
        Server::start(move || {
            let m = by_name(&name).unwrap();
            PimGptSystem::timing_only(&m, &HwConfig::paper_baseline().with_max_streams(k))
        })
    }

    #[test]
    fn serves_all_requests_with_correct_payloads() {
        let mut s = server_k("gpt-nano", 4);
        for id in 0..4 {
            s.submit(Request { id, prompt: vec![1, 2], n_new: 3, arrival_cycle: 0 }).unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..4 {
            let r = s.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 5);
            assert!(r.sim_seconds > 0.0);
            seen.push(r.id);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        let m = s.shutdown();
        assert_eq!(m.requests, 4);
        assert_eq!(m.failed, 0);
        assert_eq!(m.tokens, 20);
        assert!(m.sim_tokens_per_s() > 0.0);
        assert!(m.sim_makespan_seconds > 0.0);
        // KV-capacity queue stats are part of the aggregate metrics.
        assert_eq!(m.kv_slots, 4);
        assert!(m.peak_slots_in_use >= 1 && m.peak_slots_in_use <= 4);
        // Batch-at-zero: the engine never idles, so the busy-cycle
        // throughput basis coincides with the makespan basis.
        assert!((m.sim_busy_seconds - m.sim_makespan_seconds).abs() < 1e-12);
        assert_eq!(m.fused_sweeps, 0, "batching defaults off");
    }

    /// Batched decode through the serving loop: every response is
    /// delivered even when one fused sweep retires several streams at
    /// once (the loop drains `undelivered_completions`), and the
    /// occupancy metrics surface the fusion.
    #[test]
    fn batched_serving_delivers_all_responses_with_occupancy() {
        let mut s = Server::start(move || {
            let m = by_name("gpt-nano").unwrap();
            PimGptSystem::timing_only(
                &m,
                &HwConfig::paper_baseline().with_max_streams(4).with_batch_decode(true),
            )
        });
        for id in 0..4 {
            s.submit(Request { id, prompt: vec![1, 2], n_new: 6, arrival_cycle: 0 }).unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..4 {
            let r = s.recv().unwrap();
            assert!(r.error.is_none());
            assert!(!r.rejected);
            assert_eq!(r.tokens.len(), 8);
            assert!(r.sim_seconds > 0.0);
            seen.push(r.id);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        let m = s.shutdown();
        assert_eq!(m.requests, 4);
        assert_eq!(m.failed, 0);
        assert_eq!(m.tokens, 32);
        assert!(m.fused_sweeps > 0, "identical decode-heavy streams must fuse");
        assert!(m.mean_decode_batch >= 2.0);
        assert!(m.max_decode_batch >= 2);
        assert!(m.sim_busy_seconds > 0.0);
        assert!(m.sim_tokens_per_busy_s() >= m.sim_tokens_per_s());
    }

    /// Paged-KV serving surfaces the frame-pool counters and, with a
    /// full-context page per stream and no oversubscription, behaves
    /// exactly like slot serving (zero faults, zero preemptions).
    #[test]
    fn paged_serving_reports_frame_counters() {
        let mut s = Server::start(move || {
            let m = by_name("gpt-nano").unwrap();
            let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
            cfg.sched.kv_paging = true;
            cfg.sched.kv_page_tokens = 128; // = gpt-nano max_seq: 1 frame/context
            PimGptSystem::timing_only(&m, &cfg)
        });
        for id in 0..4 {
            s.submit(Request { id, prompt: vec![1, 2], n_new: 3, arrival_cycle: 0 }).unwrap();
        }
        for _ in 0..4 {
            let r = s.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 5);
        }
        let m = s.shutdown();
        assert_eq!((m.requests, m.failed, m.tokens), (4, 0, 20));
        assert_eq!(m.kv_pages, 4, "4 streams x 1 full-context frame");
        assert!(m.peak_pages_in_use >= 1 && m.peak_pages_in_use <= 4);
        assert_eq!((m.page_faults, m.preemptions, m.evicted_tokens), (0, 0, 0));
    }

    #[test]
    fn degraded_kv_capacity_limits_serving_concurrency() {
        // A memory too small for 4 contexts serves with fewer slots:
        // the metrics expose the real admission capacity and requests
        // queue on KV availability.
        // (Stable for the same reason as `fifo_mode_preserves_order_and_
        // queueing`: the factory's mapping build takes far longer than
        // the submit loop, so all four requests are queued before the
        // worker starts simulating.)
        let mut s = Server::start(move || {
            let m = by_name("gpt2-small").unwrap();
            let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
            cfg.gddr6.capacity_gbit = 0.34; // fits weights + ~2 contexts
            PimGptSystem::timing_only(&m, &cfg)
        });
        for id in 0..4 {
            s.submit(Request { id, prompt: vec![1], n_new: 1, arrival_cycle: 0 }).unwrap();
        }
        let mut queued = 0;
        for _ in 0..4 {
            let r = s.recv().unwrap();
            assert!(r.error.is_none());
            if r.sim_queue_seconds > 0.0 {
                queued += 1;
            }
        }
        let m = s.shutdown();
        assert_eq!(m.requests, 4);
        assert!(m.kv_slots < 4, "expected degraded capacity, got {} slots", m.kv_slots);
        assert!(m.kv_slots >= 1);
        assert!(m.peak_slots_in_use >= 1 && m.peak_slots_in_use <= m.kv_slots);
        // The queueing observations depend on all four requests being
        // ingested together (true whenever the submit loop outpaces the
        // slow factory, i.e. always in practice); guard on it so an
        // extreme scheduler preemption can't fail the test spuriously.
        // The deterministic variants live in tests/integration_sched.rs.
        if m.peak_slots_in_use == m.kv_slots {
            assert!(m.admission_blocked > 0);
            assert!(queued >= 1, "capacity-blocked requests must report queueing");
        }
    }

    #[test]
    fn fifo_mode_preserves_order_and_queueing() {
        // K = 1: strict FIFO, queueing delays accumulate like the seed.
        // (gpt2-small: the factory's mapping build takes far longer than
        // the submit loop, so all requests are queued before the worker
        // starts simulating — the queueing assertions are stable.)
        let mut s = server_k("gpt2-small", 1);
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![1], n_new: 2, arrival_cycle: 0 }).unwrap();
        }
        let r0 = s.recv().unwrap();
        let r1 = s.recv().unwrap();
        let r2 = s.recv().unwrap();
        assert_eq!((r0.id, r1.id, r2.id), (0, 1, 2));
        assert_eq!(r0.sim_queue_seconds, 0.0);
        assert!(r1.sim_queue_seconds > 0.0);
        assert!(r2.sim_queue_seconds > r1.sim_queue_seconds);
        s.shutdown();
    }

    #[test]
    fn concurrent_slots_admit_without_queueing() {
        let mut s = server_k("gpt-nano", 4);
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![1], n_new: 2, arrival_cycle: 0 }).unwrap();
        }
        for _ in 0..3 {
            let r = s.recv().unwrap();
            assert_eq!(r.sim_queue_seconds, 0.0, "req {} queued", r.id);
        }
        s.shutdown();
    }

    #[test]
    fn oversized_request_reports_error() {
        let mut s = server_k("gpt-nano", 4); // max_seq = 128
        s.submit(Request { id: 9, prompt: vec![0; 120], n_new: 100, arrival_cycle: 0 }).unwrap();
        let r = s.recv().unwrap();
        assert_eq!(r.id, 9);
        assert!(r.error.is_some());
        let m = s.shutdown();
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn empty_request_served_with_no_tokens() {
        // Seed contract: prompt=[] with n_new=0 is served successfully.
        let mut s = server_k("gpt-nano", 2);
        s.submit(Request { id: 3, prompt: vec![], n_new: 0, arrival_cycle: 0 }).unwrap();
        let r = s.recv().unwrap();
        assert_eq!(r.id, 3);
        assert!(r.error.is_none());
        assert!(r.tokens.is_empty());
        assert_eq!(r.sim_seconds, 0.0);
        let m = s.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let mut s = server_k("gpt-nano", 2);
        s.submit(Request { id: 0, prompt: vec![1], n_new: 1, arrival_cycle: 0 }).unwrap();
        let m = s.shutdown();
        assert_eq!(m.requests, 1);
        let late = Request { id: 1, prompt: vec![1], n_new: 1, arrival_cycle: 0 };
        let err = s.submit(late).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }

    #[test]
    fn shutdown_drains_then_recv_errors_cleanly() {
        let mut s = server_k("gpt-nano", 2);
        for id in 0..2 {
            s.submit(Request { id, prompt: vec![1, 2], n_new: 2, arrival_cycle: 0 }).unwrap();
        }
        // Shut down *before* receiving: both responses must still be
        // deliverable, then recv must fail instead of hanging.
        let m = s.shutdown();
        assert_eq!(m.requests, 2);
        assert!(s.recv().is_ok());
        assert!(s.recv().is_ok());
        let err = s.recv().unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        // Idempotent.
        assert_eq!(s.shutdown().requests, 2);
    }

    #[test]
    fn interleaved_throughput_beats_fifo() {
        let run = |k: usize| {
            let mut s = server_k("gpt2-small", k);
            for id in 0..4 {
                s.submit(Request {
                    id,
                    prompt: vec![1, 2, 3],
                    n_new: 3 + 2 * id as usize,
                    arrival_cycle: 0,
                })
                .unwrap();
            }
            for _ in 0..4 {
                s.recv().unwrap();
            }
            s.shutdown()
        };
        let fifo = run(1);
        let inter = run(4);
        assert_eq!(fifo.tokens, inter.tokens);
        assert!(
            inter.sim_tokens_per_s() > fifo.sim_tokens_per_s(),
            "interleaved {} !> fifo {}",
            inter.sim_tokens_per_s(),
            fifo.sim_tokens_per_s()
        );
    }

    /// Tentpole: prompted requests are served through chunked prefill —
    /// the response splits service into prefill and decode, the
    /// aggregate metrics carry both shares, and a larger chunk size
    /// strictly shrinks the prefill share of the same prompt.
    #[test]
    fn prompted_requests_report_prefill_split() {
        let run = |chunk: u64| {
            let mut s = Server::start(move || {
                let m = by_name("gpt-nano").unwrap();
                let cfg = HwConfig::paper_baseline()
                    .with_max_streams(2)
                    .with_prefill_chunk(chunk);
                PimGptSystem::timing_only(&m, &cfg)
            });
            s.submit(Request { id: 0, prompt: vec![1; 64], n_new: 4, arrival_cycle: 0 })
                .unwrap();
            let r = s.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 68);
            assert!(r.sim_prefill_seconds > 0.0, "a 64-token prompt prefills");
            assert!(
                r.sim_prefill_seconds < r.sim_seconds,
                "decode tokens take service time too"
            );
            let m = s.shutdown();
            assert!(m.sim_prefill_seconds > 0.0 && m.sim_decode_seconds > 0.0);
            let total = m.sim_prefill_seconds + m.sim_decode_seconds;
            assert!((total - m.sim_seconds).abs() < 1e-9, "split sums to service");
            r.sim_prefill_seconds
        };
        let tokenwise = run(1);
        let chunked = run(32);
        assert!(
            chunked < tokenwise,
            "chunked prefill {chunked} !< token-by-token {tokenwise}"
        );
    }

    #[test]
    fn open_loop_arrivals_yield_latency_percentiles() {
        // Requests arrive on the *simulated* clock; the metrics carry
        // the queue/TTFT/end-to-end percentile report.
        let mut s = server_k("gpt-nano", 2);
        for id in 0..4 {
            let arrival_cycle = id * 1_000;
            s.submit(Request { id, prompt: vec![1], n_new: 2, arrival_cycle }).unwrap();
        }
        for _ in 0..4 {
            assert!(s.recv().unwrap().error.is_none());
        }
        let m = s.shutdown();
        assert_eq!(m.requests, 4);
        let lat = m.latency.expect("interleaved serving reports latency percentiles");
        assert!(lat.ttft.p50 > 0, "a first token always costs cycles");
        assert!(lat.ttft.p50 <= lat.ttft.p99);
        assert!(lat.e2e.p99 >= lat.ttft.p99, "e2e dominates ttft per stream");
        assert!(lat.queue.p50 <= lat.queue.max);
    }

    #[test]
    fn empty_run_reports_no_latency_percentiles() {
        // The percentile report needs retired streams; an empty run
        // stays `None` rather than fabricating zeros.
        let mut s = server_k("gpt-nano", 2);
        let m = s.shutdown();
        assert!(m.latency.is_none());
    }

    fn server_policy(model: &str, k: usize, policy: &'static str) -> Server {
        let name = model.to_string();
        Server::start(move || {
            let m = by_name(&name).unwrap();
            let mut cfg = HwConfig::paper_baseline().with_max_streams(k);
            cfg.sched.set_policy_str(policy).unwrap();
            PimGptSystem::timing_only(&m, &cfg)
        })
    }

    #[test]
    fn slo_rejections_are_first_class_responses() {
        // A 1-cycle TTFT budget is unmeetable: every request is shed.
        // Rejections are responses (no error), counted separately from
        // failures, and leave no latency percentiles behind.
        let mut s = server_policy("gpt-nano", 2, "slo:1");
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![1], n_new: 2, arrival_cycle: 0 }).unwrap();
        }
        for _ in 0..3 {
            let r = s.recv().unwrap();
            assert!(r.rejected, "req {} should be shed", r.id);
            assert!(r.error.is_none(), "a rejection is not an error");
            assert!(r.tokens.is_empty());
            assert_eq!(r.sim_seconds, 0.0);
        }
        let m = s.shutdown();
        assert_eq!(m.requests, 3);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.failed, 0);
        assert_eq!(m.tokens, 0);
        assert!(m.latency.is_none(), "no admitted streams -> no percentiles");
    }

    #[test]
    fn slo_with_slack_budget_serves_everything() {
        // A 10-second budget never binds at this scale: the SLO path
        // degenerates to normal serving with rejected == 0.
        let mut s = server_policy("gpt-nano", 2, "slo:10000000000");
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![1], n_new: 2, arrival_cycle: 0 }).unwrap();
        }
        for _ in 0..3 {
            let r = s.recv().unwrap();
            assert!(!r.rejected);
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 3);
        }
        let m = s.shutdown();
        assert_eq!((m.requests, m.rejected, m.failed), (3, 0, 0));
        assert!(m.latency.is_some());
    }

    #[test]
    fn srf_serving_matches_fcfs_token_totals() {
        // Policies reorder service, never change the work: the same
        // request set yields identical token totals under srf.
        let run = |policy: &'static str| {
            let mut s = server_policy("gpt-nano", 1, policy);
            for id in 0..3 {
                s.submit(Request {
                    id,
                    prompt: vec![1],
                    n_new: 1 + 2 * id as usize,
                    arrival_cycle: 0,
                })
                .unwrap();
            }
            for _ in 0..3 {
                assert!(s.recv().unwrap().error.is_none());
            }
            s.shutdown()
        };
        let fcfs = run("fcfs");
        let srf = run("srf");
        assert_eq!(fcfs.tokens, srf.tokens);
        assert_eq!(srf.rejected, 0);
    }

    /// `--metrics-json` satellite: the dump round-trips through the
    /// repo's own JSON parser and carries the headline counters and
    /// the latency percentiles.
    #[test]
    fn metrics_json_round_trips() {
        let mut s = server_k("gpt-nano", 2);
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![1, 2], n_new: 2, arrival_cycle: 0 }).unwrap();
        }
        for _ in 0..3 {
            assert!(s.recv().unwrap().error.is_none());
        }
        let m = s.shutdown();
        let parsed = Json::parse(&m.to_json().to_string()).expect("metrics JSON parses");
        assert_eq!(parsed.get("requests").and_then(|j| j.as_f64()), Some(3.0));
        assert_eq!(parsed.get("tokens").and_then(|j| j.as_f64()), Some(12.0));
        assert_eq!(parsed.get("trace_path"), Some(&Json::Null), "untraced run");
        let lat = parsed.get("latency").expect("latency key present");
        assert!(
            lat.get("ttft").and_then(|t| t.get("p50")).and_then(|j| j.as_f64()).unwrap() > 0.0
        );
    }

    /// Traced serving: the worker renders the artifact through the
    /// metrics (the engine never writes files), every JSONL line
    /// parses, and the traced run's simulated results are identical to
    /// the untraced run's (observer-effect-free).
    #[test]
    fn traced_serving_returns_artifact_without_perturbing_results() {
        let run = |trace: bool| {
            let mut s = Server::start(move || {
                let m = by_name("gpt-nano").unwrap();
                let mut cfg = HwConfig::paper_baseline().with_max_streams(2);
                if trace {
                    cfg = cfg.with_trace("jsonl:t.jsonl");
                }
                PimGptSystem::timing_only(&m, &cfg)
            });
            for id in 0..3 {
                s.submit(Request { id, prompt: vec![1, 2], n_new: 3, arrival_cycle: 0 })
                    .unwrap();
            }
            let mut sims = Vec::new();
            for _ in 0..3 {
                let r = s.recv().unwrap();
                assert!(r.error.is_none());
                sims.push((r.id, r.sim_seconds.to_bits(), r.sim_queue_seconds.to_bits()));
            }
            sims.sort_unstable();
            (s.shutdown(), sims)
        };
        let (plain, plain_sims) = run(false);
        let (traced, traced_sims) = run(true);
        assert_eq!(plain_sims, traced_sims, "tracing must not change simulated results");
        assert_eq!(plain.sim_makespan_seconds.to_bits(), traced.sim_makespan_seconds.to_bits());
        assert!(plain.trace.is_none());
        let (path, contents) = traced.trace.expect("traced run returns the artifact");
        assert_eq!(path, "t.jsonl");
        assert!(!contents.is_empty());
        for line in contents.lines() {
            Json::parse(line).expect("every trace line is one JSON event");
        }
    }
}
