//! Request-serving loop: a FIFO queue in front of the (batch-1,
//! autoregressive) PIM-GPT engine.
//!
//! PIM-GPT generates one token at a time for one sequence — the paper's
//! edge-inference scenario — so the scheduler is a fair FIFO: requests
//! queue on a channel, a worker thread owns the `PimGptSystem` and
//! serves them in arrival order, reporting per-request latency (both
//! simulated-hardware and wall-clock) and aggregate throughput.
//! (std threads + mpsc stand in for tokio, unavailable offline —
//! DESIGN.md §5.) The PJRT client types are not `Send`, so the worker
//! *constructs* the system inside its own thread from a factory closure.

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::generation::PimGptSystem;
use anyhow::{anyhow, Result};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_new: usize,
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Simulated PIM-GPT latency for this request, seconds.
    pub sim_seconds: f64,
    /// Wall-clock time spent in the functional decode, seconds.
    pub wall_seconds: f64,
    /// Queueing delay in *simulated* seconds (time the request waited
    /// behind earlier requests on the simulated hardware).
    pub sim_queue_seconds: f64,
    pub error: Option<String>,
}

/// Aggregate serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerMetrics {
    pub requests: u64,
    pub failed: u64,
    pub tokens: u64,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
}

impl ServerMetrics {
    pub fn sim_tokens_per_s(&self) -> f64 {
        if self.sim_seconds == 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.sim_seconds
    }
}

/// FIFO serving loop around a `PimGptSystem`.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    rx_resp: mpsc::Receiver<Response>,
    worker: Option<JoinHandle<ServerMetrics>>,
}

impl Server {
    /// Spawn the worker thread; `factory` builds the `PimGptSystem`
    /// inside the thread (PJRT handles are not `Send`).
    pub fn start<F>(factory: F) -> Self
    where
        F: FnOnce() -> anyhow::Result<PimGptSystem> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (tx_resp, rx_resp) = mpsc::channel::<Response>();
        let worker = std::thread::spawn(move || {
            let mut metrics = ServerMetrics::default();
            let mut sim_busy_until = 0.0f64;
            let mut system = match factory() {
                Ok(s) => s,
                Err(e) => {
                    // Fail every request with the construction error.
                    while let Ok(req) = rx.recv() {
                        metrics.requests += 1;
                        metrics.failed += 1;
                        let _ = tx_resp.send(Response {
                            id: req.id,
                            tokens: vec![],
                            sim_seconds: 0.0,
                            wall_seconds: 0.0,
                            sim_queue_seconds: 0.0,
                            error: Some(format!("system init failed: {e}")),
                        });
                    }
                    return metrics;
                }
            };
            while let Ok(req) = rx.recv() {
                let wall0 = std::time::Instant::now();
                metrics.requests += 1;
                match system.generate(&req.prompt, req.n_new) {
                    Ok(r) => {
                        let wall = wall0.elapsed().as_secs_f64();
                        metrics.tokens += r.tokens.len() as u64;
                        metrics.sim_seconds += r.sim_seconds;
                        metrics.wall_seconds += wall;
                        let resp = Response {
                            id: req.id,
                            tokens: r.tokens,
                            sim_seconds: r.sim_seconds,
                            wall_seconds: wall,
                            sim_queue_seconds: sim_busy_until,
                            error: None,
                        };
                        sim_busy_until += r.sim_seconds;
                        let _ = tx_resp.send(resp);
                    }
                    Err(e) => {
                        metrics.failed += 1;
                        let _ = tx_resp.send(Response {
                            id: req.id,
                            tokens: vec![],
                            sim_seconds: 0.0,
                            wall_seconds: wall0.elapsed().as_secs_f64(),
                            sim_queue_seconds: sim_busy_until,
                            error: Some(e.to_string()),
                        });
                    }
                }
            }
            metrics
        });
        Self { tx: Some(tx), rx_resp, worker: Some(worker) }
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server shut down"))?
            .send(req)
            .map_err(|e| anyhow!("submit failed: {e}"))
    }

    /// Block for the next response.
    pub fn recv(&self) -> Result<Response> {
        self.rx_resp.recv().map_err(|e| anyhow!("recv failed: {e}"))
    }

    /// Close the queue and join the worker, returning aggregate metrics.
    pub fn shutdown(mut self) -> ServerMetrics {
        drop(self.tx.take());
        self.worker.take().map(|w| w.join().unwrap_or_default()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::model::gpt::by_name;

    fn server(model: &str) -> Server {
        let name = model.to_string();
        Server::start(move || {
            let m = by_name(&name).unwrap();
            PimGptSystem::timing_only(&m, &HwConfig::paper_baseline())
        })
    }

    #[test]
    fn serves_fifo_order() {
        let s = server("gpt-nano");
        for id in 0..4 {
            s.submit(Request { id, prompt: vec![1, 2], n_new: 3 }).unwrap();
        }
        for want in 0..4 {
            let r = s.recv().unwrap();
            assert_eq!(r.id, want);
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 5);
        }
        let m = s.shutdown();
        assert_eq!(m.requests, 4);
        assert_eq!(m.failed, 0);
        assert_eq!(m.tokens, 20);
        assert!(m.sim_tokens_per_s() > 0.0);
    }

    #[test]
    fn queueing_delay_accumulates() {
        let s = server("gpt-nano");
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![1], n_new: 2 }).unwrap();
        }
        let r0 = s.recv().unwrap();
        let r1 = s.recv().unwrap();
        let r2 = s.recv().unwrap();
        assert_eq!(r0.sim_queue_seconds, 0.0);
        assert!(r1.sim_queue_seconds > 0.0);
        assert!(r2.sim_queue_seconds > r1.sim_queue_seconds);
        s.shutdown();
    }

    #[test]
    fn oversized_request_reports_error() {
        let s = server("gpt-nano"); // max_seq = 128
        s.submit(Request { id: 9, prompt: vec![0; 120], n_new: 100 }).unwrap();
        let r = s.recv().unwrap();
        assert!(r.error.is_some());
        let m = s.shutdown();
        assert_eq!(m.failed, 1);
    }
}
