//! The L3 coordinator: owns the mapped model, the timing simulator and
//! (optionally) the functional PJRT artifact, and drives end-to-end
//! token generation and request serving.

pub mod generation;
pub mod server;

pub use generation::{GenerationResult, PimGptSystem};
pub use server::{Request, Response, Server, ServerMetrics};
