//! End-to-end generation: functional decode (PJRT artifact) co-simulated
//! with the clock-cycle timing model.
//!
//! This is where the three layers compose: the rust coordinator feeds a
//! token to the AOT-compiled L2/L1 artifact (real numerics), and
//! simultaneously advances the timing simulator over the same decode
//! graph (what the PIM+ASIC hardware would take). The returned metrics
//! carry both the generated text and the simulated latency/energy.

use std::path::Path;

use crate::config::HwConfig;
use crate::energy::SystemEnergy;
use crate::model::gpt::by_name;
use crate::model::GptModel;
use crate::runtime::{argmax, GptArtifact, PjrtRuntime};
use crate::sim::Simulator;
use anyhow::{anyhow, Result};

/// Result of one generation run.
#[derive(Clone, Debug)]
pub struct GenerationResult {
    pub tokens: Vec<i32>,
    /// Simulated PIM-GPT time for the whole request, seconds.
    pub sim_seconds: f64,
    /// Simulated per-token latency, seconds.
    pub sim_seconds_per_token: f64,
    /// Simulated system energy, joules.
    pub sim_energy_j: f64,
    /// Wall-clock time of the functional decode, seconds.
    pub wall_seconds: f64,
    /// Row-hit rate over the run.
    pub row_hit_rate: f64,
}

/// A mapped PIM-GPT instance: timing simulator + optional functional
/// artifact (models above artifact scale run timing-only).
pub struct PimGptSystem {
    pub model: GptModel,
    pub sim: Simulator,
    artifact: Option<GptArtifact>,
}

impl PimGptSystem {
    /// Timing-only system (any of the 8 paper models).
    pub fn timing_only(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        Ok(Self { model: model.clone(), sim: Simulator::new(model, cfg)?, artifact: None })
    }

    /// Full system: timing + functional artifact loaded from `dir`.
    pub fn with_artifact(name: &str, dir: &Path, cfg: &HwConfig) -> Result<Self> {
        let model = by_name(name).ok_or_else(|| anyhow!("unknown model {name}"))?;
        let rt = PjrtRuntime::cpu()?;
        let artifact = GptArtifact::load(rt, dir, name)?;
        let sim = Simulator::new(&model, cfg)?;
        Ok(Self { model, sim, artifact: Some(artifact) })
    }

    pub fn has_artifact(&self) -> bool {
        self.artifact.is_some()
    }

    /// Generate `n_new` tokens after `prompt`, co-simulating timing.
    /// Without an artifact the tokens are synthetic (timing only).
    pub fn generate(&mut self, prompt: &[i32], n_new: usize) -> Result<GenerationResult> {
        let total = prompt.len() + n_new;
        if total > self.model.max_seq {
            return Err(anyhow!("request length {total} exceeds max_seq {}", self.model.max_seq));
        }
        let wall0 = std::time::Instant::now();
        let sim_start = self.sim.clock();

        let tokens = match &self.artifact {
            Some(art) => {
                // Functional path: greedy decode through PJRT while the
                // simulator times every position.
                let (mut kc, mut vc) = art.empty_caches()?;
                let mut toks: Vec<i32> = prompt.to_vec();
                let mut logits = Vec::new();
                for (i, &t) in prompt.iter().enumerate() {
                    let (lg, k2, v2) = art.decode(t, i as i32, &kc, &vc)?;
                    logits = lg;
                    kc = k2;
                    vc = v2;
                    self.sim.decode_step(i as u64)?;
                }
                for i in prompt.len()..total {
                    let next = argmax(&logits) as i32;
                    toks.push(next);
                    self.sim.decode_step(i as u64)?;
                    if i + 1 >= total {
                        break;
                    }
                    let (lg, k2, v2) = art.decode(next, i as i32, &kc, &vc)?;
                    logits = lg;
                    kc = k2;
                    vc = v2;
                }
                toks
            }
            None => {
                for i in 0..total {
                    self.sim.decode_step(i as u64)?;
                }
                synthetic_tokens(prompt, n_new)
            }
        };

        let wall_seconds = wall0.elapsed().as_secs_f64();
        self.sim.finalize_stats();
        let freq = self.sim.cfg.gddr6.freq_ghz;
        let sim_cycles = self.sim.clock() - sim_start;
        let sim_seconds = sim_cycles as f64 / (freq * 1e9);
        let energy = SystemEnergy::from_sim(&self.sim);
        Ok(GenerationResult {
            tokens,
            sim_seconds,
            sim_seconds_per_token: sim_seconds / total as f64,
            sim_energy_j: energy.total_j(),
            wall_seconds,
            row_hit_rate: self.sim.stats.row_hit_rate(),
        })
    }
}

/// Token payload of a timing-only request: the prompt followed by
/// synthetic generated ids (there are no numerics without an artifact).
/// Shared by `PimGptSystem::generate` and the serving loop so the FIFO
/// and interleaved paths return identical payloads.
pub(crate) fn synthetic_tokens(prompt: &[i32], n_new: usize) -> Vec<i32> {
    prompt.iter().copied().chain((0..n_new).map(|i| i as i32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_only_generation() {
        let m = by_name("gpt2-small").unwrap();
        let mut sys = PimGptSystem::timing_only(&m, &HwConfig::paper_baseline()).unwrap();
        let r = sys.generate(&[1, 2, 3], 5).unwrap();
        assert_eq!(r.tokens.len(), 8);
        assert!(r.sim_seconds > 0.0);
        assert!(r.sim_energy_j > 0.0);
        assert!(r.row_hit_rate > 0.9);
        // ~115 us/token for gpt2-small
        assert!(r.sim_seconds_per_token > 50e-6 && r.sim_seconds_per_token < 500e-6);
    }

    #[test]
    fn request_too_long_rejected() {
        let m = by_name("gpt-nano").unwrap(); // max_seq 128
        let mut sys = PimGptSystem::timing_only(&m, &HwConfig::paper_baseline()).unwrap();
        assert!(sys.generate(&[0; 100], 100).is_err());
    }
}
