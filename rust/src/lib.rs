//! PIM-GPT: a hybrid process-in-memory accelerator for autoregressive
//! transformers — full-system reproduction.
//!
//! Three-layer architecture:
//! - L3 (this crate): event-driven clock-cycle-accurate simulator of the
//!   GDDR6-PIM + ASIC hybrid system, the mapping compiler, baselines and the
//!   serving coordinator.
//! - L2 (python/compile/model.py): JAX GPT decode step, AOT-lowered to HLO
//!   text artifacts.
//! - L1 (python/compile/kernels/): Pallas kernels (bank-tiled VMM, ASIC
//!   approximation ops), verified against pure-jnp oracles.
//!
//! See DESIGN.md for the full system inventory and the experiment index.

pub mod arith;
pub mod baselines;
pub mod asic;
pub mod compiler;
pub mod coordinator;
pub mod config;
pub mod dram;
pub mod energy;
pub mod mapping;
pub mod model;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
