//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the CPU PJRT client. Python is never on this path — artifacts are built
//! once by `make artifacts`.
//!
//! Interchange is HLO *text* (see `/opt/xla-example/README.md` and
//! DESIGN.md §2): jax >= 0.5 emits 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 proto path rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! **Offline builds:** the `xla` crate cannot be fetched in this
//! environment, so the real client is gated behind the `pjrt` cargo
//! feature (to enable it, add `xla` to `[dependencies]` where crates.io
//! is reachable). Without the feature this module compiles a stub whose
//! constructors fail with a clear error, and the system runs timing-only
//! (`PimGptSystem::timing_only`); artifact *metadata* parsing stays
//! available either way.

pub mod artifact;

pub use artifact::{argmax, ArtifactMeta, CacheBuf, GptArtifact, InputSpec};

use anyhow::Result;

/// Thin wrapper over the `xla` crate PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name, e.g. "cpu".
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it into an executable.
    pub fn load_hlo_text(&self, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Upload a literal to the device, *synchronously*.
    ///
    /// `buffer_from_host_literal` enqueues the copy on a worker thread and
    /// captures a reference to the source literal; returning before the
    /// copy completes is a use-after-free hazard (observed SIGSEGV in
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral` when the literal or its
    /// shape is dropped early). Awaiting the buffer's definition event
    /// via `to_literal_sync` fences the upload (`CopyRawToHost` is not
    /// implemented by this CPU client, so a cheaper 1-element probe is
    /// unavailable).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let buf = self.client.buffer_from_host_literal(None, lit)?;
        let _fence = buf.to_literal_sync()?;
        Ok(buf)
    }
}

/// Stub runtime compiled when the `pjrt` feature is off: construction
/// fails cleanly, so callers fall back to timing-only simulation.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: built without the 'pjrt' feature \
             (the xla crate cannot be vendored offline) — timing-only mode"
        )
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}
