//! Functional GPT artifacts: the L2 decode step AOT-lowered by
//! `python/compile/aot.py`, loaded and executed through PJRT.
//!
//! An artifact is three files produced by `make artifacts`:
//! `<name>.hlo.txt` (the decode computation), `<name>.weights.bin`
//! (little-endian f32 parameter blob) and `<name>.meta.json` (input
//! signature). Weights are uploaded to the device once as PJRT buffers;
//! each decode call passes (token, pos, k_cache, v_cache) and receives
//! (logits, k_cache', v_cache') — the caches round-trip as device
//! buffers, so steady-state decoding copies only the token ids and
//! logits across the host boundary.
//!
//! Metadata parsing ([`ArtifactMeta`]) has no xla dependency and is
//! always compiled; execution ([`GptArtifact`], [`CacheBuf`]) requires
//! the `pjrt` feature (see `runtime::PjrtRuntime`) and is replaced by a
//! clean-failing stub without it.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// One input in the artifact signature.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub kind: String,
    pub offset: usize,
    pub nbytes: usize,
}

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub inputs: Vec<InputSpec>,
    pub hlo_path: PathBuf,
    pub weights_path: PathBuf,
}

impl ArtifactMeta {
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&text).context("parsing artifact meta")?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("meta missing config"))?;
        let num = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let mut inputs = Vec::new();
        for inp in j.get("inputs").and_then(Json::as_arr).ok_or_else(|| anyhow!("inputs"))? {
            inputs.push(InputSpec {
                name: inp.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                shape: inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                dtype: inp.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
                kind: inp.get("kind").and_then(Json::as_str).unwrap_or_default().to_string(),
                offset: inp.get("offset").and_then(Json::as_usize).unwrap_or(0),
                nbytes: inp.get("nbytes").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        let hlo = j.get("hlo").and_then(Json::as_str).ok_or_else(|| anyhow!("hlo"))?;
        let weights =
            j.get("weights_bin").and_then(Json::as_str).ok_or_else(|| anyhow!("weights_bin"))?;
        Ok(Self {
            name: name.to_string(),
            n_layer: num("n_layer")?,
            d_model: num("d_model")?,
            n_head: num("n_head")?,
            vocab: num("vocab")?,
            max_seq: num("max_seq")?,
            inputs,
            hlo_path: dir.join(hlo),
            weights_path: dir.join(weights),
        })
    }
}

#[cfg(feature = "pjrt")]
mod exec {
    use std::path::Path;

    use super::{argmax, ArtifactMeta};
    use crate::runtime::PjrtRuntime;
    use anyhow::{anyhow, bail, Context, Result};
    use xla::{ElementType, Literal, PjRtBuffer, PjRtLoadedExecutable};

    /// A device buffer paired with the host literal it was uploaded from.
    ///
    /// `PjRtClient::buffer_from_host_literal` enqueues the host->device
    /// copy *asynchronously*: the source literal must stay alive until an
    /// execution consuming the buffer has been synchronized, or the copy
    /// reads freed memory (observed as a SIGSEGV inside
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral`). Bundling the two
    /// enforces the lifetime.
    pub struct CacheBuf {
        #[allow(dead_code)]
        lit: Literal,
        buf: PjRtBuffer,
    }

    /// A loaded, executable GPT decode step.
    pub struct GptArtifact {
        pub meta: ArtifactMeta,
        exe: PjRtLoadedExecutable,
        runtime: PjrtRuntime,
        /// Parameter buffers resident on the device, in signature order.
        weight_bufs: Vec<PjRtBuffer>,
        /// Host literals backing `weight_bufs` — kept alive for the
        /// lifetime of the artifact (see `CacheBuf` docs).
        #[allow(dead_code)]
        weight_lits: Vec<Literal>,
    }

    impl GptArtifact {
        /// Load `<dir>/<name>.{hlo.txt,weights.bin,meta.json}`.
        pub fn load(runtime: PjrtRuntime, dir: &Path, name: &str) -> Result<Self> {
            let meta = ArtifactMeta::load(dir, name)?;
            let exe = runtime
                .load_hlo_text(meta.hlo_path.to_str().unwrap())
                .with_context(|| format!("compiling {}", meta.hlo_path.display()))?;
            let blob = std::fs::read(&meta.weights_path)
                .with_context(|| format!("reading {}", meta.weights_path.display()))?;
            let mut weight_bufs = Vec::new();
            let mut weight_lits = Vec::new();
            for spec in meta.inputs.iter().filter(|i| i.kind == "param") {
                if spec.offset + spec.nbytes > blob.len() {
                    bail!("weight blob too small for {}", spec.name);
                }
                let lit = Literal::create_from_shape_and_untyped_data(
                    ElementType::F32,
                    &spec.shape,
                    &blob[spec.offset..spec.offset + spec.nbytes],
                )?;
                weight_bufs.push(runtime.to_device(&lit)?);
                weight_lits.push(lit);
            }
            Ok(Self { meta, exe, runtime, weight_bufs, weight_lits })
        }

        /// Fresh zeroed KV caches as device buffers.
        pub fn empty_caches(&self) -> Result<(CacheBuf, CacheBuf)> {
            let shape = [self.meta.n_layer, self.meta.max_seq, self.meta.d_model];
            let zeros = vec![0u8; shape.iter().product::<usize>() * 4];
            let k = Literal::create_from_shape_and_untyped_data(ElementType::F32, &shape, &zeros)?;
            let v = Literal::create_from_shape_and_untyped_data(ElementType::F32, &shape, &zeros)?;
            let kb = self.runtime.to_device(&k)?;
            let vb = self.runtime.to_device(&v)?;
            Ok((CacheBuf { lit: k, buf: kb }, CacheBuf { lit: v, buf: vb }))
        }

        /// Run one decode step. Returns (logits, k_cache', v_cache').
        ///
        /// The artifact returns one flat f32 vector — `concat(logits, kc,
        /// vc)` wrapped in a 1-tuple (see `model.aot_decode_fn`): the PJRT
        /// CPU client cannot convert multi-element tuple buffers to
        /// literals, a 1-tuple of a single array round-trips fine.
        pub fn decode(
            &self,
            token: i32,
            pos: i32,
            k_cache: &CacheBuf,
            v_cache: &CacheBuf,
        ) -> Result<(Vec<f32>, CacheBuf, CacheBuf)> {
            if pos as usize >= self.meta.max_seq {
                bail!("position {pos} exceeds max_seq {}", self.meta.max_seq);
            }
            // Input literals must outlive the synchronized execution below.
            let tok_lit = Literal::vec1(&[token]);
            let pos_lit = Literal::vec1(&[pos]);
            let tok = self.runtime.to_device(&tok_lit)?;
            let p = self.runtime.to_device(&pos_lit)?;
            let mut args: Vec<&PjRtBuffer> = vec![&tok, &p, &k_cache.buf, &v_cache.buf];
            args.extend(self.weight_bufs.iter());
            let mut outs = self.exe.execute_b(&args)?;
            let replica = outs
                .first_mut()
                .and_then(|v| (!v.is_empty()).then(|| v.remove(0)))
                .ok_or_else(|| anyhow!("no output buffer"))?;
            let flat = replica.to_literal_sync()?.to_tuple1()?.to_vec::<f32>()?;

            let cache_elems = self.meta.n_layer * self.meta.max_seq * self.meta.d_model;
            let want = self.meta.vocab + 2 * cache_elems;
            if flat.len() != want {
                bail!("flat output length {} != expected {want}", flat.len());
            }
            let logits = flat[..self.meta.vocab].to_vec();
            let cache_shape = [self.meta.n_layer, self.meta.max_seq, self.meta.d_model];
            let as_bytes =
                |xs: &[f32]| -> Vec<u8> { xs.iter().flat_map(|v| v.to_le_bytes()).collect() };
            let kc = Literal::create_from_shape_and_untyped_data(
                ElementType::F32,
                &cache_shape,
                &as_bytes(&flat[self.meta.vocab..self.meta.vocab + cache_elems]),
            )?;
            let vc = Literal::create_from_shape_and_untyped_data(
                ElementType::F32,
                &cache_shape,
                &as_bytes(&flat[self.meta.vocab + cache_elems..]),
            )?;
            let kb = self.runtime.to_device(&kc)?;
            let vb = self.runtime.to_device(&vc)?;
            Ok((logits, CacheBuf { lit: kc, buf: kb }, CacheBuf { lit: vc, buf: vb }))
        }

        /// Greedy generation: feed `prompt`, then decode `n_new` tokens.
        pub fn generate(&self, prompt: &[i32], n_new: usize) -> Result<Vec<i32>> {
            if prompt.is_empty() {
                bail!("prompt must be non-empty");
            }
            let (mut kc, mut vc) = self.empty_caches()?;
            let mut toks: Vec<i32> = prompt.to_vec();
            let mut logits = Vec::new();
            for (i, &t) in prompt.iter().enumerate() {
                let (lg, k2, v2) = self.decode(t, i as i32, &kc, &vc)?;
                logits = lg;
                kc = k2;
                vc = v2;
            }
            for i in prompt.len()..prompt.len() + n_new {
                let next = argmax(&logits) as i32;
                toks.push(next);
                if i + 1 >= self.meta.max_seq {
                    break;
                }
                let (lg, k2, v2) = self.decode(next, i as i32, &kc, &vc)?;
                logits = lg;
                kc = k2;
                vc = v2;
            }
            Ok(toks)
        }
    }
}

/// Stub execution types compiled without the `pjrt` feature: every entry
/// point fails with the same clear error `PjrtRuntime::cpu` raises, so
/// nothing downstream can silently "run" a functional model.
#[cfg(not(feature = "pjrt"))]
mod exec {
    use std::path::Path;

    use super::ArtifactMeta;
    use crate::runtime::PjrtRuntime;
    use anyhow::{bail, Result};

    const STUB_ERR: &str =
        "functional artifacts require the 'pjrt' feature (xla crate) — timing-only build";

    /// Placeholder for the PJRT device cache buffer.
    pub struct CacheBuf {}

    /// Placeholder artifact: metadata only, execution always fails.
    pub struct GptArtifact {
        pub meta: ArtifactMeta,
    }

    impl GptArtifact {
        pub fn load(_runtime: PjrtRuntime, dir: &Path, name: &str) -> Result<Self> {
            // Parse the metadata so configuration errors still surface,
            // then refuse to execute.
            let _meta = ArtifactMeta::load(dir, name)?;
            bail!(STUB_ERR)
        }

        pub fn empty_caches(&self) -> Result<(CacheBuf, CacheBuf)> {
            bail!(STUB_ERR)
        }

        pub fn decode(
            &self,
            _token: i32,
            _pos: i32,
            _k_cache: &CacheBuf,
            _v_cache: &CacheBuf,
        ) -> Result<(Vec<f32>, CacheBuf, CacheBuf)> {
            bail!(STUB_ERR)
        }

        pub fn generate(&self, _prompt: &[i32], _n_new: usize) -> Result<Vec<i32>> {
            bail!(STUB_ERR)
        }
    }
}

pub use exec::{CacheBuf, GptArtifact};

/// Index of the largest element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, 0.0]), 1);
    }

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir().join("pimgpt-meta-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("toy.meta.json"),
            r#"{"name":"toy","config":{"n_layer":2,"d_model":8,"n_head":2,"vocab":16,"max_seq":4},
                "outputs":["logits","k_cache","v_cache"],
                "inputs":[{"name":"token","shape":[1],"dtype":"i32","kind":"token"},
                          {"name":"wte","shape":[16,8],"dtype":"f32","kind":"param","offset":0,"nbytes":512}],
                "weights_bin":"toy.weights.bin","hlo":"toy.hlo.txt"}"#,
        )
        .unwrap();
        let meta = ArtifactMeta::load(&dir, "toy").unwrap();
        assert_eq!(meta.n_layer, 2);
        assert_eq!(meta.vocab, 16);
        assert_eq!(meta.inputs.len(), 2);
        assert_eq!(meta.inputs[1].kind, "param");
        assert_eq!(meta.inputs[1].nbytes, 512);
    }

    #[test]
    fn meta_missing_fields_rejected() {
        let dir = std::env::temp_dir().join("pimgpt-meta-test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad.meta.json"), r#"{"name":"bad"}"#).unwrap();
        assert!(ArtifactMeta::load(&dir, "bad").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_artifact_load_fails_cleanly() {
        let dir = std::env::temp_dir().join("pimgpt-meta-test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("toy2.meta.json"),
            r#"{"name":"toy2","config":{"n_layer":1,"d_model":8,"n_head":2,"vocab":16,"max_seq":4},
                "inputs":[],"weights_bin":"toy2.weights.bin","hlo":"toy2.hlo.txt"}"#,
        )
        .unwrap();
        let rt = crate::runtime::PjrtRuntime::cpu();
        assert!(rt.is_err(), "stub runtime must refuse construction");
        let err = rt.err().unwrap().to_string();
        assert!(err.contains("timing-only"), "{err}");
    }
}
