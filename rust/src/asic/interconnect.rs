//! PIM↔ASIC interconnect: memory bus + crossbar (paper Fig. 5).
//!
//! The ASIC reaches every channel over its GDDR6 interface (32 GB/s per
//! channel at the Table-I data rate; Fig. 13 sweeps this down to 1 Gb/s
//! per pin). The crossbar supports: fetch from one channel, send to one
//! channel, or broadcast to all channels. Transfers to *different*
//! channels proceed in parallel; transfers to the same channel serialize
//! (tracked per channel in `pim::Channel::bus_busy_until`); this module
//! models the ASIC-side cost and counts global traffic (Fig. 11b).

use crate::config::HwConfig;

/// ASIC-side transfer bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct Interconnect {
    /// Total bytes ASIC<->PIM in both directions.
    pub bytes_moved: u64,
    /// Cycles the ASIC spent sourcing/sinking transfers.
    pub busy_cycles: u64,
}

impl Interconnect {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cycles to move `bytes` to/from a single channel.
    pub fn xfer_cycles(cfg: &HwConfig, bytes: u64) -> u64 {
        let per_cycle = cfg.gddr6.channel_bytes_per_cycle();
        (bytes as f64 / per_cycle).ceil() as u64
    }

    /// Broadcast `bytes` to all channels: the ASIC drives every channel
    /// interface simultaneously, so the cost is one channel's transfer.
    pub fn broadcast(&mut self, cfg: &HwConfig, bytes: u64) -> u64 {
        let cycles = Self::xfer_cycles(cfg, bytes);
        self.bytes_moved += bytes * cfg.gddr6.channels as u64;
        self.busy_cycles += cycles;
        cycles
    }

    /// Gather `bytes_per_channel` from every channel in parallel.
    pub fn gather(&mut self, cfg: &HwConfig, bytes_per_channel: u64) -> u64 {
        let cycles = Self::xfer_cycles(cfg, bytes_per_channel);
        self.bytes_moved += bytes_per_channel * cfg.gddr6.channels as u64;
        self.busy_cycles += cycles;
        cycles
    }

    /// Point-to-point transfer to/from one channel.
    pub fn unicast(&mut self, cfg: &HwConfig, bytes: u64) -> u64 {
        let cycles = Self::xfer_cycles(cfg, bytes);
        self.bytes_moved += bytes;
        self.busy_cycles += cycles;
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_rate_32_bytes_per_cycle() {
        let cfg = HwConfig::paper_baseline();
        assert_eq!(Interconnect::xfer_cycles(&cfg, 2048), 64);
        assert_eq!(Interconnect::xfer_cycles(&cfg, 1), 1);
        assert_eq!(Interconnect::xfer_cycles(&cfg, 0), 0);
    }

    #[test]
    fn fig13_rate_sweep_slows_transfers() {
        // 16 -> 2 Gb/s/pin: 8x slower transfers.
        let fast = HwConfig::paper_baseline();
        let slow = HwConfig::paper_baseline().with_data_rate_gbps(2.0);
        let f = Interconnect::xfer_cycles(&fast, 4096);
        let s = Interconnect::xfer_cycles(&slow, 4096);
        assert_eq!(s, f * 8);
    }

    #[test]
    fn broadcast_counts_fanout_traffic() {
        let cfg = HwConfig::paper_baseline();
        let mut ic = Interconnect::new();
        let cycles = ic.broadcast(&cfg, 2048);
        assert_eq!(cycles, 64);
        assert_eq!(ic.bytes_moved, 2048 * 8);
    }

    #[test]
    fn gather_parallel_across_channels() {
        let cfg = HwConfig::paper_baseline();
        let mut ic = Interconnect::new();
        let cycles = ic.gather(&cfg, 256);
        assert_eq!(cycles, 8); // 256 B / 32 B-per-cycle
        assert_eq!(ic.bytes_moved, 256 * 8);
    }
}
