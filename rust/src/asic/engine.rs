//! ASIC computation-engine cycle model.
//!
//! The ASIC has 256 adders and 128 multipliers at 1 GHz (Table I). Every
//! non-VMM function is built from adds and multiplies only (§III.D), so
//! the latency of an op is derived from its add/multiply *operation
//! counts* divided by the lane counts (both engine classes are pipelined
//! and can run concurrently, so the op latency is the max of the two
//! streams plus a small pipeline fill).
//!
//! The engines are *deeply pipelined*: the Horner chain of a Taylor
//! polynomial, the NR iterations of a reciprocal, etc. are pipeline
//! stages, so each lane sustains one fused elementwise operation per
//! cycle after fill — the polynomial degree adds latency (absorbed in
//! the per-op fill), not throughput. Cost is therefore measured in
//! *lane-passes* over the data:
//!
//! * `exp`/`tanh`/polynomial: 1 multiplier-lane pass per element
//! * reductions (max, sum, mean, variance): 1 adder-lane pass each
//! * scalar NR reciprocal / fast rsqrt: fixed ~tens-of-cycles latency
//!
//! This pipelined-throughput model is what reproduces the paper's
//! observed behavior (arithmetic ~1.16% of GPT3-XL latency, Fig. 10;
//! <=20% slowdown at 100 MHz ASIC clock, Fig. 12). A sequential
//! op-count model would make GELU/softmax 5-20x more expensive and
//! contradicts both results.

use crate::config::HwConfig;

/// Non-VMM operations executed by the ASIC (instruction set of the
/// computation engines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AsicOp {
    /// Masked softmax over `n` attention scores total, processed in
    /// `groups` independent slices (one per attention head): the engines
    /// stream head-by-head, so only `n / groups` elements are live in
    /// SRAM at once.
    Softmax { n: u64, groups: u64 },
    /// LayerNorm over a `n`-element vector.
    LayerNorm { n: u64 },
    /// GELU over `n` elements.
    Gelu { n: u64 },
    /// Elementwise add of two `n`-vectors (residual connection).
    ResidualAdd { n: u64 },
    /// Accumulate `parts` partial VMM results of `n` elements each
    /// (input vector exceeded the 2 KB global buffer).
    PartialSum { n: u64, parts: u64 },
    /// Bias add after a VMM.
    BiasAdd { n: u64 },
    /// Scale by 1/sqrt(d_k) before softmax.
    Scale { n: u64 },
    /// Head concatenation / data re-packing (no arithmetic, SRAM move).
    Concat { n: u64 },
}

/// add/mul operation counts of an op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCost {
    pub adds: u64,
    pub muls: u64,
}

impl AsicOp {
    /// Lane-pass counts (see module docs: pipelined throughput model).
    pub fn cost(&self) -> OpCost {
        // Fixed scalar latencies of the iterative primitives (cycles,
        // folded into the add/mul streams as small constants).
        const RECIP: u64 = 24; // Algorithm 1, 3 NR iterations
        const RSQRT: u64 = 16; // Algorithm 2, 2 NR iterations
        match *self {
            AsicOp::Softmax { n, .. } => OpCost {
                // max-reduce + sum-reduce: two adder passes
                adds: 2 * n + RECIP,
                // subtract-and-exp pass + final scale pass
                muls: 2 * n,
            },
            AsicOp::LayerNorm { n } => OpCost {
                // mean pass + variance pass (sq in mul lane) + rsqrt
                adds: 2 * n + RSQRT,
                // square pass + normalize/affine pass
                muls: 2 * n,
            },
            AsicOp::Gelu { n } => OpCost {
                // inner polynomial pass + tanh/outer pass (fused pipelines)
                adds: n,
                muls: 2 * n,
            },
            AsicOp::ResidualAdd { n } => OpCost { adds: n, muls: 0 },
            AsicOp::PartialSum { n, parts } => OpCost { adds: n * parts.saturating_sub(1), muls: 0 },
            AsicOp::BiasAdd { n } => OpCost { adds: n, muls: 0 },
            AsicOp::Scale { n } => OpCost { adds: 0, muls: n },
            AsicOp::Concat { .. } => OpCost { adds: 0, muls: 0 },
        }
    }

    /// Whether the op can consume its input as a stream (elementwise or
    /// group-wise): such ops start as soon as the producing VMM's first
    /// partial results arrive at the ASIC (paper §IV.A(3) pipelining).
    /// LayerNorm is excluded: it needs global mean/variance before it can
    /// emit anything (two-pass).
    pub fn streamable(&self) -> bool {
        !matches!(self, AsicOp::LayerNorm { .. })
    }

    /// The op applied to `count` consecutive token positions (a prefill
    /// chunk): element counts scale by `count`, per-position groups
    /// multiply (each position's heads stay independent softmax slices),
    /// and `parts` stays per-pass — a chunked VMM produces `parts`
    /// partials *per position*, accumulated position by position.
    /// Positions stream through the engines back to back, so the fixed
    /// scalar latencies (NR reciprocal/rsqrt) and the pipeline fill
    /// amortize across the chunk — that amortization is one of the three
    /// wins chunked prefill buys (with row-ACT and GB-reload
    /// amortization on the PIM side). `count = 1` returns the op
    /// unchanged.
    pub fn for_positions(&self, count: u64) -> AsicOp {
        if count <= 1 {
            return *self;
        }
        match *self {
            AsicOp::Softmax { n, groups } => {
                AsicOp::Softmax { n: n * count, groups: groups * count }
            }
            AsicOp::LayerNorm { n } => AsicOp::LayerNorm { n: n * count },
            AsicOp::Gelu { n } => AsicOp::Gelu { n: n * count },
            AsicOp::ResidualAdd { n } => AsicOp::ResidualAdd { n: n * count },
            AsicOp::PartialSum { n, parts } => AsicOp::PartialSum { n: n * count, parts },
            AsicOp::BiasAdd { n } => AsicOp::BiasAdd { n: n * count },
            AsicOp::Scale { n } => AsicOp::Scale { n: n * count },
            AsicOp::Concat { n } => AsicOp::Concat { n: n * count },
        }
    }

    /// Elements live in SRAM at once (streaming-aware).
    pub fn live_elems(&self) -> u64 {
        match *self {
            AsicOp::Softmax { n, groups } => crate::util::ceil_div(n, groups.max(1)),
            _ => self.elems(),
        }
    }

    /// Elements touched (SRAM traffic estimate).
    pub fn elems(&self) -> u64 {
        match *self {
            AsicOp::Softmax { n, .. }
            | AsicOp::LayerNorm { n }
            | AsicOp::Gelu { n }
            | AsicOp::ResidualAdd { n }
            | AsicOp::BiasAdd { n }
            | AsicOp::Scale { n }
            | AsicOp::Concat { n } => n,
            AsicOp::PartialSum { n, parts } => n * parts,
        }
    }
}

/// The computation-engine latency/energy model.
#[derive(Clone, Debug)]
pub struct Engine {
    /// ASIC cycles per DRAM cycle (sim clock runs on the DRAM clock; an
    /// ASIC at 0.2 GHz makes every op 5x longer in sim cycles — Fig. 12).
    dram_per_asic: f64,
    n_adders: u64,
    n_multipliers: u64,
    /// Fixed pipeline fill per op (engine setup, SRAM read latency).
    fill: u64,
    /// Busy cycles accumulated (DRAM-clock cycles, for energy).
    pub busy_cycles: u64,
    /// Total ops executed.
    pub ops_executed: u64,
}

impl Engine {
    pub fn new(cfg: &HwConfig) -> Self {
        Self {
            dram_per_asic: cfg.gddr6.freq_ghz / cfg.asic.freq_ghz,
            n_adders: cfg.asic.n_adders as u64,
            n_multipliers: cfg.asic.n_multipliers as u64,
            fill: 4,
            busy_cycles: 0,
            ops_executed: 0,
        }
    }

    /// Latency of `op` in DRAM-clock cycles.
    pub fn latency(&self, op: &AsicOp) -> u64 {
        let c = op.cost();
        let add_cyc = crate::util::ceil_div(c.adds, self.n_adders);
        let mul_cyc = crate::util::ceil_div(c.muls, self.n_multipliers);
        // Adder and multiplier arrays are separate pipelined engines; a
        // fused op streams through both, so latency is the longer stream.
        let asic_cycles = self.fill + add_cyc.max(mul_cyc);
        (asic_cycles as f64 * self.dram_per_asic).ceil() as u64
    }

    /// Execute `op` at `start`; returns finish cycle and records busy time.
    pub fn execute(&mut self, start: u64, op: &AsicOp) -> u64 {
        let lat = self.latency(op);
        self.busy_cycles += lat;
        self.ops_executed += 1;
        start + lat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn engine() -> Engine {
        Engine::new(&HwConfig::paper_baseline())
    }

    #[test]
    fn residual_add_is_cheap() {
        let e = engine();
        // 2048-element residual add: 2048/256 = 8 cycles + fill
        assert_eq!(e.latency(&AsicOp::ResidualAdd { n: 2048 }), 4 + 8);
    }

    #[test]
    fn softmax_cost_formula() {
        let c = AsicOp::Softmax { n: 100, groups: 4 }.cost();
        assert_eq!(c.adds, 2 * 100 + 24);
        assert_eq!(c.muls, 2 * 100);
    }

    #[test]
    fn concat_is_free_arithmetic() {
        let c = AsicOp::Concat { n: 4096 }.cost();
        assert_eq!(c, OpCost { adds: 0, muls: 0 });
    }

    #[test]
    fn partial_sum_scales_with_parts() {
        assert_eq!(AsicOp::PartialSum { n: 100, parts: 3 }.cost().adds, 200);
        assert_eq!(AsicOp::PartialSum { n: 100, parts: 1 }.cost().adds, 0);
    }

    /// Chunked prefill: the per-chunk op covers `count` positions with
    /// one pipeline fill, so its latency is strictly below `count`
    /// separate per-position executions; per-head softmax SRAM liveness
    /// is unchanged (groups scale with positions).
    #[test]
    fn for_positions_scales_and_amortizes_fill() {
        let e = engine();
        let per_pos = AsicOp::Softmax { n: 1024, groups: 4 };
        let chunk = per_pos.for_positions(16);
        assert_eq!(chunk, AsicOp::Softmax { n: 16 * 1024, groups: 64 });
        assert_eq!(chunk.live_elems(), per_pos.live_elems());
        assert!(e.latency(&chunk) < 16 * e.latency(&per_pos));
        // count = 1 is the identity on every variant.
        for op in [
            AsicOp::Softmax { n: 64, groups: 4 },
            AsicOp::LayerNorm { n: 64 },
            AsicOp::Gelu { n: 64 },
            AsicOp::ResidualAdd { n: 64 },
            AsicOp::PartialSum { n: 64, parts: 3 },
            AsicOp::BiasAdd { n: 64 },
            AsicOp::Scale { n: 64 },
            AsicOp::Concat { n: 64 },
        ] {
            assert_eq!(op.for_positions(1), op);
        }
        // parts stays per-position: the chunk accumulates each
        // position's partials, so the add count scales by the count.
        let ps = AsicOp::PartialSum { n: 100, parts: 3 }.for_positions(8);
        assert_eq!(ps.cost().adds, 8 * 100 * 2);
    }

    #[test]
    fn frequency_scaling_fig12() {
        let base = engine();
        let slow = Engine::new(&HwConfig::paper_baseline().with_asic_freq_ghz(0.1));
        let op = AsicOp::Gelu { n: 3072 };
        let l1 = base.latency(&op);
        let l10 = slow.latency(&op);
        assert!((l10 as f64 / l1 as f64 - 10.0).abs() < 0.2, "{l1} {l10}");
    }

    #[test]
    fn execute_accumulates_busy_time() {
        let mut e = engine();
        let f1 = e.execute(100, &AsicOp::ResidualAdd { n: 256 });
        assert_eq!(f1, 100 + e.latency(&AsicOp::ResidualAdd { n: 256 }));
        assert_eq!(e.ops_executed, 1);
        assert!(e.busy_cycles > 0);
    }

    #[test]
    fn prop_latency_monotonic_in_n() {
        check("asic latency monotonic", 100, |rng| {
            let e = engine();
            let n1 = rng.gen_range(10_000) + 1;
            let n2 = n1 + rng.gen_range(10_000) + 1;
            for (a, b) in [
                (AsicOp::Softmax { n: n1, groups: 1 }, AsicOp::Softmax { n: n2, groups: 1 }),
                (AsicOp::LayerNorm { n: n1 }, AsicOp::LayerNorm { n: n2 }),
                (AsicOp::Gelu { n: n1 }, AsicOp::Gelu { n: n2 }),
            ] {
                if e.latency(&a) > e.latency(&b) {
                    return Err(format!("{a:?} slower than {b:?}"));
                }
            }
            Ok(())
        });
    }
}
