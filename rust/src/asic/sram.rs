//! 128 KB ASIC SRAM buffer (Table I): capacity tracking for intermediate
//! vectors (input vectors, partial sums, attention scores). The compiler
//! checks every intermediate against this capacity; overflow is a mapping
//! bug, not a runtime reallocation.

use crate::config::HwConfig;

/// SRAM occupancy tracker.
#[derive(Clone, Debug)]
pub struct Sram {
    capacity_bytes: usize,
    used_bytes: usize,
    /// High-water mark for reporting.
    pub peak_bytes: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub struct SramOverflow {
    pub need: usize,
    pub used: usize,
    pub cap: usize,
}

impl std::fmt::Display for SramOverflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SRAM overflow: need {} bytes, {} of {} in use",
            self.need, self.used, self.cap
        )
    }
}

impl std::error::Error for SramOverflow {}

impl Sram {
    pub fn new(cfg: &HwConfig) -> Self {
        Self { capacity_bytes: cfg.asic.sram_kb * 1024, used_bytes: 0, peak_bytes: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_bytes
    }

    pub fn used(&self) -> usize {
        self.used_bytes
    }

    /// Reserve `bytes`; errors on overflow.
    pub fn alloc(&mut self, bytes: usize) -> Result<(), SramOverflow> {
        if self.used_bytes + bytes > self.capacity_bytes {
            return Err(SramOverflow { need: bytes, used: self.used_bytes, cap: self.capacity_bytes });
        }
        self.used_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        Ok(())
    }

    pub fn free(&mut self, bytes: usize) {
        debug_assert!(bytes <= self.used_bytes, "freeing more than allocated");
        self.used_bytes = self.used_bytes.saturating_sub(bytes);
    }

    pub fn reset(&mut self) {
        self.used_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sram() -> Sram {
        Sram::new(&HwConfig::paper_baseline())
    }

    #[test]
    fn capacity_is_128kb() {
        assert_eq!(sram().capacity(), 128 * 1024);
    }

    #[test]
    fn alloc_free_cycle() {
        let mut s = sram();
        s.alloc(100_000).unwrap();
        assert_eq!(s.used(), 100_000);
        s.free(60_000);
        assert_eq!(s.used(), 40_000);
        s.alloc(80_000).unwrap();
        assert_eq!(s.peak_bytes, 120_000);
    }

    #[test]
    fn overflow_detected() {
        let mut s = sram();
        s.alloc(128 * 1024).unwrap();
        let err = s.alloc(1).unwrap_err();
        assert_eq!(err.need, 1);
        assert_eq!(err.used, 128 * 1024);
    }

    #[test]
    fn gpt3_xl_vectors_fit() {
        // Largest model: d=2048, d_ff=8192 bf16 elements must fit with
        // room for double-buffering: (2048 + 8192) * 2 bytes = 20.5 KB.
        let mut s = sram();
        s.alloc(2048 * 2).unwrap();
        s.alloc(8192 * 2).unwrap();
        s.alloc(8192 * 2).unwrap(); // double buffer
        assert!(s.used() < s.capacity());
    }
}
