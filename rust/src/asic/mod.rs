//! ASIC model: computation engines, SRAM buffer and the PIM↔ASIC
//! interconnect (paper §III.C-D, Fig. 5).

pub mod engine;
pub mod interconnect;
pub mod sram;

pub use engine::{AsicOp, Engine, OpCost};
pub use interconnect::Interconnect;
pub use sram::Sram;
