//! PIM channel model: global buffer, 16 banks with MAC units, broadcast
//! and result forwarding (paper §III.B, Fig. 4).

pub mod channel;

pub use channel::{Channel, ChannelExec, UnitWork, VmmPlan};
