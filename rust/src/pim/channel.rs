//! One GDDR6-PIM channel: a 2 KB global buffer shared by 16 banks, each
//! with a 16-lane MAC unit (Fig. 4a).
//!
//! Timeline of a channel-level VMM (paper §IV.A):
//!
//! 1. the ASIC broadcasts the input vector into the GB over the GDDR6
//!    interface (`gb_load` cycles; input longer than the GB is split by
//!    the compiler into multiple VMM instructions + ASIC partial-sums);
//! 2. all banks MAC their mapped work in parallel, consuming open rows
//!    at `lanes` values per cycle;
//! 3. partial outputs are forwarded to the ASIC as they become ready
//!    (never written back to DRAM — §IV.A(1)); the drain is pipelined
//!    with the MAC, so only the *tail* that outlives the slowest bank
//!    adds latency.
//!
//! Refresh: the channel issues an all-bank refresh every `tREFI`; a VMM
//! overlapping a refresh deadline stalls for `tRFC` (modeled per bank).

use crate::config::HwConfig;
use crate::dram::bank::RowBlock;
use crate::dram::{Bank, BankStats, CommandCounts, RowSegment, TimingCycles};

/// Work assigned to one bank by a VMM instruction.
#[derive(Clone, Debug)]
pub enum UnitWork {
    /// Nothing mapped to this bank.
    Idle,
    /// A weight block: consecutive fully-mapped rows (Fig. 6b layout).
    Block(RowBlock),
    /// Explicit segments (irregular shapes; kept for tests/ablations).
    Segments(Vec<RowSegment>),
    /// `reps` repetitions of a row-fill `pattern` from `base_row` — the
    /// KV-cache read fast path (O(1) in context length).
    Pattern {
        base_row: u32,
        reps: u32,
        pattern: [u32; crate::mapping::kv_reserve::MAX_PATTERN],
        pattern_len: u8,
    },
    /// A paged KV read: one `Pattern`-shaped run per covered page frame,
    /// executed back to back on the bank. A single-run list is
    /// cycle-identical to the equivalent `Pattern` (same `mac_pattern`
    /// call); between runs the bank's own `busy_until`/`opened_at` state
    /// charges the honest row-switch cost when frames are not adjacent.
    PatternRuns(Vec<crate::mapping::PatternRun>),
}

impl UnitWork {
    pub fn is_idle(&self) -> bool {
        matches!(self, UnitWork::Idle)
            || matches!(self, UnitWork::Segments(s) if s.is_empty())
            || matches!(self, UnitWork::Block(b) if b.total_rows() == 0)
            || matches!(self, UnitWork::Pattern { reps, pattern_len, .. }
                        if *reps == 0 || *pattern_len == 0)
            || matches!(self, UnitWork::PatternRuns(runs)
                        if runs.iter().all(|r| r.reps == 0 || r.pattern_len == 0))
    }

    fn first_row(&self) -> Option<u32> {
        match self {
            UnitWork::Idle => None,
            UnitWork::Block(b) => (b.total_rows() > 0).then_some(b.base_row),
            UnitWork::Segments(s) => s.first().map(|seg| seg.row),
            UnitWork::Pattern { base_row, reps, pattern_len, .. } => {
                (*reps > 0 && *pattern_len > 0).then_some(*base_row)
            }
            UnitWork::PatternRuns(runs) => runs
                .iter()
                .find(|r| r.reps > 0 && r.pattern_len > 0)
                .map(|r| r.base_row),
        }
    }
}

/// Per-bank work of one channel-level VMM instruction.
#[derive(Clone, Debug)]
pub struct VmmPlan {
    /// Work per bank (index = bank id).
    pub bank_work: Vec<UnitWork>,
    /// Input vector elements to broadcast into the GB *per pass*.
    pub input_elems: u64,
    /// Output elements this channel produces per pass (drained to the
    /// ASIC).
    pub output_elems: u64,
    /// Input vectors streamed through the mapped rows (matrix-matrix
    /// mode, chunked prefill). 1 = the classic vector-matrix VMM. Each
    /// pass broadcasts its own `input_elems` into the GB and drains its
    /// own `output_elems`; the banks pay their row ACT/PRE once and
    /// `passes` MAC streams per row (`Bank::mac_block` /
    /// `Bank::mac_pattern`).
    pub passes: u64,
}

/// Result of executing one instruction on a channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelExec {
    /// Cycle the channel finished (all banks done + drain tail).
    pub finish: u64,
    /// Cycle the first partial result reached the ASIC (drain start) —
    /// downstream streamable ASIC ops may begin here (paper §IV.A(3)).
    pub first_ready: u64,
    /// Interface cycles spent on the GB broadcast.
    pub gb_load_cycles: u64,
    /// Interface cycles spent draining results.
    pub drain_cycles: u64,
}

/// A PIM channel: banks + refresh bookkeeping.
#[derive(Clone, Debug)]
pub struct Channel {
    pub banks: Vec<Bank>,
    /// Next refresh deadline (cycle).
    next_refresh: u64,
    /// Interface busy-until (GB loads, result drains and KV write-backs
    /// all serialize on the bus).
    bus_busy_until: u64,
    /// Bytes written into the channel (GB loads + KV write-backs).
    pub bytes_in: u64,
    /// Bytes drained out of the channel (VMM results).
    pub bytes_out: u64,
}

impl Channel {
    pub fn new(cfg: &HwConfig) -> Self {
        let t = TimingCycles::from_config(cfg);
        Self {
            banks: (0..cfg.gddr6.banks_per_channel).map(|_| Bank::new()).collect(),
            next_refresh: t.trefi,
            bus_busy_until: 0,
            bytes_in: 0,
            bytes_out: 0,
        }
    }

    /// Apply any refresh deadlines passed by `now`.
    pub fn catch_up_refresh(&mut self, now: u64, t: &TimingCycles) {
        while now >= self.next_refresh {
            let at = self.next_refresh;
            for b in &mut self.banks {
                b.refresh(at, t);
            }
            self.next_refresh += t.trefi;
        }
    }

    /// Interface cycles to move `bytes` over this channel's pins.
    fn xfer_cycles(cfg: &HwConfig, bytes: u64) -> u64 {
        let per_cycle = cfg.gddr6.channel_bytes_per_cycle();
        (bytes as f64 / per_cycle).ceil() as u64
    }

    /// Execute a VMM instruction starting no earlier than `start`.
    ///
    /// With `plan.passes = T > 1` (matrix-matrix mode, chunked prefill)
    /// the T input vectors stream through the mapped rows back to back:
    /// the MACs begin once the *first* vector is staged in the GB while
    /// the bus keeps feeding the rest (the MACs cannot finish before the
    /// last vector has fully arrived), the banks pay each row's ACT/PRE
    /// once for all T streams, and the drain moves T passes' worth of
    /// results. `passes = 1` reproduces the classic vector-matrix
    /// timeline cycle for cycle.
    pub fn execute_vmm(
        &mut self,
        cfg: &HwConfig,
        t: &TimingCycles,
        start: u64,
        plan: &VmmPlan,
    ) -> ChannelExec {
        assert_eq!(plan.bank_work.len(), self.banks.len(), "plan/bank arity");
        let passes = plan.passes.max(1);
        self.catch_up_refresh(start, t);

        // 1. GB broadcast over the interface (serializes on the bus).
        // Matrix-matrix mode loads one vector per pass; the MACs start
        // after the first and the remaining loads pipeline underneath.
        let in_bytes = plan.input_elems * 2;
        let per_pass_load = Self::xfer_cycles(cfg, in_bytes);
        let gb_load = passes * per_pass_load;
        let bus_free = self.bus_busy_until.max(start);
        let macs_start = bus_free + per_pass_load;
        let input_done = bus_free + gb_load;
        self.bytes_in += passes * in_bytes;

        // 2. Banks in parallel.
        let lanes = cfg.pim.mac_lanes as u64;
        let fill = cfg.pim.pipeline_fill;
        let row_elems = cfg.gddr6.row_elems() as u32;
        let mut slowest = macs_start;
        let mut first_ready = u64::MAX;
        for (bank, work) in self.banks.iter_mut().zip(&plan.bank_work) {
            if work.is_idle() {
                continue;
            }
            if let Some(row) = work.first_row() {
                first_ready = first_ready.min(bank.first_result_at(macs_start, row, t, fill));
            }
            let fin = match work {
                UnitWork::Idle => macs_start,
                UnitWork::Block(b) => {
                    bank.mac_block(macs_start, b, row_elems, t, lanes, fill, passes)
                }
                UnitWork::Segments(s) => bank.mac_sweep(macs_start, s, t, lanes, fill),
                UnitWork::Pattern { base_row, reps, pattern, pattern_len } => bank.mac_pattern(
                    macs_start,
                    *base_row,
                    *reps,
                    &pattern[..*pattern_len as usize],
                    t,
                    lanes,
                    fill,
                    passes,
                ),
                UnitWork::PatternRuns(runs) => {
                    // Back-to-back per-page sweeps: `mac_pattern` clamps
                    // its start to the bank's `busy_until`, so chaining
                    // each run's finish composes cycle-exactly with one
                    // contiguous sweep when the frames are adjacent and
                    // pays the row-switch conflict when they are not.
                    let mut fin = macs_start;
                    for r in runs {
                        if r.reps == 0 || r.pattern_len == 0 {
                            continue;
                        }
                        fin = bank.mac_pattern(
                            fin,
                            r.base_row,
                            r.reps,
                            &r.pattern[..r.pattern_len as usize],
                            t,
                            lanes,
                            fill,
                            passes,
                        );
                    }
                    fin
                }
            };
            slowest = slowest.max(fin);
        }
        if first_ready == u64::MAX {
            first_ready = macs_start;
        }
        // The last pass cannot finish before its input left the bus.
        let slowest = slowest.max(input_done);

        // 3. Drain, pipelined: starts when the first partial result is
        // ready, proceeds at interface rate, cannot finish before the
        // slowest bank produced its last element.
        let out_bytes = passes * plan.output_elems * 2;
        let drain = Self::xfer_cycles(cfg, out_bytes);
        self.bytes_out += out_bytes;
        let finish = (first_ready + drain).max(slowest);
        self.bus_busy_until = finish;

        ChannelExec { finish, first_ready, gb_load_cycles: gb_load, drain_cycles: drain }
    }

    /// Write-back of a Key vector slice (row-major, Fig. 7a) to one bank.
    /// Like a VMM, the write occupies the channel's shared bus for its
    /// duration (the data arrives over the same GB port), so concurrent
    /// traffic on the channel serializes behind it.
    pub fn write_k(&mut self, t: &TimingCycles, start: u64, bank: usize, seg: RowSegment) -> u64 {
        self.catch_up_refresh(start, t);
        let start = start.max(self.bus_busy_until);
        self.bytes_in += seg.elems as u64 * 2;
        let fin = self.banks[bank].write_row_major(start, seg, t);
        self.bus_busy_until = fin;
        fin
    }

    /// Write-back of Value elements (column-major, Fig. 7b) to one bank:
    /// `n_elems` elements into rows `base_row + i*row_stride`. Holds the
    /// channel bus like `write_k`.
    pub fn write_v(
        &mut self,
        t: &TimingCycles,
        start: u64,
        bank: usize,
        n_elems: u32,
        base_row: u32,
        row_stride: u32,
    ) -> u64 {
        self.catch_up_refresh(start, t);
        let start = start.max(self.bus_busy_until);
        self.bytes_in += n_elems as u64 * 2;
        let fin = self.banks[bank].write_col_major(start, n_elems, base_row, row_stride, t);
        self.bus_busy_until = fin;
        fin
    }

    /// Merge all bank stats.
    pub fn stats(&self) -> (BankStats, CommandCounts) {
        let mut s = BankStats::default();
        let mut c = CommandCounts::default();
        for b in &self.banks {
            s.merge(&b.stats);
            c.merge(&b.cmds);
        }
        (s, c)
    }

    /// Total bytes moved over the channel interface (Fig. 11b).
    pub fn bytes_transferred(&self) -> u64 {
        self.bytes_in + self.bytes_out
    }

    pub fn busy_until(&self) -> u64 {
        self.banks.iter().map(|b| b.busy_until()).max().unwrap_or(0).max(self.bus_busy_until)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn setup() -> (HwConfig, TimingCycles) {
        let cfg = HwConfig::paper_baseline();
        let t = TimingCycles::from_config(&cfg);
        (cfg, t)
    }

    fn uniform_plan(cfg: &HwConfig, rows_per_bank: u32, input: u64, output: u64) -> VmmPlan {
        VmmPlan {
            bank_work: (0..cfg.gddr6.banks_per_channel)
                .map(|_| UnitWork::Block(RowBlock { base_row: 0, full_rows: rows_per_bank, tail_elems: 0 }))
                .collect(),
            input_elems: input,
            output_elems: output,
            passes: 1,
        }
    }

    #[test]
    fn banks_run_in_parallel() {
        let (cfg, t) = setup();
        let mut ch = Channel::new(&cfg);
        let e16 = ch.execute_vmm(&cfg, &t, 0, &uniform_plan(&cfg, 1, 1024, 16));
        let mut ch1 = Channel::new(&cfg);
        let mut plan1 = uniform_plan(&cfg, 1, 1024, 16);
        for b in 1..16 {
            plan1.bank_work[b] = UnitWork::Idle;
        }
        let e1 = ch1.execute_vmm(&cfg, &t, 0, &plan1);
        assert!(e16.finish <= e1.finish + 1, "{} vs {}", e16.finish, e1.finish);
    }

    #[test]
    fn gb_load_precedes_macs() {
        let (cfg, t) = setup();
        let mut ch = Channel::new(&cfg);
        let e = ch.execute_vmm(&cfg, &t, 0, &uniform_plan(&cfg, 1, 1024, 16));
        // 2048 bytes at 32 B/cycle = 64 cycles of GB load, then ACT+MAC.
        assert_eq!(e.gb_load_cycles, 64);
        assert!(e.finish >= 64 + t.trcd + 64);
    }

    #[test]
    fn drain_pipelined_not_additive() {
        let (cfg, t) = setup();
        let mut ch = Channel::new(&cfg);
        let plan = uniform_plan(&cfg, 8, 1024, 1024);
        let e = ch.execute_vmm(&cfg, &t, 0, &plan);
        let mac_only = {
            let mut ch2 = Channel::new(&cfg);
            let mut p2 = plan.clone();
            p2.output_elems = 1;
            ch2.execute_vmm(&cfg, &t, 0, &p2).finish
        };
        assert!(e.finish <= mac_only + 64, "drain should overlap: {} vs {mac_only}", e.finish);
    }

    #[test]
    fn refresh_interrupts_long_runs() {
        let (cfg, t) = setup();
        let mut ch = Channel::new(&cfg);
        let mut now = 0;
        for _ in 0..10 {
            now = ch.execute_vmm(&cfg, &t, now, &uniform_plan(&cfg, 2, 1024, 16)).finish;
        }
        ch.catch_up_refresh(3 * t.trefi + 1, &t);
        let (_, cmds) = ch.stats();
        assert!(cmds.refresh >= 3 * 16, "refresh count {}", cmds.refresh);
    }

    #[test]
    fn bytes_tracked() {
        let (cfg, t) = setup();
        let mut ch = Channel::new(&cfg);
        ch.execute_vmm(&cfg, &t, 0, &uniform_plan(&cfg, 1, 512, 128));
        assert_eq!(ch.bytes_in, 512 * 2);
        assert_eq!(ch.bytes_out, 128 * 2);
    }

    #[test]
    fn segments_and_blocks_mix() {
        let (cfg, t) = setup();
        let mut ch = Channel::new(&cfg);
        let mut plan = uniform_plan(&cfg, 2, 256, 64);
        plan.bank_work[3] =
            UnitWork::Segments(vec![RowSegment { row: 7, elems: 100 }, RowSegment { row: 7, elems: 50 }]);
        let e = ch.execute_vmm(&cfg, &t, 0, &plan);
        assert!(e.finish > 0);
        let (s, _) = ch.stats();
        assert!(s.row_hits > 0);
    }

    /// Tentpole pin (chunked prefill): a T-pass matrix-matrix VMM is
    /// strictly cheaper than T separate vector-matrix VMMs over the same
    /// rows (row ACT/PRE paid once instead of T times), never cheaper
    /// than T times the pure MAC-stream time, and moves exactly T times
    /// the bytes.
    #[test]
    fn multi_pass_vmm_amortizes_activations() {
        let (cfg, t) = setup();
        let passes = 8u64;
        let plan1 = uniform_plan(&cfg, 4, 1024, 64);
        let mut plant = uniform_plan(&cfg, 4, 1024, 64);
        plant.passes = passes;

        let mut chunked = Channel::new(&cfg);
        let e = chunked.execute_vmm(&cfg, &t, 0, &plant);

        let mut serial = Channel::new(&cfg);
        let mut fin = 0;
        for _ in 0..passes {
            fin = serial.execute_vmm(&cfg, &t, fin, &plan1).finish;
        }
        assert!(
            e.finish < fin,
            "matrix-matrix {} !< {passes} vector-matrix passes {fin}",
            e.finish
        );
        // Same data volume either way.
        assert_eq!(chunked.bytes_in, serial.bytes_in);
        assert_eq!(chunked.bytes_out, serial.bytes_out);
        // Lower bound: the MAC streams themselves don't compress — at
        // least T * rows * chunks of tCCD must elapse.
        let min_mac = passes * 4 * 64 * t.tccd;
        assert!(e.finish > min_mac, "finish {} below pure MAC floor {min_mac}", e.finish);
        // passes = 1 in the plan is byte-identical to the legacy shape.
        let mut a = Channel::new(&cfg);
        let mut b = Channel::new(&cfg);
        let ea = a.execute_vmm(&cfg, &t, 0, &plan1);
        let mut plan1b = plan1.clone();
        plan1b.passes = 1;
        let eb = b.execute_vmm(&cfg, &t, 0, &plan1b);
        assert_eq!(ea, eb);
    }

    /// The bus keeps feeding later passes while the MACs run, but the
    /// VMM cannot finish before every pass's input has arrived.
    #[test]
    fn multi_pass_input_bounds_finish() {
        let (cfg, t) = setup();
        // Tiny MAC work, many passes: the input stream dominates.
        let mut plan = uniform_plan(&cfg, 1, 1024, 1);
        for b in 1..16 {
            plan.bank_work[b] = UnitWork::Idle;
        }
        plan.passes = 64;
        let mut ch = Channel::new(&cfg);
        let e = ch.execute_vmm(&cfg, &t, 0, &plan);
        // 64 passes x 64 cycles of GB load = 4096 cycles of input.
        assert_eq!(e.gb_load_cycles, 64 * 64);
        assert!(e.finish >= 64 * 64, "finish {} before input done", e.finish);
    }

    /// Paged-KV pin: a single-run `PatternRuns` is cycle-identical to
    /// the equivalent `Pattern`, and an adjacent two-run split of one
    /// sweep composes to the exact same finish (the bank's
    /// `busy_until`/`opened_at` continuation is what the paged read path
    /// relies on for the page-size = max_seq equivalence).
    #[test]
    fn pattern_runs_compose_like_one_sweep() {
        use crate::mapping::PatternRun;
        let (cfg, t) = setup();
        let mut pattern = [0u32; crate::mapping::kv_reserve::MAX_PATTERN];
        pattern[0] = 1024;
        pattern[1] = 512;
        let plan = |work: UnitWork| {
            let mut bank_work = vec![UnitWork::Idle; cfg.gddr6.banks_per_channel];
            bank_work[2] = work;
            VmmPlan { bank_work, input_elems: 512, output_elems: 64, passes: 1 }
        };
        let one = UnitWork::Pattern { base_row: 40, reps: 5, pattern, pattern_len: 2 };
        let single_run = UnitWork::PatternRuns(vec![PatternRun {
            base_row: 40,
            reps: 5,
            pattern,
            pattern_len: 2,
        }]);
        // Rows advance pattern_len per rep, so rep 3 starts at row 46.
        let split = UnitWork::PatternRuns(vec![
            PatternRun { base_row: 40, reps: 3, pattern, pattern_len: 2 },
            PatternRun { base_row: 46, reps: 2, pattern, pattern_len: 2 },
        ]);
        let base = Channel::new(&cfg).execute_vmm(&cfg, &t, 0, &plan(one));
        let runs1 = Channel::new(&cfg).execute_vmm(&cfg, &t, 0, &plan(single_run));
        let runs2 = Channel::new(&cfg).execute_vmm(&cfg, &t, 0, &plan(split));
        assert_eq!(base, runs1, "single run != Pattern");
        assert_eq!(base, runs2, "adjacent split != contiguous sweep");
        // Empty / all-zero run lists are idle, like a zero-rep Pattern.
        assert!(UnitWork::PatternRuns(vec![]).is_idle());
        assert!(UnitWork::PatternRuns(vec![PatternRun {
            base_row: 0,
            reps: 0,
            pattern,
            pattern_len: 2
        }])
        .is_idle());
    }

    #[test]
    fn prop_finish_monotonic_in_work() {
        check("channel finish grows with rows", 50, |rng| {
            let (cfg, t) = setup();
            let r1 = rng.usize_in(1, 8) as u32;
            let r2 = r1 + rng.usize_in(1, 8) as u32;
            let f1 = Channel::new(&cfg)
                .execute_vmm(&cfg, &t, 0, &uniform_plan(&cfg, r1, 1024, 64))
                .finish;
            let f2 = Channel::new(&cfg)
                .execute_vmm(&cfg, &t, 0, &uniform_plan(&cfg, r2, 1024, 64))
                .finish;
            if f2 > f1 { Ok(()) } else { Err(format!("{f2} <= {f1}")) }
        });
    }

    #[test]
    fn prop_wider_mac_never_slower() {
        check("wider MAC units never slower (Fig 15a)", 30, |rng| {
            let rows = rng.usize_in(1, 16) as u32;
            let (cfg16, t) = setup();
            let cfg64 = HwConfig::paper_baseline().with_mac_lanes(64);
            let f16 = Channel::new(&cfg16)
                .execute_vmm(&cfg16, &t, 0, &uniform_plan(&cfg16, rows, 1024, 64))
                .finish;
            let f64_ = Channel::new(&cfg64)
                .execute_vmm(&cfg64, &t, 0, &uniform_plan(&cfg64, rows, 1024, 64))
                .finish;
            if f64_ <= f16 { Ok(()) } else { Err(format!("{f64_} > {f16}")) }
        });
    }
}
