//! ASIC arithmetic: software bfloat16 and the add/mul-only approximation
//! algorithms of paper §III.D (Algorithms 1-2).
//!
//! These are the *functional* twins of the cycle models in `asic::engine`
//! and are mirrored bit-for-bit by `python/compile/kernels/asic_ops.py`
//! (shared golden-value tests keep the two locked). The rust side is used
//! by unit tests, failure-injection tests and the functional cross-checks
//! of the coordinator.

pub mod approx;
pub mod bf16;

pub use approx::{exp_taylor6, gelu_asic, layernorm_asic, reciprocal_nr, rsqrt_fast, softmax_asic, tanh_exp};
pub use bf16::Bf16;
