//! Software bfloat16: the datatype of every PIM-GPT tensor (paper §III.A —
//! "All data in PIM-GPT are in bfloat16 format"). bf16 is the 16 high bits
//! of an IEEE-754 f32; conversion rounds to nearest-even.

/// A bfloat16 value (bit pattern).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3F80);

    /// Round-to-nearest-even conversion from f32.
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            // Preserve NaN, force a quiet mantissa bit.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = 0x0000_8000u32;
        let lower = bits & 0xFFFF;
        let mut hi = (bits >> 16) as u16;
        // round to nearest, ties to even
        if lower > round_bit || (lower == round_bit && (hi & 1) == 1) {
            hi = hi.wrapping_add(1);
        }
        Bf16(hi)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// Machine epsilon of bf16 (2^-8).
    pub const EPSILON: f32 = 0.0078125;
}

impl From<f32> for Bf16 {
    fn from(v: f32) -> Self {
        Bf16::from_f32(v)
    }
}

impl From<Bf16> for f32 {
    fn from(v: Bf16) -> f32 {
        v.to_f32()
    }
}

/// Quantize an f32 slice through bf16 (storage precision of the PIM banks).
pub fn quantize_slice(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| Bf16::from_f32(x).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "{v}");
        }
    }

    #[test]
    fn one_has_known_bits() {
        assert_eq!(Bf16::from_f32(1.0), Bf16::ONE);
        assert_eq!(Bf16::from_f32(0.0), Bf16::ZERO);
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + eps/2 rounds down to 1.0 (tie -> even)
        let tie = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(tie).to_f32(), 1.0);
        // just above the tie rounds up
        let up = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(up).to_f32(), 1.0 + Bf16::EPSILON);
    }

    #[test]
    fn nan_and_inf_preserved() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn prop_relative_error_bounded() {
        check("bf16 rel error < eps", 1000, |rng| {
            let x = (rng.normal() as f32) * 100.0;
            if x == 0.0 {
                return Ok(());
            }
            let q = Bf16::from_f32(x).to_f32();
            let rel = ((q - x) / x).abs();
            if rel <= Bf16::EPSILON {
                Ok(())
            } else {
                Err(format!("x={x} q={q} rel={rel}"))
            }
        });
    }

    #[test]
    fn prop_roundtrip_idempotent() {
        check("bf16 quantization idempotent", 1000, |rng| {
            let x = (rng.normal() as f32) * 10.0;
            let q1 = Bf16::from_f32(x).to_f32();
            let q2 = Bf16::from_f32(q1).to_f32();
            if q1.to_bits() == q2.to_bits() {
                Ok(())
            } else {
                Err(format!("{x}: {q1} != {q2}"))
            }
        });
    }
}
