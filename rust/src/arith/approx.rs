//! Add/mul-only approximation algorithms (paper §III.D).
//!
//! * `exp_taylor6` — range-reduced 6-term Taylor series.
//! * `reciprocal_nr` — Algorithm 1, Newton-Raphson division.
//! * `rsqrt_fast` — Algorithm 2, Quake fast inverse square root.
//! * `tanh_exp` — tanh via the exp identity.
//! * vector ops `softmax_asic` / `layernorm_asic` / `gelu_asic` built on
//!   the scalar primitives, matching the ASIC engine dataflow.
//!
//! Mirrors `python/compile/kernels/asic_ops.py`; the golden-value tests at
//! the bottom replicate `test_asic_ops.py::test_golden_values_rust_mirror`.

const LN2: f32 = 0.693_147_18;
const INV_LN2: f32 = 1.442_695_04;
const EXP_COEF: [f32; 6] = [1.0, 1.0, 0.5, 1.0 / 6.0, 1.0 / 24.0, 1.0 / 120.0];

/// Range-reduced 6-term Taylor exp: x = k ln2 + r, e^x = 2^k * P(r).
pub fn exp_taylor6(x: f32) -> f32 {
    let x = x.clamp(-87.0, 87.0);
    let k = (x * INV_LN2).round();
    let r = x - k * LN2;
    // Horner (5 mul + 5 add), identical coefficient order to python.
    let mut p = EXP_COEF[5];
    for c in EXP_COEF[..5].iter().rev() {
        p = p * r + c;
    }
    // 2^k by exponent assembly.
    let biased = ((k + 127.0) as i32).clamp(1, 254);
    let two_k = f32::from_bits((biased as u32) << 23);
    p * two_k
}

/// Paper Algorithm 1: Newton-Raphson reciprocal.
/// D scaled into [0.5, 1) by exponent subtraction; X0 = 48/17 - 32/17 D';
/// `iters` quadratic refinement steps; rescale by the same exponent.
pub fn reciprocal_nr(d: f32, iters: u32) -> f32 {
    debug_assert!(d != 0.0 && d.is_finite());
    let sign = if d < 0.0 { -1.0f32 } else { 1.0 };
    let mag = d * sign;
    let bits = mag.to_bits() as i32;
    let e = ((bits >> 23) & 0xFF) - 127;
    let dp = f32::from_bits((bits - ((e + 1) << 23)) as u32); // in [0.5, 1)
    let mut x = 48.0 / 17.0 - (32.0 / 17.0) * dp;
    for _ in 0..iters {
        x = x + x * (1.0 - dp * x);
    }
    let xbits = x.to_bits() as i32;
    f32::from_bits((xbits - ((e + 1) << 23)) as u32) * sign
}

/// Paper Algorithm 2: Quake fast inverse square root, `iters` NR steps.
pub fn rsqrt_fast(d: f32, iters: u32) -> f32 {
    debug_assert!(d > 0.0);
    let half = 0.5 * d;
    let mut x = f32::from_bits(0x5F37_59DF - (d.to_bits() >> 1));
    for _ in 0..iters {
        x = x * (1.5 - half * x * x);
    }
    x
}

/// tanh via exp identity: 1 - 2 / (e^{2x} + 1).
pub fn tanh_exp(x: f32) -> f32 {
    let xc = x.clamp(-9.0, 9.0);
    let e2x = exp_taylor6(2.0 * xc);
    1.0 - 2.0 * reciprocal_nr(e2x + 1.0, 3)
}

/// Masked softmax with ASIC arithmetic (max-subtract, Taylor exp,
/// adder-tree sum, NR reciprocal). In-place over `xs[..n_valid]`; entries
/// at and beyond `n_valid` are zeroed.
pub fn softmax_asic(xs: &mut [f32], n_valid: usize) {
    assert!(n_valid > 0 && n_valid <= xs.len());
    let m = xs[..n_valid].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in xs[..n_valid].iter_mut() {
        *v = exp_taylor6(*v - m);
        sum += *v;
    }
    let inv = reciprocal_nr(sum, 3);
    for v in xs[..n_valid].iter_mut() {
        *v *= inv;
    }
    for v in xs[n_valid..].iter_mut() {
        *v = 0.0;
    }
}

/// LayerNorm with ASIC arithmetic (1/n constant multiplies + Algorithm 2).
pub fn layernorm_asic(xs: &[f32], gamma: &[f32], beta: &[f32], eps: f32) -> Vec<f32> {
    let n = xs.len();
    assert!(n > 0 && gamma.len() == n && beta.len() == n);
    let inv_n = 1.0 / n as f32; // compile-time constant in hardware
    let mu: f32 = xs.iter().sum::<f32>() * inv_n;
    let var: f32 = xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() * inv_n;
    let rs = rsqrt_fast(var + eps, 2);
    xs.iter()
        .zip(gamma.iter().zip(beta))
        .map(|(&x, (&g, &b))| (x - mu) * rs * g + b)
        .collect()
}

/// Paper Eq. 4 GELU with the ASIC tanh.
pub fn gelu_asic(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + tanh_exp(C * (x + 0.044715 * x * x * x)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn rel(a: f32, b: f32) -> f32 {
        ((a - b) / b).abs()
    }

    // --- golden values mirrored from python test_asic_ops.py ---
    #[test]
    fn golden_values_python_mirror() {
        assert!(rel(reciprocal_nr(1.0, 3), 1.0) < 1e-5);
        assert!(rel(reciprocal_nr(2.0, 3), 0.5) < 1e-5);
        assert!(rel(reciprocal_nr(0.25, 3), 4.0) < 1e-5);
        assert!(rel(reciprocal_nr(3.0, 3), 0.333_333_3) < 1e-5);
        assert!(rel(rsqrt_fast(1.0, 2), 1.0) < 5e-5);
        assert!(rel(rsqrt_fast(4.0, 2), 0.5) < 5e-5);
        assert!(rel(rsqrt_fast(0.25, 2), 2.0) < 5e-5);
        assert!(rel(rsqrt_fast(2.0, 2), 0.707_106_78) < 5e-5);
        assert!(rel(exp_taylor6(-1.0), 0.367_879_44) < 1e-5);
        assert!(rel(tanh_exp(0.5), 0.462_117_16) < 1e-4);
    }

    #[test]
    fn prop_exp_matches_libm() {
        check("exp_taylor6 rel error", 500, |rng| {
            let x = (rng.f64() * 90.0 - 80.0) as f32;
            let got = exp_taylor6(x);
            let want = x.exp();
            let r = rel(got, want);
            if r < 1e-5 { Ok(()) } else { Err(format!("x={x} rel={r}")) }
        });
    }

    #[test]
    fn prop_reciprocal_matches() {
        check("reciprocal_nr rel error", 500, |rng| {
            let mag = 10f32.powf((rng.f64() * 40.0 - 20.0) as f32);
            let x = if rng.bool() { mag } else { -mag };
            let r = rel(reciprocal_nr(x, 3), 1.0 / x);
            if r < 1e-5 { Ok(()) } else { Err(format!("x={x} rel={r}")) }
        });
    }

    #[test]
    fn prop_rsqrt_matches() {
        check("rsqrt_fast rel error", 500, |rng| {
            let x = 10f32.powf((rng.f64() * 60.0 - 30.0) as f32);
            let r = rel(rsqrt_fast(x, 2), 1.0 / x.sqrt());
            if r < 5e-5 { Ok(()) } else { Err(format!("x={x} rel={r}")) }
        });
    }

    #[test]
    fn prop_tanh_abs_error() {
        check("tanh_exp abs error", 500, |rng| {
            let x = (rng.f64() * 100.0 - 50.0) as f32;
            let err = (tanh_exp(x) - x.tanh()).abs();
            if err < 2e-6 { Ok(()) } else { Err(format!("x={x} err={err}")) }
        });
    }

    #[test]
    fn softmax_normalizes_and_masks() {
        let mut xs = vec![1.0, 2.0, 3.0, 99.0, 99.0];
        softmax_asic(&mut xs, 3);
        let sum: f32 = xs[..3].iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "{sum}");
        assert_eq!(&xs[3..], &[0.0, 0.0]);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn prop_softmax_matches_exact() {
        check("softmax_asic vs exact", 200, |rng| {
            let n = rng.usize_in(1, 64);
            let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 4.0) as f32).collect();
            let mut got = xs.clone();
            softmax_asic(&mut got, n);
            // exact
            let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let es: Vec<f32> = xs.iter().map(|x| (x - m).exp()).collect();
            let s: f32 = es.iter().sum();
            for (g, e) in got.iter().zip(es.iter()) {
                if (g - e / s).abs() > 1e-5 {
                    return Err(format!("n={n} {g} vs {}", e / s));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn layernorm_matches_exact() {
        check("layernorm_asic vs exact", 200, |rng| {
            let n = rng.usize_in(2, 256);
            let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0 + 0.5) as f32).collect();
            let gamma = vec![1.0f32; n];
            let beta = vec![0.0f32; n];
            let got = layernorm_asic(&xs, &gamma, &beta, 1e-5);
            let mu = xs.iter().sum::<f32>() / n as f32;
            let var = xs.iter().map(|x| (x - mu).powi(2)).sum::<f32>() / n as f32;
            for (g, x) in got.iter().zip(xs.iter()) {
                let want = (x - mu) / (var + 1e-5).sqrt();
                if (g - want).abs() > 5e-4 {
                    return Err(format!("n={n} got {g} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gelu_matches_reference() {
        check("gelu_asic vs tanh reference", 300, |rng| {
            let x = (rng.f64() * 20.0 - 10.0) as f32;
            let c = (2.0f32 / std::f32::consts::PI).sqrt();
            let want = 0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh());
            let got = gelu_asic(x);
            if (got - want).abs() < 1e-5 * want.abs().max(1.0) {
                Ok(())
            } else {
                Err(format!("x={x} got={got} want={want}"))
            }
        });
    }
}
