//! pim-gpt CLI: the system launcher.
//!
//! ```text
//! pim-gpt info [--config FILE]
//! pim-gpt simulate --model NAME [--tokens N] [--config FILE] [--json]
//! pim-gpt figures [--fig ID] [--tokens N]
//! pim-gpt generate --model NAME [--artifacts DIR] [--prompt 1,2,3] [--n N]
//! pim-gpt serve --model NAME [--requests N] [--concurrency K] [--arrivals SPEC]
//!               [--seed N] [--artifacts DIR]
//! ```
//!
//! (Arg parsing is hand-rolled — clap is unavailable offline, DESIGN.md §5.)

use std::path::Path;

use anyhow::{anyhow, bail, Result};
use pim_gpt::config::HwConfig;
use pim_gpt::coordinator::{PimGptSystem, Request, Server};
use pim_gpt::energy::SystemEnergy;
use pim_gpt::model::gpt::by_name;
use pim_gpt::report;
use pim_gpt::sim::arrivals::{self, ArrivalSpec};
use pim_gpt::sim::Simulator;
use pim_gpt::util::table::fmt_time_s;

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument '{a}'");
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

fn load_config(args: &Args) -> Result<HwConfig> {
    match args.get("config") {
        Some(path) => HwConfig::load(path),
        None => Ok(HwConfig::paper_baseline()),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(argv.get(1..).unwrap_or(&[]))?;
    match cmd {
        "info" => cmd_info(&args),
        "simulate" => cmd_simulate(&args),
        "figures" => cmd_figures(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try 'pim-gpt help')"),
    }
}

const HELP: &str = "\
pim-gpt — hybrid process-in-memory accelerator for autoregressive transformers

USAGE:
  pim-gpt info     [--config FILE]
  pim-gpt simulate --model NAME [--tokens N] [--config FILE] [--json]
  pim-gpt figures  [--fig 1|8|10|11|12|13|14|15|t1|t2|serving|all] [--tokens N]
  pim-gpt generate --model gpt-nano|gpt-mini [--artifacts DIR] [--prompt 1,2,3] [--n N]
  pim-gpt serve    --model NAME [--requests N] [--concurrency K] [--arrivals SPEC]
                   [--seed N] [--artifacts DIR]

ARRIVALS (open-loop serving; latencies report p50/p95/p99 from arrival):
  batch (default) | fixed:<cycles> | poisson:<req/s> | trace:<file.json>
  trace schema: {\"requests\": [{\"arrival_cycle\": 0, \"n_tokens\": 16}, ...]}
  (functional-artifact serving is FIFO and ignores arrival stamps)

MODELS: gpt2-small|medium|large|xl, gpt3-small|medium|large|xl (timing),
        gpt-nano, gpt-mini (functional artifacts in artifacts/)
";

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!("pim-gpt {}", pim_gpt::VERSION);
    let t1 = report::table1_config(&cfg);
    println!("\n{}\n{}", t1.title, t1.rendered);
    let f1 = report::fig1_model_zoo();
    println!("{}\n{}", f1.title, f1.rendered);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let model = by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'"))?;
    let tokens = args.u64_or("tokens", 64)?;
    let cfg = load_config(args)?;
    let mut sim = Simulator::new(&model, &cfg)?;
    let wall0 = std::time::Instant::now();
    sim.generate(tokens)?;
    sim.finalize_stats();
    let energy = SystemEnergy::from_sim(&sim);
    let s = &sim.stats;
    let secs = s.seconds(cfg.gddr6.freq_ghz);
    if args.get("json").is_some() {
        use pim_gpt::util::json::Json;
        let j = Json::obj(vec![
            ("model", name.into()),
            ("tokens", tokens.into()),
            ("sim_seconds", secs.into()),
            ("sim_us_per_token", (secs * 1e6 / tokens as f64).into()),
            ("energy_j", energy.total_j().into()),
            ("row_hit_rate", s.row_hit_rate().into()),
            ("bytes_moved", s.bytes_moved().into()),
            ("vmm_fraction", s.vmm_fraction().into()),
            ("instructions", s.instructions.into()),
        ]);
        println!("{j}");
    } else {
        println!("model            : {name} ({} params)", model.n_params());
        println!("tokens           : {tokens}");
        println!(
            "simulated time   : {} ({} / token)",
            fmt_time_s(secs),
            fmt_time_s(secs / tokens as f64)
        );
        println!(
            "energy           : {} ({} / token)",
            pim_gpt::util::table::fmt_energy_j(energy.total_j()),
            pim_gpt::util::table::fmt_energy_j(energy.total_j() / tokens as f64)
        );
        println!("row hit rate     : {:.2}%", 100.0 * s.row_hit_rate());
        println!("PIM<->ASIC bytes : {:.1} MB", s.bytes_moved() as f64 / 1e6);
        println!("vmm share        : {:.1}%", 100.0 * s.vmm_fraction());
        println!("instructions     : {}", s.instructions);
        println!("wall time        : {}", fmt_time_s(wall0.elapsed().as_secs_f64()));
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args.get("fig").unwrap_or("all");
    let tokens = args.u64_or("tokens", 64)?;
    let mut reports = Vec::new();
    let all = which == "all";
    if all || which == "1" {
        reports.push(report::fig1_model_zoo());
    }
    if all || which == "t1" {
        reports.push(report::table1_config(&HwConfig::paper_baseline()));
    }
    if all || which == "8" || which == "9" {
        reports.push(report::fig8_9_speedup_energy(tokens)?);
    }
    if all || which == "10" {
        reports.push(report::fig10_breakdown(tokens)?);
    }
    if all || which == "11" {
        reports.push(report::fig11_locality(tokens)?);
    }
    if all || which == "12" {
        reports.push(report::fig12_asic_freq(tokens.min(16))?);
    }
    if all || which == "13" {
        reports.push(report::fig13_bandwidth(tokens.min(16))?);
    }
    if all || which == "14" {
        reports.push(report::fig14_long_token(&[1024, 2048, 4096, 8096])?);
    }
    if all || which == "15" {
        reports.push(report::fig15_scalability(tokens.min(16))?);
    }
    if all || which == "t2" {
        reports.push(report::table2_comparison(tokens)?);
    }
    if all || which == "serving" {
        reports.push(report::fig_serving_tail_latency(6, 4, &[0.5, 1.0, 2.0], 7)?);
    }
    if reports.is_empty() {
        bail!("unknown figure '{which}'");
    }
    for r in reports {
        println!("{}\n{}", r.title, r.rendered);
    }
    Ok(())
}

fn parse_prompt(s: &str) -> Result<Vec<i32>> {
    s.split(',')
        .map(|t| t.trim().parse::<i32>().map_err(|_| anyhow!("bad token '{t}'")))
        .collect()
}

fn cmd_generate(args: &Args) -> Result<()> {
    let name = args.get("model").unwrap_or("gpt-nano");
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let prompt = parse_prompt(args.get("prompt").unwrap_or("1,2,3"))?;
    let n = args.u64_or("n", 16)? as usize;
    let cfg = load_config(args)?;
    let mut sys = PimGptSystem::with_artifact(name, Path::new(dir), &cfg)?;
    let r = sys.generate(&prompt, n)?;
    println!("tokens           : {:?}", r.tokens);
    println!(
        "simulated        : {} ({} / token)",
        fmt_time_s(r.sim_seconds),
        fmt_time_s(r.sim_seconds_per_token)
    );
    println!("simulated energy : {}", pim_gpt::util::table::fmt_energy_j(r.sim_energy_j));
    println!("functional wall  : {}", fmt_time_s(r.wall_seconds));
    println!("row hit rate     : {:.2}%", 100.0 * r.row_hit_rate);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.get("model").unwrap_or("gpt-nano");
    let mut cfg = load_config(args)?;
    if let Some(k) = args.get("concurrency") {
        let k: usize = k.parse().map_err(|_| anyhow!("--concurrency must be an integer"))?;
        if k == 0 {
            bail!("--concurrency must be >= 1");
        }
        cfg.sched.max_streams = k;
    }
    if let Some(spec) = args.get("arrivals") {
        cfg.sched.arrival = ArrivalSpec::parse(spec)?;
    }
    if let Some(seed) = args.get("seed") {
        cfg.sched.seed = seed.parse().map_err(|_| anyhow!("--seed must be an integer"))?;
    }
    // Build the whole request trace up front: arrivals are *simulated*
    // cycles, so the set is known before serving starts. The worker is
    // gated on a barrier until every request is submitted, so the
    // replay never races ingestion against simulated time — identical
    // seeds give identical percentiles.
    let requests: Vec<Request> = match cfg.sched.arrival.clone() {
        ArrivalSpec::Trace { path } => {
            if args.get("requests").is_some() {
                bail!("--requests conflicts with trace arrivals: the trace defines the requests");
            }
            arrivals::load_trace(&path)?
                .iter()
                .enumerate()
                .map(|(id, t)| Request {
                    id: id as u64,
                    prompt: vec![1],
                    n_new: (t.n_tokens - 1) as usize,
                    arrival_cycle: t.arrival_cycle,
                })
                .collect()
        }
        spec => {
            let n = args.u64_or("requests", 8)? as usize;
            let cycles = arrivals::generate(&spec, n, cfg.gddr6.freq_ghz, cfg.sched.seed)?;
            cycles
                .iter()
                .enumerate()
                .map(|(id, &arrival_cycle)| Request {
                    id: id as u64,
                    prompt: vec![1, 2, 3, (id % 17) as i32],
                    n_new: 12,
                    arrival_cycle,
                })
                .collect()
        }
    };
    let n_requests = requests.len() as u64;
    let dir = Path::new(args.get("artifacts").unwrap_or("artifacts"));
    let use_artifact = by_name(name).map(|m| m.max_seq <= 512).unwrap_or(false)
        && dir.join(format!("{name}.meta.json")).exists();
    let functional = use_artifact;
    if functional && cfg.sched.arrival != ArrivalSpec::Batch {
        eprintln!(
            "pim-gpt serve: functional artifact serving is FIFO and ignores --arrivals \
             {} (no latency percentiles will be reported)",
            cfg.sched.arrival
        );
    }
    let name_owned = name.to_string();
    let dir_owned = dir.to_path_buf();
    let cfg_owned = cfg.clone();
    // Determinism barrier: the worker must not ingest (or step) until
    // the whole trace sits in the channel — otherwise a fast mapping
    // build could let simulated time warp past not-yet-submitted
    // arrivals and the percentiles would depend on thread timing.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let mut server = Server::start(move || {
        let _ = ready_rx.recv();
        if use_artifact {
            PimGptSystem::with_artifact(&name_owned, &dir_owned, &cfg_owned)
        } else {
            let m = by_name(&name_owned)
                .ok_or_else(|| anyhow!("unknown model '{name_owned}'"))?;
            PimGptSystem::timing_only(&m, &cfg_owned)
        }
    });
    for req in requests {
        server.submit(req)?;
    }
    let _ = ready_tx.send(());
    for _ in 0..n_requests {
        let r = server.recv()?;
        match r.error {
            None => println!(
                "req {:>3}: {} tokens, sim {} (+{} queue), wall {}",
                r.id,
                r.tokens.len(),
                fmt_time_s(r.sim_seconds),
                fmt_time_s(r.sim_queue_seconds),
                fmt_time_s(r.wall_seconds),
            ),
            Some(e) => println!("req {:>3}: ERROR {e}", r.id),
        }
    }
    let m = server.shutdown();
    // Functional (artifact) serving is FIFO regardless of --concurrency:
    // the PJRT decode is one-token-at-a-time against a single KV cache.
    let k_served = if functional { 1 } else { cfg.sched.max_streams };
    println!(
        "\nserved {} requests ({} tokens), functional={functional}, K={k_served}, \
         simulated makespan {}, throughput {:.0} tok/s",
        m.requests,
        m.tokens,
        fmt_time_s(m.sim_makespan_seconds),
        m.sim_tokens_per_s()
    );
    // KV-capacity admission stats: fewer slots than K means the mapping
    // degraded (DRAM rows could not hold K disjoint contexts).
    // admission_blocked sums queued requests over admission attempts
    // (queue-depth-weighted pressure), not distinct blocked requests.
    println!(
        "kv slots {} (peak in use {}), admission-blocked pressure {} request-attempts",
        m.kv_slots, m.peak_slots_in_use, m.admission_blocked
    );
    // Open-loop tail latency, measured from each request's arrival.
    if let Some(lat) = m.latency {
        let t = |cycles: u64| fmt_time_s(cycles as f64 / (cfg.gddr6.freq_ghz * 1e9));
        println!("arrivals {} (seed {})", cfg.sched.arrival, cfg.sched.seed);
        println!("latency (simulated)   p50 / p95 / p99");
        println!("  queue     {} / {} / {}", t(lat.queue.p50), t(lat.queue.p95), t(lat.queue.p99));
        println!("  ttft      {} / {} / {}", t(lat.ttft.p50), t(lat.ttft.p95), t(lat.ttft.p99));
        println!("  e2e       {} / {} / {}", t(lat.e2e.p50), t(lat.e2e.p95), t(lat.e2e.p99));
    }
    Ok(())
}
