//! pim-gpt CLI: the system launcher.
//!
//! ```text
//! pim-gpt info [--config FILE]
//! pim-gpt simulate --model NAME [--tokens N] [--config FILE] [--json]
//! pim-gpt figures [--fig ID] [--tokens N]
//! pim-gpt generate --model NAME [--artifacts DIR] [--prompt 1,2,3] [--n N]
//! pim-gpt serve --model NAME [--requests N] [--concurrency K] [--arrivals SPEC]
//!               [--policy SPEC] [--seed N] [--prompt-tokens P] [--artifacts DIR]
//! pim-gpt profile --model NAME [--json FILE] [--from-jsonl FILE]
//! pim-gpt profile --calibrate [--models A,B] [--json FILE]
//! ```
//!
//! (Arg parsing is hand-rolled — clap is unavailable offline, DESIGN.md
//! §5. Flags take `--key value` or `--key=value`; the `=` form is the
//! escape hatch for values that themselves start with `--`, and a
//! valued flag left bare fails loudly instead of being silently
//! swallowed as a boolean.)

use std::path::Path;

use anyhow::{anyhow, bail, Result};
use pim_gpt::config::HwConfig;
use pim_gpt::coordinator::{PimGptSystem, Request, Server};
use pim_gpt::energy::SystemEnergy;
use pim_gpt::model::gpt::by_name;
use pim_gpt::report;
use pim_gpt::sim::arrivals::{self, ArrivalSpec};
use pim_gpt::sim::{
    calibrate, validate_chrome, FleetSim, Profile, ProfileSink, ProfileSpec, Simulator,
    StreamSpec, TraceSpec,
};
use pim_gpt::util::json::Json;
use pim_gpt::util::table::fmt_time_s;

/// A parsed flag: bare (`--json`) or valued (`--tokens 64`,
/// `--tokens=64`). Keeping the two shapes distinct is what lets `get`
/// reject the classic silent-swallow bug (`--arrivals --seed 5` turning
/// `--arrivals` into a boolean) with a clear error instead.
#[derive(Clone, Debug, PartialEq, Eq)]
enum ArgVal {
    Bare,
    Value(String),
}

/// Minimal flag parser: `--key value` / `--key=value` pairs (plus bare
/// `--key` switches) after the subcommand.
struct Args {
    flags: std::collections::BTreeMap<String, ArgVal>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(body) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}' (flags are --key value, --key=value or --key)");
            };
            if body.is_empty() {
                bail!("bare '--' is not a flag");
            }
            // `--key=value` binds unambiguously, so it is the escape
            // hatch for values that themselves start with '--'.
            let (key, val) = match body.split_once('=') {
                Some((k, v)) => {
                    if k.is_empty() {
                        bail!("missing flag name in '{a}'");
                    }
                    if v.is_empty() {
                        bail!("empty value in '{a}' (drop the '=' for a bare switch)");
                    }
                    (k, ArgVal::Value(v.to_string()))
                }
                None => {
                    // Space-separated form: the next token is this
                    // flag's value unless it is itself a flag. A value
                    // that legitimately starts with '--' must use
                    // --key=value; a negative number ('-5') is fine
                    // here.
                    if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                        i += 1;
                        (body, ArgVal::Value(argv[i].clone()))
                    } else {
                        (body, ArgVal::Bare)
                    }
                }
            };
            if flags.insert(key.to_string(), val).is_some() {
                bail!("duplicate flag --{key}");
            }
            i += 1;
        }
        Ok(Self { flags })
    }

    /// The value of `--key`. A bare `--key` (including the ambiguous
    /// `--key --next ...` form that used to be silently swallowed as a
    /// boolean) is a loud error, because the caller expects a value.
    fn get(&self, key: &str) -> Result<Option<&str>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(ArgVal::Value(v)) => Ok(Some(v.as_str())),
            Some(ArgVal::Bare) => bail!(
                "--{key} needs a value (write --{key}=<value> if the value starts with '--')"
            ),
        }
    }

    /// Whether `--key` appeared at all (bare switches like `--json`).
    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Reject flags the command does not know. Without this a typo'd
    /// flag (`--polcy srf`) would be parsed, stored, never read — and
    /// the run would silently proceed with defaults, corrupting the
    /// experiment the same way a typo'd JSON config key used to.
    fn expect_only(&self, cmd: &str, allowed: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} for '{cmd}' (accepted: {})",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
                );
            }
        }
        Ok(())
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key)? {
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

fn load_config(args: &Args) -> Result<HwConfig> {
    match args.get("config")? {
        Some(path) => HwConfig::load(path),
        None => Ok(HwConfig::paper_baseline()),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(argv.get(1..).unwrap_or(&[]))?;
    match cmd {
        "info" => cmd_info(&args),
        "simulate" => cmd_simulate(&args),
        "figures" => cmd_figures(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try 'pim-gpt help')"),
    }
}

const HELP: &str = "\
pim-gpt — hybrid process-in-memory accelerator for autoregressive transformers

USAGE:
  pim-gpt info     [--config FILE]
  pim-gpt simulate --model NAME [--tokens N] [--config FILE] [--json]
  pim-gpt figures  [--fig 1|8|10|11|12|13|14|15|t1|t2|serving|policies|prefill|batching|
                    paging|sharding|timeline|profile|all] [--tokens N] [--models A,B]
  pim-gpt generate --model gpt-nano|gpt-mini [--artifacts DIR] [--prompt 1,2,3] [--n N]
  pim-gpt serve    --model NAME [--requests N] [--concurrency K] [--arrivals SPEC]
                   [--policy SPEC] [--seed N] [--prompt-tokens P] [--batch-decode on|off]
                   [--kv-paging on|off] [--trace SPEC] [--profile SPEC]
                   [--metrics-json FILE] [--artifacts DIR]
  pim-gpt profile  --model NAME [--requests N] [--prompt-tokens P] [--gen-tokens G]
                   [--concurrency K] [--batch-decode on|off] [--kv-paging on|off]
                   [--seed N] [--config FILE] [--json FILE] [--from-jsonl FILE]
  pim-gpt profile  --calibrate [--models A,B] [--requests N] [--seed N] [--json FILE]

ARRIVALS (open-loop serving; latencies report p50/p95/p99 from arrival):
  batch (default) | fixed:<cycles> | poisson:<req/s> | trace:<file.json>
  trace schema: {\"requests\": [{\"arrival_cycle\": 0, \"n_tokens\": 16,
                 \"prompt_tokens\": 8}, ...]} (prompt_tokens optional, default 1,
                 counted inside n_tokens)
  (functional-artifact serving is FIFO and ignores arrival stamps)

PREFILL (prompts run as batched chunk programs; sched.prefill_chunk in --config):
  --prompt-tokens P gives every generated request a P-token prompt; TTFT is the
  first *generated* token (prompt prefill completion). Chunked prefill amortizes
  DRAM row activations over the chunk — see figures --fig prefill.

BATCHED DECODE (sched.batch_decode in --config, or serve --batch-decode on):
  fuses the ready decode tokens of concurrent streams into one multi-pass
  weight sweep (continuous batching): one ACT/PRE sweep + one ASIC pipeline
  fill serve K streams. off (default) is cycle-identical to the unbatched
  engine; see figures --fig batching (--models filters the model sweep).

PAGED KV (sched.kv_paging in --config, or serve --kv-paging on):
  carves the KV row budget into fixed-size pages (sched.kv_page_tokens) behind
  per-stream page tables: admission commits *expected* footprint (oversubscribe
  with sched.kv_oversub > 1), pages allocate on demand as decode advances, and
  an exhausted pool preempts a victim stream (context written back, re-queued).
  off (default) is cycle-identical to the static-slot engine; see figures
  --fig paging.

MULTI-DEVICE SHARDING (sched.devices / sched.partition in --config):
  partitions a model across N PIM packages — layer_pipeline (contiguous layer
  ranges, activations hop stage to stage) or tensor_parallel (Megatron-style
  head/FFN-column shards, two all-reduces per layer + an LM-head gather) —
  with interconnect modeled from sched.link_gbit_s / sched.link_hop_cycles
  and charged explicitly. devices = 1 (default) is cycle-identical to the
  single-package engine; see figures --fig sharding.

TRACING (sched.trace / sched.trace_window in --config, or serve --trace SPEC):
  SPEC = off | jsonl:<path> | chrome:<path>. Records every lifecycle edge
  (submit/release/admit/reject, prefill chunks, decode steps, fused sweeps,
  page faults, evictions, writebacks/restores, retires, link transfers) as a
  JSONL event log or a Perfetto-loadable Chrome trace (streams = tracks).
  Deterministic and observer-effect-free: tracing never changes a simulated
  cycle. sched.trace_window > 0 additionally bins a busy/idle/link/pages
  utilization timeline into the stats — see figures --fig timeline.
  serve --metrics-json FILE dumps the full aggregate ServerMetrics as JSON.

PROFILING (sched.profile in --config, or serve --profile SPEC; pim-gpt profile):
  SPEC = off | text:<path> | json:<path> (a bare serve --profile path means
  json:). Aggregates the trace stream online — no event log needed — into a
  hierarchical cycle-attribution tree (phase x position-regime x decode-batch
  occupancy x device; leaf sums + residual reconcile exactly against busy
  cycles), log-bucketed span-latency histograms (p50/p95/p99 per class) and a
  per-span CostTable whose predict() estimates a request's cycles without
  simulating it. `pim-gpt profile --calibrate` cross-validates those
  predictions against the cycle-accurate engine and reports mean/max relative
  error per model. `pim-gpt profile --from-jsonl FILE` replays a recorded
  jsonl: trace through the same aggregation. sched.strict_reconcile = 1
  extends trace/stats reconciliation to release builds, surfacing mismatches
  as a structured ServerMetrics error instead of a debug panic.

POLICY (scheduling; sched.policy / sched.slo_ttft_cycles in --config):
  fcfs (default) | srf | fair | slo[:<ttft-cycles>]
  slo sheds requests whose predicted TTFT busts the budget; they come
  back as first-class REJECTED responses, not errors

MODELS: gpt2-small|medium|large|xl, gpt3-small|medium|large|xl (timing),
        gpt-nano, gpt-mini (functional artifacts in artifacts/)
";

fn cmd_info(args: &Args) -> Result<()> {
    args.expect_only("info", &["config"])?;
    let cfg = load_config(args)?;
    println!("pim-gpt {}", pim_gpt::VERSION);
    let t1 = report::table1_config(&cfg);
    println!("\n{}\n{}", t1.title, t1.rendered);
    let f1 = report::fig1_model_zoo();
    println!("{}\n{}", f1.title, f1.rendered);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.expect_only("simulate", &["model", "tokens", "config", "json"])?;
    let name = args.get("model")?.ok_or_else(|| anyhow!("--model required"))?;
    let model = by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'"))?;
    let tokens = args.u64_or("tokens", 64)?;
    let cfg = load_config(args)?;
    let mut sim = Simulator::new(&model, &cfg)?;
    let wall0 = std::time::Instant::now();
    sim.generate(tokens)?;
    sim.finalize_stats();
    let energy = SystemEnergy::from_sim(&sim);
    let s = &sim.stats;
    let secs = s.seconds(cfg.gddr6.freq_ghz);
    if args.has("json") {
        let j = Json::obj(vec![
            ("model", name.into()),
            ("tokens", tokens.into()),
            ("sim_seconds", secs.into()),
            ("sim_us_per_token", (secs * 1e6 / tokens as f64).into()),
            ("energy_j", energy.total_j().into()),
            ("row_hit_rate", s.row_hit_rate().into()),
            ("bytes_moved", s.bytes_moved().into()),
            ("vmm_fraction", s.vmm_fraction().into()),
            ("instructions", s.instructions.into()),
        ]);
        println!("{j}");
    } else {
        println!("model            : {name} ({} params)", model.n_params());
        println!("tokens           : {tokens}");
        println!(
            "simulated time   : {} ({} / token)",
            fmt_time_s(secs),
            fmt_time_s(secs / tokens as f64)
        );
        println!(
            "energy           : {} ({} / token)",
            pim_gpt::util::table::fmt_energy_j(energy.total_j()),
            pim_gpt::util::table::fmt_energy_j(energy.total_j() / tokens as f64)
        );
        println!("row hit rate     : {:.2}%", 100.0 * s.row_hit_rate());
        println!("PIM<->ASIC bytes : {:.1} MB", s.bytes_moved() as f64 / 1e6);
        println!("vmm share        : {:.1}%", 100.0 * s.vmm_fraction());
        println!("instructions     : {}", s.instructions);
        println!("wall time        : {}", fmt_time_s(wall0.elapsed().as_secs_f64()));
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    args.expect_only("figures", &["fig", "tokens", "models"])?;
    let which = args.get("fig")?.unwrap_or("all");
    let tokens = args.u64_or("tokens", 64)?;
    // Optional model filter (comma-separated), consumed by the figures
    // that sweep the paper zoo; empty = all 8 paper models.
    let models: Vec<String> = match args.get("models")? {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => Vec::new(),
    };
    let mut reports = Vec::new();
    let all = which == "all";
    if all || which == "1" {
        reports.push(report::fig1_model_zoo());
    }
    if all || which == "t1" {
        reports.push(report::table1_config(&HwConfig::paper_baseline()));
    }
    if all || which == "8" || which == "9" {
        reports.push(report::fig8_9_speedup_energy(tokens)?);
    }
    if all || which == "10" {
        reports.push(report::fig10_breakdown(tokens)?);
    }
    if all || which == "11" {
        reports.push(report::fig11_locality(tokens)?);
    }
    if all || which == "12" {
        reports.push(report::fig12_asic_freq(tokens.min(16))?);
    }
    if all || which == "13" {
        reports.push(report::fig13_bandwidth(tokens.min(16))?);
    }
    if all || which == "14" {
        reports.push(report::fig14_long_token(&[1024, 2048, 4096, 8096])?);
    }
    if all || which == "15" {
        reports.push(report::fig15_scalability(tokens.min(16))?);
    }
    if all || which == "t2" {
        reports.push(report::table2_comparison(tokens)?);
    }
    if all || which == "serving" {
        reports.push(report::fig_serving_tail_latency(6, 4, &[0.5, 1.0, 2.0], 7)?);
    }
    if all || which == "policies" {
        reports.push(report::fig_policy_comparison(6, 4, 1.5, 7)?);
    }
    if all || which == "prefill" {
        reports.push(report::fig_prefill(8, &[1, 8, 32, 128], &[64, 256])?);
    }
    if all || which == "batching" {
        reports.push(report::fig_batching(tokens.min(12), &[1, 2, 4], &models)?);
    }
    if all || which == "paging" {
        reports.push(report::fig_paging(tokens.min(8), &models)?);
    }
    if all || which == "sharding" {
        reports.push(report::fig_sharding(tokens.min(8), &models)?);
    }
    if all || which == "timeline" {
        reports.push(report::fig_timeline(tokens.min(8), &models)?);
    }
    if all || which == "profile" {
        reports.push(report::fig_profile(tokens.min(8), &models)?);
    }
    if reports.is_empty() {
        bail!("unknown figure '{which}'");
    }
    for r in reports {
        println!("{}\n{}", r.title, r.rendered);
    }
    Ok(())
}

fn parse_prompt(s: &str) -> Result<Vec<i32>> {
    s.split(',')
        .map(|t| t.trim().parse::<i32>().map_err(|_| anyhow!("bad token '{t}'")))
        .collect()
}

fn cmd_generate(args: &Args) -> Result<()> {
    args.expect_only("generate", &["model", "artifacts", "prompt", "n", "config"])?;
    let name = args.get("model")?.unwrap_or("gpt-nano");
    let dir = args.get("artifacts")?.unwrap_or("artifacts");
    let prompt = parse_prompt(args.get("prompt")?.unwrap_or("1,2,3"))?;
    let n = args.u64_or("n", 16)? as usize;
    let cfg = load_config(args)?;
    let mut sys = PimGptSystem::with_artifact(name, Path::new(dir), &cfg)?;
    let r = sys.generate(&prompt, n)?;
    println!("tokens           : {:?}", r.tokens);
    println!(
        "simulated        : {} ({} / token)",
        fmt_time_s(r.sim_seconds),
        fmt_time_s(r.sim_seconds_per_token)
    );
    println!("simulated energy : {}", pim_gpt::util::table::fmt_energy_j(r.sim_energy_j));
    println!("functional wall  : {}", fmt_time_s(r.wall_seconds));
    println!("row hit rate     : {:.2}%", 100.0 * r.row_hit_rate);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only(
        "serve",
        &[
            "model",
            "requests",
            "concurrency",
            "arrivals",
            "seed",
            "policy",
            "prompt-tokens",
            "batch-decode",
            "kv-paging",
            "trace",
            "profile",
            "metrics-json",
            "artifacts",
            "config",
        ],
    )?;
    let name = args.get("model")?.unwrap_or("gpt-nano");
    let mut cfg = load_config(args)?;
    if let Some(k) = args.get("concurrency")? {
        let k: usize = k.parse().map_err(|_| anyhow!("--concurrency must be an integer"))?;
        if k == 0 {
            bail!("--concurrency must be >= 1");
        }
        cfg.sched.max_streams = k;
    }
    if let Some(spec) = args.get("arrivals")? {
        cfg.sched.arrival = ArrivalSpec::parse(spec)?;
    }
    if let Some(seed) = args.get("seed")? {
        cfg.sched.seed = seed.parse().map_err(|_| anyhow!("--seed must be an integer"))?;
    }
    if let Some(policy) = args.get("policy")? {
        cfg.sched.set_policy_str(policy)?;
    }
    if let Some(v) = args.get("batch-decode")? {
        cfg.sched.batch_decode = match v {
            "on" => true,
            "off" => false,
            other => bail!("--batch-decode must be 'on' or 'off', got '{other}'"),
        };
    }
    if let Some(v) = args.get("kv-paging")? {
        cfg.sched.kv_paging = match v {
            "on" => true,
            "off" => false,
            other => bail!("--kv-paging must be 'on' or 'off', got '{other}'"),
        };
    }
    if let Some(spec) = args.get("trace")? {
        cfg.sched.trace = TraceSpec::parse(spec)?;
    }
    if let Some(spec) = args.get("profile")? {
        // A bare path is the ergonomic form: `--profile out.json` means
        // `json:out.json`; the explicit `off|text:|json:` spellings
        // still go through the strict parser.
        cfg.sched.profile = if spec == "off" || spec.contains(':') {
            ProfileSpec::parse(spec)?
        } else {
            ProfileSpec::Json(spec.to_string())
        };
    }
    // Build the whole request trace up front: arrivals are *simulated*
    // cycles, so the set is known before serving starts. The worker is
    // gated on a barrier until every request is submitted, so the
    // replay never races ingestion against simulated time — identical
    // seeds give identical percentiles.
    let requests: Vec<Request> = match cfg.sched.arrival.clone() {
        ArrivalSpec::Trace { path } => {
            if args.has("requests") {
                bail!("--requests conflicts with trace arrivals: the trace defines the requests");
            }
            if args.has("prompt-tokens") {
                bail!(
                    "--prompt-tokens conflicts with trace arrivals: the trace carries \
                     per-request prompt_tokens"
                );
            }
            // The trace's prompt/generation split maps 1:1 onto the
            // request: `prompt_tokens` prompt positions (prefilled in
            // chunks), the rest generated. An oversized total is
            // rejected at submit with this request's id/index.
            arrivals::load_trace(&path)?
                .iter()
                .enumerate()
                .map(|(id, t)| Request {
                    id: id as u64,
                    prompt: vec![1; t.prompt_tokens as usize],
                    n_new: (t.n_tokens - t.prompt_tokens) as usize,
                    arrival_cycle: t.arrival_cycle,
                })
                .collect()
        }
        spec => {
            let n = args.u64_or("requests", 8)? as usize;
            let prompt_len = args.u64_or("prompt-tokens", 4)? as usize;
            if prompt_len == 0 {
                bail!("--prompt-tokens must be >= 1 (every request prefills one position)");
            }
            let cycles = arrivals::generate(&spec, n, cfg.gddr6.freq_ghz, cfg.sched.seed)?;
            cycles
                .iter()
                .enumerate()
                .map(|(id, &arrival_cycle)| Request {
                    id: id as u64,
                    prompt: (0..prompt_len).map(|i| ((id + i) % 17) as i32 + 1).collect(),
                    n_new: 12,
                    arrival_cycle,
                })
                .collect()
        }
    };
    let n_requests = requests.len() as u64;
    let dir = Path::new(args.get("artifacts")?.unwrap_or("artifacts"));
    let use_artifact = by_name(name).map(|m| m.max_seq <= 512).unwrap_or(false)
        && dir.join(format!("{name}.meta.json")).exists();
    let functional = use_artifact;
    if functional && cfg.sched.arrival != ArrivalSpec::Batch {
        eprintln!(
            "pim-gpt serve: functional artifact serving is FIFO and ignores --arrivals \
             {} (no latency percentiles will be reported)",
            cfg.sched.arrival
        );
    }
    if functional && cfg.sched.policy != pim_gpt::sim::PolicySpec::Fcfs {
        eprintln!(
            "pim-gpt serve: functional artifact serving is FIFO and ignores --policy {}",
            cfg.sched.policy
        );
    }
    let name_owned = name.to_string();
    let dir_owned = dir.to_path_buf();
    let cfg_owned = cfg.clone();
    // Determinism barrier: the worker must not ingest (or step) until
    // the whole trace sits in the channel — otherwise a fast mapping
    // build could let simulated time warp past not-yet-submitted
    // arrivals and the percentiles would depend on thread timing.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let mut server = Server::start(move || {
        let _ = ready_rx.recv();
        if use_artifact {
            PimGptSystem::with_artifact(&name_owned, &dir_owned, &cfg_owned)
        } else {
            let m = by_name(&name_owned)
                .ok_or_else(|| anyhow!("unknown model '{name_owned}'"))?;
            PimGptSystem::timing_only(&m, &cfg_owned)
        }
    });
    for req in requests {
        server.submit(req)?;
    }
    let _ = ready_tx.send(());
    for _ in 0..n_requests {
        let r = server.recv()?;
        match (&r.error, r.rejected) {
            (None, true) => println!(
                "req {:>3}: REJECTED by {} admission after {} queued",
                r.id,
                cfg.sched.policy,
                fmt_time_s(r.sim_queue_seconds),
            ),
            (None, false) => println!(
                "req {:>3}: {} tokens, sim {} (+{} queue), wall {}",
                r.id,
                r.tokens.len(),
                fmt_time_s(r.sim_seconds),
                fmt_time_s(r.sim_queue_seconds),
                fmt_time_s(r.wall_seconds),
            ),
            (Some(e), _) => println!("req {:>3}: ERROR {e}", r.id),
        }
    }
    let m = server.shutdown();
    // Functional (artifact) serving is FIFO regardless of --concurrency:
    // the PJRT decode is one-token-at-a-time against a single KV cache.
    let k_served = if functional { 1 } else { cfg.sched.max_streams };
    println!(
        "\nserved {} requests ({} tokens), functional={functional}, K={k_served}, \
         simulated makespan {}, throughput {:.0} tok/s",
        m.requests,
        m.tokens,
        fmt_time_s(m.sim_makespan_seconds),
        m.sim_tokens_per_s()
    );
    // Busy-cycle basis: makespan minus idle arrival-gap warps — engine
    // capacity rather than offered load (they coincide for batch
    // arrivals, where the engine never idles).
    if m.sim_busy_seconds > 0.0 {
        println!(
            "busy time {} (idle warps excluded), capacity throughput {:.0} tok/s",
            fmt_time_s(m.sim_busy_seconds),
            m.sim_tokens_per_busy_s()
        );
    }
    if cfg.sched.batch_decode {
        println!(
            "batched decode: {} fused sweeps (mean {:.2} / max {} streams), {} solo decode steps",
            m.fused_sweeps, m.mean_decode_batch, m.max_decode_batch, m.solo_decode_steps
        );
    }
    // Prefill/decode service split: the compute-dense prompt phase vs
    // the memory-bound generation phase (timing-only serving; FIFO
    // functional serving runs token-by-token and reports no split).
    if m.sim_prefill_seconds > 0.0 || m.sim_decode_seconds > 0.0 {
        println!(
            "prefill chunk {}: prefill {} / decode {} of summed service {}",
            cfg.sched.prefill_chunk,
            fmt_time_s(m.sim_prefill_seconds),
            fmt_time_s(m.sim_decode_seconds),
            fmt_time_s(m.sim_seconds),
        );
    }
    // KV-capacity admission stats: fewer slots than K means the mapping
    // degraded (DRAM rows could not hold K disjoint contexts).
    // admission_blocked sums queued requests over admission attempts
    // (queue-depth-weighted pressure), not distinct blocked requests.
    println!(
        "kv slots {} (peak in use {}), admission-blocked pressure {} request-attempts",
        m.kv_slots, m.peak_slots_in_use, m.admission_blocked
    );
    // Paged-KV frame pool: faults resolve by preempting a victim stream
    // (its context is written back and it re-queues for re-admission).
    if cfg.sched.kv_paging {
        println!(
            "kv pages {} x {} tokens (peak in use {}): {} page faults, {} preemptions, \
             {} tokens written back",
            m.kv_pages,
            cfg.sched.kv_page_tokens,
            m.peak_pages_in_use,
            m.page_faults,
            m.preemptions,
            m.evicted_tokens
        );
    }
    // Scheduling policy + per-policy reject count (SLO sheds requests
    // whose predicted TTFT busts the budget; other policies never do).
    if cfg.sched.policy == pim_gpt::sim::PolicySpec::Slo {
        println!(
            "policy {} (ttft budget {} cycles): rejected {} of {} requests",
            cfg.sched.policy, cfg.sched.slo_ttft_cycles, m.rejected, m.requests
        );
    } else {
        println!("policy {}: rejected {}", cfg.sched.policy, m.rejected);
    }
    // Open-loop tail latency, measured from each request's arrival.
    if let Some(lat) = &m.latency {
        let t = |cycles: u64| fmt_time_s(cycles as f64 / (cfg.gddr6.freq_ghz * 1e9));
        println!("arrivals {} (seed {})", cfg.sched.arrival, cfg.sched.seed);
        println!("latency (simulated)   p50 / p95 / p99");
        println!("  queue     {} / {} / {}", t(lat.queue.p50), t(lat.queue.p95), t(lat.queue.p99));
        println!("  ttft      {} / {} / {}", t(lat.ttft.p50), t(lat.ttft.p95), t(lat.ttft.p99));
        println!("  e2e       {} / {} / {}", t(lat.e2e.p50), t(lat.e2e.p95), t(lat.e2e.p99));
    }
    // Trace artifact: the engine renders it in memory (it never does
    // IO); write it here, validating Chrome traces before they land.
    if let Some((path, contents)) = &m.trace {
        let summary = match &cfg.sched.trace {
            TraceSpec::Chrome(_) => {
                let events = validate_chrome(contents)
                    .map_err(|e| anyhow!("chrome trace failed validation: {e}"))?;
                format!("{events} events (chrome)")
            }
            _ => format!("{} events (jsonl)", contents.lines().count()),
        };
        std::fs::write(path, contents)
            .map_err(|e| anyhow!("writing trace to '{path}': {e}"))?;
        println!("trace: {summary} -> {path}");
    } else if cfg.sched.trace != TraceSpec::Off {
        // Functional (FIFO) serving has no interleaved engine to trace.
        eprintln!("pim-gpt serve: no trace produced (functional serving is untraced)");
    }
    // Profile artifact: same in-memory rendering contract as the trace.
    if let Some((path, contents)) = &m.profile {
        std::fs::write(path, contents)
            .map_err(|e| anyhow!("writing profile to '{path}': {e}"))?;
        println!("profile -> {path}");
    } else if cfg.sched.profile.is_on() {
        eprintln!("pim-gpt serve: no profile produced (functional serving is unprofiled)");
    }
    // sched.strict_reconcile turns a release-build trace/stats mismatch
    // into data instead of a debug panic; make it loud at the CLI too.
    if let Some(e) = &m.reconcile_error {
        eprintln!("pim-gpt serve: trace reconciliation FAILED: {e}");
    }
    if let Some(path) = args.get("metrics-json")? {
        std::fs::write(path, format!("{}\n", m.to_json()))
            .map_err(|e| anyhow!("writing metrics to '{path}': {e}"))?;
        println!("metrics json -> {path}");
    }
    Ok(())
}

fn on_off(args: &Args, key: &str) -> Result<Option<bool>> {
    match args.get(key)? {
        None => Ok(None),
        Some("on") => Ok(Some(true)),
        Some("off") => Ok(Some(false)),
        Some(other) => bail!("--{key} must be 'on' or 'off', got '{other}'"),
    }
}

/// `pim-gpt profile`: run a small interleaved workload with the
/// profiling observer attached and print the attribution tree, latency
/// histograms and extracted cost table (or replay a recorded `jsonl:`
/// trace with --from-jsonl, or cross-validate cost predictions with
/// --calibrate). The attribution is hard-checked against the engine's
/// busy cycles before anything is printed — a mismatch is an error, not
/// a footnote.
fn cmd_profile(args: &Args) -> Result<()> {
    args.expect_only(
        "profile",
        &[
            "model",
            "requests",
            "prompt-tokens",
            "gen-tokens",
            "concurrency",
            "batch-decode",
            "kv-paging",
            "seed",
            "config",
            "json",
            "from-jsonl",
            "calibrate",
            "models",
        ],
    )?;
    if args.has("calibrate") {
        return cmd_profile_calibrate(args);
    }
    if args.has("models") {
        bail!("--models only applies to --calibrate (use --model NAME)");
    }
    let name = args.get("model")?.ok_or_else(|| anyhow!("--model required (or --calibrate)"))?;
    let model = by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'"))?;
    let mut cfg = load_config(args)?;
    if let Some(k) = args.get("concurrency")? {
        let k: usize = k.parse().map_err(|_| anyhow!("--concurrency must be an integer"))?;
        if k == 0 {
            bail!("--concurrency must be >= 1");
        }
        cfg.sched.max_streams = k;
    }
    if let Some(v) = on_off(args, "batch-decode")? {
        cfg.sched.batch_decode = v;
    }
    if let Some(v) = on_off(args, "kv-paging")? {
        cfg.sched.kv_paging = v;
    }
    if let Some(seed) = args.get("seed")? {
        cfg.sched.seed = seed.parse().map_err(|_| anyhow!("--seed must be an integer"))?;
    }
    let profile = if let Some(path) = args.get("from-jsonl")? {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading trace '{path}': {e}"))?;
        Profile::from_jsonl(&text, &model, &cfg)?
    } else {
        let n = args.u64_or("requests", 6)?.max(1);
        let prompt = args.u64_or("prompt-tokens", 8)?;
        let gen = args.u64_or("gen-tokens", 8)?;
        if prompt == 0 || gen == 0 {
            bail!("--prompt-tokens and --gen-tokens must be >= 1");
        }
        let max_seq = model.max_seq as u64;
        let mut fleet = FleetSim::new(&model, &cfg)?;
        fleet.set_profile(ProfileSink::new(&model, &cfg));
        for id in 0..n {
            // Deterministic shape jitter so the profile exercises more
            // than one (regime, occupancy) attribution cell.
            let p = (prompt + id % 3).clamp(1, max_seq.saturating_sub(1).max(1));
            let g = (gen + id % 2).clamp(1, (max_seq - p).max(1));
            fleet.submit(StreamSpec {
                id,
                n_tokens: p + g,
                prompt_tokens: p,
                arrival_cycle: 0,
            })?;
        }
        fleet.run_all()?;
        fleet.finalize_stats();
        fleet
            .profile_report()
            .ok_or_else(|| anyhow!("profiler produced no report (sink not attached?)"))?
    };
    profile.check().map_err(|e| anyhow!("cycle attribution failed to reconcile: {e}"))?;
    println!("{}", profile.render_text());
    if let Some(path) = args.get("json")? {
        std::fs::write(path, format!("{}\n", profile.to_json()))
            .map_err(|e| anyhow!("writing profile to '{path}': {e}"))?;
        println!("profile json -> {path}");
    }
    Ok(())
}

/// `pim-gpt profile --calibrate`: for each model, train a CostTable on
/// a small simulated workload, cross-validate `predict` against fresh
/// cycle-accurate runs and report the per-model mean/max relative
/// error (the --json artifact is the CI calibration record).
fn cmd_profile_calibrate(args: &Args) -> Result<()> {
    for conflict in ["model", "from-jsonl", "concurrency", "batch-decode", "kv-paging"] {
        if args.has(conflict) {
            bail!("--{conflict} does not apply to --calibrate (use --models A,B)");
        }
    }
    let cfg = load_config(args)?;
    let seed = args.u64_or("seed", 7)?;
    let n_validate = args.u64_or("requests", 6)?.max(1) as usize;
    let models: Vec<String> = match args.get("models")? {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => ["gpt2-small", "gpt2-medium", "gpt2-large", "gpt2-xl"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let mut rows = Vec::new();
    let (mut worst, mut mean_sum) = (0.0f64, 0.0f64);
    for name in &models {
        let model = by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'"))?;
        let rep = calibrate(&model, &cfg, seed, n_validate)?;
        println!("{}", rep.render_text());
        worst = worst.max(rep.max_rel_err);
        mean_sum += rep.mean_rel_err;
        rows.push(rep.to_json());
    }
    let mean = mean_sum / models.len() as f64;
    println!(
        "calibration over {} models: mean rel err {:.2}%, max rel err {:.2}%",
        models.len(),
        100.0 * mean,
        100.0 * worst
    );
    if let Some(path) = args.get("json")? {
        let j = Json::obj(vec![
            ("seed", seed.into()),
            ("n_validate", (n_validate as u64).into()),
            ("mean_rel_err", mean.into()),
            ("max_rel_err", worst.into()),
            ("models", Json::Arr(rows)),
        ]);
        std::fs::write(path, format!("{j}\n"))
            .map_err(|e| anyhow!("writing calibration to '{path}': {e}"))?;
        println!("calibration json -> {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Result<Args> {
        let owned: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        Args::parse(&owned)
    }

    #[test]
    fn parses_pairs_switches_and_equals_form() {
        let a = parse(&["--model", "gpt2-small", "--json", "--tokens=64"]).unwrap();
        assert_eq!(a.get("model").unwrap(), Some("gpt2-small"));
        assert_eq!(a.u64_or("tokens", 8).unwrap(), 64);
        assert!(a.has("json"));
        assert!(!a.has("absent"));
        assert_eq!(a.get("absent").unwrap(), None);
        // Trailing bare switch.
        let a = parse(&["--seed", "7", "--json"]).unwrap();
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert!(a.has("json"));
    }

    /// Satellite: values that start with '--' bind via the '=' escape
    /// hatch, and negative numbers work in both forms — neither is
    /// swallowed as a bare boolean.
    #[test]
    fn awkward_values_bind_unambiguously() {
        let a = parse(&["--prompt=--5,3", "--offset", "-5"]).unwrap();
        assert_eq!(a.get("prompt").unwrap(), Some("--5,3"));
        assert_eq!(a.get("offset").unwrap(), Some("-5"));
        let a = parse(&["--offset=-5"]).unwrap();
        assert_eq!(a.get("offset").unwrap(), Some("-5"));
    }

    /// Satellite: the old parser silently turned `--arrivals --seed 5`
    /// into `arrivals=true` and ran the wrong experiment. Reading a
    /// value out of a bare flag is now a loud, self-explanatory error.
    #[test]
    fn bare_flag_read_as_value_errors_clearly() {
        let a = parse(&["--arrivals", "--seed", "5"]).unwrap();
        assert_eq!(a.u64_or("seed", 0).unwrap(), 5, "the trailing pair still binds");
        let err = a.get("arrivals").unwrap_err().to_string();
        assert!(err.contains("--arrivals needs a value"), "{err}");
        assert!(err.contains("--arrivals=<value>"), "names the escape hatch: {err}");
        // u64_or goes through the same gate.
        assert!(a.u64_or("arrivals", 1).is_err());
        // And `has` still treats it as a present switch.
        assert!(a.has("arrivals"));
    }

    #[test]
    fn malformed_flags_rejected() {
        for bad in [
            &["stray"][..],
            &["-x"][..],
            &["--"][..],
            &["--=v"][..],
            &["--key="][..],
            &["--model", "a", "--model", "b"][..],
            &["--model=a", "--model", "b"][..],
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
        let err = parse(&["--model", "a", "--model", "b"]).unwrap_err().to_string();
        assert!(err.contains("duplicate flag --model"), "{err}");
    }

    #[test]
    fn unknown_command_and_bad_integers_error() {
        assert!(run(&["frobnicate".to_string()]).is_err());
        let a = parse(&["--tokens", "many"]).unwrap();
        let err = a.u64_or("tokens", 1).unwrap_err().to_string();
        assert!(err.contains("--tokens must be an integer"), "{err}");
    }

    /// A typo'd flag *name* is rejected by the command's allowlist
    /// (validated before any work starts) instead of being stored,
    /// never read, and silently running the default experiment.
    #[test]
    fn unknown_flags_rejected_per_command() {
        let run_strs = |argv: &[&str]| {
            let owned: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
            run(&owned).unwrap_err().to_string()
        };
        let err = run_strs(&["serve", "--polcy", "srf"]);
        assert!(err.contains("unknown flag --polcy"), "{err}");
        assert!(err.contains("--policy"), "names the accepted set: {err}");
        let err = run_strs(&["info", "--model", "gpt2-small"]);
        assert!(err.contains("unknown flag --model"), "{err}");
        let err = run_strs(&["figures", "--tokn", "3"]);
        assert!(err.contains("unknown flag --tokn"), "{err}");
    }
}
