//! GPT model descriptions and the per-token computation graph.

pub mod gpt;
pub mod graph;

pub use gpt::{GptModel, PAPER_MODELS};
pub use graph::{DecodeGraph, GraphNode, GraphOp, MatrixId, MatrixKind, VmmClass};
