//! Per-token decode computation graph.
//!
//! One token's pass through the model is a small DAG of VMMs (PIM), ASIC
//! ops and KV write-backs. The compiler lowers it to an instruction
//! stream (paper Fig. 3b); the graph also drives the mapping stage
//! (Algorithm 3 walks `vmmBlock`s and `write_k/v` blocks).
//!
//! Dependency structure within one layer:
//!
//! ```text
//! LN1 -> VMM(qkv)+bias -> { WriteK, WriteV, VMM(scores) }
//! VMM(scores) needs WriteK;  scale+softmax -> VMM(attn x V) needs WriteV
//! -> VMM(proj)+bias -> residual -> LN2 -> VMM(fc1)+bias -> GELU
//! -> VMM(fc2)+bias -> residual
//! ```

use crate::asic::AsicOp;
use super::gpt::GptModel;

/// Which stored matrix a VMM reads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MatrixKind {
    /// Fused W_Q|W_K|W_V (d x 3d), head-concatenated (Fig. 6a).
    Wqkv,
    /// Attention output projection (d x d).
    Wo,
    /// FFN up projection (d x 4d).
    W1,
    /// FFN down projection (4d x d).
    W2,
    /// Tied embedding / LM head (d x vocab).
    Wte,
    /// The Key cache region of a layer (read by q @ K^T).
    KCache,
    /// The Value cache region of a layer (read by scores @ V).
    VCache,
}

impl MatrixKind {
    /// KV-cache regions are reserved per stream slot (unlike weights,
    /// which are shared by all streams) — reads of them are slot-addressed.
    pub fn is_kv_cache(&self) -> bool {
        matches!(self, MatrixKind::KCache | MatrixKind::VCache)
    }
}

/// Identifies one stored matrix (layer-local except Wte).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MatrixId {
    pub layer: usize,
    pub kind: MatrixKind,
}

impl MatrixId {
    pub fn new(layer: usize, kind: MatrixKind) -> Self {
        Self { layer, kind }
    }
}

/// Latency-class of a VMM, for the Fig. 10 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VmmClass {
    Qkv,
    Score,
    AttnV,
    Proj,
    Fc1,
    Fc2,
    LmHead,
}

/// A node in the decode graph.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphOp {
    /// VMM on the PIM chips.
    Vmm {
        matrix: MatrixId,
        class: VmmClass,
        /// Input vector elements broadcast to the channels.
        in_elems: u64,
        /// Output vector elements gathered back.
        out_elems: u64,
    },
    /// Non-VMM computation on the ASIC.
    Asic(AsicOp),
    /// Write the concatenated Key vector (row-major) for this token.
    WriteK { layer: usize, elems: u64 },
    /// Write the Value vector (column-major) for this token.
    WriteV { layer: usize, elems: u64 },
}

/// A graph node with explicit dependencies (indices into `ops`).
#[derive(Clone, Debug)]
pub struct GraphNode {
    pub op: GraphOp,
    pub deps: Vec<usize>,
}

/// The decode-step DAG for one token at context length `ltoken`.
#[derive(Clone, Debug)]
pub struct DecodeGraph {
    pub nodes: Vec<GraphNode>,
    pub ltoken: u64,
}

impl DecodeGraph {
    /// Build the graph for generating the token at position `pos`
    /// (0-based; the VMMs then attend over `ltoken = pos + 1` tokens).
    pub fn build(m: &GptModel, pos: u64) -> Self {
        let ltoken = pos + 1;
        let d = m.d_model as u64;
        let ff = m.d_ff() as u64;
        let h = m.n_head as u64;
        let v = m.vocab as u64;
        let mut nodes: Vec<GraphNode> = Vec::with_capacity(m.n_layer * 14 + 3);
        let mut push = |op: GraphOp, deps: Vec<usize>| -> usize {
            nodes.push(GraphNode { op, deps });
            nodes.len() - 1
        };

        // Embedding lookup is a DRAM row read + add; negligible and
        // modeled as a residual-add-sized ASIC op.
        let mut prev = push(GraphOp::Asic(AsicOp::ResidualAdd { n: d }), vec![]);

        for l in 0..m.n_layer {
            let ln1 = push(GraphOp::Asic(AsicOp::LayerNorm { n: d }), vec![prev]);
            let qkv = push(
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::Wqkv),
                    class: VmmClass::Qkv,
                    in_elems: d,
                    out_elems: 3 * d,
                },
                vec![ln1],
            );
            let bias = push(GraphOp::Asic(AsicOp::BiasAdd { n: 3 * d }), vec![qkv]);
            let wk = push(GraphOp::WriteK { layer: l, elems: d }, vec![bias]);
            let wv = push(GraphOp::WriteV { layer: l, elems: d }, vec![bias]);
            // q @ K^T over all heads: reads the K cache (ltoken rows of d).
            let score = push(
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::KCache),
                    class: VmmClass::Score,
                    in_elems: d,
                    out_elems: h * ltoken,
                },
                vec![bias, wk],
            );
            let scale = push(GraphOp::Asic(AsicOp::Scale { n: h * ltoken }), vec![score]);
            let softmax = push(GraphOp::Asic(AsicOp::Softmax { n: h * ltoken, groups: h }), vec![scale]);
            // scores @ V: reads the V cache (d columns of ltoken).
            let av = push(
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::VCache),
                    class: VmmClass::AttnV,
                    in_elems: h * ltoken,
                    out_elems: d,
                },
                vec![softmax, wv],
            );
            let concat = push(GraphOp::Asic(AsicOp::Concat { n: d }), vec![av]);
            let proj = push(
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::Wo),
                    class: VmmClass::Proj,
                    in_elems: d,
                    out_elems: d,
                },
                vec![concat],
            );
            let bias2 = push(GraphOp::Asic(AsicOp::BiasAdd { n: d }), vec![proj]);
            let res1 = push(GraphOp::Asic(AsicOp::ResidualAdd { n: d }), vec![bias2, prev]);
            let ln2 = push(GraphOp::Asic(AsicOp::LayerNorm { n: d }), vec![res1]);
            let fc1 = push(
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::W1),
                    class: VmmClass::Fc1,
                    in_elems: d,
                    out_elems: ff,
                },
                vec![ln2],
            );
            let bias3 = push(GraphOp::Asic(AsicOp::BiasAdd { n: ff }), vec![fc1]);
            let gelu = push(GraphOp::Asic(AsicOp::Gelu { n: ff }), vec![bias3]);
            let fc2 = push(
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::W2),
                    class: VmmClass::Fc2,
                    in_elems: ff,
                    out_elems: d,
                },
                vec![gelu],
            );
            let bias4 = push(GraphOp::Asic(AsicOp::BiasAdd { n: d }), vec![fc2]);
            prev = push(GraphOp::Asic(AsicOp::ResidualAdd { n: d }), vec![bias4, res1]);
        }

        let lnf = push(GraphOp::Asic(AsicOp::LayerNorm { n: d }), vec![prev]);
        push(
            GraphOp::Vmm {
                matrix: MatrixId::new(0, MatrixKind::Wte),
                class: VmmClass::LmHead,
                in_elems: d,
                out_elems: v,
            },
            vec![lnf],
        );

        Self { nodes, ltoken }
    }

    /// All weight matrices the mapper must place for this model.
    pub fn weight_matrices(m: &GptModel) -> Vec<(MatrixId, u64, u64)> {
        let d = m.d_model as u64;
        let ff = m.d_ff() as u64;
        let mut out = Vec::new();
        for l in 0..m.n_layer {
            out.push((MatrixId::new(l, MatrixKind::Wqkv), d, 3 * d));
            out.push((MatrixId::new(l, MatrixKind::Wo), d, d));
            out.push((MatrixId::new(l, MatrixKind::W1), d, ff));
            out.push((MatrixId::new(l, MatrixKind::W2), ff, d));
        }
        out.push((MatrixId::new(0, MatrixKind::Wte), d, m.vocab as u64));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    #[test]
    fn graph_shape() {
        let m = by_name("gpt3-small").unwrap();
        let g = DecodeGraph::build(&m, 0);
        // 1 embed + 20/layer + LNf + LM head
        assert_eq!(g.nodes.len(), 1 + 20 * 12 + 2);
        assert_eq!(g.ltoken, 1);
    }

    #[test]
    fn deps_are_acyclic_and_backward() {
        let m = by_name("gpt2-small").unwrap();
        let g = DecodeGraph::build(&m, 100);
        for (i, n) in g.nodes.iter().enumerate() {
            for &d in &n.deps {
                assert!(d < i, "node {i} depends on later node {d}");
            }
        }
    }

    #[test]
    fn vmm_count_per_layer() {
        let m = by_name("gpt2-small").unwrap();
        let g = DecodeGraph::build(&m, 7);
        let vmms = g.nodes.iter().filter(|n| matches!(n.op, GraphOp::Vmm { .. })).count();
        // 6 per layer (qkv, score, av, proj, fc1, fc2) + lm head
        assert_eq!(vmms, 6 * 12 + 1);
    }

    #[test]
    fn score_av_scale_with_ltoken() {
        let m = by_name("gpt2-small").unwrap();
        let g = DecodeGraph::build(&m, 511);
        let h = m.n_head as u64;
        let found = g.nodes.iter().any(|n| matches!(
            n.op,
            GraphOp::Vmm { class: VmmClass::Score, out_elems, .. } if out_elems == h * 512
        ));
        assert!(found);
    }

    #[test]
    fn score_depends_on_write_k() {
        let m = by_name("gpt-nano").unwrap();
        let g = DecodeGraph::build(&m, 3);
        for (i, n) in g.nodes.iter().enumerate() {
            if let GraphOp::Vmm { class: VmmClass::Score, .. } = n.op {
                let has_wk_dep = n.deps.iter().any(|&d| matches!(g.nodes[d].op, GraphOp::WriteK { .. }));
                assert!(has_wk_dep, "score node {i} missing WriteK dep");
            }
        }
    }

    #[test]
    fn weight_matrix_inventory() {
        let m = by_name("gpt2-medium").unwrap();
        let ws = DecodeGraph::weight_matrices(&m);
        assert_eq!(ws.len(), 4 * 24 + 1);
        let total: u64 = ws.iter().map(|(_, r, c)| r * c).sum();
        // weight-matrix elements dominate params (no biases/LN here)
        assert!((total as f64) > 0.95 * m.n_params() as f64 - (m.vocab * m.d_model) as f64);
    }
}
