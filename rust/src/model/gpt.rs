//! The GPT model zoo: the 8 models of the paper's evaluation (4x GPT-2,
//! 4x GPT-3, up to 1.4B parameters) plus the tiny functional configs that
//! ship as executable artifacts. Mirrors `python/compile/configs.py`.

/// A decoder-only transformer configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GptModel {
    pub name: &'static str,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl GptModel {
    pub const fn new(
        name: &'static str,
        n_layer: usize,
        d_model: usize,
        n_head: usize,
        vocab: usize,
        max_seq: usize,
    ) -> Self {
        Self { name, n_layer, d_model, n_head, vocab, max_seq }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    /// Parameter count (weights + biases + layernorms + embeddings) —
    /// cross-checked against published sizes in python `test_model.py`.
    pub fn n_params(&self) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff() as u64;
        let per_layer = d * 3 * d + 3 * d    // qkv
            + d * d + d                      // attn proj
            + d * ff + ff                    // fc1
            + ff * d + d                     // fc2
            + 4 * d;                         // 2x layernorm
        self.n_layer as u64 * per_layer
            + (self.vocab as u64 + self.max_seq as u64) * d
            + 2 * d
    }

    /// MAC-dominated op count for decoding one token at context length
    /// `seq_len` (mul+add = 2 ops), incl. the LM head. Mirrors python.
    pub fn flops_per_token(&self, seq_len: u64) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff() as u64;
        let per_layer = 2 * (d * 3 * d + d * seq_len + seq_len * d + d * d + d * ff + ff * d);
        self.n_layer as u64 * per_layer + 2 * d * self.vocab as u64
    }

    /// Weight bytes in bf16 (what the PIM banks must store).
    pub fn weight_bytes(&self) -> u64 {
        self.n_params() * 2
    }

    /// KV-cache bytes in bf16 at full context.
    pub fn kv_bytes(&self) -> u64 {
        2 * (self.n_layer * self.max_seq * self.d_model) as u64 * 2
    }
}

/// The 8 models of the paper's Fig. 8/9 evaluation.
pub const PAPER_MODELS: [GptModel; 8] = [
    GptModel::new("gpt2-small", 12, 768, 12, 50257, 1024),
    GptModel::new("gpt2-medium", 24, 1024, 16, 50257, 1024),
    GptModel::new("gpt2-large", 36, 1280, 20, 50257, 1024),
    GptModel::new("gpt2-xl", 48, 1600, 25, 50257, 1024),
    GptModel::new("gpt3-small", 12, 768, 12, 50257, 2048),
    GptModel::new("gpt3-medium", 24, 1024, 16, 50257, 2048),
    GptModel::new("gpt3-large", 24, 1536, 16, 50257, 2048),
    GptModel::new("gpt3-xl", 24, 2048, 24, 50257, 2048),
];

/// Look up a paper model or a functional artifact config by name.
pub fn by_name(name: &str) -> Option<GptModel> {
    PAPER_MODELS.iter().find(|m| m.name == name).cloned().or(match name {
        "gpt-nano" => Some(GptModel::new("gpt-nano", 2, 128, 4, 512, 128)),
        "gpt-mini" => Some(GptModel::new("gpt-mini", 4, 256, 8, 2048, 256)),
        _ => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published() {
        let published: &[(&str, f64)] = &[
            ("gpt2-small", 124e6),
            ("gpt2-medium", 355e6),
            ("gpt2-large", 774e6),
            ("gpt2-xl", 1558e6),
            ("gpt3-small", 125e6),
            ("gpt3-medium", 350e6),
            ("gpt3-large", 760e6),
            ("gpt3-xl", 1320e6),
        ];
        for (name, want) in published {
            let got = by_name(name).unwrap().n_params() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.06, "{name}: {got} vs {want} ({rel})");
        }
    }

    #[test]
    fn fig1_ops_per_param_ratio() {
        // Fig. 1b: GPT ops/parameter ~ 2, the memory-bound motivation.
        for m in &PAPER_MODELS {
            let ratio = m.flops_per_token(1024) as f64 / m.n_params() as f64;
            assert!((1.5..3.0).contains(&ratio), "{}: {ratio}", m.name);
        }
    }

    #[test]
    fn all_models_fit_in_pim_capacity() {
        // 8 channels x 4 Gb = 4 GiB. Weights + full KV must fit (the
        // paper stores everything in the PIM banks).
        let capacity = 8u64 * (4 << 30) / 8;
        for m in &PAPER_MODELS {
            let need = m.weight_bytes() + m.kv_bytes();
            assert!(need < capacity, "{}: {need} > {capacity}", m.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("gpt3-xl").unwrap().d_model, 2048);
        assert_eq!(by_name("gpt-nano").unwrap().n_layer, 2);
        assert!(by_name("nonexistent").is_none());
    }
}
