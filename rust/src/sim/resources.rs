//! Explicit hardware resource pool with `busy_until` reservations.
//!
//! The simulator's schedulable resources are: 8 PIM channels (each a
//! shared GB/drain bus plus 16 per-bank MAC units and write ports, all
//! carrying their own `busy_until` inside `pim::Channel` / `dram::Bank`)
//! and the ASIC computation engines (`asic_free`). An instruction is
//! *issued* at the max of its dependency finish times and the relevant
//! resource free times; every leaf model clamps its start to its own
//! `busy_until`, so issues from different request streams may arrive in
//! any time order — the resources serialize them, which is exactly what
//! lets the multi-stream scheduler (`sim::sched`) interleave programs
//! without a global event queue.
//!
//! `Resources::issue` (crate-internal) is the *only* path that executes
//! an instruction;
//! the single-stream `Simulator` and the multi-stream `MultiSim` both go
//! through it, which is what makes K=1 interleaved scheduling reproduce
//! the single-stream simulator cycle-for-cycle (see
//! `tests/integration_sched.rs`).

use super::stats::{LatClass, SimStats};
use crate::asic::{AsicOp, Engine};
use crate::compiler::Instr;
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::ModelMapping;
use crate::model::{GptModel, MatrixKind};
use crate::pim::{Channel, UnitWork, VmmPlan};

/// Cycles to flush the last streamed chunk through an ASIC engine after
/// its final input arrives (engine fill + one burst).
pub const TAIL_CYCLES: u64 = 12;

/// The reservable hardware: PIM channels + ASIC engines.
pub struct Resources {
    pub channels: Vec<Channel>,
    pub engine: Engine,
    /// ASIC engine availability (ops serialize on the engines).
    pub asic_free: u64,
}

/// Immutable per-issue context (model/mapping are shared by all streams).
pub(crate) struct IssueCtx<'a> {
    pub cfg: &'a HwConfig,
    pub t: &'a TimingCycles,
    pub model: &'a GptModel,
    pub mapping: &'a ModelMapping,
}

/// Timing outcome of one issued instruction.
pub(crate) struct Issued {
    /// When every dependency had fully finished (attribution baseline).
    pub ready: u64,
    /// When the instruction finished.
    pub finish: u64,
    /// When its first partial result was available (== finish for
    /// non-VMM instructions).
    pub first_ready: u64,
    /// Latency class for the Fig. 10 breakdown.
    pub class: LatClass,
}

/// A `VmmPlan` sized for this config's channels (reused across issues —
/// plan allocation churn was ~15% of sim time, EXPERIMENTS.md §Perf).
pub fn empty_plan(cfg: &HwConfig) -> VmmPlan {
    VmmPlan {
        bank_work: (0..cfg.gddr6.banks_per_channel).map(|_| UnitWork::Idle).collect(),
        input_elems: 0,
        output_elems: 0,
        passes: 1,
    }
}

impl Resources {
    pub fn new(cfg: &HwConfig) -> Self {
        Self {
            channels: (0..cfg.gddr6.channels).map(|_| Channel::new(cfg)).collect(),
            engine: Engine::new(cfg),
            asic_free: 0,
        }
    }

    /// Execute one instruction of a stream's program.
    ///
    /// `finish` / `first_ready` are the issuing stream's per-node times
    /// for already-issued nodes of the *current* step; `step_start` is
    /// when that step began; `pos` / `ltoken` drive KV addressing.
    ///
    /// `passes` is the number of consecutive token positions the step
    /// covers (a prefill *chunk*; 1 = a plain decode step): VMMs run in
    /// matrix-matrix mode (row ACT/PRE and GB staging amortized over the
    /// `passes` input vectors), ASIC ops cover `passes` positions with
    /// one pipeline fill, and KV writes store positions
    /// `pos .. pos + passes`. KV reads address the chunk-end context
    /// `ltoken = pos + passes` for every pass — conservative for the
    /// causally-masked earlier positions of the chunk (they attend over
    /// fewer tokens than charged), which keeps the chunk program a
    /// single instruction stream; the parallel-bank critical path is
    /// dominated by the oldest token's unit either way.
    ///
    /// `pages` selects the KV addressing mode: `None` is the historical
    /// slot path (the instruction's patched `slot` id names a full
    /// `max_seq` context), `Some(table)` resolves every KV read/write
    /// through the issuing stream's page table at issue time (paged KV
    /// — the `slot` id is ignored and reads become per-page
    /// `PatternRuns`). The caller guarantees the table covers
    /// `ltoken` / `pos + passes`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn issue(
        &mut self,
        ctx: &IssueCtx,
        plan: &mut VmmPlan,
        instr: &Instr,
        deps: &[usize],
        step_start: u64,
        finish: &[u64],
        first_ready: &[u64],
        pos: u64,
        ltoken: u64,
        passes: u64,
        pages: Option<&[u32]>,
    ) -> Issued {
        let passes = passes.max(1);
        let mut ready = step_start;
        for &d in deps {
            ready = ready.max(finish[d]);
        }
        match instr {
            Instr::PimVmm { matrix, class, in_elems, slot, .. } => {
                let (fin, fr) = self.exec_vmm(
                    ctx, plan, ready, matrix.layer, matrix.kind, *slot, *in_elems, ltoken, passes,
                    pages,
                );
                Issued {
                    ready,
                    finish: fin,
                    first_ready: fr.min(fin),
                    class: LatClass::Vmm((*class).into()),
                }
            }
            Instr::Asic(op) => {
                // Pipelining (paper §IV.A(3)): a streamable op begins
                // once every dependency has *started producing* —
                // VMM deps gate at first_ready — but cannot finish
                // before all inputs have fully arrived (dep finish)
                // plus the tail of processing the last chunk.
                let op = op.for_positions(passes);
                let start = if op.streamable() {
                    let mut s = step_start;
                    for &d in deps {
                        s = s.max(first_ready[d]);
                    }
                    s.max(self.asic_free)
                } else {
                    ready.max(self.asic_free)
                };
                let fin = self.engine.execute(start, &op);
                let fin = if op.streamable() {
                    // Last-chunk tail: engine fill + one burst.
                    fin.max(ready + TAIL_CYCLES)
                } else {
                    fin
                };
                self.asic_free = fin;
                Issued { ready, finish: fin, first_ready: fin, class: asic_class(&op) }
            }
            Instr::WriteK { layer, slot } => {
                // A chunk writes the Key vectors of every covered
                // position; tokens round-robin over units, so the writes
                // fan out across channels (each channel's shared bus
                // serializes whatever lands on it).
                let mut fin = ready;
                for p in pos..pos + passes {
                    let (unit, segs) = match pages {
                        Some(table) => ctx.mapping.kv.k_write_paged(*layer, table, p),
                        None => ctx.mapping.kv.k_write(*layer, *slot, p),
                    };
                    let mut f = ready;
                    for seg in segs {
                        f = self.channels[unit.channel].write_k(ctx.t, f, unit.bank, seg);
                    }
                    fin = fin.max(f);
                }
                Issued { ready, finish: fin, first_ready: fin, class: LatClass::KvWrite }
            }
            Instr::WriteV { layer, slot } => {
                // The write data for every bank of a channel arrives over
                // that channel's shared GB bus, so successive units on
                // one channel serialize in issue order (`chan_fin`
                // threads through); channels proceed in parallel. The
                // issue-order chain — not just the leaf `busy_until`
                // clamp — is what the K=1 equivalence guarantee depends
                // on (pinned by `writev_serializes_per_channel_pinned`).
                // A chunk stores every covered position's Value elements
                // (column-major writes have no locality to amortize —
                // paper §IV.B — so the chunk pays the full per-position
                // cost and the `chan_fin` chain simply extends over the
                // chunk's positions).
                let kv = &ctx.mapping.kv;
                let banks = kv.banks_per_channel;
                let n_channels = kv.n_units / banks;
                let mut fin = ready;
                for ch in 0..n_channels {
                    let mut chan_fin = ready;
                    for p in pos..pos + passes {
                        for b in 0..banks {
                            let u = ch * banks + b;
                            let (base, n_cols, stride) = match pages {
                                Some(table) => kv.v_write_paged(*layer, table, p, u),
                                None => kv.v_write(*layer, *slot, p, u),
                            };
                            if n_cols == 0 {
                                continue;
                            }
                            chan_fin =
                                self.channels[ch].write_v(ctx.t, chan_fin, b, n_cols, base, stride);
                        }
                    }
                    fin = fin.max(chan_fin);
                }
                Issued { ready, finish: fin, first_ready: fin, class: LatClass::KvWrite }
            }
        }
    }

    /// Dispatch a VMM to all channels; returns (slowest finish, earliest
    /// first-partial-result time). `passes > 1` runs matrix-matrix
    /// (chunked prefill): the same mapped rows stream `passes` input
    /// vectors, paying ACT/PRE once per row.
    #[allow(clippy::too_many_arguments)]
    fn exec_vmm(
        &mut self,
        ctx: &IssueCtx,
        plan: &mut VmmPlan,
        start: u64,
        layer: usize,
        kind: MatrixKind,
        slot: usize,
        in_elems: u64,
        ltoken: u64,
        passes: u64,
        pages: Option<&[u32]>,
    ) -> (u64, u64) {
        let banks = ctx.cfg.gddr6.banks_per_channel;
        let n_head = ctx.model.n_head as u64;
        let mut slowest = start;
        let mut first_ready = u64::MAX;
        plan.input_elems = in_elems;
        plan.passes = passes;
        match kind {
            MatrixKind::KCache | MatrixKind::VCache if pages.is_some() => {
                // Paged KV reads: the page table resolves to one
                // pattern run per covered frame (`PatternRuns`); a
                // single-page context issues the identical `mac_pattern`
                // call as the slot path below.
                let table = pages.unwrap();
                let kv = &ctx.mapping.kv;
                for (ch, channel) in self.channels.iter_mut().enumerate() {
                    let mut out = 0u64;
                    for b in 0..banks {
                        let u = ch * banks + b;
                        let runs = if kind == MatrixKind::KCache {
                            out += kv.k_out_elems(u, ltoken, n_head);
                            kv.k_read_runs(layer, table, ltoken, u)
                        } else {
                            out += kv.v_cols(u) as u64;
                            kv.v_read_runs(layer, table, ltoken, u)
                        };
                        plan.bank_work[b] = UnitWork::PatternRuns(runs);
                    }
                    plan.output_elems = out;
                    let e = channel.execute_vmm(ctx.cfg, ctx.t, start, plan);
                    slowest = slowest.max(e.finish);
                    first_ready = first_ready.min(e.first_ready);
                }
            }
            MatrixKind::KCache | MatrixKind::VCache => {
                // KV reads are uniform repetitions of a row-fill pattern
                // per unit: O(1) work via `Bank::mac_pattern` regardless
                // of context length (EXPERIMENTS.md §Perf iteration 2).
                let kv = &ctx.mapping.kv;
                let (pattern, pattern_len) = if kind == MatrixKind::KCache {
                    kv.k_read_pattern()
                } else {
                    kv.v_read_pattern(ltoken)
                };
                for (ch, channel) in self.channels.iter_mut().enumerate() {
                    let mut out = 0u64;
                    for b in 0..banks {
                        let u = ch * banks + b;
                        let (base_row, reps) = if kind == MatrixKind::KCache {
                            out += kv.k_out_elems(u, ltoken, n_head);
                            (kv.k_base[layer][slot][u], kv.k_owned(u, ltoken))
                        } else {
                            let cols = kv.v_cols(u);
                            out += cols as u64;
                            (kv.v_base[layer][slot][u], cols)
                        };
                        plan.bank_work[b] =
                            UnitWork::Pattern { base_row, reps, pattern, pattern_len };
                    }
                    plan.output_elems = out;
                    let e = channel.execute_vmm(ctx.cfg, ctx.t, start, plan);
                    slowest = slowest.max(e.finish);
                    first_ready = first_ready.min(e.first_ready);
                }
            }
            _ => {
                let id = crate::model::MatrixId::new(layer, kind);
                let placement = &ctx.mapping.matrices[&id];
                for (ch, channel) in self.channels.iter_mut().enumerate() {
                    let mut out = 0u64;
                    for b in 0..banks {
                        let u = ch * banks + b;
                        out += placement.out_cols[u];
                        plan.bank_work[b] = UnitWork::Block(placement.per_unit[u]);
                    }
                    plan.output_elems = out;
                    let e = channel.execute_vmm(ctx.cfg, ctx.t, start, plan);
                    slowest = slowest.max(e.finish);
                    first_ready = first_ready.min(e.first_ready);
                }
            }
        }
        if first_ready == u64::MAX {
            first_ready = slowest;
        }
        (slowest, first_ready)
    }

    /// Fold channel/engine counters into `stats` (call once at the end
    /// of a run; counters accumulate monotonically, so the fields are
    /// reset before summing).
    pub fn fold_stats(&self, stats: &mut SimStats) {
        stats.row_hits = 0;
        stats.row_misses = 0;
        stats.bytes_in = 0;
        stats.bytes_out = 0;
        stats.acts = 0;
        stats.pres = 0;
        stats.refreshes = 0;
        stats.mac_read_cycles = 0;
        stats.write_cycles = 0;
        stats.write_recoveries = 0;
        stats.bank_busy_cycles = 0;
        for ch in &self.channels {
            let (s, c) = ch.stats();
            stats.row_hits += s.row_hits;
            stats.row_misses += s.row_misses;
            stats.bytes_in += ch.bytes_in;
            stats.bytes_out += ch.bytes_out;
            stats.acts += c.act;
            stats.pres += c.pre;
            stats.refreshes += c.refresh;
            stats.mac_read_cycles += c.mac_read_cycles;
            stats.write_cycles += c.write_cycles;
            stats.write_recoveries += c.write_recoveries;
            stats.bank_busy_cycles += c.busy_cycles;
        }
        stats.asic_busy_cycles = self.engine.busy_cycles;
        stats.asic_ops = self.engine.ops_executed;
    }
}

pub(crate) fn asic_class(op: &AsicOp) -> LatClass {
    match op {
        AsicOp::Softmax { .. } => LatClass::Softmax,
        AsicOp::LayerNorm { .. } => LatClass::LayerNorm,
        AsicOp::Gelu { .. } => LatClass::Gelu,
        AsicOp::ResidualAdd { .. } => LatClass::Residual,
        AsicOp::PartialSum { .. } => LatClass::PartialSum,
        AsicOp::BiasAdd { .. } | AsicOp::Scale { .. } => LatClass::BiasScale,
        AsicOp::Concat { .. } => LatClass::Other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn setup(model: &str, streams: usize) -> (HwConfig, TimingCycles, GptModel, ModelMapping) {
        let cfg = HwConfig::paper_baseline().with_max_streams(streams);
        let t = TimingCycles::from_config(&cfg);
        let m = by_name(model).unwrap();
        let mapping = ModelMapping::build(&m, &cfg).unwrap();
        (cfg, t, m, mapping)
    }

    fn issue_one(
        cfg: &HwConfig,
        t: &TimingCycles,
        model: &GptModel,
        mapping: &ModelMapping,
        instr: &Instr,
        ltoken: u64,
    ) -> Issued {
        let mut res = Resources::new(cfg);
        let mut plan = empty_plan(cfg);
        let ctx = IssueCtx { cfg, t, model, mapping };
        res.issue(&ctx, &mut plan, instr, &[], 0, &[], &[], ltoken - 1, ltoken, 1, None)
    }

    fn issue_chunk(
        cfg: &HwConfig,
        t: &TimingCycles,
        model: &GptModel,
        mapping: &ModelMapping,
        instr: &Instr,
        pos: u64,
        passes: u64,
    ) -> Issued {
        let mut res = Resources::new(cfg);
        let mut plan = empty_plan(cfg);
        let ctx = IssueCtx { cfg, t, model, mapping };
        res.issue(&ctx, &mut plan, instr, &[], 0, &[], &[], pos, pos + passes, passes, None)
    }

    /// Regression pin (satellite): a WriteV's units serialize over each
    /// channel's shared bus in issue order, so its finish equals
    /// `banks_per_channel * n_cols * per_element_write_cost` — not the
    /// per-unit cost the old `ready`-start code produced whenever bank
    /// `busy_until`s were all clear.
    #[test]
    fn writev_serializes_per_channel_pinned() {
        let (cfg, t, m, mapping) = setup("gpt2-small", 1);
        // gpt2-small: 768 cols / 128 units = 6 V columns per unit, every
        // unit identical. Each column write is ACT + 1 write + tWR (+
        // tRAS residency) + PRE; see `Bank::write_col_major`.
        let n_cols = 6u64;
        let per_elem = (t.trcd + t.tccd + t.twr).max(t.tras) + t.trp;
        let per_unit = n_cols * per_elem;
        let per_channel = cfg.gddr6.banks_per_channel as u64 * per_unit;
        let out = issue_one(&cfg, &t, &m, &mapping, &Instr::WriteV { layer: 0, slot: 0 }, 1);
        assert_eq!(out.finish, per_channel, "expected full per-channel serialization");
        // Sanity: strictly more than one unit's worth (the old bug).
        assert!(out.finish > per_unit);
    }

    /// Tentpole pin (chunked prefill): issuing one instruction with
    /// `passes = T` costs strictly less than issuing it `T` times
    /// position by position for weight VMMs (activation + GB-staging
    /// amortization) and ASIC ops (fill amortization), and exactly the
    /// per-position sum for KV writes (column-major writes have no
    /// locality to amortize; K writes land on different units whose
    /// channel buses run in parallel, so the chunk can even finish
    /// earlier — never later than the slowest single position).
    #[test]
    fn chunk_issue_amortizes_weight_vmms_and_asic() {
        use crate::model::MatrixId;
        let (cfg, t, m, mapping) = setup("gpt2-small", 1);
        let passes = 8u64;

        let vmm = Instr::PimVmm {
            matrix: MatrixId::new(0, MatrixKind::Wqkv),
            class: crate::model::VmmClass::Qkv,
            in_elems: m.d_model as u64,
            out_elems: 3 * m.d_model as u64,
            parts: 1,
            slot: 0,
        };
        let chunk = issue_chunk(&cfg, &t, &m, &mapping, &vmm, 0, passes);
        let mut serial = Resources::new(&cfg);
        let mut plan = empty_plan(&cfg);
        let ctx = IssueCtx { cfg: &cfg, t: &t, model: &m, mapping: &mapping };
        let mut fin = 0u64;
        for p in 0..passes {
            fin = serial
                .issue(&ctx, &mut plan, &vmm, &[], fin, &[], &[], p, p + 1, 1, None)
                .finish;
        }
        assert!(chunk.finish < fin, "chunk VMM {} !< serial {fin}", chunk.finish);

        let gelu = Instr::Asic(crate::asic::AsicOp::Gelu { n: 4 * m.d_model as u64 });
        let chunk = issue_chunk(&cfg, &t, &m, &mapping, &gelu, 0, passes);
        let single = issue_one(&cfg, &t, &m, &mapping, &gelu, 1);
        assert!(chunk.finish < passes * single.finish, "asic fill must amortize");
        assert!(chunk.finish > single.finish, "a chunk still covers more work");

        // K writes: a chunk stores every position; round-robin units put
        // consecutive positions on different channels, so the chunk is
        // bounded by the per-position cost, not the sum.
        let wk = Instr::WriteK { layer: 0, slot: 0 };
        let chunk = issue_chunk(&cfg, &t, &m, &mapping, &wk, 0, passes);
        let single = issue_one(&cfg, &t, &m, &mapping, &wk, 1);
        assert!(chunk.finish >= single.finish);
        assert!(chunk.finish <= passes * single.finish);

        // V writes: no locality to amortize — exactly the serial sum
        // (per-channel chains extend over the chunk's positions).
        let wv = Instr::WriteV { layer: 0, slot: 0 };
        let chunk = issue_chunk(&cfg, &t, &m, &mapping, &wv, 0, passes);
        let single = issue_one(&cfg, &t, &m, &mapping, &wv, 1);
        assert_eq!(chunk.finish, passes * single.finish);
    }

    /// Paged-KV pin: with page size = max_seq (one page per context) the
    /// paged mapping assigns the identical base rows as the slot build,
    /// and every KV instruction issued through a one-entry page table is
    /// cycle-identical to the slot-addressed issue — the resource-layer
    /// half of the `kv_paging` equivalence contract.
    #[test]
    fn paged_full_context_issue_is_cycle_identical() {
        use crate::model::MatrixId;
        let (cfg, t, m, mapping) = setup("gpt2-small", 2);
        let mut pcfg = cfg.clone();
        pcfg.sched.kv_paging = true;
        pcfg.sched.kv_page_tokens = m.max_seq as u64;
        let pmapping = ModelMapping::build(&m, &pcfg).unwrap();
        assert_eq!(pmapping.kv.page_tokens, Some(m.max_seq as u64));
        assert_eq!(pmapping.kv.n_slots, mapping.kv.n_slots, "frame pool == slot pool");
        let instrs = [
            Instr::PimVmm {
                matrix: MatrixId::new(1, MatrixKind::KCache),
                class: crate::model::VmmClass::Score,
                in_elems: m.d_model as u64,
                out_elems: 0,
                parts: 1,
                slot: 0,
            },
            Instr::PimVmm {
                matrix: MatrixId::new(1, MatrixKind::VCache),
                class: crate::model::VmmClass::AttnV,
                in_elems: 64,
                out_elems: 0,
                parts: 1,
                slot: 0,
            },
            Instr::WriteK { layer: 1, slot: 0 },
            Instr::WriteV { layer: 1, slot: 0 },
        ];
        for frame in 0..2u32 {
            let pages = [frame];
            for instr in &instrs {
                let mut slotted = instr.clone();
                match &mut slotted {
                    Instr::PimVmm { slot, .. }
                    | Instr::WriteK { slot, .. }
                    | Instr::WriteV { slot, .. } => *slot = frame as usize,
                    _ => {}
                }
                for ltoken in [1u64, 129, 777] {
                    let base = issue_one(&cfg, &t, &m, &mapping, &slotted, ltoken);
                    let mut res = Resources::new(&pcfg);
                    let mut plan = empty_plan(&pcfg);
                    let ctx = IssueCtx { cfg: &pcfg, t: &t, model: &m, mapping: &pmapping };
                    let paged = res.issue(
                        &ctx,
                        &mut plan,
                        instr,
                        &[],
                        0,
                        &[],
                        &[],
                        ltoken - 1,
                        ltoken,
                        1,
                        Some(&pages),
                    );
                    assert_eq!(
                        (base.finish, base.first_ready),
                        (paged.finish, paged.first_ready),
                        "{instr:?} frame {frame} ltoken {ltoken}"
                    );
                }
            }
        }
    }

    /// Slot choice shifts KV base rows but never cycle costs: the same
    /// instruction issued against slot 0 and slot 1 of a 2-slot mapping
    /// must finish at the same cycle on fresh hardware.
    #[test]
    fn kv_slots_are_timing_equivalent() {
        let (cfg, t, m, mapping) = setup("gpt2-small", 2);
        assert_eq!(mapping.kv.n_slots, 2);
        for instr in [
            Instr::WriteV { layer: 1, slot: 0 },
            Instr::WriteK { layer: 1, slot: 0 },
        ] {
            let base = issue_one(&cfg, &t, &m, &mapping, &instr, 8);
            let mut other = instr.clone();
            match &mut other {
                Instr::WriteV { slot, .. } | Instr::WriteK { slot, .. } => *slot = 1,
                _ => unreachable!(),
            }
            let shifted = issue_one(&cfg, &t, &m, &mapping, &other, 8);
            assert_eq!(base.finish, shifted.finish, "{instr:?}");
        }
    }
}
