//! Simulation statistics: latency breakdown (Fig. 10), row-hit rates
//! (Fig. 11a), data movement (Fig. 11b) and the raw inputs of the energy
//! model.

use std::collections::BTreeMap;

use super::sched::StreamResult;
use crate::model::VmmClass;

/// Latency classes reported in the Fig. 10 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LatClass {
    Vmm(VmmClassKey),
    Softmax,
    LayerNorm,
    Gelu,
    Residual,
    PartialSum,
    BiasScale,
    KvWrite,
    Other,
}

/// Orderable mirror of `VmmClass`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum VmmClassKey {
    Qkv,
    Score,
    AttnV,
    Proj,
    Fc1,
    Fc2,
    LmHead,
}

impl From<VmmClass> for VmmClassKey {
    fn from(c: VmmClass) -> Self {
        match c {
            VmmClass::Qkv => Self::Qkv,
            VmmClass::Score => Self::Score,
            VmmClass::AttnV => Self::AttnV,
            VmmClass::Proj => Self::Proj,
            VmmClass::Fc1 => Self::Fc1,
            VmmClass::Fc2 => Self::Fc2,
            VmmClass::LmHead => Self::LmHead,
        }
    }
}

impl LatClass {
    pub fn label(&self) -> String {
        match self {
            LatClass::Vmm(k) => format!("vmm:{k:?}").to_lowercase(),
            other => format!("{other:?}").to_lowercase(),
        }
    }

    pub fn is_vmm(&self) -> bool {
        matches!(self, LatClass::Vmm(_))
    }
}

/// Aggregated run statistics.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// Total simulated cycles (DRAM clock).
    pub cycles: u64,
    /// Tokens generated.
    pub tokens: u64,
    /// Per-class *critical-path* cycles: each instruction's wall time is
    /// attributed to its class. Concurrent instructions (KV writes
    /// overlapping VMMs) can make the column sum exceed `cycles`; the
    /// breakdown is reported as proportions, like the paper's Fig. 10.
    pub class_cycles: BTreeMap<LatClass, u64>,
    /// DRAM row hits/misses at column-access granularity (Fig. 11a).
    pub row_hits: u64,
    pub row_misses: u64,
    /// Bytes over the PIM<->ASIC interface, by direction (Fig. 11b).
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// DRAM command totals (energy model inputs).
    pub acts: u64,
    pub pres: u64,
    pub refreshes: u64,
    pub mac_read_cycles: u64,
    pub write_cycles: u64,
    pub write_recoveries: u64,
    pub bank_busy_cycles: u64,
    /// ASIC engine busy cycles + op count.
    pub asic_busy_cycles: u64,
    pub asic_ops: u64,
    /// Instructions executed.
    pub instructions: u64,
    /// Compiled-program cache: lookups served from cache vs compiles.
    pub program_cache_hits: u64,
    pub program_cache_misses: u64,
    /// Disjoint per-stream KV contexts the mapping reserved (admission
    /// capacity of the multi-stream scheduler; 0 for single-stream runs
    /// that never finalize through `MultiSim`).
    pub kv_slots: u64,
    /// Most KV slots ever occupied at once.
    pub peak_slots_in_use: u64,
    /// Arrived requests found waiting with every KV slot occupied,
    /// summed over admission attempts (one attempt per `step()` entry
    /// plus one per stream retirement). The unit is *blocked requests*,
    /// not attempts: ten stuck requests weigh ten times one stuck
    /// request at every scheduling point, so the counter reads as
    /// queue-depth-weighted KV-capacity pressure. Not-yet-arrived
    /// (pending) requests never count — they are waiting on their own
    /// arrival, not on capacity.
    pub admission_blocked: u64,
    /// Requests shed by the admission policy (`sim::policy`,
    /// `StreamOutcome::Rejected`). Always 0 under `AdmitAlways`;
    /// rejected requests never appear in `streams` or the latency
    /// percentiles — they received no service.
    pub rejected: u64,
    /// Prefill chunk programs executed (`sim::prefill`; one per
    /// `sched.prefill_chunk`-sized slice of each admitted prompt — a
    /// 1-token prompt costs exactly one 1-position chunk).
    pub prefill_chunks: u64,
    /// Sum over retired streams of their prefill service (admission to
    /// prompt completion). Like `service_cycles`, per-stream spans
    /// overlap under concurrency, so the sum can exceed wall cycles.
    pub prefill_cycles: u64,
    /// Sum over retired streams of their decode service (prompt
    /// completion to last token). `prefill_cycles + decode_cycles` =
    /// summed `service_cycles`.
    pub decode_cycles: u64,
    /// Cycles an *idle* engine warped forward to the next arrival
    /// (`MultiSim::step` with no active stream). Makespan-based
    /// throughput divides by `cycles`, which under open-loop arrivals
    /// conflates offered load with capacity; `busy_cycles()` subtracts
    /// these gaps to measure the engine itself. Always 0 for
    /// batch-at-zero and single-stream runs.
    pub idle_cycles: u64,
    /// Fused decode sweeps executed (cross-stream batched decode: one
    /// multi-pass weight sweep shared by >= 2 streams' decode tokens).
    /// 0 whenever `sched.batch_decode` is off.
    pub fused_sweeps: u64,
    /// Sum of batch sizes over fused sweeps (mean occupancy =
    /// `fused_streams / fused_sweeps`).
    pub fused_streams: u64,
    /// Largest number of streams ever fused into one sweep.
    pub max_decode_batch: u64,
    /// Decode steps that ran unfused (solo) — either batching is off,
    /// or no same-regime partner was at its step boundary.
    pub solo_decode_steps: u64,
    /// Paged KV (`sched.kv_paging`): page-frame pool size the mapping
    /// reserved (`kv_slots` counts frames in paged mode; this mirrors
    /// it under the paging name). 0 when paging is off.
    pub kv_pages: u64,
    /// Most page frames ever allocated at once across all streams.
    pub peak_pages_in_use: u64,
    /// On-demand frame allocations that found the free list empty and
    /// had to preempt to make room. 0 whenever `kv_oversub` is 1.0.
    pub page_faults: u64,
    /// Streams evicted to resolve page faults (one stream may be
    /// preempted, re-admitted, and preempted again — each eviction
    /// counts).
    pub preemptions: u64,
    /// KV token positions written back on eviction, summed over
    /// preemptions (the modeled writeback/restore traffic is
    /// proportional to this).
    pub evicted_tokens: u64,
    /// PIM-GPT devices the model was partitioned across
    /// (`sched.devices`; 1 for every single-package run, including all
    /// runs that never go through `FleetSim`).
    pub devices: u64,
    /// Inter-device link cycles charged for activations crossing
    /// pipeline-stage boundaries, tensor-parallel all-reduces, and the
    /// LM-head gather (`mapping::partition` link-cost model). Always 0
    /// at `devices = 1`.
    pub link_transfer_cycles: u64,
    /// Per-device busy cycles (compute the device was charged,
    /// excluding link transfers), index = device id. Empty at
    /// `devices = 1` — single-package utilization stays in
    /// `bank_busy_cycles`/`asic_busy_cycles`.
    pub device_busy_cycles: Vec<u64>,
    /// Per-request-stream attribution (one entry per retired stream;
    /// empty for plain single-program runs).
    pub streams: Vec<StreamStats>,
    /// Windowed utilization timeline (`sim::trace::Timeline`): one row
    /// per `sched.trace_window` cycles with busy/idle/link cycles and
    /// pages-in-use. Empty whenever `trace_window` is 0 (the default),
    /// so pinned-stats equivalence is unaffected.
    pub timeline: Vec<super::trace::TraceWindow>,
    /// Trace-vs-stats reconciliation failure recorded at finalize when
    /// `sched.strict_reconcile` is on (release builds return the
    /// structured error instead of panicking; debug builds still
    /// panic). `None` = reconciled clean or reconciliation not run.
    pub reconcile_error: Option<String>,
}

/// Per-stream share of a multi-request run (`sim::sched::MultiSim`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StreamStats {
    pub id: u64,
    /// KV slot the stream occupied while in flight.
    pub kv_slot: u64,
    pub tokens: u64,
    /// Leading positions that were prompt (prefill); the rest decoded.
    pub prompt_tokens: u64,
    pub instructions: u64,
    /// Sum of per-instruction critical-path cycles attributed to this
    /// stream (same semantics as `class_cycles`: concurrency can make
    /// the sum across streams exceed wall cycles).
    pub attributed_cycles: u64,
    /// Simulated cycle the request arrived (open-loop traces; 0 for
    /// batch-at-zero runs).
    pub arrival_cycle: u64,
    /// Simulated cycles spent queued between arrival and admission.
    pub queue_cycles: u64,
    /// Simulated cycles from admission to last token
    /// (`prefill_cycles + decode_cycles()`).
    pub service_cycles: u64,
    /// Prefill share of the service: admission to prompt completion
    /// (the cycle the first generated token became available).
    pub prefill_cycles: u64,
    /// Time to first *generated* token: prompt-prefill completion minus
    /// arrival, queueing included — what a client actually waits before
    /// the first output token. For 1-token prompts this equals the
    /// first decode-step completion (the historical definition); see
    /// `StreamResult::ttft_cycles`.
    pub ttft_cycles: u64,
}

impl StreamStats {
    /// Derive the stats row from the stream's completion record — the
    /// single source of truth for queue/service/TTFT accounting, so the
    /// two views cannot drift apart.
    pub fn from_result(r: &StreamResult, instructions: u64, attributed_cycles: u64) -> Self {
        Self {
            id: r.id,
            kv_slot: r.kv_slot as u64,
            tokens: r.tokens,
            prompt_tokens: r.prompt_tokens,
            instructions,
            attributed_cycles,
            arrival_cycle: r.arrival_cycle,
            queue_cycles: r.queue_cycles(),
            service_cycles: r.service_cycles(),
            prefill_cycles: r.prefill_cycles(),
            ttft_cycles: r.ttft_cycles(),
        }
    }

    /// End-to-end latency: arrival to last token.
    pub fn e2e_cycles(&self) -> u64 {
        self.queue_cycles + self.service_cycles
    }

    /// Decode share of the service (prompt completion to last token).
    pub fn decode_cycles(&self) -> u64 {
        self.service_cycles - self.prefill_cycles
    }
}

/// Nearest-rank percentiles of a latency sample, in simulated cycles.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Percentiles {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl Percentiles {
    /// Nearest-rank percentiles (`sorted[ceil(q*n) - 1]`); `None` for an
    /// empty sample. Deterministic — no interpolation, no float compare.
    pub fn of(values: &[u64]) -> Option<Self> {
        if values.is_empty() {
            return None;
        }
        let mut v = values.to_vec();
        v.sort_unstable();
        let n = v.len();
        let pick = |q: f64| v[((q * n as f64).ceil() as usize).clamp(1, n) - 1];
        Some(Self { p50: pick(0.50), p95: pick(0.95), p99: pick(0.99), max: v[n - 1] })
    }
}

/// Tail-latency report of an open-loop run: percentiles of per-stream
/// queueing, time-to-first-*generated*-token (prompt-prefill
/// completion — see `StreamResult::ttft_cycles`) and end-to-end
/// latency (all measured from each request's *arrival* cycle).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencyReport {
    pub queue: Percentiles,
    pub ttft: Percentiles,
    pub e2e: Percentiles,
}

impl SimStats {
    pub fn add_class(&mut self, class: LatClass, cycles: u64) {
        *self.class_cycles.entry(class).or_insert(0) += cycles;
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            return 1.0;
        }
        self.row_hits as f64 / total as f64
    }

    pub fn bytes_moved(&self) -> u64 {
        self.bytes_in + self.bytes_out
    }

    /// Seconds at `freq_ghz` DRAM clock.
    pub fn seconds(&self, freq_ghz: f64) -> f64 {
        self.cycles as f64 / (freq_ghz * 1e9)
    }

    /// Makespan cycles minus idle arrival-gap warp cycles: the time the
    /// engine actually had work. The capacity-honest denominator for
    /// open-loop throughput (`tokens / busy_seconds`), equal to
    /// `cycles` for batch-at-zero runs.
    pub fn busy_cycles(&self) -> u64 {
        self.cycles.saturating_sub(self.idle_cycles)
    }

    /// `busy_cycles()` in seconds at `freq_ghz` DRAM clock.
    pub fn busy_seconds(&self, freq_ghz: f64) -> f64 {
        self.busy_cycles() as f64 / (freq_ghz * 1e9)
    }

    /// Mean streams per fused decode sweep (0.0 when nothing fused).
    pub fn mean_decode_batch(&self) -> f64 {
        if self.fused_sweeps == 0 {
            return 0.0;
        }
        self.fused_streams as f64 / self.fused_sweeps as f64
    }

    /// Fraction of attributed time spent in VMM classes.
    pub fn vmm_fraction(&self) -> f64 {
        let total: u64 = self.class_cycles.values().sum();
        if total == 0 {
            return 0.0;
        }
        let vmm: u64 = self.class_cycles.iter().filter(|(c, _)| c.is_vmm()).map(|(_, v)| v).sum();
        vmm as f64 / total as f64
    }

    /// Tail-latency percentiles over the retired streams (`None` until a
    /// stream has retired, e.g. single-program runs).
    pub fn latency_report(&self) -> Option<LatencyReport> {
        let queue: Vec<u64> = self.streams.iter().map(|s| s.queue_cycles).collect();
        let ttft: Vec<u64> = self.streams.iter().map(|s| s.ttft_cycles).collect();
        let e2e: Vec<u64> = self.streams.iter().map(|s| s.e2e_cycles()).collect();
        Some(LatencyReport {
            queue: Percentiles::of(&queue)?,
            ttft: Percentiles::of(&ttft)?,
            e2e: Percentiles::of(&e2e)?,
        })
    }

    /// Compiled-program cache hit rate (1.0 when never consulted).
    pub fn program_cache_hit_rate(&self) -> f64 {
        let total = self.program_cache_hits + self.program_cache_misses;
        if total == 0 {
            return 1.0;
        }
        self.program_cache_hits as f64 / total as f64
    }

    /// Mean busy fraction of the PIM bank units over the run
    /// (`total_units` = channels x banks_per_channel).
    pub fn pim_utilization(&self, total_units: u64) -> f64 {
        if self.cycles == 0 || total_units == 0 {
            return 0.0;
        }
        self.bank_busy_cycles as f64 / (self.cycles * total_units) as f64
    }

    /// Mean busy fraction of device `dev` over a fleet run (0.0 when
    /// the run had no wall time or the index is out of range — e.g.
    /// any single-package run, which leaves `device_busy_cycles`
    /// empty).
    pub fn device_utilization(&self, dev: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        match self.device_busy_cycles.get(dev) {
            Some(&busy) => busy as f64 / self.cycles as f64,
            None => 0.0,
        }
    }

    /// Busy fraction of the ASIC computation engines over the run.
    ///
    /// Deliberately *unclamped*: the engines serialize on `asic_free`,
    /// so busy cycles can never legitimately exceed wall cycles — a
    /// ratio above 1.0 means an attribution bug (double-counted busy
    /// time or a missing `cycles` update), and clamping it would mask
    /// exactly that. The `debug_assert` makes over-attribution loud in
    /// test builds while release reports the raw (diagnosable) ratio.
    pub fn asic_utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        debug_assert!(
            self.asic_busy_cycles <= self.cycles,
            "asic_busy_cycles {} exceeds wall cycles {} — attribution over-counting",
            self.asic_busy_cycles,
            self.cycles
        );
        self.asic_busy_cycles as f64 / self.cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_accumulation() {
        let mut s = SimStats::default();
        s.add_class(LatClass::Softmax, 10);
        s.add_class(LatClass::Softmax, 5);
        s.add_class(LatClass::Vmm(VmmClassKey::Qkv), 85);
        assert_eq!(s.class_cycles[&LatClass::Softmax], 15);
        assert!((s.vmm_fraction() - 0.85).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_bounds() {
        let s = SimStats { row_hits: 98, row_misses: 2, ..Default::default() };
        assert!((s.row_hit_rate() - 0.98).abs() < 1e-12);
        assert_eq!(SimStats::default().row_hit_rate(), 1.0);
    }

    #[test]
    fn seconds_conversion() {
        let s = SimStats { cycles: 2_000_000_000, ..Default::default() };
        assert!((s.seconds(1.0) - 2.0).abs() < 1e-12);
        assert!((s.seconds(2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn labels_lowercase() {
        assert_eq!(LatClass::Vmm(VmmClassKey::LmHead).label(), "vmm:lmhead");
        assert_eq!(LatClass::KvWrite.label(), "kvwrite");
    }

    #[test]
    fn cache_hit_rate_and_utilization() {
        let s = SimStats {
            program_cache_hits: 98,
            program_cache_misses: 2,
            cycles: 1000,
            bank_busy_cycles: 64_000,
            asic_busy_cycles: 250,
            ..Default::default()
        };
        assert!((s.program_cache_hit_rate() - 0.98).abs() < 1e-12);
        assert!((s.pim_utilization(128) - 0.5).abs() < 1e-12);
        assert!((s.asic_utilization() - 0.25).abs() < 1e-12);
        assert_eq!(SimStats::default().program_cache_hit_rate(), 1.0);
        assert_eq!(SimStats::default().asic_utilization(), 0.0);
    }

    #[test]
    fn device_utilization_per_device() {
        let s = SimStats {
            cycles: 1000,
            devices: 2,
            device_busy_cycles: vec![800, 500],
            ..Default::default()
        };
        assert!((s.device_utilization(0) - 0.8).abs() < 1e-12);
        assert!((s.device_utilization(1) - 0.5).abs() < 1e-12);
        assert_eq!(s.device_utilization(2), 0.0, "out of range -> 0");
        assert_eq!(SimStats::default().device_utilization(0), 0.0);
    }

    #[test]
    fn busy_cycles_subtract_idle_warp_time() {
        let s = SimStats { cycles: 1000, idle_cycles: 300, ..Default::default() };
        assert_eq!(s.busy_cycles(), 700);
        assert!((s.busy_seconds(1.0) - 700e-9).abs() < 1e-18);
        // Batch-at-zero runs never warp: busy == makespan.
        let s = SimStats { cycles: 1000, ..Default::default() };
        assert_eq!(s.busy_cycles(), s.cycles);
        // Defensive: idle beyond makespan saturates instead of wrapping.
        let s = SimStats { cycles: 10, idle_cycles: 99, ..Default::default() };
        assert_eq!(s.busy_cycles(), 0);
    }

    #[test]
    fn decode_batch_occupancy() {
        let s = SimStats::default();
        assert_eq!(s.mean_decode_batch(), 0.0, "nothing fused -> 0, not NaN");
        let s = SimStats { fused_sweeps: 4, fused_streams: 10, ..Default::default() };
        assert!((s.mean_decode_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_percentiles() {
        assert_eq!(Percentiles::of(&[]), None);
        assert_eq!(Percentiles::of(&[7]), Some(Percentiles { p50: 7, p95: 7, p99: 7, max: 7 }));
        // 1..=100 sorted: rank ceil(q*100) picks exactly q as a value.
        let v: Vec<u64> = (1..=100).rev().collect(); // unsorted input is fine
        let p = Percentiles::of(&v).unwrap();
        assert_eq!(p, Percentiles { p50: 50, p95: 95, p99: 99, max: 100 });
        // Small samples round up to the nearest rank.
        let p = Percentiles::of(&[10, 20, 30, 40]).unwrap();
        assert_eq!((p.p50, p.p95, p.p99, p.max), (20, 40, 40, 40));
    }

    #[test]
    fn latency_report_from_streams() {
        let mut s = SimStats::default();
        assert!(s.latency_report().is_none(), "no retired streams -> no report");
        let cases = [(0u64, 100u64, 30u64), (50, 100, 80), (200, 100, 230)];
        for (i, &(queue, service, ttft)) in cases.iter().enumerate() {
            s.streams.push(StreamStats {
                id: i as u64,
                queue_cycles: queue,
                service_cycles: service,
                ttft_cycles: ttft,
                ..Default::default()
            });
        }
        let r = s.latency_report().unwrap();
        assert_eq!(r.queue.p50, 50);
        assert_eq!(r.queue.p99, 200);
        assert_eq!(r.ttft.p50, 80);
        assert_eq!(r.e2e.p50, 150);
        assert_eq!(r.e2e.max, 300);
        // TTFT never exceeds end-to-end; e2e = queue + service.
        assert!(r.ttft.p99 <= r.e2e.p99);
    }

    /// Satellite acceptance: attribution over-counting is *detectable* —
    /// busy cycles beyond the wall clock trip the debug assertion
    /// instead of being silently clamped to a plausible 100%.
    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug_assert only fires in debug builds")]
    #[should_panic(expected = "attribution over-counting")]
    fn asic_over_attribution_detectable() {
        let s = SimStats { cycles: 100, asic_busy_cycles: 150, ..Default::default() };
        let _ = s.asic_utilization();
    }

    /// In release builds the same over-attribution shows up as a ratio
    /// above 1.0 (the clamp used to hide it at exactly 1.0).
    #[test]
    #[cfg_attr(debug_assertions, ignore = "covered by the should_panic variant in debug")]
    fn asic_over_attribution_visible_in_release() {
        let s = SimStats { cycles: 100, asic_busy_cycles: 150, ..Default::default() };
        assert!(s.asic_utilization() > 1.0);
    }
}
