//! Chunked-prefill planning and cost accounting.
//!
//! Prompt ingestion is compute-dense and latency-critical (time to
//! first token *is* the prefill completion), while decode is
//! memory-bound — serving-oriented PIM work treats the two as distinct
//! scheduling problems. This module owns the prefill side:
//!
//! * **Chunk planning** ([`chunks`]): a prompt of `P` positions is
//!   split into `ceil(P / chunk)` chunks of at most
//!   `sched.prefill_chunk` consecutive positions. Each chunk executes
//!   as *one* program (the decode template of its last position,
//!   served from the shared `ProgramCache`) issued in matrix-matrix
//!   mode: `Resources::issue` receives the chunk length as `passes`,
//!   so every weight row's ACT/PRE and every ASIC op's pipeline fill
//!   are paid once per chunk instead of once per position — prefill
//!   cost grows sublinearly in the chunk size.
//!
//! * **Amortization model**: per weight row, token-by-token prefill
//!   pays `T * (switch + fill + chunks·tCCD)`; a `T`-position chunk
//!   pays `switch + T * (fill + chunks·tCCD)` (`dram::bank`), the GB
//!   staging of the `T` input vectors pipelines under the MACs
//!   (`pim::channel`), and the ASIC executes one `T`-scaled op per
//!   node (`AsicOp::for_positions`). KV writes cover all `T`
//!   positions at full per-position cost (column-major V writes have
//!   no locality to amortize — paper §IV.B). KV *reads* charge the
//!   chunk-end context for every pass — conservative for the
//!   causally-masked earlier positions, but the parallel-bank
//!   critical path is set by the oldest token's unit either way.
//!
//! * **Head-of-line bound**: the multi-stream engine interleaves at
//!   instruction granularity, so a chunk's individual instructions —
//!   each up to `chunk`× longer than a decode-step instruction — are
//!   the unit of head-of-line blocking another stream can experience.
//!   `sched.prefill_chunk` is therefore a latency/throughput knob:
//!   larger chunks amortize more but hold shared resources longer.
//!
//! * **Isolated prefill cost** ([`isolated_prefill_cost`]): the exact
//!   uncontended critical path of a prompt's chunk sequence, replayed
//!   on scratch [`Resources`] (live hardware state untouched). The
//!   SLO admission predictor uses this instead of the old regime-0
//!   single-step replay, so admission decisions track the *actual*
//!   prompt length of each request. For a 1-token prompt it
//!   degenerates to exactly the regime-0 replay.
//!
//! **Determinism rules**: chunk boundaries are a pure function of
//! `(prompt_tokens, prefill_chunk)`; the cost replay consults no
//! clock and no RNG. `prefill_chunk = 1` issues every position with
//! `passes = 1` and is cycle-identical to the historical
//! token-by-token path (pinned in `tests/integration_sched.rs`).

use super::resources::{empty_plan, IssueCtx, Resources};
use crate::compiler::ProgramCache;
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::ModelMapping;
use crate::model::GptModel;
use anyhow::Result;

/// One prefill chunk: `len` consecutive positions starting at
/// `start_pos` (so it attends over `start_pos + len` tokens).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub start_pos: u64,
    pub len: u64,
}

impl Chunk {
    /// Context length after the chunk (the `ltoken` its KV reads use).
    pub fn ltoken_end(&self) -> u64 {
        self.start_pos + self.len
    }

    /// Position whose decode regime compiles this chunk's program (the
    /// chunk's last position — the conservative representative).
    pub fn regime_pos(&self) -> u64 {
        self.start_pos + self.len - 1
    }
}

/// Deterministic chunk schedule for a `prompt_tokens`-position prompt
/// at chunk size `chunk` (clamped to >= 1): full-size chunks followed
/// by one remainder chunk. `chunks(p, 1)` yields `p` single-position
/// chunks — the token-by-token path.
pub fn chunks(prompt_tokens: u64, chunk: u64) -> impl Iterator<Item = Chunk> {
    let step = chunk.max(1);
    let n = crate::util::ceil_div(prompt_tokens, step);
    (0..n).map(move |i| {
        chunk_at(i * step, prompt_tokens, step)
            .expect("i * step < prompt_tokens for every yielded index")
    })
}

/// The prefill chunk whose step begins at `pos`, or `None` once the
/// prompt is done (the caller is in decode). This is the single source
/// of truth for chunk boundaries: the engine's admission and
/// step-advance paths and the SLO predictor's replay (via [`chunks`])
/// all derive their chunk length and regime position from it.
pub fn chunk_at(pos: u64, prompt_tokens: u64, chunk: u64) -> Option<Chunk> {
    if pos >= prompt_tokens {
        return None;
    }
    Some(Chunk { start_pos: pos, len: chunk.max(1).min(prompt_tokens - pos) })
}

/// Chunk length of the step that begins at `pos` (0 once the prompt is
/// done).
pub fn chunk_len_at(pos: u64, prompt_tokens: u64, chunk: u64) -> u64 {
    chunk_at(pos, prompt_tokens, chunk).map_or(0, |c| c.len)
}

/// Exact uncontended critical path of prefilling a `prompt_tokens`
/// prompt under `cfg.sched.prefill_chunk`-sized chunks, replayed on
/// scratch hardware (the caller's live `Resources` are untouched).
/// Chunk programs come from (and warm) the shared `cache`. This is the
/// first-*generated*-token service bound the SLO admission predictor
/// pads with worst-case warm-start costs (`MultiSim`).
pub fn isolated_prefill_cost(
    model: &GptModel,
    cfg: &HwConfig,
    t: &TimingCycles,
    mapping: &ModelMapping,
    cache: &mut ProgramCache,
    prompt_tokens: u64,
) -> Result<u64> {
    let mut res = Resources::new(cfg);
    let mut plan = empty_plan(cfg);
    let mut finish: Vec<u64> = Vec::new();
    let mut first_ready: Vec<u64> = Vec::new();
    let ctx = IssueCtx { cfg, t, model, mapping };
    let mut step_start = 0u64;
    for c in chunks(prompt_tokens.max(1), cfg.sched.prefill_chunk) {
        let tpl = cache.get(model, cfg, c.regime_pos())?;
        finish.clear();
        first_ready.clear();
        let mut chunk_finish = step_start;
        for i in 0..tpl.len() {
            let instr = tpl.instr_at(i, c.ltoken_end(), 0);
            let out = res.issue(
                &ctx,
                &mut plan,
                &instr,
                tpl.deps_of(i),
                step_start,
                &finish,
                &first_ready,
                c.start_pos,
                c.ltoken_end(),
                c.len,
                // The admission predictor's uncontended bound stays
                // slot-addressed: page indirection shifts base rows, not
                // uncontended cycle costs.
                None,
            );
            first_ready.push(out.first_ready);
            finish.push(out.finish);
            chunk_finish = chunk_finish.max(out.finish);
        }
        step_start = chunk_finish;
    }
    Ok(step_start)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    #[test]
    fn chunk_plan_covers_the_prompt_exactly() {
        for (p, c, want) in [
            (1u64, 32u64, vec![(0u64, 1u64)]),
            (5, 1, vec![(0, 1), (1, 1), (2, 1), (3, 1), (4, 1)]),
            (64, 32, vec![(0, 32), (32, 32)]),
            (70, 32, vec![(0, 32), (32, 32), (64, 6)]),
            (32, 128, vec![(0, 32)]),
            // chunk = 0 clamps to 1 (token-by-token).
            (3, 0, vec![(0, 1), (1, 1), (2, 1)]),
        ] {
            let got: Vec<(u64, u64)> =
                chunks(p, c).map(|ch| (ch.start_pos, ch.len)).collect();
            assert_eq!(got, want, "prompt {p} chunk {c}");
            let covered: u64 = got.iter().map(|&(_, l)| l).sum();
            assert_eq!(covered, p);
            // Contiguous, in order.
            let mut next = 0;
            for &(s, l) in &got {
                assert_eq!(s, next);
                assert!(l >= 1);
                next = s + l;
            }
        }
    }

    #[test]
    fn chunk_len_at_matches_plan() {
        for p in [1u64, 5, 64, 70] {
            for c in [1u64, 8, 32] {
                for ch in chunks(p, c) {
                    assert_eq!(chunk_len_at(ch.start_pos, p, c), ch.len);
                }
                assert_eq!(chunk_len_at(p, p, c), 0, "decode positions have no chunk");
            }
        }
        assert_eq!(chunks(0, 8).count(), 0, "no prompt, no chunks");
    }

    /// The isolated cost is deterministic, strictly positive, and
    /// monotone in prompt length; chunking strictly beats token-by-token
    /// on a long prompt (the amortization the subsystem exists for).
    #[test]
    fn isolated_cost_monotone_and_amortized() {
        let m = by_name("gpt-nano").unwrap();
        let cost = |prompt: u64, chunk: u64| {
            let mut cfg = HwConfig::paper_baseline();
            cfg.sched.prefill_chunk = chunk;
            let mapping = ModelMapping::build(&m, &cfg).unwrap();
            let t = TimingCycles::from_config(&cfg);
            let mut cache = ProgramCache::new();
            isolated_prefill_cost(&m, &cfg, &t, &mapping, &mut cache, prompt).unwrap()
        };
        let c1 = cost(1, 32);
        assert!(c1 > 0);
        assert_eq!(c1, cost(1, 1), "a 1-token prompt is one 1-position chunk regardless");
        assert!(cost(16, 32) > c1, "longer prompts cost more");
        let tokenwise = cost(64, 1);
        let chunked = cost(64, 32);
        assert!(
            chunked < tokenwise,
            "chunked prefill {chunked} !< token-by-token {tokenwise}"
        );
        assert_eq!(cost(64, 32), chunked, "deterministic replay");
    }
}
