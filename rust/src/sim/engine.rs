//! Event-driven clock-cycle simulator (paper §V.A "Simulation
//! Configuration") — single-stream front end.
//!
//! The hardware is a tree — package -> 8 channels -> 16 banks — plus the
//! ASIC. Every node carries a `busy_until` ("next_time") and transitions
//! Idle -> Process when an instruction is issued, exactly as the paper
//! describes. Because each compiled instruction names its dependencies,
//! the data-triggered scheduler reduces to: issue each instruction at
//! `max(dep finish times, resource free time)` and record its finish.
//! Instruction order is topological, so a single in-order pass over the
//! program *is* the event-driven execution — there is no speculative
//! reordering in the hardware to model.
//!
//! The reservable hardware itself lives in [`super::resources::Resources`]
//! (shared with the multi-stream scheduler `sim::sched`); timing fidelity
//! lives in the leaf models: bank-level ACT/PRE/MAC/WR cycle layout
//! (`dram::bank`), channel GB-broadcast + drain pipelining
//! (`pim::channel`), ASIC engine add/mul streams (`asic::engine`), and
//! per-channel refresh (tREFI/tRFC).
//!
//! `decode_step` no longer rebuilds and re-lowers the decode graph per
//! token: programs are served from a [`ProgramCache`] keyed by position
//! regime, and the context length is applied as a runtime parameter
//! (`compiler::template`).

use super::resources::{empty_plan, IssueCtx, Resources};
use super::stats::SimStats;
use crate::asic::Engine;
use crate::compiler::{ProgramCache, ProgramTemplate};
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::ModelMapping;
use crate::model::GptModel;
use crate::pim::{Channel, VmmPlan};
use anyhow::Result;

/// Per-token result.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepResult {
    pub start_cycle: u64,
    pub finish_cycle: u64,
}

impl StepResult {
    pub fn cycles(&self) -> u64 {
        self.finish_cycle - self.start_cycle
    }
}

/// The PIM-GPT system simulator (one decode stream).
pub struct Simulator {
    pub cfg: HwConfig,
    pub model: GptModel,
    pub mapping: ModelMapping,
    t: TimingCycles,
    res: Resources,
    clock: u64,
    pub stats: SimStats,
    /// Reusable finish-time scratch (avoids per-step allocation).
    finish: Vec<u64>,
    /// First-partial-result time per instruction (== finish for non-VMM);
    /// streamable ASIC consumers may start here (paper §IV.A(3)).
    first_ready: Vec<u64>,
    /// Reusable per-channel VMM plan (bank_work rebuilt in place —
    /// profiling showed plan allocation churn was ~15% of sim time,
    /// EXPERIMENTS.md §Perf).
    plan_scratch: VmmPlan,
    /// Compiled-program cache (one template per position regime).
    cache: ProgramCache,
}

impl Simulator {
    pub fn new(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        let mapping = ModelMapping::build(model, cfg)?;
        let t = TimingCycles::from_config(cfg);
        Ok(Self {
            cfg: cfg.clone(),
            model: model.clone(),
            mapping,
            t,
            res: Resources::new(cfg),
            clock: 0,
            stats: SimStats::default(),
            finish: Vec::new(),
            first_ready: Vec::new(),
            plan_scratch: empty_plan(cfg),
            cache: ProgramCache::new(),
        })
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Simulate decoding the token at position `pos`.
    pub fn decode_step(&mut self, pos: u64) -> Result<StepResult> {
        let tpl = self.cache.get(&self.model, &self.cfg, pos)?;
        self.run_template(&tpl, pos)
    }

    /// Simulate a full generation of `n_tokens` (positions 0..n).
    pub fn generate(&mut self, n_tokens: u64) -> Result<StepResult> {
        let start = self.clock;
        for pos in 0..n_tokens {
            self.decode_step(pos)?;
        }
        Ok(StepResult { start_cycle: start, finish_cycle: self.clock })
    }

    /// Execute one compiled program template at token position `pos`
    /// (the context length `ltoken = pos + 1` specializes the
    /// position-scaled instructions at issue time).
    pub fn run_template(&mut self, tpl: &ProgramTemplate, pos: u64) -> Result<StepResult> {
        let ltoken = pos + 1;
        let step_start = self.clock;
        self.finish.clear();
        self.finish.reserve(tpl.len());
        self.first_ready.clear();
        self.first_ready.reserve(tpl.len());

        let ctx = IssueCtx {
            cfg: &self.cfg,
            t: &self.t,
            model: &self.model,
            mapping: &self.mapping,
        };
        for i in 0..tpl.len() {
            // Single-stream decoding always occupies KV slot 0 and runs
            // one position per step (`passes = 1`; chunked prefill lives
            // in the multi-stream engine, `sim::sched` + `sim::prefill`).
            let instr = tpl.instr_at(i, ltoken, 0);
            let out = self.res.issue(
                &ctx,
                &mut self.plan_scratch,
                &instr,
                tpl.deps_of(i),
                step_start,
                &self.finish,
                &self.first_ready,
                pos,
                ltoken,
                1,
                None,
            );
            // Streamable ops may *start* before `ready` (pipelined with
            // their producer) but never finish before it.
            self.stats.add_class(out.class, out.finish.saturating_sub(out.ready));
            self.first_ready.push(out.first_ready);
            self.finish.push(out.finish);
            self.clock = self.clock.max(out.finish);
        }

        self.stats.tokens += 1;
        self.stats.instructions += tpl.len() as u64;
        Ok(StepResult { start_cycle: step_start, finish_cycle: self.clock })
    }

    /// Fold channel/engine counters into the stats (call once at the end
    /// of a run; counters accumulate monotonically).
    pub fn finalize_stats(&mut self) -> &SimStats {
        self.stats.cycles = self.clock;
        self.res.fold_stats(&mut self.stats);
        self.stats.program_cache_hits = self.cache.hits;
        self.stats.program_cache_misses = self.cache.misses;
        &self.stats
    }

    /// Access to per-bank command counts (energy model).
    pub fn channels(&self) -> &[Channel] {
        &self.res.channels
    }

    pub fn engine(&self) -> &Engine {
        &self.res.engine
    }

    /// The compiled-program cache (hit/miss counters, entry count).
    pub fn program_cache(&self) -> &ProgramCache {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn sim(model: &str) -> Simulator {
        Simulator::new(&by_name(model).unwrap(), &HwConfig::paper_baseline()).unwrap()
    }

    #[test]
    fn one_token_advances_clock() {
        let mut s = sim("gpt2-small");
        let r = s.decode_step(0).unwrap();
        assert!(r.cycles() > 0);
        assert_eq!(s.clock(), r.finish_cycle);
    }

    #[test]
    fn later_tokens_cost_more() {
        // Attention grows with context: step at pos 500 must cost more
        // cycles than step at pos 0.
        let mut s = sim("gpt2-small");
        let r0 = s.decode_step(0).unwrap();
        let r500 = s.decode_step(500).unwrap();
        assert!(r500.cycles() > r0.cycles(), "{} vs {}", r500.cycles(), r0.cycles());
    }

    #[test]
    fn vmm_dominates_latency() {
        // Fig. 10: VMM operations dominate total execution time. The V
        // write-back serializes element writes over each channel's bus
        // (ACT + WR + PRE per element, no locality — paper §IV.B), so
        // KvWrite carries a real attributed share at short contexts;
        // VMM must still be the largest class by a wide margin and
        // dwarf every ASIC compute class.
        use crate::sim::LatClass;
        let mut s = sim("gpt2-small");
        for pos in 0..4 {
            s.decode_step(pos).unwrap();
        }
        s.finalize_stats();
        assert!(s.stats.vmm_fraction() > 0.6, "vmm fraction {}", s.stats.vmm_fraction());
        let total: u64 = s.stats.class_cycles.values().sum();
        let kv = s.stats.class_cycles.get(&LatClass::KvWrite).copied().unwrap_or(0);
        let vmm: u64 =
            s.stats.class_cycles.iter().filter(|(c, _)| c.is_vmm()).map(|(_, v)| v).sum();
        assert!(vmm > kv, "vmm {vmm} vs kv write {kv}");
        assert!(
            vmm as f64 / (total - kv) as f64 > 0.9,
            "vmm {vmm} of non-KV {}",
            total - kv
        );
    }

    #[test]
    fn row_hit_rate_high() {
        // Fig. 11a: ~98% for all tested GPT models.
        let mut s = sim("gpt2-small");
        for pos in 0..4 {
            s.decode_step(pos).unwrap();
        }
        s.finalize_stats();
        let rate = s.stats.row_hit_rate();
        assert!(rate > 0.95, "row hit rate {rate}");
    }

    #[test]
    fn bigger_model_slower() {
        let mut a = sim("gpt2-small");
        let mut b = sim("gpt2-medium");
        let ra = a.decode_step(0).unwrap();
        let rb = b.decode_step(0).unwrap();
        assert!(rb.cycles() > ra.cycles());
    }

    #[test]
    fn deterministic() {
        let mut a = sim("gpt3-small");
        let mut b = sim("gpt3-small");
        for pos in 0..3 {
            assert_eq!(a.decode_step(pos).unwrap().cycles(), b.decode_step(pos).unwrap().cycles());
        }
    }

    #[test]
    fn per_token_latency_plausible() {
        // gpt2-small (124M params): weights alone need P/(128 units * 16
        // lanes) = ~61k cycles of pure MAC; with ACT/PRE overheads the
        // step must land within a small factor of that.
        let mut s = sim("gpt2-small");
        let r = s.decode_step(0).unwrap();
        let pure_mac = 124e6 / (128.0 * 16.0);
        let ratio = r.cycles() as f64 / pure_mac;
        assert!(ratio > 1.0 && ratio < 3.0, "ratio {ratio} ({} cycles)", r.cycles());
    }

    #[test]
    fn stats_bytes_match_channels() {
        let mut s = sim("gpt-nano");
        s.decode_step(0).unwrap();
        s.finalize_stats();
        let direct: u64 = s.channels().iter().map(|c| c.bytes_transferred()).sum();
        assert_eq!(s.stats.bytes_moved(), direct);
        assert!(direct > 0);
    }

    #[test]
    fn program_cache_amortizes_compilation() {
        // Acceptance: > 90% hit rate on a 256-token generation.
        let mut s = sim("gpt2-small");
        s.generate(256).unwrap();
        s.finalize_stats();
        assert_eq!(s.stats.program_cache_misses, 2); // one per regime
        assert_eq!(s.stats.program_cache_hits, 254);
        assert!(s.stats.program_cache_hit_rate() > 0.9);
        assert_eq!(s.program_cache().len(), 2);
    }

    #[test]
    fn utilization_counters_sane() {
        let mut s = sim("gpt2-small");
        s.generate(4).unwrap();
        s.finalize_stats();
        let units = s.cfg.total_mac_units() as u64;
        let pim = s.stats.pim_utilization(units);
        let asic = s.stats.asic_utilization();
        assert!(pim > 0.0 && pim <= 1.0, "pim util {pim}");
        assert!(asic > 0.0 && asic <= 1.0, "asic util {asic}");
    }
}
