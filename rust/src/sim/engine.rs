//! Event-driven clock-cycle simulator (paper §V.A "Simulation
//! Configuration").
//!
//! The hardware is a tree — package -> 8 channels -> 16 banks — plus the
//! ASIC. Every node carries a `busy_until` ("next_time") and transitions
//! Idle -> Process when an instruction is issued, exactly as the paper
//! describes. Because each compiled instruction names its dependencies,
//! the data-triggered scheduler reduces to: issue each instruction at
//! `max(dep finish times, resource free time)` and record its finish.
//! Instruction order is topological, so a single in-order pass over the
//! program *is* the event-driven execution — there is no speculative
//! reordering in the hardware to model.
//!
//! Timing fidelity lives in the leaf models: bank-level ACT/PRE/MAC/WR
//! cycle layout (`dram::bank`), channel GB-broadcast + drain pipelining
//! (`pim::channel`), ASIC engine add/mul streams (`asic::engine`), and
//! per-channel refresh (tREFI/tRFC).

use super::stats::{LatClass, SimStats};
use crate::asic::{AsicOp, Engine};
use crate::compiler::{compile, Instr, Program};
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::ModelMapping;
use crate::model::{DecodeGraph, GptModel, MatrixKind};
use crate::pim::{Channel, UnitWork, VmmPlan};
use anyhow::Result;

/// Cycles to flush the last streamed chunk through an ASIC engine after
/// its final input arrives (engine fill + one burst).
const TAIL_CYCLES: u64 = 12;

/// Per-token result.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepResult {
    pub start_cycle: u64,
    pub finish_cycle: u64,
}

impl StepResult {
    pub fn cycles(&self) -> u64 {
        self.finish_cycle - self.start_cycle
    }
}

/// The PIM-GPT system simulator.
pub struct Simulator {
    pub cfg: HwConfig,
    pub model: GptModel,
    pub mapping: ModelMapping,
    t: TimingCycles,
    channels: Vec<Channel>,
    engine: Engine,
    /// ASIC engine availability (ops serialize on the engines).
    asic_free: u64,
    clock: u64,
    pub stats: SimStats,
    /// Reusable finish-time scratch (avoids per-step allocation).
    finish: Vec<u64>,
    /// First-partial-result time per instruction (== finish for non-VMM);
    /// streamable ASIC consumers may start here (paper §IV.A(3)).
    first_ready: Vec<u64>,
    /// Reusable per-channel VMM plan (bank_work rebuilt in place —
    /// profiling showed plan allocation churn was ~15% of sim time,
    /// EXPERIMENTS.md §Perf).
    plan_scratch: VmmPlan,
}

impl Simulator {
    pub fn new(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        let mapping = ModelMapping::build(model, cfg)?;
        let t = TimingCycles::from_config(cfg);
        let channels = (0..cfg.gddr6.channels).map(|_| Channel::new(cfg)).collect();
        Ok(Self {
            cfg: cfg.clone(),
            model: model.clone(),
            mapping,
            t,
            channels,
            engine: Engine::new(cfg),
            asic_free: 0,
            clock: 0,
            stats: SimStats::default(),
            finish: Vec::new(),
            first_ready: Vec::new(),
            plan_scratch: VmmPlan {
                bank_work: (0..cfg.gddr6.banks_per_channel).map(|_| UnitWork::Idle).collect(),
                input_elems: 0,
                output_elems: 0,
            },
        })
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Simulate decoding the token at position `pos`.
    pub fn decode_step(&mut self, pos: u64) -> Result<StepResult> {
        let graph = DecodeGraph::build(&self.model, pos);
        let program = compile(&graph, &self.cfg)?;
        self.run_program(&program, pos)
    }

    /// Simulate a full generation of `n_tokens` (positions 0..n).
    pub fn generate(&mut self, n_tokens: u64) -> Result<StepResult> {
        let start = self.clock;
        for pos in 0..n_tokens {
            self.decode_step(pos)?;
        }
        Ok(StepResult { start_cycle: start, finish_cycle: self.clock })
    }

    /// Execute one compiled program; the token position drives KV
    /// addressing.
    pub fn run_program(&mut self, program: &Program, pos: u64) -> Result<StepResult> {
        let step_start = self.clock;
        self.finish.clear();
        self.finish.reserve(program.nodes.len());
        self.first_ready.clear();
        self.first_ready.reserve(program.nodes.len());

        for node in &program.nodes {
            let mut ready = step_start;
            for &d in &node.deps {
                ready = ready.max(self.finish[d]);
            }
            let mut node_first_ready = None;
            let (fin, class) = match &node.instr {
                Instr::PimVmm { matrix, class, in_elems, out_elems, parts } => {
                    let (fin, fr) = self.exec_vmm(ready, matrix.layer, matrix.kind, *in_elems, *out_elems, *parts, program.ltoken);
                    node_first_ready = Some(fr.min(fin));
                    (fin, LatClass::Vmm((*class).into()))
                }
                Instr::Asic(op) => {
                    // Pipelining (paper §IV.A(3)): a streamable op begins
                    // once every dependency has *started producing* —
                    // VMM deps gate at first_ready — but cannot finish
                    // before all inputs have fully arrived (dep finish)
                    // plus the tail of processing the last chunk.
                    let start = if op.streamable() {
                        let mut s = step_start;
                        for &d in &node.deps {
                            s = s.max(self.first_ready[d]);
                        }
                        s.max(self.asic_free)
                    } else {
                        ready.max(self.asic_free)
                    };
                    let fin = self.engine.execute(start, op);
                    let fin = if op.streamable() {
                        // Last-chunk tail: engine fill + one burst.
                        fin.max(ready + TAIL_CYCLES)
                    } else {
                        fin
                    };
                    self.asic_free = fin;
                    (fin, asic_class(op))
                }
                Instr::WriteK { layer } => {
                    let (unit, segs) = self.mapping.kv.k_write(*layer, pos);
                    let mut fin = ready;
                    for seg in segs {
                        fin = self.channels[unit.channel].write_k(&self.t, fin, unit.bank, seg);
                    }
                    (fin, LatClass::KvWrite)
                }
                Instr::WriteV { layer } => {
                    let n_units = self.mapping.kv.n_units;
                    let banks = self.mapping.kv.banks_per_channel;
                    let mut fin = ready;
                    for u in 0..n_units {
                        let (base, n_cols, stride) = self.mapping.kv.v_write(*layer, pos, u);
                        if n_cols == 0 {
                            continue;
                        }
                        let f = self.channels[u / banks].write_v(&self.t, ready, u % banks, n_cols, base, stride);
                        fin = fin.max(f);
                    }
                    (fin, LatClass::KvWrite)
                }
            };
            // Streamable ops may *start* before `ready` (pipelined with
            // their producer) but never finish before it.
            let attributed = fin.saturating_sub(ready);
            self.stats.add_class(class, attributed);
            self.first_ready.push(node_first_ready.unwrap_or(fin));
            self.finish.push(fin);
            self.clock = self.clock.max(fin);
        }

        self.stats.tokens += 1;
        self.stats.instructions += program.nodes.len() as u64;
        Ok(StepResult { start_cycle: step_start, finish_cycle: self.clock })
    }

    /// Dispatch a VMM to all channels; returns (slowest finish, earliest
    /// first-partial-result time).
    fn exec_vmm(
        &mut self,
        start: u64,
        layer: usize,
        kind: MatrixKind,
        in_elems: u64,
        _out_elems: u64,
        _parts: u64,
        ltoken: u64,
    ) -> (u64, u64) {
        let banks = self.cfg.gddr6.banks_per_channel;
        let n_head = self.model.n_head as u64;
        let mut slowest = start;
        let mut first_ready = u64::MAX;
        let plan = &mut self.plan_scratch;
        plan.input_elems = in_elems;
        match kind {
            MatrixKind::KCache | MatrixKind::VCache => {
                // KV reads are uniform repetitions of a row-fill pattern
                // per unit: O(1) work via `Bank::mac_pattern` regardless
                // of context length (EXPERIMENTS.md §Perf iteration 2).
                let kv = &self.mapping.kv;
                let (pattern, pattern_len) = if kind == MatrixKind::KCache {
                    kv.k_read_pattern()
                } else {
                    kv.v_read_pattern(ltoken)
                };
                for (ch, channel) in self.channels.iter_mut().enumerate() {
                    let mut out = 0u64;
                    for b in 0..banks {
                        let u = ch * banks + b;
                        let (base_row, reps) = if kind == MatrixKind::KCache {
                            out += kv.k_out_elems(u, ltoken, n_head);
                            (kv.k_base[layer][u], kv.k_owned(u, ltoken))
                        } else {
                            let cols = kv.v_cols(u);
                            out += cols as u64;
                            (kv.v_base[layer][u], cols)
                        };
                        plan.bank_work[b] =
                            UnitWork::Pattern { base_row, reps, pattern, pattern_len };
                    }
                    plan.output_elems = out;
                    let e = channel.execute_vmm(&self.cfg, &self.t, start, plan);
                    slowest = slowest.max(e.finish);
                    first_ready = first_ready.min(e.first_ready);
                }
            }
            _ => {
                let id = crate::model::MatrixId::new(layer, kind);
                let placement = &self.mapping.matrices[&id];
                for (ch, channel) in self.channels.iter_mut().enumerate() {
                    let mut out = 0u64;
                    for b in 0..banks {
                        let u = ch * banks + b;
                        out += placement.out_cols[u];
                        plan.bank_work[b] = UnitWork::Block(placement.per_unit[u]);
                    }
                    plan.output_elems = out;
                    let e = channel.execute_vmm(&self.cfg, &self.t, start, plan);
                    slowest = slowest.max(e.finish);
                    first_ready = first_ready.min(e.first_ready);
                }
            }
        }
        if first_ready == u64::MAX {
            first_ready = slowest;
        }
        (slowest, first_ready)
    }

    /// Fold channel/engine counters into the stats (call once at the end
    /// of a run; counters accumulate monotonically).
    pub fn finalize_stats(&mut self) -> &SimStats {
        self.stats.cycles = self.clock;
        self.stats.row_hits = 0;
        self.stats.row_misses = 0;
        self.stats.bytes_in = 0;
        self.stats.bytes_out = 0;
        self.stats.acts = 0;
        self.stats.pres = 0;
        self.stats.refreshes = 0;
        self.stats.mac_read_cycles = 0;
        self.stats.write_cycles = 0;
        self.stats.write_recoveries = 0;
        self.stats.bank_busy_cycles = 0;
        for ch in &self.channels {
            let (s, c) = ch.stats();
            self.stats.row_hits += s.row_hits;
            self.stats.row_misses += s.row_misses;
            self.stats.bytes_in += ch.bytes_in;
            self.stats.bytes_out += ch.bytes_out;
            self.stats.acts += c.act;
            self.stats.pres += c.pre;
            self.stats.refreshes += c.refresh;
            self.stats.mac_read_cycles += c.mac_read_cycles;
            self.stats.write_cycles += c.write_cycles;
            self.stats.write_recoveries += c.write_recoveries;
            self.stats.bank_busy_cycles += c.busy_cycles;
        }
        self.stats.asic_busy_cycles = self.engine.busy_cycles;
        self.stats.asic_ops = self.engine.ops_executed;
        &self.stats
    }

    /// Access to per-bank command counts (energy model).
    pub fn channels(&self) -> &[Channel] {
        &self.channels
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

fn asic_class(op: &AsicOp) -> LatClass {
    match op {
        AsicOp::Softmax { .. } => LatClass::Softmax,
        AsicOp::LayerNorm { .. } => LatClass::LayerNorm,
        AsicOp::Gelu { .. } => LatClass::Gelu,
        AsicOp::ResidualAdd { .. } => LatClass::Residual,
        AsicOp::PartialSum { .. } => LatClass::PartialSum,
        AsicOp::BiasAdd { .. } | AsicOp::Scale { .. } => LatClass::BiasScale,
        AsicOp::Concat { .. } => LatClass::Other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn sim(model: &str) -> Simulator {
        Simulator::new(&by_name(model).unwrap(), &HwConfig::paper_baseline()).unwrap()
    }

    #[test]
    fn one_token_advances_clock() {
        let mut s = sim("gpt2-small");
        let r = s.decode_step(0).unwrap();
        assert!(r.cycles() > 0);
        assert_eq!(s.clock(), r.finish_cycle);
    }

    #[test]
    fn later_tokens_cost_more() {
        // Attention grows with context: step at pos 500 must cost more
        // cycles than step at pos 0.
        let mut s = sim("gpt2-small");
        let r0 = s.decode_step(0).unwrap();
        let r500 = s.decode_step(500).unwrap();
        assert!(r500.cycles() > r0.cycles(), "{} vs {}", r500.cycles(), r0.cycles());
    }

    #[test]
    fn vmm_dominates_latency() {
        // Fig. 10: VMM operations dominate total execution time.
        let mut s = sim("gpt2-small");
        for pos in 0..4 {
            s.decode_step(pos).unwrap();
        }
        s.finalize_stats();
        assert!(s.stats.vmm_fraction() > 0.8, "vmm fraction {}", s.stats.vmm_fraction());
    }

    #[test]
    fn row_hit_rate_high() {
        // Fig. 11a: ~98% for all tested GPT models.
        let mut s = sim("gpt2-small");
        for pos in 0..4 {
            s.decode_step(pos).unwrap();
        }
        s.finalize_stats();
        let rate = s.stats.row_hit_rate();
        assert!(rate > 0.95, "row hit rate {rate}");
    }

    #[test]
    fn bigger_model_slower() {
        let mut a = sim("gpt2-small");
        let mut b = sim("gpt2-medium");
        let ra = a.decode_step(0).unwrap();
        let rb = b.decode_step(0).unwrap();
        assert!(rb.cycles() > ra.cycles());
    }

    #[test]
    fn deterministic() {
        let mut a = sim("gpt3-small");
        let mut b = sim("gpt3-small");
        for pos in 0..3 {
            assert_eq!(a.decode_step(pos).unwrap().cycles(), b.decode_step(pos).unwrap().cycles());
        }
    }

    #[test]
    fn per_token_latency_plausible() {
        // gpt2-small (124M params): weights alone need P/(128 units * 16
        // lanes) = ~61k cycles of pure MAC; with ACT/PRE overheads the
        // step must land within a small factor of that.
        let mut s = sim("gpt2-small");
        let r = s.decode_step(0).unwrap();
        let pure_mac = 124e6 / (128.0 * 16.0);
        let ratio = r.cycles() as f64 / pure_mac;
        assert!(ratio > 1.0 && ratio < 3.0, "ratio {ratio} ({} cycles)", r.cycles());
    }

    #[test]
    fn stats_bytes_match_channels() {
        let mut s = sim("gpt-nano");
        s.decode_step(0).unwrap();
        s.finalize_stats();
        let direct: u64 = s.channels().iter().map(|c| c.bytes_transferred()).sum();
        assert_eq!(s.stats.bytes_moved(), direct);
        assert!(direct > 0);
    }
}
