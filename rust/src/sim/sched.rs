//! Multi-stream resource-reservation scheduler: interleaves the
//! instruction streams of up to K concurrent decode requests on the
//! shared PIM + ASIC hardware.
//!
//! The paper's simulator (and the seed's `Simulator`) executes one
//! program at a time, so the whole package idles whenever a single
//! request's ASIC op blocks its own critical path. Here each in-flight
//! request keeps its own dependency-tracking cursor over its compiled
//! program (served from the shared `ProgramCache`), and the scheduler
//! repeatedly issues one stream's next instruction through the same
//! `Resources::issue` path the single-stream simulator uses. Resource
//! contention needs no global event queue — every channel bus, bank and
//! the ASIC engine carries its own `busy_until` and serializes whatever
//! lands on it — so one request's ASIC softmax naturally overlaps
//! another's bank-level VMM.
//!
//! **Scheduling policies** (`super::policy`): *which* stream runs is a
//! pluggable decision. A `PickPolicy` picks both the queued request that
//! gets the next free KV slot and the active stream that issues next
//! (`fcfs` — the historical greedy earliest-dependency-ready rule,
//! extracted; `srf` — shortest-remaining-first; `fair` — deficit
//! round-robin over stream slots), and an `AdmissionPolicy` decides
//! *whether* a picked request is admitted at all (`AdmitAlways`;
//! `SloAdmission`, which sheds requests whose predicted TTFT busts a
//! budget). Rejected requests retire as first-class
//! [`StreamOutcome::Rejected`] results. With the default `fcfs` policy
//! the engine is cycle-identical to the pre-policy scheduler, and with
//! `max_streams = 1` it reproduces the single-stream `Simulator`
//! token-for-token (`tests/integration_sched.rs`).
//!
//! **Chunked prefill** (`super::prefill`): every request carries a
//! prompt/generation split. The leading `prompt_tokens` positions run
//! as a sequence of `sched.prefill_chunk`-sized *chunk programs* — one
//! instruction stream covering up to `chunk` consecutive positions,
//! issued in matrix-matrix mode so weight-row activations, GB staging
//! and ASIC pipeline fills amortize over the chunk — and the remaining
//! positions decode one token per step. Chunk instructions interleave
//! with other streams' decode instructions at the same per-instruction
//! granularity, so `prefill_chunk` bounds the head-of-line blocking a
//! long prompt can inflict (each chunk instruction holds shared
//! resources up to `chunk`x longer than a decode instruction). TTFT is
//! the *first generated token*: the completion of the prompt's last
//! prefill position, when the first output token's logits exist. With
//! `prefill_chunk = 1` every position issues exactly like the
//! historical all-decode path, cycle for cycle.
//!
//! **Cross-stream batched decode** (`sched.batch_decode`): decode is
//! memory-bound — every generated token re-streams the full weight
//! matrices — so at K concurrent streams the unbatched engine pays the
//! same weight-row ACT/PRE and ASIC pipeline-fill cost K times per
//! layer per step. With batching on, active streams whose next step is
//! a decode token in the same position regime are *fused* into one
//! sweep ([`FusedBatch`]): the shareable nodes — weight-stationary
//! VMMs (QKV / attention output / FFN / LM head) and fixed-size ASIC
//! ops (`ProgramTemplate::shareable_across_streams`) — issue **once**
//! with `passes = K` through the same matrix-matrix machinery chunked
//! prefill uses, while the per-stream nodes (K/V writes, KCache/VCache
//! attention reads, position-scaled softmax/scale/partial sums) issue
//! once per member at that member's own position and KV slot. A
//! stream whose sweep boundary has a same-regime partner still
//! mid-step *waits at the boundary* to fuse with it; batches dissolve
//! when their sweep completes and re-form every step, so streams join
//! and leave between sweeps — continuous batching, not static
//! batching. `batch_decode = off` (the default) and K = 1 are
//! cycle-identical to the unbatched schedule on any arrival trace.
//!
//! **Open-loop arrivals**: every request carries an explicit
//! `arrival_cycle` (simulated time; 0 = present at start, reproducing
//! the closed-loop batch). `submit` is *host bookkeeping* and stamps
//! nothing — submitted requests wait in a pending set ordered by
//! arrival and are released into the admission queue only once
//! simulated time reaches their arrival (an idle engine warps time
//! forward to the next arrival; a busy engine releases the moment the
//! next issue would pass it). Arrival traces come from
//! [`super::arrivals`] (batch / fixed-interval / Poisson / JSON trace
//! replay).
//!
//! **KV-capacity admission**: the mapping reserves one disjoint
//! `max_seq` KV context per stream *slot* (`mapping::KvReservation`,
//! up to `max_streams` slots, fewer when DRAM rows run out — see
//! `ModelMapping::kv_shortfall`). A released request is admitted only
//! when a free slot exists; it occupies that slot's reserved KV rows
//! for its whole lifetime and the slot id is recycled at retirement.
//! Admission is stamped at `max(arrival cycle, slot free cycle)` — the
//! cycle the hardware could actually have started it — so
//! `queue_cycles` measures real KV-capacity queueing from the
//! request's own arrival, never from the global clock high-water mark
//! (which can sit far ahead of a mid-run arrival and would corrupt
//! every queue/TTFT percentile). Blocked requests, peak slot occupancy
//! and policy rejections are counted in `SimStats`
//! (`admission_blocked`, `peak_slots_in_use`, `rejected`).
//!
//! **Paged KV** (`sched.kv_paging`): instead of one worst-case
//! `max_seq` KV region per stream, the mapping carves its KV budget
//! into fixed-size *page frames* of `sched.kv_page_tokens` positions
//! (`mapping::KvReservation::build_paged`) and each stream owns a page
//! *table* — logical token pages mapped to physical frames, grown on
//! demand as its context advances. Admission then charges a stream its
//! *expected* footprint (`ceil(n_tokens / P)` frames — the size it will
//! actually reach) instead of a full `max_seq` reservation, so short
//! requests stop paying for contexts they never grow;
//! `sched.kv_oversub > 1` additionally lets the committed total
//! overshoot the physical pool. When an on-demand frame allocation
//! finds the free list empty (a *page fault* — only possible when
//! oversubscribed), the engine preempts a victim stream
//! (`PickPolicy::pick_victim`, default latest-admitted): the victim's
//! partial step is discarded, its KV context is written back at the
//! modeled interface cost, its frames and virtual slot are recycled,
//! and it waits in an evicted queue with priority over fresh
//! admissions — re-admission restores the context (same cost model)
//! and resumes at the evicted position with all its original stamps.
//! `kv_paging = off` (the default) is cycle-identical to the slot
//! engine, and paging with `kv_page_tokens = max_seq` and
//! `kv_oversub = 1` is *also* cycle-identical on any arrival trace —
//! one full-context frame per stream reproduces the slot layout row
//! for row (pinned here and in `tests/integration_sched.rs`).
//! Counters: `SimStats::{kv_pages, peak_pages_in_use, page_faults,
//! preemptions, evicted_tokens}`.

use std::collections::VecDeque;
use std::rc::Rc;

use super::policy::{self, AdmissionDecision, AdmissionPolicy, IssueCandidate, PickPolicy};
use super::prefill;
use super::resources::{empty_plan, IssueCtx, Resources};
use super::stats::{SimStats, StreamStats};
use super::trace::{TraceEvent, Tracer};
use crate::compiler::{PosRegime, ProgramCache, ProgramTemplate};
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::ModelMapping;
use crate::model::GptModel;
use crate::pim::VmmPlan;
use anyhow::{bail, Result};

/// One generation request, in simulator terms: positions
/// `0..n_tokens`, of which the leading `prompt_tokens` are prompt
/// (batched into prefill chunks — `super::prefill`) and the rest are
/// generated one decode step at a time.
#[derive(Clone, Copy, Debug)]
pub struct StreamSpec {
    pub id: u64,
    /// Total positions (prompt + generated), >= 1.
    pub n_tokens: u64,
    /// Leading positions that are prompt, in `[1, n_tokens]`. 1 (the
    /// [`StreamSpec::new`] default) reproduces the historical
    /// no-prompt-split behavior cycle for cycle; use
    /// [`StreamSpec::with_prompt`] for real prompted requests.
    pub prompt_tokens: u64,
    /// Simulated cycle the request arrives. 0 (see [`StreamSpec::new`])
    /// reproduces the closed-loop batch-at-zero behavior exactly.
    pub arrival_cycle: u64,
}

impl StreamSpec {
    /// A request present at cycle 0 (closed-loop batch) with a 1-token
    /// prompt — the historical constructor, pinned cycle-identical to
    /// the pre-prefill engine.
    pub fn new(id: u64, n_tokens: u64) -> Self {
        Self { id, n_tokens, prompt_tokens: 1, arrival_cycle: 0 }
    }

    /// A request with an explicit prompt/generation split: a
    /// `prompt_tokens`-position prompt followed by `gen_tokens`
    /// generated tokens (total positions = `prompt_tokens +
    /// gen_tokens`; the prompt's last position produces the first
    /// generated token, so `gen_tokens = 0` is a pure-prefill request).
    pub fn with_prompt(id: u64, prompt_tokens: u64, gen_tokens: u64) -> Self {
        Self { id, n_tokens: prompt_tokens + gen_tokens, prompt_tokens, arrival_cycle: 0 }
    }

    /// Positions past the prompt (decode steps).
    pub fn gen_tokens(&self) -> u64 {
        self.n_tokens.saturating_sub(self.prompt_tokens)
    }
}

/// Completion record of one stream. All latency views derive from the
/// four stamps here (arrival -> admitted -> first token -> finish);
/// `StreamStats::from_result` copies them so the per-stream stats row
/// can never drift from this record.
#[derive(Clone, Debug)]
pub struct StreamResult {
    pub id: u64,
    /// Cycle the request arrived (its `StreamSpec::arrival_cycle` — not
    /// the submit call, which is host bookkeeping and stamps nothing).
    pub arrival_cycle: u64,
    /// Cycle a KV slot was available for it (`max(arrival, slot free)`).
    pub admitted_cycle: u64,
    /// Cycle its last token finished.
    pub finish_cycle: u64,
    pub tokens: u64,
    /// Leading positions that were prompt (prefill).
    pub prompt_tokens: u64,
    /// KV slot the stream occupied while in flight.
    pub kv_slot: usize,
    /// Finish cycle of each position (nondecreasing; the positions of
    /// one prefill chunk share their chunk's finish, decode positions
    /// strictly increase; first entry >= admitted).
    pub token_finishes: Vec<u64>,
}

impl StreamResult {
    /// Cycles spent waiting for a KV slot, measured from arrival.
    pub fn queue_cycles(&self) -> u64 {
        self.admitted_cycle - self.arrival_cycle
    }

    pub fn service_cycles(&self) -> u64 {
        self.finish_cycle - self.admitted_cycle
    }

    /// Cycle the prompt finished prefilling — when the first *generated*
    /// token's logits exist (the prompt's last position produces them).
    pub fn prefill_finish_cycle(&self) -> u64 {
        let idx = self.prompt_tokens.clamp(1, self.token_finishes.len() as u64) as usize;
        self.token_finishes.get(idx - 1).copied().unwrap_or(self.finish_cycle)
    }

    /// Time to first *generated* token: prompt-prefill completion minus
    /// arrival (includes queueing). This is the client-visible first
    /// output token, not the first prefill position — the engine runs
    /// prompts as chunked prefill (`super::prefill`) and stamps the
    /// real thing. For a 1-token prompt it equals the first step's
    /// completion, the historical definition.
    pub fn ttft_cycles(&self) -> u64 {
        self.prefill_finish_cycle() - self.arrival_cycle
    }

    /// Prefill share of the service: admission to prompt completion.
    pub fn prefill_cycles(&self) -> u64 {
        self.prefill_finish_cycle() - self.admitted_cycle
    }

    /// Decode share of the service: prompt completion to last token.
    pub fn decode_cycles(&self) -> u64 {
        self.finish_cycle - self.prefill_finish_cycle()
    }

    /// End-to-end latency: arrival to last token.
    pub fn e2e_cycles(&self) -> u64 {
        self.finish_cycle - self.arrival_cycle
    }
}

/// Record of a request shed by the admission policy — a first-class
/// result (the request was *served* with a rejection), not an error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RejectedStream {
    pub id: u64,
    pub arrival_cycle: u64,
    /// Cycle the rejection was decided: the admission stamp the request
    /// *would* have received (`max(arrival, slot free)`).
    pub decided_cycle: u64,
    pub n_tokens: u64,
    /// The predicted TTFT that busted the budget (queue wait so far +
    /// conservative uncontended first-token cost).
    pub predicted_ttft_cycles: u64,
    /// The budget it was judged against.
    pub ttft_budget_cycles: u64,
}

impl RejectedStream {
    /// Cycles the request waited before the rejection was decided.
    pub fn waited_cycles(&self) -> u64 {
        self.decided_cycle - self.arrival_cycle
    }
}

/// Terminal outcome of one submitted request: completed with per-token
/// timings, or shed by the admission policy.
#[derive(Clone, Debug)]
pub enum StreamOutcome {
    Completed(StreamResult),
    Rejected(RejectedStream),
}

impl StreamOutcome {
    pub fn id(&self) -> u64 {
        match self {
            Self::Completed(r) => r.id,
            Self::Rejected(r) => r.id,
        }
    }

    /// The completion record, if the request ran to completion.
    pub fn into_completed(self) -> Option<StreamResult> {
        match self {
            Self::Completed(r) => Some(r),
            Self::Rejected(_) => None,
        }
    }

    pub fn as_completed(&self) -> Option<&StreamResult> {
        match self {
            Self::Completed(r) => Some(r),
            Self::Rejected(_) => None,
        }
    }

    pub fn as_rejected(&self) -> Option<&RejectedStream> {
        match self {
            Self::Completed(_) => None,
            Self::Rejected(r) => Some(r),
        }
    }
}

/// An in-flight stream: program cursor + per-node timing state.
struct Stream {
    id: u64,
    tpl: Rc<ProgramTemplate>,
    /// KV slot whose reserved regions this stream's KV traffic addresses.
    slot: usize,
    /// First position of the current step; the step covers
    /// `pos .. pos + step_positions` and attends over
    /// `ltoken = pos + step_positions` tokens.
    pos: u64,
    end_pos: u64,
    /// Leading positions that are prompt (prefill chunks).
    prompt_tokens: u64,
    /// Positions the current step covers: a prefill chunk length while
    /// `pos < prompt_tokens`, 1 in decode. Doubles as the `passes`
    /// handed to `Resources::issue`.
    step_positions: u64,
    /// Next instruction index in the current step's program.
    next: usize,
    finish: Vec<u64>,
    first_ready: Vec<u64>,
    step_start: u64,
    /// Max finish among this token's issued nodes so far.
    step_finish: u64,
    arrival: u64,
    admitted: u64,
    token_finishes: Vec<u64>,
    instructions: u64,
    attributed: u64,
    /// Page table (`sched.kv_paging`): physical frame of each logical
    /// token page, grown on demand as the context advances. Always
    /// empty in slot mode.
    pages: Vec<u32>,
}

/// A preempted stream's swapped-out state: everything needed to resume
/// it from `pos` once frames free up (`sched.kv_paging`). Original
/// arrival/admission stamps and completed-token finishes are preserved
/// — eviction delays a stream, it never re-queues it as a new request.
struct EvictedStream {
    id: u64,
    end_pos: u64,
    prompt_tokens: u64,
    /// Completed positions at eviction (the partial step in flight was
    /// discarded; its KV writes are rolled into the writeback).
    pos: u64,
    arrival: u64,
    admitted: u64,
    token_finishes: Vec<u64>,
    instructions: u64,
    attributed: u64,
    /// Cycle the eviction writeback completes — the earliest its
    /// restore can begin.
    ready_at: u64,
}

/// A fused decode sweep in flight: >= 2 streams' decode tokens sharing
/// one multi-pass program walk (`sched.batch_decode`). Members advance
/// in lockstep over the shared template — shareable nodes issue once
/// with `passes = K`, per-stream nodes once per member — and the batch
/// dissolves when the sweep completes, so streams join and leave
/// between sweeps (continuous batching, not static batching).
struct FusedBatch {
    /// KV slots of the members, in admission order. Slots are unique
    /// among active streams (ids need not be), so they are the stable
    /// member key while `active` indices shift around retirements.
    member_slots: Vec<usize>,
    /// The shared decode template (every member is at the same
    /// position regime, so they hold the same `Rc` from the cache).
    tpl: Rc<ProgramTemplate>,
    /// Next node index in the shared template walk.
    next: usize,
}

/// Where an `IssueCandidate` came from: a solo stream (index into
/// `active`) or a fused batch (index into `batches`).
enum CandSrc {
    Stream(usize),
    Batch(usize),
}

/// The interleaved multi-request engine.
pub struct MultiSim {
    pub cfg: HwConfig,
    pub model: GptModel,
    pub mapping: ModelMapping,
    t: TimingCycles,
    res: Resources,
    plan_scratch: VmmPlan,
    cache: ProgramCache,
    active: Vec<Stream>,
    /// Submitted requests that have not yet *arrived* (simulated time is
    /// still short of their `arrival_cycle`), ordered by (arrival,
    /// submit order). In-order submissions append in O(1); release pops
    /// the front.
    pending: VecDeque<StreamSpec>,
    /// Arrived requests awaiting a free KV slot, in arrival order. The
    /// pick policy chooses which entry the next free slot goes to
    /// (FCFS = the front).
    queue: VecDeque<StreamSpec>,
    clock: u64,
    /// Event-time high-water mark: the latest point simulated time has
    /// demonstrably reached (issue ready times, retirements, idle warps
    /// to the next arrival). Gates the pending -> queue release.
    now: u64,
    pub stats: SimStats,
    /// Which queued/active stream gets the next free engine or KV slot.
    pick: Box<dyn PickPolicy>,
    /// Whether a picked request is admitted at all.
    admission: Box<dyn AdmissionPolicy>,
    /// Rejections decided but not yet returned from `step` (admission
    /// can shed several requests in one pass; outcomes drain one per
    /// step so every request surfaces individually).
    rejections: VecDeque<RejectedStream>,
    /// Reusable issue-candidate scratch (hot path: rebuilt per issue).
    cand: Vec<IssueCandidate>,
    /// Source of each entry in `cand` (same length, same order).
    cand_src: Vec<CandSrc>,
    /// Fused decode sweeps in flight (`sched.batch_decode` only;
    /// always empty on the unbatched path).
    batches: Vec<FusedBatch>,
    /// Completions decided but not yet returned from `step` (a fused
    /// sweep can retire several streams at once; outcomes drain one
    /// per step so every request surfaces individually).
    completions: VecDeque<StreamResult>,
    /// Cached conservative first-token cost per prompt length (SLO
    /// admission predictor; the chunked-prefill replay is exact per
    /// prompt length, so each length is computed at most once).
    ttft_est: std::collections::BTreeMap<u64, u64>,
    /// Free KV slot ids (admission pops the earliest-free one). Under
    /// paging these are *virtual* stream identities — KV capacity is
    /// governed by the frame pool, not the slot count.
    free_slots: Vec<usize>,
    /// Cycle each slot was last vacated (0 for never-used slots).
    slot_free_at: Vec<u64>,
    /// Concurrency cap: KV slots actually reserved by the mapping
    /// (<= `cfg.sched.max_streams`; fewer when capacity degraded), or
    /// `max_streams` virtual slots under paging.
    n_slots: usize,
    /// Paged KV frame pool size (`mapping.kv.n_slots` under paging; 0
    /// when paging is off).
    n_frames: usize,
    /// Free physical frame ids (allocation picks the earliest-free).
    free_frames: Vec<u32>,
    /// Cycle each frame was last vacated (retirement or eviction
    /// writeback completion; 0 for never-used frames).
    frame_free_at: Vec<u64>,
    /// Frames committed by admitted (active + evicted) streams at their
    /// expected full footprint (`frames_for(n_tokens)`). Admission
    /// blocks when this would exceed `floor(n_frames * kv_oversub)`.
    committed_frames: u64,
    /// Preempted streams awaiting re-admission, in eviction order.
    /// Re-admission has priority over the fresh queue.
    evicted: VecDeque<EvictedStream>,
    /// Event tracing + utilization timeline (`sim::trace`). Off (the
    /// default, `cfg.sched.trace = off` and `trace_window = 0`) costs
    /// one branch per emission site and never allocates; on, sinks are
    /// pure observers — no simulated cycle ever depends on them.
    trace: Tracer,
}

impl MultiSim {
    pub fn new(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        let mapping = ModelMapping::build(model, cfg)?;
        Ok(Self::from_mapping(model, cfg, mapping))
    }

    /// Build from an existing mapping (avoids re-running the Algorithm-3
    /// placement when the caller already holds one, e.g. the server's
    /// `PimGptSystem`). The pick/admission policies are instantiated
    /// from `cfg.sched.policy`.
    pub fn from_mapping(model: &GptModel, cfg: &HwConfig, mapping: ModelMapping) -> Self {
        // The mapping is the source of truth for how much disjoint KV
        // capacity exists; the config can only lower it further. Slot
        // mode: one `max_seq` context per slot, concurrency = slots.
        // Paged mode: the mapping's "slots" are page frames, slots
        // become virtual stream identities capped by `max_streams`, and
        // concurrency is governed by frame commitment instead.
        let paging = cfg.sched.kv_paging;
        let n_slots = if paging {
            cfg.sched.max_streams.max(1)
        } else {
            mapping.kv.n_slots.min(cfg.sched.max_streams.max(1)).max(1)
        };
        let n_frames = if paging { mapping.kv.n_slots } else { 0 };
        let (pick, admission) = policy::build(&cfg.sched);
        let mut trace = Tracer::new(cfg.sched.trace.clone(), cfg.sched.trace_window);
        if cfg.sched.profile.is_on() {
            trace.set_profile(super::profile::ProfileSink::new(model, cfg));
        }
        Self {
            cfg: cfg.clone(),
            model: model.clone(),
            mapping,
            t: TimingCycles::from_config(cfg),
            res: Resources::new(cfg),
            plan_scratch: empty_plan(cfg),
            cache: ProgramCache::new(),
            active: Vec::new(),
            pending: VecDeque::new(),
            queue: VecDeque::new(),
            clock: 0,
            now: 0,
            stats: SimStats::default(),
            pick,
            admission,
            rejections: VecDeque::new(),
            cand: Vec::new(),
            cand_src: Vec::new(),
            batches: Vec::new(),
            completions: VecDeque::new(),
            ttft_est: std::collections::BTreeMap::new(),
            free_slots: (0..n_slots).collect(),
            slot_free_at: vec![0; n_slots],
            n_slots,
            n_frames,
            free_frames: (0..n_frames as u32).collect(),
            frame_free_at: vec![0; n_frames],
            committed_frames: 0,
            evicted: VecDeque::new(),
            trace,
        }
    }

    /// Attach a trace sink directly (test harnesses; runs normally use
    /// `cfg.sched.trace`). The sink observes — it can never perturb
    /// scheduling.
    pub fn set_trace_sink(&mut self, sink: Box<dyn super::trace::TraceSink>) {
        self.trace.set_sink(sink);
    }

    /// Traced event tallies (all zero when tracing is off) — the
    /// reconciliation source checked against `SimStats` at finalize.
    pub fn trace_counts(&self) -> &super::trace::TraceCounts {
        self.trace.counts()
    }

    /// Render the trace artifact: `(path, contents)` when a sink is
    /// attached via config. Call after the run; the caller writes the
    /// file (engines never touch the filesystem).
    pub fn render_trace(&mut self) -> Option<(String, String)> {
        self.trace.render()
    }

    /// Attach a profiler directly (test harnesses and `calibrate`; runs
    /// normally use `cfg.sched.profile`). Like every sink it observes —
    /// it can never perturb scheduling.
    pub fn set_profile(&mut self, profile: super::profile::ProfileSink) {
        self.trace.set_profile(profile);
    }

    /// Finished profile when a profiler is attached, reconciled against
    /// the run's busy/link cycles. Call after the run drains (the
    /// stats are finalized on the last `step`).
    pub fn profile_report(&self) -> Option<super::profile::Profile> {
        self.trace.profile_sink().map(|p| {
            p.finish(Some(self.stats.busy_cycles()), Some(self.stats.link_transfer_cycles))
        })
    }

    /// Render the profile artifact per `cfg.sched.profile`:
    /// `(path, contents)`. The caller writes the file (engines never
    /// touch the filesystem).
    pub fn render_profile(&self) -> Option<(String, String)> {
        let profile = self.profile_report()?;
        match &self.cfg.sched.profile {
            super::profile::ProfileSpec::Off => None,
            super::profile::ProfileSpec::Text(p) => Some((p.clone(), profile.render_text())),
            super::profile::ProfileSpec::Json(p) => {
                Some((p.clone(), profile.to_json().to_string() + "\n"))
            }
        }
    }

    /// Install a calibrated cost table on the admission policy
    /// (`SloAdmission` uses it as its first-token estimate; other
    /// policies ignore it).
    pub fn set_cost_table(&mut self, table: super::profile::CostTable) {
        self.admission.install_cost_table(table);
    }

    /// Effective concurrency cap: the number of disjoint KV slots the
    /// mapping reserved (<= the configured `max_streams`).
    pub fn max_streams(&self) -> usize {
        self.n_slots
    }

    /// Total KV slots (same as `max_streams`; named for stats readers).
    pub fn kv_slots(&self) -> usize {
        self.n_slots
    }

    /// KV slots currently unoccupied.
    pub fn free_kv_slots(&self) -> usize {
        self.free_slots.len()
    }

    /// Current simulated time (max finish cycle issued so far).
    pub fn clock(&self) -> u64 {
        self.clock
    }

    pub fn active_streams(&self) -> usize {
        self.active.len()
    }

    /// Requests submitted but not currently running: arrived-and-waiting
    /// (KV-blocked), not-yet-arrived (pending), and preempted streams
    /// awaiting re-admission (`sched.kv_paging`).
    pub fn queued_streams(&self) -> usize {
        self.queue.len() + self.pending.len() + self.evicted.len()
    }

    /// Paged KV frame pool size (0 when `sched.kv_paging` is off).
    pub fn kv_pages(&self) -> usize {
        self.n_frames
    }

    /// Free page frames (0 when paging is off).
    pub fn free_kv_pages(&self) -> usize {
        self.free_frames.len()
    }

    /// Preempted streams waiting to be restored.
    pub fn evicted_streams(&self) -> usize {
        self.evicted.len()
    }

    /// Rejections already decided but not yet returned by [`MultiSim::step`]
    /// (admission can shed several requests in one pass; outcomes drain
    /// one per step). A serving loop must keep stepping while this is
    /// non-zero — these requests still owe their caller a response.
    pub fn undelivered_rejections(&self) -> usize {
        self.rejections.len()
    }

    /// Completions already decided but not yet returned by
    /// [`MultiSim::step`]: a fused decode sweep (`sched.batch_decode`)
    /// can retire several streams at the same cycle; outcomes drain
    /// one per step. A serving loop must keep stepping while this is
    /// non-zero — these requests still owe their caller a response.
    pub fn undelivered_completions(&self) -> usize {
        self.completions.len()
    }

    /// Register a request. Submission is host bookkeeping: nothing is
    /// stamped here — the request sits pending until simulated time
    /// reaches its `arrival_cycle`, and every latency is then measured
    /// from that arrival. (The old behavior stamped `self.clock` at
    /// submit, so a mid-run submit inherited the global max-finish
    /// high-water mark as its "arrival" and `queue_cycles` was
    /// meaningless for trace-driven runs.)
    pub fn submit(&mut self, spec: StreamSpec) -> Result<()> {
        if spec.n_tokens == 0 {
            bail!("request {} has zero tokens", spec.id);
        }
        if spec.n_tokens > self.model.max_seq as u64 {
            bail!(
                "request {} length {} (prompt {} + generated {}) exceeds max_seq {}",
                spec.id,
                spec.n_tokens,
                spec.prompt_tokens,
                spec.n_tokens.saturating_sub(spec.prompt_tokens),
                self.model.max_seq
            );
        }
        if spec.prompt_tokens == 0 {
            bail!(
                "request {} has a zero-token prompt (every request prefills at least \
                 one position; StreamSpec::new defaults to 1)",
                spec.id
            );
        }
        if spec.prompt_tokens > spec.n_tokens {
            bail!(
                "request {} prompt {} exceeds its total length {}",
                spec.id,
                spec.prompt_tokens,
                spec.n_tokens
            );
        }
        if self.cfg.sched.kv_paging {
            // A request whose full context cannot fit in the physical
            // frame pool could never complete — even alone, with every
            // peer evicted — so refuse it up front. This also
            // guarantees eviction can always make room for a fault:
            // no single stream can hold the entire pool and still need
            // more.
            let need = self.mapping.kv.frames_for(spec.n_tokens);
            if need > self.n_frames {
                bail!(
                    "request {} needs {} KV page frames ({} tokens at {} tokens/page) \
                     but the pool holds {}",
                    spec.id,
                    need,
                    spec.n_tokens,
                    self.mapping.kv.page_tokens.unwrap_or(0),
                    self.n_frames
                );
            }
        }
        self.trace.emit(|| TraceEvent::Submit {
            stream: spec.id,
            at: self.now,
            arrival: spec.arrival_cycle,
            prompt_tokens: spec.prompt_tokens,
            tokens: spec.n_tokens,
        });
        // Keep pending sorted by (arrival, submit order): stable insert
        // behind every entry arriving at or before this one (O(1) for
        // traces already in arrival order).
        let at = self.pending.partition_point(|p| p.arrival_cycle <= spec.arrival_cycle);
        self.pending.insert(at, spec);
        Ok(())
    }

    /// Release pending requests whose arrival simulated time has
    /// reached (`arrival_cycle <= now`) into the admission queue.
    fn release_arrivals(&mut self) {
        while self.next_arrival().is_some_and(|a| a <= self.now) {
            let spec = self.pending.pop_front().expect("checked non-empty");
            self.trace.emit(|| TraceEvent::Release { stream: spec.id, at: self.now });
            self.queue.push_back(spec);
        }
    }

    /// Arrival cycle of the earliest not-yet-released request.
    fn next_arrival(&self) -> Option<u64> {
        self.pending.front().map(|p| p.arrival_cycle)
    }

    /// Conservative upper bound on the *uncontended* cost of a stream's
    /// first *generated* token, for the SLO admission predictor. The
    /// request's actual prompt is replayed as its chunked-prefill
    /// program sequence on scratch `Resources`
    /// (`prefill::isolated_prefill_cost` — live hardware state
    /// untouched), then padded with the worst-case costs a warm start
    /// can add over a cold one: refresh-phase misalignment (one tRFC
    /// per tREFI window the prefill can straddle) and stale bank state
    /// (write recovery + precharge + activate + row residency). Exact
    /// per-prompt-length cycle cost, not a heuristic fit — cached per
    /// prompt length, so each length replays at most once per engine.
    /// A 1-token prompt degenerates to exactly the old regime-0
    /// single-step replay.
    fn first_token_estimate(&mut self, prompt_tokens: u64) -> Result<u64> {
        if let Some(&est) = self.ttft_est.get(&prompt_tokens) {
            return Ok(est);
        }
        let isolated = prefill::isolated_prefill_cost(
            &self.model,
            &self.cfg,
            &self.t,
            &self.mapping,
            &mut self.cache,
            prompt_tokens,
        )?;
        // Worst case, every refresh window the padded prefill can touch
        // (including the catch-up at a warm start) lands on the critical
        // path while none did in the isolated replay.
        let t = &self.t;
        let refresh_pad = (isolated / t.trefi + 4) * t.trfc;
        let est = isolated + refresh_pad + t.twr + t.trp + t.trcd + t.tras;
        self.ttft_est.insert(prompt_tokens, est);
        Ok(est)
    }

    /// Committed-frame ceiling: the physical pool scaled by the
    /// oversubscription ratio. `kv_oversub = 1` admits only what fits,
    /// so the free list can never run dry and no fault can occur.
    fn frame_budget(&self) -> u64 {
        (self.n_frames as f64 * self.cfg.sched.kv_oversub).floor() as u64
    }

    /// Whether another request could be admitted right now: a free slot
    /// in slot mode; a free virtual slot *and* committed-frame headroom
    /// under paging (every request commits at least one frame).
    fn has_admission_headroom(&self) -> bool {
        if !self.cfg.sched.kv_paging {
            return !self.free_slots.is_empty();
        }
        !self.free_slots.is_empty() && self.committed_frames < self.frame_budget()
    }

    /// Modeled cycles to move a `tokens`-position KV context across the
    /// GDDR6 interface (eviction writeback and re-admission restore are
    /// symmetric): K and V vectors of every layer, bf16, streamed at
    /// the aggregate per-cycle interface bandwidth.
    fn kv_transfer_cycles(&self, tokens: u64) -> u64 {
        let bytes = tokens * self.model.n_layer as u64 * 2 * self.model.d_model as u64 * 2;
        let per_cycle =
            self.cfg.gddr6.channel_bytes_per_cycle() * self.cfg.gddr6.channels as f64;
        (bytes as f64 / per_cycle).ceil() as u64
    }

    /// The `need` earliest-free frames (ties -> lowest id), without
    /// removing them, plus the latest cycle any of them frees — the
    /// admission-stamp contribution. `None` if the free list is short.
    /// The (free-cycle, id) order mirrors the slot pick, which makes
    /// the full-context paged frame sequence identical to the slot
    /// sequence — the cycle-equivalence anchor.
    fn pick_free_frames(&self, need: usize) -> Option<(Vec<u32>, u64)> {
        if self.free_frames.len() < need {
            return None;
        }
        let mut frames = self.free_frames.clone();
        frames.sort_by_key(|&f| (self.frame_free_at[f as usize], f));
        frames.truncate(need);
        let free_at = frames.iter().map(|&f| self.frame_free_at[f as usize]).max().unwrap_or(0);
        Some((frames, free_at))
    }

    /// Remove `frames` (previously returned by [`Self::pick_free_frames`])
    /// from the free list and record the occupancy high-water mark.
    fn take_frames(&mut self, frames: &[u32]) {
        self.free_frames.retain(|f| !frames.contains(f));
        let in_use = (self.n_frames - self.free_frames.len()) as u64;
        self.stats.peak_pages_in_use = self.stats.peak_pages_in_use.max(in_use);
    }

    /// Grow stream `si`'s page table to cover its armed step
    /// (`pos + step_positions` positions), allocating frames on demand.
    /// An empty free list is a page fault: a victim stream is preempted
    /// (`PickPolicy::pick_victim`) until a frame exists. The step start
    /// is clamped to the allocated frames' free cycles — a frame still
    /// draining its previous owner's writeback is not usable earlier.
    /// No-op in slot mode and whenever the table already covers the
    /// step (in particular always, after admission, when
    /// `kv_page_tokens = max_seq`).
    fn grow_stream_frames(&mut self, si: usize) -> Result<()> {
        if !self.cfg.sched.kv_paging {
            return Ok(());
        }
        let slot = self.active[si].slot;
        let needed = {
            let s = &self.active[si];
            self.mapping.kv.frames_for(s.pos + s.step_positions)
        };
        // Low-watermark early eviction (`sched.kv_evict_watermark`):
        // when a growing stream finds the free list below the
        // watermark, preempt victims ahead of demand so the
        // allocations below come from the free list instead of
        // faulting one frame at a time. Only solo peers are taken —
        // dissolving fused sweeps stays a real-fault measure. Off at
        // 0.0 (the default): `wm_frames` is 0 and nothing runs.
        let wm_frames =
            (self.n_frames as f64 * self.cfg.sched.kv_evict_watermark).floor() as usize;
        if wm_frames > 0 && self.active[si].pages.len() < needed {
            while self.free_frames.len() < wm_frames && self.has_evictable_peer(slot) {
                self.evict_victim(slot)?;
            }
        }
        loop {
            // Re-derive the index each round: eviction removes streams
            // and shifts `active` (the slot is the stable identity).
            let si = self.stream_index_by_slot(slot);
            if self.active[si].pages.len() >= needed {
                break;
            }
            if self.free_frames.is_empty() {
                self.stats.page_faults += 1;
                let (faulter, at) = (self.active[si].id, self.now);
                self.trace.emit(|| TraceEvent::PageFault { stream: faulter, at });
                self.evict_victim(slot)?;
            }
            let (frames, free_at) =
                self.pick_free_frames(1).expect("eviction freed at least one frame");
            self.take_frames(&frames);
            let s = &mut self.active[si];
            s.pages.push(frames[0]);
            s.step_start = s.step_start.max(free_at);
            s.step_finish = s.step_finish.max(s.step_start);
            let at = self.active[si].step_start;
            self.sample_pages(at);
        }
        Ok(())
    }

    /// Timeline hook: record the current frame occupancy at cycle `at`
    /// (no-op unless `sched.trace_window > 0`).
    fn sample_pages(&mut self, at: u64) {
        let in_use = (self.n_frames - self.free_frames.len()) as u64;
        self.trace.pages_sample(at, in_use);
    }

    /// Whether a stream other than `faulting_slot`'s could be preempted
    /// right now without dissolving a fused sweep — the watermark
    /// early-evict's guard (it never breaks up batches; that cost is
    /// reserved for real faults).
    fn has_evictable_peer(&self, faulting_slot: usize) -> bool {
        self.active
            .iter()
            .any(|s| s.slot != faulting_slot && s.pos < s.end_pos && !self.slot_in_batch(s.slot))
    }

    /// Resolve a page fault raised while growing the stream occupying
    /// `faulting_slot`: preempt one victim among the other active
    /// streams (never the faulting one; fused-sweep members only after
    /// every solo candidate is exhausted — dissolving a sweep discards
    /// all its members' partial work). The victim's partial step is
    /// discarded (`pos` unchanged — preempted work is wasted work; the
    /// cycles it burned on shared hardware stay burned), its context is
    /// written back at the modeled interface cost, its frames and
    /// virtual slot recycle at writeback completion, and it joins the
    /// evicted queue with every original stamp intact.
    fn evict_victim(&mut self, faulting_slot: usize) -> Result<()> {
        // Never the faulting stream, never a stream that already
        // finished its last token (it is about to retire and free its
        // frames anyway — evicting it would resurrect it).
        let evictable = |s: &Stream| s.slot != faulting_slot && s.pos < s.end_pos;
        let mut idxs: Vec<usize> = (0..self.active.len())
            .filter(|&i| {
                evictable(&self.active[i]) && !self.slot_in_batch(self.active[i].slot)
            })
            .collect();
        if idxs.is_empty() {
            // Every peer is mid fused sweep: dissolve the sweeps
            // (members return to their step boundary, partial sweep
            // work discarded) so they become evictable.
            self.dissolve_batches_for_eviction();
            idxs = (0..self.active.len())
                .filter(|&i| evictable(&self.active[i]))
                .collect();
        }
        // `submit` guarantees no single stream can hold the whole pool
        // and still fault, so a peer must exist.
        assert!(
            !idxs.is_empty(),
            "page fault with no evictable peer (stream alone in a pool it cannot exhaust)"
        );
        let cands: Vec<IssueCandidate> = idxs
            .iter()
            .map(|&i| {
                let s = &self.active[i];
                let mut ready = s.step_start;
                if s.next < s.tpl.len() {
                    for &d in s.tpl.deps_of(s.next) {
                        ready = ready.max(s.finish[d]);
                    }
                }
                IssueCandidate {
                    id: s.id,
                    slot: s.slot,
                    ready,
                    remaining_tokens: s.end_pos - s.pos,
                    served_cycles: s.attributed,
                }
            })
            .collect();
        let vi = self.pick.pick_victim(&cands);
        assert!(
            vi < cands.len(),
            "pick policy '{}' returned victim index {vi} of {}",
            self.pick.name(),
            cands.len()
        );
        let v = self.active.remove(idxs[vi]);
        let writeback = self.kv_transfer_cycles(v.pos);
        let done = v.step_finish + writeback;
        for &f in &v.pages {
            self.frame_free_at[f as usize] = done;
            self.free_frames.push(f);
        }
        self.slot_free_at[v.slot] = done;
        self.free_slots.push(v.slot);
        self.committed_frames -= self.mapping.kv.frames_for(v.end_pos) as u64;
        self.stats.preemptions += 1;
        self.stats.evicted_tokens += v.pos;
        let by = self.stream_by_slot(faulting_slot).id;
        self.trace.emit(|| TraceEvent::Evict {
            victim: v.id,
            by,
            at: v.step_finish,
            tokens: v.pos,
        });
        self.trace.emit(|| TraceEvent::Writeback {
            stream: v.id,
            start: v.step_finish,
            finish: done,
            tokens: v.pos,
        });
        self.sample_pages(done);
        self.evicted.push_back(EvictedStream {
            id: v.id,
            end_pos: v.end_pos,
            prompt_tokens: v.prompt_tokens,
            pos: v.pos,
            arrival: v.arrival,
            admitted: v.admitted,
            token_finishes: v.token_finishes,
            instructions: v.instructions,
            attributed: v.attributed,
            ready_at: done,
        });
        Ok(())
    }

    /// Recycle a retiring stream's KV capacity: its slot (free as of
    /// the stream's own last cycle, not the global clock) and, under
    /// paging, its page frames and footprint commitment.
    fn release_stream_kv(&mut self, s: &Stream) {
        self.slot_free_at[s.slot] = s.step_finish;
        self.free_slots.push(s.slot);
        for &f in &s.pages {
            self.frame_free_at[f as usize] = s.step_finish;
            self.free_frames.push(f);
        }
        if self.cfg.sched.kv_paging {
            self.committed_frames -= self.mapping.kv.frames_for(s.end_pos) as u64;
            self.sample_pages(s.step_finish);
        }
    }

    /// Discard every fused sweep in flight: members return to their
    /// decode-step boundary with the sweep's partial work thrown away
    /// (resource cycles already burned stay burned). Only used when a
    /// page fault finds every potential victim mid-sweep.
    fn dissolve_batches_for_eviction(&mut self) {
        for b in std::mem::take(&mut self.batches) {
            for &slot in &b.member_slots {
                let mi = self.stream_index_by_slot(slot);
                let s = &mut self.active[mi];
                s.next = 0;
                s.finish.clear();
                s.first_ready.clear();
                s.step_start = s.step_finish;
            }
        }
    }

    /// Restore evicted streams while capacity allows, in eviction order
    /// — with priority over the fresh queue (`admit` calls this first),
    /// so a preempted request cannot be starved by new arrivals.
    /// Re-admission needs a free virtual slot, committed-frame headroom
    /// for the stream's full expected footprint, and enough free frames
    /// to cover its resume step; the restore pays the same interface
    /// cost its writeback did, then the stream resumes at its evicted
    /// position with its original stamps and token history.
    fn readmit_evicted(&mut self) -> Result<()> {
        while let Some(e) = self.evicted.front() {
            let need_total = self.mapping.kv.frames_for(e.end_pos) as u64;
            let (regime_pos, step_positions) =
                match prefill::chunk_at(e.pos, e.prompt_tokens, self.cfg.sched.prefill_chunk) {
                    Some(c) => (c.regime_pos(), c.len),
                    None => (e.pos, 1),
                };
            let need_now = self.mapping.kv.frames_for(e.pos + step_positions);
            if self.free_slots.is_empty()
                || self.committed_frames + need_total > self.frame_budget()
                || self.free_frames.len() < need_now
            {
                break;
            }
            let e = self.evicted.pop_front().expect("front checked");
            let i = self
                .free_slots
                .iter()
                .enumerate()
                .min_by_key(|&(_, &s)| (self.slot_free_at[s], s))
                .map(|(i, _)| i)
                .expect("free_slots checked non-empty");
            let slot = self.free_slots.swap_remove(i);
            let (pages, frames_free_at) =
                self.pick_free_frames(need_now).expect("free_frames checked sufficient");
            self.take_frames(&pages);
            let tpl = self.cache.get(&self.model, &self.cfg, regime_pos)?;
            let restore_start =
                e.ready_at.max(self.slot_free_at[slot]).max(frames_free_at);
            let step_start = restore_start + self.kv_transfer_cycles(e.pos);
            self.committed_frames += need_total;
            self.trace.emit(|| TraceEvent::Restore {
                stream: e.id,
                start: restore_start,
                finish: step_start,
                tokens: e.pos,
            });
            self.sample_pages(step_start);
            self.active.push(Stream {
                id: e.id,
                tpl,
                slot,
                pos: e.pos,
                end_pos: e.end_pos,
                prompt_tokens: e.prompt_tokens,
                step_positions,
                next: 0,
                finish: Vec::new(),
                first_ready: Vec::new(),
                step_start,
                step_finish: step_start,
                arrival: e.arrival,
                admitted: e.admitted,
                token_finishes: e.token_finishes,
                instructions: e.instructions,
                attributed: e.attributed,
                pages,
            });
            let in_use = (self.n_slots - self.free_slots.len()) as u64;
            self.stats.peak_slots_in_use = self.stats.peak_slots_in_use.max(in_use);
        }
        Ok(())
    }

    /// Admit released requests while free KV slots exist. Admission is a
    /// *capacity* decision gated by a *policy* decision: the pick policy
    /// chooses which queued request gets the earliest-free slot, the
    /// request is stamped admitted at `max(arrival cycle, slot free
    /// cycle)` — the freed slot's actual free time, not the global clock
    /// (which can lie far past the retiring stream's last cycle and
    /// would inflate `queue_cycles`) — and the admission policy then
    /// admits it or sheds it as a `RejectedStream` (buffered; `step`
    /// returns rejections one at a time). With `count_blocked`,
    /// requests left waiting are added to `SimStats::admission_blocked`
    /// (unit: blocked *requests* per attempt — see the field docs).
    fn admit(&mut self, count_blocked: bool) -> Result<()> {
        if self.cfg.sched.kv_paging {
            // Preempted streams are restored before any fresh request
            // is considered — eviction must never starve its victim.
            self.readmit_evicted()?;
        }
        while !self.queue.is_empty() && self.has_admission_headroom() {
            // Earliest-free slot first (ties -> lowest id): deterministic
            // and admits as early as the KV capacity allows.
            let i = self
                .free_slots
                .iter()
                .enumerate()
                .min_by_key(|&(_, &s)| (self.slot_free_at[s], s))
                .map(|(i, _)| i)
                .expect("free_slots checked non-empty");
            let slot = self.free_slots[i];
            let qi = self.pick.pick_admission(self.queue.make_contiguous());
            assert!(
                qi < self.queue.len(),
                "pick policy '{}' returned queue index {qi} of {}",
                self.pick.name(),
                self.queue.len()
            );
            let paging = self.cfg.sched.kv_paging;
            // Paged capacity gates, checked against the picked request
            // *before* it leaves the queue: commitment headroom for its
            // full expected footprint, and free frames for its first
            // prefill chunk. Either shortfall blocks admission exactly
            // like a missing slot (head-of-line; retirements and
            // eviction writebacks free capacity and re-trigger).
            let mut first_frames: Vec<u32> = Vec::new();
            let mut frames_free_at = 0u64;
            if paging {
                let spec = &self.queue[qi];
                let need_total = self.mapping.kv.frames_for(spec.n_tokens) as u64;
                if self.committed_frames + need_total > self.frame_budget() {
                    break;
                }
                let first =
                    prefill::chunk_at(0, spec.prompt_tokens, self.cfg.sched.prefill_chunk)
                        .expect("prompt_tokens >= 1 is validated at submit");
                let need_now = self.mapping.kv.frames_for(first.ltoken_end());
                let Some((frames, free_at)) = self.pick_free_frames(need_now) else {
                    break;
                };
                first_frames = frames;
                frames_free_at = free_at;
            }
            let spec = self.queue.remove(qi).expect("index checked in range");
            // Under paging the admission stamp tracks the *frames*'
            // availability — the virtual slot is bookkeeping, not
            // capacity. With one full-context frame per stream the
            // frame pick mirrors the slot pick and the stamps are
            // identical (the cycle-equivalence contract).
            let admitted = if paging {
                spec.arrival_cycle.max(frames_free_at)
            } else {
                spec.arrival_cycle.max(self.slot_free_at[slot])
            };
            let wait = admitted - spec.arrival_cycle;
            let est = if self.admission.needs_estimate() {
                // A calibrated cost table on the policy outranks the
                // uncontended replay; both then get the same
                // batch-occupancy amortization below.
                let est = match self.admission.first_token_override(&spec) {
                    Some(cycles) => cycles,
                    None => self.first_token_estimate(spec.prompt_tokens)?,
                };
                if self.cfg.sched.batch_decode {
                    // Batch-aware estimate: the uncontended replay
                    // charges full per-step sweep cost, but with fused
                    // decode the weight sweep is shared by every batch
                    // member, so the engine's effective per-stream cost
                    // shrinks by the observed mean sweep occupancy.
                    // Without this, SLO admission over-sheds under
                    // `batch_decode = on` — it prices contention the
                    // fusion machinery removes. Occupancy 0 (nothing
                    // fused yet) clamps to 1: the raw estimate.
                    let occ = self.stats.mean_decode_batch().max(1.0);
                    (est as f64 / occ).ceil() as u64
                } else {
                    est
                }
            } else {
                0
            };
            match self.admission.decide(&spec, wait, est) {
                AdmissionDecision::Admit => {
                    // The first step is the prompt's first prefill chunk
                    // (1 position for the historical 1-token prompts —
                    // the regime-0 template, exactly as before).
                    let first = prefill::chunk_at(
                        0,
                        spec.prompt_tokens,
                        self.cfg.sched.prefill_chunk,
                    )
                    .expect("prompt_tokens >= 1 is validated at submit");
                    let tpl = self.cache.get(&self.model, &self.cfg, first.regime_pos())?;
                    self.free_slots.swap_remove(i);
                    if paging {
                        self.take_frames(&first_frames);
                        self.committed_frames +=
                            self.mapping.kv.frames_for(spec.n_tokens) as u64;
                        self.sample_pages(admitted);
                    }
                    self.trace.emit(|| TraceEvent::Admit {
                        stream: spec.id,
                        at: admitted,
                        slot: slot as u64,
                    });
                    self.active.push(Stream {
                        id: spec.id,
                        tpl,
                        slot,
                        pos: 0,
                        end_pos: spec.n_tokens,
                        prompt_tokens: spec.prompt_tokens,
                        step_positions: first.len,
                        next: 0,
                        finish: Vec::new(),
                        first_ready: Vec::new(),
                        step_start: admitted,
                        step_finish: admitted,
                        arrival: spec.arrival_cycle,
                        admitted,
                        token_finishes: Vec::new(),
                        instructions: 0,
                        attributed: 0,
                        pages: first_frames,
                    });
                    let in_use = (self.n_slots - self.free_slots.len()) as u64;
                    self.stats.peak_slots_in_use = self.stats.peak_slots_in_use.max(in_use);
                }
                AdmissionDecision::Reject { predicted_ttft_cycles, ttft_budget_cycles } => {
                    self.stats.rejected += 1;
                    self.trace.emit(|| TraceEvent::Reject {
                        stream: spec.id,
                        at: admitted,
                        predicted_ttft: predicted_ttft_cycles,
                        ttft_budget: ttft_budget_cycles,
                    });
                    self.rejections.push_back(RejectedStream {
                        id: spec.id,
                        arrival_cycle: spec.arrival_cycle,
                        decided_cycle: admitted,
                        n_tokens: spec.n_tokens,
                        predicted_ttft_cycles,
                        ttft_budget_cycles,
                    });
                }
            }
        }
        if count_blocked && !self.queue.is_empty() {
            // Arrived requests stuck behind fully-occupied KV slots.
            self.stats.admission_blocked += self.queue.len() as u64;
        }
        Ok(())
    }

    /// Pop one buffered rejection, if any.
    fn take_rejection(&mut self) -> Option<StreamOutcome> {
        self.rejections.pop_front().map(StreamOutcome::Rejected)
    }

    /// Whether `slot`'s stream is a member of a fused sweep in flight.
    fn slot_in_batch(&self, slot: usize) -> bool {
        self.batches.iter().any(|b| b.member_slots.contains(&slot))
    }

    /// The active stream occupying `slot`. Slots are unique among
    /// active streams, so this is the stable member lookup while
    /// `active` indices shift around retirements.
    fn stream_by_slot(&self, slot: usize) -> &Stream {
        self.active
            .iter()
            .find(|s| s.slot == slot)
            .expect("batch member stays active during its sweep")
    }

    /// Index of the active stream occupying `slot`.
    fn stream_index_by_slot(&self, slot: usize) -> usize {
        self.active
            .iter()
            .position(|s| s.slot == slot)
            .expect("batch member stays active during its sweep")
    }

    /// Form new fused decode sweeps (`sched.batch_decode`): group the
    /// active streams sitting at a decode-step boundary (`next == 0`,
    /// past their prompt, not already fused) by position regime;
    /// every group with >= 2 members becomes a [`FusedBatch`]. Runs at
    /// the top of each issue iteration, so a stream reaching its
    /// boundary fuses at the earliest opportunity — the
    /// continuous-batching join point. Note K = 1 never forms a batch:
    /// a lone boundary stream issues solo, exactly the unbatched path.
    fn form_batches(&mut self) {
        let mut groups: Vec<(PosRegime, Vec<usize>)> = Vec::new();
        for i in 0..self.active.len() {
            let s = &self.active[i];
            if s.next != 0 || s.pos < s.prompt_tokens || self.slot_in_batch(s.slot) {
                continue;
            }
            let regime = PosRegime::of(&self.model, &self.cfg, s.pos);
            match groups.iter_mut().find(|(r, _)| *r == regime) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((regime, vec![i])),
            }
        }
        for (_, idxs) in groups {
            if idxs.len() < 2 {
                continue;
            }
            let member_slots: Vec<usize> = idxs.iter().map(|&i| self.active[i].slot).collect();
            // Same regime -> same cached template `Rc`, so the lead
            // member's template is the shared walk for everyone.
            let tpl = Rc::clone(&self.active[idxs[0]].tpl);
            self.batches.push(FusedBatch { member_slots, tpl, next: 0 });
        }
    }

    /// Whether stream `i`, sitting at a decode-step boundary, should
    /// wait for a partner: some other active stream is mid-step (or in
    /// a flying sweep) whose *next* step is a decode token in the same
    /// position regime. The issue loop warps time and issues eagerly,
    /// so without this boundary wait two streams would essentially
    /// never be simultaneously at a boundary and fusion would never
    /// trigger. Stateless — recomputed every issue iteration — and
    /// deadlock-free: the partner is itself issuable (solo mid-step or
    /// via its batch), so the engine always makes progress, and once
    /// the partner reaches its boundary `form_batches` fuses the pair.
    /// If the partner instead retires, the deferral vanishes and the
    /// stream issues solo on the next iteration.
    fn deferred_for_fusion(&self, i: usize) -> bool {
        let s = &self.active[i];
        if s.next != 0 || s.pos < s.prompt_tokens {
            return false;
        }
        let regime = PosRegime::of(&self.model, &self.cfg, s.pos);
        self.active.iter().enumerate().any(|(j, p)| {
            if j == i || (p.next == 0 && !self.slot_in_batch(p.slot)) {
                return false;
            }
            let next_pos = p.pos + p.step_positions;
            next_pos >= p.prompt_tokens
                && next_pos < p.end_pos
                && PosRegime::of(&self.model, &self.cfg, next_pos) == regime
        })
    }

    /// The issue candidate representing fused batch `bi`: lead member's
    /// identity, members' collective readiness for the batch's next
    /// node (max over members for a shared multi-pass node — all pass
    /// inputs must exist; min for a per-stream node — the earliest
    /// member's issue can start then), and the most conservative
    /// remaining/served figures so SRF/fair policies rank the batch at
    /// least as urgent as its neediest member.
    fn batch_candidate(&self, bi: usize) -> IssueCandidate {
        let b = &self.batches[bi];
        let deps = b.tpl.deps_of(b.next);
        let shareable = b.tpl.shareable_across_streams(b.next);
        let lead = self.stream_by_slot(b.member_slots[0]);
        let mut ready: Option<u64> = None;
        let mut remaining = u64::MAX;
        let mut served = u64::MAX;
        for &ms in &b.member_slots {
            let s = self.stream_by_slot(ms);
            let mut r = s.step_start;
            for &d in deps {
                r = r.max(s.finish[d]);
            }
            ready = Some(match ready {
                None => r,
                Some(acc) if shareable => acc.max(r),
                Some(acc) => acc.min(r),
            });
            remaining = remaining.min(s.end_pos - s.pos);
            served = served.min(s.attributed);
        }
        IssueCandidate {
            id: lead.id,
            slot: lead.slot,
            ready: ready.expect("a batch has >= 2 members"),
            remaining_tokens: remaining,
            served_cycles: served,
        }
    }

    /// Issue fused batch `bi`'s next node and advance the shared walk.
    /// A shareable node issues **once** with `passes = K` on behalf of
    /// every member (dependency times remapped to the per-dep max over
    /// members — a pass cannot start before its own inputs exist); a
    /// per-stream node issues once per member at that member's own
    /// position and KV slot with its own timing vectors. When the walk
    /// completes, every member retires one decode token, the batch
    /// dissolves (members re-fuse or leave next iteration — continuous
    /// batching), finished members retire exactly like the solo path,
    /// and the first completion is returned (the rest drain one per
    /// `step` via `completions`).
    fn issue_batch_node(&mut self, bi: usize) -> Result<Option<StreamOutcome>> {
        let tpl = Rc::clone(&self.batches[bi].tpl);
        let node = self.batches[bi].next;
        let member_slots = self.batches[bi].member_slots.clone();
        let members: Vec<usize> =
            member_slots.iter().map(|&slot| self.stream_index_by_slot(slot)).collect();
        let deps = tpl.deps_of(node);
        let ctx = IssueCtx {
            cfg: &self.cfg,
            t: &self.t,
            model: &self.model,
            mapping: &self.mapping,
        };
        if tpl.shareable_across_streams(node) {
            // One multi-pass issue for all members: same weights, K
            // input vectors — one ACT/PRE sweep, one pipeline fill.
            let step_start =
                members.iter().map(|&mi| self.active[mi].step_start).max().expect(">= 2 members");
            let fdeps: Vec<usize> = (0..deps.len()).collect();
            let mut ffin = Vec::with_capacity(deps.len());
            let mut ffr = Vec::with_capacity(deps.len());
            for &d in deps {
                ffin.push(
                    members.iter().map(|&mi| self.active[mi].finish[d]).max().expect("members"),
                );
                ffr.push(
                    members
                        .iter()
                        .map(|&mi| self.active[mi].first_ready[d])
                        .max()
                        .expect("members"),
                );
            }
            let (pos, slot) = {
                let lead = &self.active[members[0]];
                (lead.pos, lead.slot)
            };
            // Shareable nodes are ltoken/slot-invariant within the
            // regime (`shareable_nodes_are_exactly_the_...` test) and
            // never page-indirected — `shareable_across_streams`
            // excludes every KV-addressed node, so a fused issue never
            // needs a page table (`pages = None`) and stays correct
            // under `kv_paging`. The lead member's patch stands in for
            // everyone (slot 0 under paging: virtual slot ids can
            // exceed the mapping's frame count, and the patch value is
            // unused on non-KV nodes anyway).
            let ltoken = pos + 1;
            let patch_slot = if self.cfg.sched.kv_paging { 0 } else { slot };
            let instr = tpl.instr_at(node, ltoken, patch_slot);
            let out = self.res.issue(
                &ctx,
                &mut self.plan_scratch,
                &instr,
                &fdeps,
                step_start,
                &ffin,
                &ffr,
                pos,
                ltoken,
                members.len() as u64,
                None,
            );
            self.stats.add_class(out.class, out.finish.saturating_sub(out.ready));
            self.stats.instructions += 1;
            self.clock = self.clock.max(out.finish);
            let span = out.finish.saturating_sub(out.ready);
            for &mi in &members {
                let s = &mut self.active[mi];
                s.instructions += 1;
                s.attributed += span;
                s.first_ready.push(out.first_ready);
                s.finish.push(out.finish);
                s.step_finish = s.step_finish.max(out.finish);
                s.next += 1;
            }
        } else {
            // Per-stream node (K/V writes, KV-cache attention reads,
            // position-scaled ASIC ops): KV contexts are disjoint, so
            // each member issues at its own position and slot — or,
            // under paging, through its own page table.
            for &mi in &members {
                let (pos, slot, step_start) = {
                    let s = &self.active[mi];
                    (s.pos, s.slot, s.step_start)
                };
                let ltoken = pos + 1;
                let patch_slot = if self.cfg.sched.kv_paging { 0 } else { slot };
                let instr = tpl.instr_at(node, ltoken, patch_slot);
                let out = {
                    let s = &self.active[mi];
                    let pages =
                        if self.cfg.sched.kv_paging { Some(s.pages.as_slice()) } else { None };
                    self.res.issue(
                        &ctx,
                        &mut self.plan_scratch,
                        &instr,
                        deps,
                        step_start,
                        &s.finish,
                        &s.first_ready,
                        pos,
                        ltoken,
                        1,
                        pages,
                    )
                };
                self.stats.add_class(out.class, out.finish.saturating_sub(out.ready));
                self.stats.instructions += 1;
                self.clock = self.clock.max(out.finish);
                let s = &mut self.active[mi];
                s.instructions += 1;
                s.attributed += out.finish.saturating_sub(out.ready);
                s.first_ready.push(out.first_ready);
                s.finish.push(out.finish);
                s.step_finish = s.step_finish.max(out.finish);
                s.next += 1;
            }
        }
        self.batches[bi].next = node + 1;
        if node + 1 < tpl.len() {
            return Ok(None);
        }

        // Sweep complete: every member finished one decode token.
        self.stats.fused_sweeps += 1;
        self.stats.fused_streams += members.len() as u64;
        self.stats.max_decode_batch = self.stats.max_decode_batch.max(members.len() as u64);
        self.stats.tokens += members.len() as u64;
        if self.trace.is_on() {
            let ids: Vec<u64> = members.iter().map(|&mi| self.active[mi].id).collect();
            let start =
                members.iter().map(|&mi| self.active[mi].step_start).min().unwrap_or(0);
            let finish =
                members.iter().map(|&mi| self.active[mi].step_finish).max().unwrap_or(0);
            self.trace.emit(move || TraceEvent::FusedSweep {
                device: 0,
                start,
                finish,
                streams: ids,
            });
        }
        let mut finished_slots = Vec::new();
        let mut survivor_slots = Vec::new();
        for &mi in &members {
            let s = &mut self.active[mi];
            let fin = s.step_finish;
            s.token_finishes.push(fin);
            s.pos += 1;
            if s.pos >= s.end_pos {
                finished_slots.push(s.slot);
            } else {
                survivor_slots.push(s.slot);
            }
        }
        // Dissolve the batch before touching `active` (removals shift
        // stream indices; slots stay stable) — survivors re-fuse or
        // issue solo next iteration, the continuous-batching leave
        // point.
        self.batches.remove(bi);
        // Retire finished members before re-arming survivors: their
        // freed frames are then available to a survivor's page-table
        // growth (and a finished stream is never an eviction victim).
        let mut first_outcome = None;
        for &slot in &finished_slots {
            let si = self.stream_index_by_slot(slot);
            let s = self.active.remove(si);
            self.release_stream_kv(&s);
            self.now = self.now.max(s.step_finish);
            let (rid, rat, rtok) = (s.id, s.step_finish, s.token_finishes.len() as u64);
            self.trace.emit(|| TraceEvent::StreamRetire { stream: rid, at: rat, tokens: rtok });
            let result = StreamResult {
                id: s.id,
                arrival_cycle: s.arrival,
                admitted_cycle: s.admitted,
                finish_cycle: s.step_finish,
                tokens: s.token_finishes.len() as u64,
                prompt_tokens: s.prompt_tokens,
                kv_slot: s.slot,
                token_finishes: s.token_finishes,
            };
            self.stats.prefill_cycles += result.prefill_cycles();
            self.stats.decode_cycles += result.decode_cycles();
            let row = StreamStats::from_result(&result, s.instructions, s.attributed);
            self.stats.streams.push(row);
            if first_outcome.is_none() {
                first_outcome = Some(StreamOutcome::Completed(result));
            } else {
                self.completions.push_back(result);
            }
        }
        for &slot in &survivor_slots {
            // A survivor can be preempted by an earlier survivor's
            // page-table growth in this very loop — it is already in
            // the evicted queue, boundary state intact; skip it.
            let Some(mi) = self.active.iter().position(|s| s.slot == slot) else {
                continue;
            };
            let pos = self.active[mi].pos;
            // Decode steps are always single-position; `cache.get`
            // re-keys the template when the stream crosses a regime
            // boundary.
            let tpl = self.cache.get(&self.model, &self.cfg, pos)?;
            let s = &mut self.active[mi];
            s.tpl = tpl;
            s.step_positions = 1;
            s.step_start = s.step_finish;
            s.next = 0;
            s.finish.clear();
            s.first_ready.clear();
            self.grow_stream_frames(mi)?;
        }
        if !finished_slots.is_empty() {
            self.release_arrivals();
            self.admit(true)?;
        }
        Ok(first_outcome)
    }

    /// Advance the simulation until the next request reaches a terminal
    /// outcome — completion or an admission-policy rejection — and
    /// return it, or `None` when nothing is in flight, queued or
    /// pending. An idle engine warps time forward to the next pending
    /// arrival instead of spinning.
    pub fn step(&mut self) -> Result<Option<StreamOutcome>> {
        if let Some(r) = self.take_rejection() {
            return Ok(Some(r));
        }
        if let Some(r) = self.completions.pop_front() {
            return Ok(Some(StreamOutcome::Completed(r)));
        }
        self.release_arrivals();
        self.admit(true)?;
        if let Some(r) = self.take_rejection() {
            return Ok(Some(r));
        }
        while self.active.is_empty() {
            // An idle engine has every slot and frame free, so the
            // `admit` above restored any evicted stream — none can be
            // stranded here.
            debug_assert!(
                self.evicted.is_empty(),
                "evicted streams must re-admit once the engine drains"
            );
            // Nothing running and nothing arrived (an arrived request
            // would have been admitted or rejected — all slots are
            // free). Warp to the next arrival or report the drain
            // complete. The loop re-warps when an SLO policy sheds
            // every request a warp released.
            let Some(arrival) = self.next_arrival() else {
                return Ok(None);
            };
            // The warp-to-arrival gap is offered-load idle time, not
            // engine capacity: count it so busy-cycle throughput can
            // subtract it (`SimStats::busy_cycles`).
            self.stats.idle_cycles += arrival.saturating_sub(self.now);
            self.trace.idle_span(self.now, arrival);
            self.now = self.now.max(arrival);
            self.release_arrivals();
            self.admit(false)?;
            if let Some(r) = self.take_rejection() {
                return Ok(Some(r));
            }
        }
        loop {
            // Ask the pick policy which active stream (or fused batch)
            // issues next. The candidate list is rebuilt per issue
            // (admission-ordered, same order as `active`, batches
            // after solos); the FCFS pick reproduces the historical
            // greedy earliest-dependency-ready rule exactly. With
            // batching off the list is one candidate per active stream
            // in `active` order — identical to the unbatched engine.
            if self.cfg.sched.batch_decode {
                self.form_batches();
            }
            self.cand.clear();
            self.cand_src.clear();
            for i in 0..self.active.len() {
                if self.cfg.sched.batch_decode
                    && (self.slot_in_batch(self.active[i].slot) || self.deferred_for_fusion(i))
                {
                    // Batch members are represented by their batch's
                    // candidate; a deferred stream waits at its decode
                    // boundary for a same-regime partner to reach it.
                    continue;
                }
                let s = &self.active[i];
                let mut ready = s.step_start;
                for &d in s.tpl.deps_of(s.next) {
                    ready = ready.max(s.finish[d]);
                }
                self.cand.push(IssueCandidate {
                    id: s.id,
                    slot: s.slot,
                    ready,
                    remaining_tokens: s.end_pos - s.pos,
                    served_cycles: s.attributed,
                });
                self.cand_src.push(CandSrc::Stream(i));
            }
            for bi in 0..self.batches.len() {
                let c = self.batch_candidate(bi);
                self.cand.push(c);
                self.cand_src.push(CandSrc::Batch(bi));
            }
            assert!(
                !self.cand.is_empty(),
                "issue loop produced no candidates with {} active streams",
                self.active.len()
            );
            let ci = self.pick.pick_issue(&self.cand);
            assert!(
                ci < self.cand.len(),
                "pick policy '{}' returned candidate index {ci} of {}",
                self.pick.name(),
                self.cand.len()
            );
            let best_ready = self.cand[ci].ready;

            // Event-driven release: a pending request whose arrival
            // precedes the next issue gets admitted first when KV
            // capacity is free — it may well be the better pick. (With
            // no admission headroom a release changes nothing until a
            // retirement, which releases anyway.)
            if self.has_admission_headroom() {
                if let Some(arrival) = self.next_arrival() {
                    if arrival <= best_ready {
                        self.now = self.now.max(arrival);
                        self.release_arrivals();
                        self.admit(false)?;
                        if let Some(r) = self.take_rejection() {
                            return Ok(Some(r));
                        }
                        continue;
                    }
                }
            }
            self.now = self.now.max(best_ready);

            let si = match self.cand_src[ci] {
                CandSrc::Stream(si) => si,
                CandSrc::Batch(bi) => {
                    // A fused sweep advances one node per pick, same
                    // granularity as solo streams, and may retire
                    // several members at once when it completes.
                    if let Some(outcome) = self.issue_batch_node(bi)? {
                        return Ok(Some(outcome));
                    }
                    continue;
                }
            };

            // Issue it on the shared resources, addressed to the
            // stream's own KV slot. A prefill chunk issues with the
            // chunk-end context and its position count as the pass
            // count (`passes = 1` in decode — the historical path).
            let tpl = Rc::clone(&self.active[si].tpl);
            let (pos, step_start, next, slot, step_positions) = {
                let s = &self.active[si];
                (s.pos, s.step_start, s.next, s.slot, s.step_positions)
            };
            let ltoken = pos + step_positions;
            // Under paging the KV addressing comes from the stream's
            // page table, not the slot patch (slot ids are virtual and
            // the patched rows are unused on the paged path).
            let patch_slot = if self.cfg.sched.kv_paging { 0 } else { slot };
            let instr = tpl.instr_at(next, ltoken, patch_slot);
            let ctx = IssueCtx {
                cfg: &self.cfg,
                t: &self.t,
                model: &self.model,
                mapping: &self.mapping,
            };
            let out = {
                let s = &self.active[si];
                let pages =
                    if self.cfg.sched.kv_paging { Some(s.pages.as_slice()) } else { None };
                self.res.issue(
                    &ctx,
                    &mut self.plan_scratch,
                    &instr,
                    tpl.deps_of(next),
                    step_start,
                    &s.finish,
                    &s.first_ready,
                    pos,
                    ltoken,
                    step_positions,
                    pages,
                )
            };

            self.stats.add_class(out.class, out.finish.saturating_sub(out.ready));
            self.stats.instructions += 1;
            self.clock = self.clock.max(out.finish);

            let token_done = {
                let s = &mut self.active[si];
                s.instructions += 1;
                s.attributed += out.finish.saturating_sub(out.ready);
                s.first_ready.push(out.first_ready);
                s.finish.push(out.finish);
                s.step_finish = s.step_finish.max(out.finish);
                s.next += 1;
                s.next == s.tpl.len()
            };
            if !token_done {
                continue;
            }

            // The step retires all the positions it covered: every
            // position of a prefill chunk completes at the chunk's
            // finish (its tokens only exist once the whole chunk has
            // run), a decode step completes its single token.
            self.stats.tokens += step_positions;
            let (sid, step_fin) = {
                let s = &self.active[si];
                (s.id, s.step_finish)
            };
            if pos < self.active[si].prompt_tokens {
                self.stats.prefill_chunks += 1;
                self.trace.emit(|| TraceEvent::PrefillChunk {
                    stream: sid,
                    device: 0,
                    start: step_start,
                    finish: step_fin,
                    pos,
                    positions: step_positions,
                });
            } else {
                self.stats.solo_decode_steps += 1;
                self.trace.emit(|| TraceEvent::DecodeStep {
                    stream: sid,
                    device: 0,
                    start: step_start,
                    finish: step_fin,
                    pos,
                });
            }
            let stream_done = {
                let s = &mut self.active[si];
                let fin = s.step_finish;
                for _ in 0..step_positions {
                    s.token_finishes.push(fin);
                }
                s.pos += step_positions;
                s.pos >= s.end_pos
            };
            if !stream_done {
                // Next step: the prompt's next prefill chunk, or a
                // 1-position decode step once the prompt is done.
                let (next_pos, prompt_tokens) = {
                    let s = &self.active[si];
                    (s.pos, s.prompt_tokens)
                };
                let (regime_pos, step_positions) =
                    match prefill::chunk_at(next_pos, prompt_tokens, self.cfg.sched.prefill_chunk)
                    {
                        Some(c) => (c.regime_pos(), c.len),
                        None => (next_pos, 1),
                    };
                let tpl = self.cache.get(&self.model, &self.cfg, regime_pos)?;
                let s = &mut self.active[si];
                s.tpl = tpl;
                s.step_positions = step_positions;
                s.step_start = s.step_finish;
                s.next = 0;
                s.finish.clear();
                s.first_ready.clear();
                // Paged: the new step may cross a page boundary —
                // extend the table (allocating, faulting and evicting
                // as needed) before the step can issue.
                self.grow_stream_frames(si)?;
                continue;
            }

            // Retire the stream: recycle its KV capacity (free as of
            // the stream's own last cycle, not the global clock) and
            // backfill from the queue. The stats row is derived from
            // the completion record so the two views cannot diverge.
            let s = self.active.remove(si);
            self.release_stream_kv(&s);
            self.now = self.now.max(s.step_finish);
            let (rid, rat, rtok) = (s.id, s.step_finish, s.token_finishes.len() as u64);
            self.trace.emit(|| TraceEvent::StreamRetire { stream: rid, at: rat, tokens: rtok });
            let result = StreamResult {
                id: s.id,
                arrival_cycle: s.arrival,
                admitted_cycle: s.admitted,
                finish_cycle: s.step_finish,
                tokens: s.token_finishes.len() as u64,
                prompt_tokens: s.prompt_tokens,
                kv_slot: s.slot,
                token_finishes: s.token_finishes,
            };
            self.stats.prefill_cycles += result.prefill_cycles();
            self.stats.decode_cycles += result.decode_cycles();
            let row = StreamStats::from_result(&result, s.instructions, s.attributed);
            self.stats.streams.push(row);
            self.release_arrivals();
            self.admit(true)?;
            return Ok(Some(StreamOutcome::Completed(result)));
        }
    }

    /// Drain everything: run until every submitted stream reaches a
    /// terminal outcome. Outcomes are in decision order (completions at
    /// their finish, rejections at their admission attempt).
    pub fn run_all(&mut self) -> Result<Vec<StreamOutcome>> {
        let mut out = Vec::new();
        while let Some(r) = self.step()? {
            out.push(r);
        }
        Ok(out)
    }

    /// Fold resource counters into the stats (end of run).
    pub fn finalize_stats(&mut self) -> &SimStats {
        self.stats.cycles = self.clock;
        self.stats.kv_slots = self.n_slots as u64;
        self.stats.kv_pages = self.n_frames as u64;
        self.res.fold_stats(&mut self.stats);
        self.stats.program_cache_hits = self.cache.hits;
        self.stats.program_cache_misses = self.cache.misses;
        self.stats.timeline = self.trace.finish_timeline(self.clock);
        // Debug builds always reconcile and panic; `strict_reconcile`
        // extends the check to release builds, recording a structured
        // error instead of aborting a serving process.
        match self.trace.reconcile(&self.stats) {
            Err(e) if self.cfg.sched.strict_reconcile => {
                self.stats.reconcile_error = Some(e);
            }
            #[cfg(debug_assertions)]
            Err(e) => panic!("trace reconciliation failed: {e}"),
            _ => {}
        }
        &self.stats
    }

    /// The compiled-program cache (hit/miss counters, entry count).
    pub fn program_cache(&self) -> &ProgramCache {
        &self.cache
    }

    /// Test support: the page-table bijection. Every physical frame is
    /// either free exactly once or owned by exactly one active stream's
    /// table (no sharing, no double-free — across admissions,
    /// preemptions and re-admissions), evicted streams hold no frames,
    /// and the committed-frame ledger equals the active population's
    /// expected footprints.
    #[cfg(test)]
    fn assert_frame_invariants(&self) {
        if !self.cfg.sched.kv_paging {
            assert!(self.free_frames.is_empty(), "slot mode has no frame pool");
            assert!(self.active.iter().all(|s| s.pages.is_empty()));
            return;
        }
        let mut owners = vec![0u32; self.n_frames];
        for &f in &self.free_frames {
            owners[f as usize] += 1;
        }
        for s in &self.active {
            assert!(
                s.pages.len() >= 1 && s.pages.len() <= self.mapping.kv.frames_for(s.end_pos),
                "stream {} holds {} frames outside [1, {}]",
                s.id,
                s.pages.len(),
                self.mapping.kv.frames_for(s.end_pos)
            );
            for &f in &s.pages {
                owners[f as usize] += 1;
            }
        }
        for (f, &n) in owners.iter().enumerate() {
            assert_eq!(n, 1, "frame {f} referenced {n} times (bijection violated)");
        }
        let committed: u64 =
            self.active.iter().map(|s| self.mapping.kv.frames_for(s.end_pos) as u64).sum();
        assert_eq!(committed, self.committed_frames, "committed-frame ledger drifted");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn msim(model: &str, k: usize) -> MultiSim {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        MultiSim::new(&m, &cfg).unwrap()
    }

    fn msim_policy(model: &str, k: usize, policy: &str) -> MultiSim {
        let m = by_name(model).unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(k);
        cfg.sched.set_policy_str(policy).unwrap();
        MultiSim::new(&m, &cfg).unwrap()
    }

    /// Keep the completions of a drained run, in completion order.
    fn completed(outcomes: Vec<StreamOutcome>) -> Vec<StreamResult> {
        outcomes.into_iter().filter_map(StreamOutcome::into_completed).collect()
    }

    #[test]
    fn empty_engine_steps_to_none() {
        let mut ms = msim("gpt-nano", 2);
        assert!(ms.step().unwrap().is_none());
    }

    #[test]
    fn single_request_completes() {
        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec::new(7, 5)).unwrap();
        let r = ms.step().unwrap().unwrap().into_completed().expect("completed");
        assert_eq!(r.id, 7);
        assert_eq!(r.tokens, 5);
        assert_eq!(r.token_finishes.len(), 5);
        assert!(r.token_finishes.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(r.queue_cycles(), 0);
        assert!(r.service_cycles() > 0);
        assert!(ms.step().unwrap().is_none());
    }

    #[test]
    fn submit_rejects_invalid_lengths() {
        let mut ms = msim("gpt-nano", 2); // max_seq 128
        assert!(ms.submit(StreamSpec::new(0, 0)).is_err());
        assert!(ms.submit(StreamSpec::new(1, 129)).is_err());
        assert!(ms.submit(StreamSpec::new(2, 128)).is_ok());
    }

    #[test]
    fn excess_requests_queue_and_report_waiting() {
        let mut ms = msim("gpt-nano", 2);
        for id in 0..4 {
            ms.submit(StreamSpec::new(id, 4)).unwrap();
        }
        assert_eq!(ms.queued_streams(), 4);
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 4);
        // First two admitted immediately; the last two waited.
        let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).queue_cycles(), 0);
        assert_eq!(by_id(1).queue_cycles(), 0);
        assert!(by_id(2).queue_cycles() > 0);
        assert!(by_id(3).queue_cycles() > 0);
    }

    #[test]
    fn interleaving_beats_fifo_on_makespan() {
        // Same request set, K=1 (FIFO) vs K=4: the interleaved schedule
        // must finish strictly earlier (it fills channel idle gaps with
        // the other streams' VMMs).
        let specs: Vec<StreamSpec> = (0..4).map(|id| StreamSpec::new(id, 4 + 2 * id)).collect();
        let mut fifo = msim("gpt2-small", 1);
        let mut inter = msim("gpt2-small", 4);
        for s in &specs {
            fifo.submit(*s).unwrap();
            inter.submit(*s).unwrap();
        }
        fifo.run_all().unwrap();
        inter.run_all().unwrap();
        assert!(
            inter.clock() < fifo.clock(),
            "interleaved {} !< fifo {}",
            inter.clock(),
            fifo.clock()
        );
    }

    #[test]
    fn deterministic_interleaving() {
        let run = || {
            let mut ms = msim("gpt2-small", 3);
            for id in 0..5 {
                ms.submit(StreamSpec::new(id, 3 + id)).unwrap();
            }
            let results = completed(ms.run_all().unwrap());
            (ms.clock(), results.iter().map(|r| r.finish_cycle).collect::<Vec<_>>())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn per_stream_stats_recorded() {
        let mut ms = msim("gpt-nano", 2);
        for id in 0..3 {
            ms.submit(StreamSpec::new(id, 4)).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        assert_eq!(ms.stats.streams.len(), 3);
        let total_tokens: u64 = ms.stats.streams.iter().map(|s| s.tokens).sum();
        assert_eq!(total_tokens, 12);
        assert_eq!(ms.stats.tokens, 12);
        for s in &ms.stats.streams {
            assert!(s.instructions > 0);
            assert!(s.attributed_cycles > 0);
            assert!(s.service_cycles > 0);
            assert!(s.kv_slot < 2, "slot {} out of range", s.kv_slot);
        }
    }

    #[test]
    fn slots_recycled_with_occupancy_and_blocked_counters() {
        let mut ms = msim("gpt-nano", 2);
        assert_eq!(ms.kv_slots(), 2);
        assert_eq!(ms.free_kv_slots(), 2);
        for id in 0..5 {
            ms.submit(StreamSpec::new(id, 3)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        ms.finalize_stats();
        assert_eq!(ms.free_kv_slots(), 2, "all slots recycled after drain");
        assert_eq!(ms.stats.kv_slots, 2);
        assert_eq!(ms.stats.peak_slots_in_use, 2);
        assert_eq!(ms.stats.rejected, 0, "admit-always never sheds");
        assert!(ms.stats.admission_blocked > 0, "5 requests on 2 slots must block");
        // Every stream ran in a valid slot, both slots were used, and 5
        // streams over 2 slots implies at least one id was recycled.
        assert!(results.iter().all(|r| r.kv_slot < 2));
        let s0 = results.iter().filter(|r| r.kv_slot == 0).count();
        assert!((1..=4).contains(&s0), "slot 0 used {s0} of 5 times");
    }

    /// Satellite regression: a backfilled stream is admitted at the
    /// *retiring stream's* last cycle (its slot's actual free time), not
    /// at the global clock — the global max finish can lie far past a
    /// short stream's retirement and would inflate `queue_cycles`.
    #[test]
    fn backfill_admits_at_freed_slot_cycle() {
        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec::new(0, 12)).unwrap(); // long
        ms.submit(StreamSpec::new(1, 2)).unwrap(); // short
        ms.submit(StreamSpec::new(2, 2)).unwrap(); // backfill
        let results = completed(ms.run_all().unwrap());
        let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
        let short = by_id(1);
        let backfill = by_id(2);
        // Stream 1 retires first (both admitted at 0, fewer tokens);
        // stream 2 takes its slot at exactly that finish cycle.
        assert!(short.finish_cycle < by_id(0).finish_cycle);
        assert_eq!(backfill.admitted_cycle, short.finish_cycle);
        assert_eq!(backfill.queue_cycles(), short.finish_cycle);
        assert_eq!(backfill.kv_slot, short.kv_slot);
    }

    /// Acceptance: when the mapping degrades the slot count below
    /// `max_streams`, admission blocks on KV capacity — fewer concurrent
    /// streams, positive queueing, and the shortfall is reported.
    #[test]
    fn kv_capacity_limits_admission_below_max_streams() {
        let m = by_name("gpt2-small").unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
        cfg.gddr6.capacity_gbit = 0.34; // weights + ~2 contexts per bank
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        assert!(ms.kv_slots() < 4, "expected degraded slots, got {}", ms.kv_slots());
        assert!(ms.mapping.kv_shortfall.is_some());
        for id in 0..4 {
            ms.submit(StreamSpec::new(id, 2)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        ms.finalize_stats();
        assert_eq!(results.len(), 4);
        assert_eq!(ms.stats.peak_slots_in_use, ms.kv_slots() as u64);
        assert!(ms.stats.admission_blocked > 0);
        let queued = results.iter().filter(|r| r.queue_cycles() > 0).count();
        assert!(queued >= 1, "capacity-blocked requests must report queueing");
    }

    /// Tentpole regression (the arrival-stamping bug): a request
    /// submitted *mid-run* must report latencies measured from its own
    /// `arrival_cycle`, not from the global clock high-water mark the
    /// old `submit` stamped (`self.clock`), which by then sits at the
    /// previous stream's finish and zeroed every queue observation.
    #[test]
    fn mid_run_submit_measures_queue_from_arrival_not_clock() {
        let mut ms = msim("gpt-nano", 1);
        ms.submit(StreamSpec::new(0, 12)).unwrap();
        let r0 = ms.step().unwrap().unwrap().into_completed().expect("completed");
        let arrival = 1_000u64;
        assert!(arrival < r0.finish_cycle, "12 gpt-nano tokens outlast cycle {arrival}");
        assert!(ms.clock() >= r0.finish_cycle);
        ms.submit(StreamSpec { id: 1, n_tokens: 2, prompt_tokens: 1, arrival_cycle: arrival })
            .unwrap();
        let r1 = ms.step().unwrap().unwrap().into_completed().expect("completed");
        assert_eq!(r1.arrival_cycle, arrival);
        // The only KV slot frees at r0's finish: queueing spans arrival
        // -> that cycle. The old stamping reported queue_cycles == 0.
        assert_eq!(r1.admitted_cycle, r0.finish_cycle);
        assert_eq!(r1.queue_cycles(), r0.finish_cycle - arrival);
        assert_eq!(r1.ttft_cycles(), r1.token_finishes[0] - arrival);
        assert_eq!(r1.e2e_cycles(), r1.queue_cycles() + r1.service_cycles());
    }

    /// An idle engine warps simulated time to the next arrival instead
    /// of admitting early (or spinning): the request starts at its own
    /// arrival with zero queueing.
    #[test]
    fn idle_engine_warps_to_future_arrival() {
        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec { id: 0, n_tokens: 2, prompt_tokens: 1, arrival_cycle: 50_000 })
            .unwrap();
        assert_eq!(ms.queued_streams(), 1);
        let r = ms.step().unwrap().unwrap().into_completed().expect("completed");
        assert_eq!(r.arrival_cycle, 50_000);
        assert_eq!(r.admitted_cycle, 50_000);
        assert_eq!(r.queue_cycles(), 0);
        assert!(r.token_finishes[0] > 50_000);
        assert!(ms.clock() > 50_000, "clock follows the warped schedule");
    }

    /// Requests are released in *arrival* order, not submit order.
    #[test]
    fn release_follows_arrival_order_not_submit_order() {
        let mut ms = msim("gpt-nano", 1);
        ms.submit(StreamSpec { id: 0, n_tokens: 2, prompt_tokens: 1, arrival_cycle: 2_000 })
            .unwrap();
        ms.submit(StreamSpec { id: 1, n_tokens: 8, prompt_tokens: 1, arrival_cycle: 0 }).unwrap();
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results[0].id, 1, "the earlier arrival runs first on K=1");
        assert_eq!(results[1].id, 0);
        assert!(results[1].admitted_cycle >= 2_000);
    }

    /// Event-driven release: while another stream is running, a pending
    /// arrival is admitted into a free slot the moment simulated time
    /// passes it — stamped at its own arrival, with zero queueing.
    #[test]
    fn busy_engine_releases_arrival_into_free_slot() {
        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec::new(0, 12)).unwrap();
        ms.submit(StreamSpec { id: 1, n_tokens: 2, prompt_tokens: 1, arrival_cycle: 500 }).unwrap();
        let results = completed(ms.run_all().unwrap());
        let r1 = results.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.arrival_cycle, 500);
        assert_eq!(r1.admitted_cycle, 500, "free slot -> admitted at arrival");
        assert_eq!(r1.queue_cycles(), 0);
    }

    /// Satellite pin: `admission_blocked` counts blocked *requests* per
    /// admission attempt, so deep queues weigh more than shallow ones.
    /// With 1 slot and n equal requests at cycle 0 the attempts are:
    /// step-1 entry admits r0 leaving n-1 waiting; then for each
    /// retirement i (admitting the next request, n-1-i left) the
    /// following step entry sees the same n-1-i still waiting — total
    /// (n-1) + 2*sum(1..=n-2) = (n-1)^2.
    #[test]
    fn admission_blocked_counts_waiting_requests() {
        let run = |n: u64| {
            let mut ms = msim("gpt-nano", 1);
            for id in 0..n {
                ms.submit(StreamSpec::new(id, 2)).unwrap();
            }
            ms.run_all().unwrap();
            ms.finalize_stats();
            ms.stats.admission_blocked
        };
        assert_eq!(run(3), 4);
        assert_eq!(run(6), 25);
        // The old unit (one count per attempt regardless of depth)
        // reported 3 vs 9 here — depth was invisible at equal cadence.
        assert_eq!(run(1), 0, "a lone request never blocks");
    }

    /// Tentpole: SRF admission drains a heterogeneous backlog shortest
    /// first. On one slot, four queued requests of lengths {8, 2, 4, 2}
    /// complete in deterministic shortest-first order (ties by queue
    /// position), while FCFS keeps arrival order.
    #[test]
    fn srf_admission_picks_shortest_queued_request() {
        let lens = [8u64, 2, 4, 2];
        let order = |policy: &str| {
            let mut ms = msim_policy("gpt-nano", 1, policy);
            for (id, &n) in lens.iter().enumerate() {
                ms.submit(StreamSpec::new(id as u64, n)).unwrap();
            }
            let results = completed(ms.run_all().unwrap());
            results.iter().map(|r| r.id).collect::<Vec<_>>()
        };
        assert_eq!(order("fcfs"), vec![0, 1, 2, 3]);
        assert_eq!(order("srf"), vec![1, 3, 2, 0]);
    }

    /// Tentpole: fair-share keeps identical concurrent streams in
    /// lockstep — the spread of per-stream service cycles stays a small
    /// fraction of the service itself.
    #[test]
    fn fair_share_bounds_service_spread_on_identical_streams() {
        let mut ms = msim_policy("gpt-nano", 4, "fair");
        for id in 0..4 {
            ms.submit(StreamSpec::new(id, 6)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 4);
        let services: Vec<u64> = results.iter().map(|r| r.service_cycles()).collect();
        let max = *services.iter().max().unwrap();
        let min = *services.iter().min().unwrap();
        assert!(min > 0);
        assert!(
            max - min <= max / 2,
            "fair-share spread {} exceeds half the max service {max}",
            max - min
        );
    }

    /// Tentpole: SLO admission sheds queued requests as first-class
    /// rejected outcomes (never errors) with the prediction that
    /// triggered them, while the uncongested request completes.
    #[test]
    fn slo_rejections_are_first_class_outcomes() {
        // Probe the isolated first-token cost to place the budget:
        // generous enough to admit a wait-free request, far below the
        // wait behind a 24-token stream on the only slot.
        let mut probe = msim("gpt-nano", 1);
        probe.submit(StreamSpec::new(0, 2)).unwrap();
        let ttft0 = completed(probe.run_all().unwrap())[0].token_finishes[0];
        let budget = 4 * ttft0 + 3_000;

        let mut ms = msim_policy("gpt-nano", 1, &format!("slo:{budget}"));
        ms.submit(StreamSpec::new(0, 24)).unwrap();
        for id in 1..5 {
            ms.submit(StreamSpec::new(id, 2)).unwrap();
        }
        let outcomes = ms.run_all().unwrap();
        ms.finalize_stats();
        assert_eq!(outcomes.len(), 5, "every request reaches a terminal outcome");
        let completed_ids: Vec<u64> =
            outcomes.iter().filter_map(|o| o.as_completed().map(|r| r.id)).collect();
        let rejected: Vec<&RejectedStream> =
            outcomes.iter().filter_map(|o| o.as_rejected()).collect();
        assert_eq!(completed_ids, vec![0], "only the wait-free request runs");
        assert_eq!(rejected.len(), 4);
        assert_eq!(ms.stats.rejected, 4);
        let r0_finish = outcomes[0].as_completed().unwrap().finish_cycle;
        for r in rejected {
            // Each rejection was decided when the only slot freed, and
            // the busted prediction is carried on the record.
            assert_eq!(r.decided_cycle, r0_finish);
            assert_eq!(r.waited_cycles(), r0_finish);
            assert_eq!(r.ttft_budget_cycles, budget);
            assert!(r.predicted_ttft_cycles > budget);
        }
        // Latency percentiles cover admitted streams only.
        assert_eq!(ms.stats.streams.len(), 1);
    }

    /// One admission pass can shed several requests; the outcomes drain
    /// one per `step` and `undelivered_rejections` exposes the backlog
    /// (the serving loop keeps stepping on it instead of blocking).
    #[test]
    fn buffered_rejections_drain_one_per_step() {
        let mut ms = msim_policy("gpt-nano", 2, "slo:1");
        for id in 0..3 {
            ms.submit(StreamSpec::new(id, 2)).unwrap();
        }
        let first = ms.step().unwrap().unwrap();
        assert_eq!(first.as_rejected().map(|r| r.id), Some(0));
        assert_eq!(ms.undelivered_rejections(), 2);
        assert!(ms.step().unwrap().unwrap().as_rejected().is_some());
        assert!(ms.step().unwrap().unwrap().as_rejected().is_some());
        assert_eq!(ms.undelivered_rejections(), 0);
        assert!(ms.step().unwrap().is_none());
        assert_eq!(ms.stats.rejected, 3);
    }

    /// An idle-warp arrival that busts the budget is shed too (the warp
    /// loop must not assume a warp always admits something).
    #[test]
    fn slo_sheds_warped_arrival_and_drains() {
        let mut ms = msim_policy("gpt-nano", 1, "slo:1");
        ms.submit(StreamSpec { id: 0, n_tokens: 2, prompt_tokens: 1, arrival_cycle: 10_000 })
            .unwrap();
        let out = ms.step().unwrap().unwrap();
        let rej = out.as_rejected().expect("budget of 1 cycle rejects everything");
        assert_eq!(rej.id, 0);
        assert_eq!(rej.arrival_cycle, 10_000);
        assert_eq!(rej.decided_cycle, 10_000, "decided at the warped arrival");
        assert!(ms.step().unwrap().is_none(), "engine drains after the rejection");
    }

    /// Policies are seed-deterministic: identical runs produce identical
    /// outcome sequences, cycle for cycle.
    #[test]
    fn policies_are_deterministic() {
        for policy in ["fcfs", "srf", "fair", "slo:40000"] {
            let run = || {
                let mut ms = msim_policy("gpt-nano", 2, policy);
                for id in 0..6 {
                    let spec = StreamSpec {
                        id,
                        n_tokens: 2 + (id % 3),
                        prompt_tokens: 1,
                        arrival_cycle: id * 700,
                    };
                    ms.submit(spec)
                        .unwrap();
                }
                let outcomes = ms.run_all().unwrap();
                let sig: Vec<(u64, u64, bool)> = outcomes
                    .iter()
                    .map(|o| match o {
                        StreamOutcome::Completed(r) => (r.id, r.finish_cycle, false),
                        StreamOutcome::Rejected(r) => (r.id, r.decided_cycle, true),
                    })
                    .collect();
                (ms.clock(), sig)
            };
            assert_eq!(run(), run(), "policy {policy} diverged across identical runs");
        }
    }

    /// Satellite property: over randomized seeded arrival traces *and*
    /// randomized prompt/generation splits and chunk sizes, the latency
    /// views agree (queue + service == finish - arrival, prefill +
    /// decode == service), token finishes are nondecreasing (equal only
    /// within a prefill chunk) and strictly increasing across decode
    /// steps, TTFT is the prompt-completion stamp, and the derived
    /// `StreamStats` row matches its `StreamResult` exactly.
    #[test]
    fn stream_identities_over_random_arrival_traces() {
        use crate::util::prop::check;
        check("stream latency identities", 12, |rng| {
            let k = 1 + rng.gen_range(3) as usize;
            let n_req = 1 + rng.gen_range(5);
            let m = by_name("gpt-nano").unwrap();
            let mut cfg = HwConfig::paper_baseline().with_max_streams(k);
            cfg.sched.prefill_chunk = 1 + rng.gen_range(16);
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            for id in 0..n_req {
                let n_tokens = 1 + rng.gen_range(24);
                let spec = StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(20_000),
                };
                ms.submit(spec).map_err(|e| e.to_string())?;
            }
            let outcomes = ms.run_all().map_err(|e| e.to_string())?;
            let results: Vec<StreamResult> =
                outcomes.into_iter().filter_map(StreamOutcome::into_completed).collect();
            ms.finalize_stats();
            if results.len() as u64 != n_req {
                return Err(format!("{} of {n_req} streams retired", results.len()));
            }
            for r in &results {
                if r.admitted_cycle < r.arrival_cycle {
                    return Err(format!("stream {} admitted before arrival", r.id));
                }
                if r.queue_cycles() + r.service_cycles() != r.e2e_cycles() {
                    return Err(format!("stream {} latency identity broken", r.id));
                }
                if r.prefill_cycles() + r.decode_cycles() != r.service_cycles() {
                    return Err(format!("stream {} prefill/decode split broken", r.id));
                }
                if !r.token_finishes.windows(2).all(|w| w[0] <= w[1]) {
                    return Err(format!("stream {} token finishes decrease", r.id));
                }
                // Decode positions (past the prompt) strictly increase.
                let decode = &r.token_finishes[r.prompt_tokens as usize - 1..];
                if !decode.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("stream {} decode finishes not strict", r.id));
                }
                if r.token_finishes[0] < r.admitted_cycle {
                    return Err(format!("stream {} first token before admission", r.id));
                }
                if r.prefill_finish_cycle()
                    != r.token_finishes[r.prompt_tokens as usize - 1]
                {
                    return Err(format!("stream {} ttft stamp not the prompt's last", r.id));
                }
                if r.ttft_cycles() > r.e2e_cycles() {
                    return Err(format!("stream {} ttft exceeds e2e", r.id));
                }
                let s = ms
                    .stats
                    .streams
                    .iter()
                    .find(|s| s.id == r.id)
                    .ok_or_else(|| format!("stream {} missing from stats", r.id))?;
                let same = s.arrival_cycle == r.arrival_cycle
                    && s.queue_cycles == r.queue_cycles()
                    && s.service_cycles == r.service_cycles()
                    && s.prefill_cycles == r.prefill_cycles()
                    && s.decode_cycles() == r.decode_cycles()
                    && s.ttft_cycles == r.ttft_cycles()
                    && s.e2e_cycles() == r.e2e_cycles()
                    && s.tokens == r.tokens
                    && s.prompt_tokens == r.prompt_tokens;
                if !same {
                    return Err(format!("stream {} stats diverge from result", r.id));
                }
            }
            // Aggregate split matches the per-stream sums.
            let prefill: u64 = results.iter().map(|r| r.prefill_cycles()).sum();
            let decode: u64 = results.iter().map(|r| r.decode_cycles()).sum();
            if ms.stats.prefill_cycles != prefill || ms.stats.decode_cycles != decode {
                return Err("aggregate prefill/decode split diverges".into());
            }
            Ok(())
        });
    }

    /// Tentpole: a prompted request is one prefill-chunk sequence plus
    /// decode steps — token counts, chunk counters and the TTFT stamp
    /// all line up, and every prompt position completes at its chunk's
    /// finish.
    #[test]
    fn chunked_prompt_completes_with_chunk_grained_finishes() {
        let m = by_name("gpt-nano").unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(2);
        cfg.sched.prefill_chunk = 8;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::with_prompt(0, 20, 3)).unwrap();
        let r = ms.step().unwrap().unwrap().into_completed().expect("completed");
        ms.finalize_stats();
        assert_eq!(r.tokens, 23);
        assert_eq!(r.prompt_tokens, 20);
        assert_eq!(r.token_finishes.len(), 23);
        // 20 prompt positions at chunk 8 -> chunks of 8, 8, 4.
        assert_eq!(ms.stats.prefill_chunks, 3);
        assert_eq!(ms.stats.tokens, 23);
        // Chunk-grained finishes: positions within a chunk share one
        // finish cycle; distinct chunks/decodes strictly advance.
        let f = &r.token_finishes;
        assert_eq!(f[0..8].iter().collect::<std::collections::BTreeSet<_>>().len(), 1);
        assert_eq!(f[8..16].iter().collect::<std::collections::BTreeSet<_>>().len(), 1);
        assert_eq!(f[16..20].iter().collect::<std::collections::BTreeSet<_>>().len(), 1);
        assert!(f[7] < f[8] && f[15] < f[16]);
        assert!(f[19] < f[20] && f[20] < f[21] && f[21] < f[22]);
        // TTFT is the prompt-completion stamp, not the first chunk's.
        assert_eq!(r.prefill_finish_cycle(), f[19]);
        assert_eq!(r.ttft_cycles(), f[19]);
        assert!(r.prefill_cycles() > 0 && r.decode_cycles() > 0);
        assert_eq!(r.prefill_cycles() + r.decode_cycles(), r.service_cycles());
    }

    /// Tentpole acceptance: chunked prefill strictly lowers TTFT and
    /// makespan versus token-by-token prefill of the same prompt
    /// (`prefill_chunk = 1`), and larger chunks keep helping.
    #[test]
    fn chunked_prefill_beats_token_by_token_ttft() {
        let m = by_name("gpt-nano").unwrap();
        let run = |chunk: u64| {
            let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
            cfg.sched.prefill_chunk = chunk;
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            ms.submit(StreamSpec::with_prompt(0, 96, 4)).unwrap();
            let r = ms.step().unwrap().unwrap().into_completed().expect("completed");
            (r.ttft_cycles(), r.e2e_cycles())
        };
        let (ttft1, e2e1) = run(1);
        let (ttft16, e2e16) = run(16);
        let (ttft48, e2e48) = run(48);
        assert!(ttft16 < ttft1, "chunk 16 ttft {ttft16} !< token-by-token {ttft1}");
        assert!(ttft48 < ttft16, "chunk 48 ttft {ttft48} !< chunk 16 {ttft16}");
        assert!(e2e16 < e2e1, "chunk 16 e2e {e2e16} !< token-by-token {e2e1}");
        assert!(e2e48 < e2e16);
    }

    /// Pure-prefill requests (`gen_tokens = 0`) are legal: the last
    /// prompt position is the first generated token, so TTFT == e2e.
    #[test]
    fn pure_prefill_request_ttft_equals_e2e() {
        let m = by_name("gpt-nano").unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
        cfg.sched.prefill_chunk = 16;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::with_prompt(0, 24, 0)).unwrap();
        let r = ms.step().unwrap().unwrap().into_completed().expect("completed");
        assert_eq!(r.tokens, 24);
        assert_eq!(r.ttft_cycles(), r.e2e_cycles());
        assert_eq!(r.decode_cycles(), 0);
    }

    /// Submit validation covers the prompt split: zero-prompt and
    /// prompt-exceeds-total both fail loudly with the request id, and
    /// the total-length error names the split.
    #[test]
    fn submit_rejects_invalid_prompt_splits() {
        let mut ms = msim("gpt-nano", 2); // max_seq 128
        let bad = StreamSpec { id: 7, n_tokens: 4, prompt_tokens: 0, arrival_cycle: 0 };
        let err = ms.submit(bad).unwrap_err().to_string();
        assert!(err.contains("request 7") && err.contains("zero-token prompt"), "{err}");
        let bad = StreamSpec { id: 8, n_tokens: 4, prompt_tokens: 5, arrival_cycle: 0 };
        let err = ms.submit(bad).unwrap_err().to_string();
        assert!(err.contains("request 8") && err.contains("prompt 5"), "{err}");
        let err = ms.submit(StreamSpec::with_prompt(9, 100, 29)).unwrap_err().to_string();
        assert!(err.contains("request 9") && err.contains("prompt 100"), "{err}");
        assert!(ms.submit(StreamSpec::with_prompt(10, 100, 28)).is_ok());
    }

    /// The SLO predictor tracks the actual prompt length: a long prompt
    /// predicts a higher first-token cost than a short one, so a budget
    /// can admit short prompts while shedding long ones.
    #[test]
    fn slo_prediction_scales_with_prompt_length() {
        let m = by_name("gpt-nano").unwrap();
        // Probe the short-prompt cost to place the budget between the
        // two prompt lengths.
        let mut probe = msim_policy("gpt-nano", 2, "slo:1");
        probe.submit(StreamSpec::with_prompt(0, 1, 1)).unwrap();
        let short_pred = probe
            .run_all()
            .unwrap()
            .remove(0)
            .as_rejected()
            .expect("1-cycle budget rejects")
            .predicted_ttft_cycles;

        let mut cfg = HwConfig::paper_baseline().with_max_streams(2);
        cfg.sched.set_policy_str(&format!("slo:{}", 2 * short_pred)).unwrap();
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::with_prompt(0, 1, 1)).unwrap();
        ms.submit(StreamSpec::with_prompt(1, 96, 1)).unwrap();
        let outcomes = ms.run_all().unwrap();
        ms.finalize_stats();
        let completed_ids: Vec<u64> =
            outcomes.iter().filter_map(|o| o.as_completed().map(|r| r.id)).collect();
        let rejected: Vec<&RejectedStream> =
            outcomes.iter().filter_map(|o| o.as_rejected()).collect();
        assert_eq!(completed_ids, vec![0], "short prompt admitted");
        assert_eq!(rejected.len(), 1, "long prompt shed on its own predicted prefill");
        assert_eq!(rejected[0].id, 1);
        assert_eq!(rejected[0].waited_cycles(), 0, "shed at admission, not after queueing");
        assert!(rejected[0].predicted_ttft_cycles > 2 * short_pred);
    }

    /// Tentpole pin: `batch_decode = on` at K = 1 replays the unbatched
    /// schedule cycle-for-cycle on arbitrary arrival traces — a lone
    /// stream never has a fusion partner, so it never defers and never
    /// fuses (and `batch_decode = off` is the untouched historical path
    /// at any K).
    #[test]
    fn batch_decode_k1_is_cycle_identical_over_random_traces() {
        use crate::util::prop::check;
        check("batched K=1 equivalence", 10, |rng| {
            let n_req = 1 + rng.gen_range(5);
            let chunk = 1 + rng.gen_range(8);
            let mut specs = Vec::new();
            for id in 0..n_req {
                let n_tokens = 1 + rng.gen_range(20);
                specs.push(StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(30_000),
                });
            }
            let run = |batch: bool| -> Result<(u64, u64, u64, Vec<(u64, u64, Vec<u64>)>), String> {
                let m = by_name("gpt-nano").unwrap();
                let mut cfg =
                    HwConfig::paper_baseline().with_max_streams(1).with_batch_decode(batch);
                cfg.sched.prefill_chunk = chunk;
                let mut ms = MultiSim::new(&m, &cfg).unwrap();
                for s in &specs {
                    ms.submit(*s).map_err(|e| e.to_string())?;
                }
                let results = ms.run_all().map_err(|e| e.to_string())?;
                ms.finalize_stats();
                let sig: Vec<(u64, u64, Vec<u64>)> = results
                    .into_iter()
                    .filter_map(StreamOutcome::into_completed)
                    .map(|r| (r.id, r.admitted_cycle, r.token_finishes))
                    .collect();
                Ok((ms.clock(), ms.stats.instructions, ms.stats.fused_sweeps, sig))
            };
            let on = run(true)?;
            let off = run(false)?;
            if on.2 != 0 {
                return Err(format!("K=1 fused {} sweeps", on.2));
            }
            if on != off {
                return Err(format!("K=1 batched diverged: clock {} vs {}", on.0, off.0));
            }
            Ok(())
        });
    }

    /// Tentpole: four identical decode-heavy streams at K = 4 fuse
    /// (occupancy counters move) and the batched engine finishes
    /// strictly earlier than the unbatched one — the shared ACT/PRE
    /// sweep and ASIC pipeline fill amortize across streams.
    #[test]
    fn batched_decode_fuses_and_beats_unbatched_makespan() {
        let run = |batch: bool| {
            let m = by_name("gpt-nano").unwrap();
            let cfg = HwConfig::paper_baseline().with_max_streams(4).with_batch_decode(batch);
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            for id in 0..4 {
                ms.submit(StreamSpec::new(id, 12)).unwrap();
            }
            let results = completed(ms.run_all().unwrap());
            ms.finalize_stats();
            assert_eq!(results.len(), 4);
            for r in &results {
                assert_eq!(r.tokens, 12);
                let decode = &r.token_finishes[r.prompt_tokens as usize - 1..];
                assert!(decode.windows(2).all(|w| w[0] < w[1]), "decode finishes not strict");
            }
            (ms.clock(), ms.stats.clone())
        };
        let (on_clock, on) = run(true);
        let (off_clock, off) = run(false);
        assert!(on.fused_sweeps > 0, "no sweeps fused at K=4");
        assert!(on.max_decode_batch >= 2);
        assert!(on.mean_decode_batch() >= 2.0);
        assert_eq!(on.tokens, off.tokens);
        assert_eq!(off.fused_sweeps, 0, "unbatched engine must not fuse");
        assert_eq!(off.max_decode_batch, 0);
        assert!(
            on.solo_decode_steps < off.solo_decode_steps,
            "batching must convert solo decode steps into fused sweeps"
        );
        assert!(on_clock < off_clock, "batched makespan {on_clock} !< unbatched {off_clock}");
    }

    /// Edge: staggered lengths — the short member retires mid-run while
    /// the survivors keep fusing; slots recycle and every stream's
    /// token count is exact.
    #[test]
    fn stream_retires_mid_batch_and_survivors_keep_fusing() {
        let m = by_name("gpt-nano").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(3).with_batch_decode(true);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::new(0, 4)).unwrap();
        ms.submit(StreamSpec::new(1, 10)).unwrap();
        ms.submit(StreamSpec::new(2, 16)).unwrap();
        let results = completed(ms.run_all().unwrap());
        ms.finalize_stats();
        assert_eq!(results.len(), 3);
        let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).tokens, 4);
        assert_eq!(by_id(1).tokens, 10);
        assert_eq!(by_id(2).tokens, 16);
        assert!(ms.stats.fused_sweeps > 0);
        assert!(ms.stats.max_decode_batch >= 2);
        assert_eq!(ms.free_kv_slots(), 3, "slots recycled after drain");
        assert_eq!(ms.stats.tokens, 30);
    }

    /// Edge: a request arriving mid-run joins later sweeps (the
    /// continuous-batching join) — it completes with exact latency
    /// stamps while the earlier pair keeps fusing.
    #[test]
    fn stream_arriving_mid_run_joins_later_sweeps() {
        let m = by_name("gpt-nano").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(3).with_batch_decode(true);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::new(0, 20)).unwrap();
        ms.submit(StreamSpec::new(1, 20)).unwrap();
        ms.submit(StreamSpec { id: 2, n_tokens: 8, prompt_tokens: 1, arrival_cycle: 10_000 })
            .unwrap();
        let results = completed(ms.run_all().unwrap());
        ms.finalize_stats();
        assert_eq!(results.len(), 3);
        let late = results.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(late.arrival_cycle, 10_000);
        assert_eq!(late.queue_cycles(), 0, "a free slot admits the arrival immediately");
        assert_eq!(late.tokens, 8);
        assert!(ms.stats.fused_sweeps > 0);
        assert!(ms.stats.max_decode_batch >= 2);
    }

    /// A sweep that retires several members at once surfaces one
    /// completion per `step`; `undelivered_completions` exposes the
    /// backlog so a serving loop keeps stepping instead of blocking.
    #[test]
    fn fused_retirements_drain_one_per_step() {
        let m = by_name("gpt-nano").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(2).with_batch_decode(true);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::new(0, 6)).unwrap();
        ms.submit(StreamSpec::new(1, 6)).unwrap();
        let first = ms.step().unwrap().unwrap();
        assert!(first.as_completed().is_some());
        // Identical twins retire on the same final sweep: the second
        // completion is buffered and drains on the next step.
        assert_eq!(ms.undelivered_completions(), 1);
        let second = ms.step().unwrap().unwrap();
        assert!(second.as_completed().is_some());
        assert_eq!(ms.undelivered_completions(), 0);
        assert!(ms.step().unwrap().is_none());
        ms.finalize_stats();
        assert!(ms.stats.fused_sweeps > 0);
    }

    /// Edge: chunked prefill interleaves with decode batching —
    /// prefill chunks run per-stream with chunk-grained finishes while
    /// the decode phases fuse (a last-chunk prefiller counts as a
    /// fusion partner, so the decode stream waits at its boundary).
    #[test]
    fn mixed_prefill_chunks_and_decode_batches() {
        let m = by_name("gpt-nano").unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(2).with_batch_decode(true);
        cfg.sched.prefill_chunk = 8;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::with_prompt(0, 20, 10)).unwrap();
        ms.submit(StreamSpec::with_prompt(1, 12, 10)).unwrap();
        let results = completed(ms.run_all().unwrap());
        ms.finalize_stats();
        assert_eq!(results.len(), 2);
        let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).tokens, 30);
        assert_eq!(by_id(1).tokens, 22);
        // 20 prompt positions at chunk 8 -> 3 chunks; 12 -> 2 chunks.
        assert_eq!(ms.stats.prefill_chunks, 5);
        assert!(ms.stats.fused_sweeps > 0, "decode phases must fuse");
        // Prefill keeps chunk-grained finishes under batching.
        let f = &by_id(0).token_finishes;
        assert_eq!(f[0..8].iter().collect::<std::collections::BTreeSet<_>>().len(), 1);
        assert!(f.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(by_id(0).prefill_finish_cycle(), f[19]);
    }

    /// Satellite property: with batching ON over random traces, prompt
    /// splits, chunk sizes and K, every latency identity from the
    /// unbatched engine still holds, token accounting is exact, and
    /// the occupancy counters are internally consistent.
    #[test]
    fn batched_identities_over_random_arrival_traces() {
        use crate::util::prop::check;
        check("batched stream identities", 10, |rng| {
            let k = 2 + rng.gen_range(3) as usize;
            let n_req = 2 + rng.gen_range(5);
            let m = by_name("gpt-nano").unwrap();
            let mut cfg = HwConfig::paper_baseline().with_max_streams(k).with_batch_decode(true);
            cfg.sched.prefill_chunk = 1 + rng.gen_range(8);
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            let mut total = 0u64;
            for id in 0..n_req {
                let n_tokens = 2 + rng.gen_range(20);
                total += n_tokens;
                let spec = StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(20_000),
                };
                ms.submit(spec).map_err(|e| e.to_string())?;
            }
            let results: Vec<StreamResult> = ms
                .run_all()
                .map_err(|e| e.to_string())?
                .into_iter()
                .filter_map(StreamOutcome::into_completed)
                .collect();
            ms.finalize_stats();
            if results.len() as u64 != n_req {
                return Err(format!("{} of {n_req} streams retired", results.len()));
            }
            if ms.stats.tokens != total {
                return Err(format!("token total {} != {total}", ms.stats.tokens));
            }
            for r in &results {
                if r.admitted_cycle < r.arrival_cycle {
                    return Err(format!("stream {} admitted before arrival", r.id));
                }
                if r.queue_cycles() + r.service_cycles() != r.e2e_cycles() {
                    return Err(format!("stream {} latency identity broken", r.id));
                }
                if r.prefill_cycles() + r.decode_cycles() != r.service_cycles() {
                    return Err(format!("stream {} prefill/decode split broken", r.id));
                }
                if r.token_finishes.len() as u64 != r.tokens {
                    return Err(format!("stream {} token count broken", r.id));
                }
                let decode = &r.token_finishes[r.prompt_tokens as usize - 1..];
                if !decode.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("stream {} decode finishes not strict", r.id));
                }
            }
            let s = &ms.stats;
            if s.fused_streams < 2 * s.fused_sweeps {
                return Err("fused_streams < 2 * fused_sweeps".into());
            }
            if s.fused_sweeps > 0 && s.mean_decode_batch() > s.max_decode_batch as f64 {
                return Err("mean occupancy exceeds max".into());
            }
            Ok(())
        });
    }

    /// Satellite: idle arrival-gap warp time is counted and excluded
    /// from busy cycles — a lone late arrival warps exactly its gap,
    /// while a batch-at-zero run has zero idle.
    #[test]
    fn idle_warp_time_is_excluded_from_busy_cycles() {
        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec { id: 0, n_tokens: 2, prompt_tokens: 1, arrival_cycle: 50_000 })
            .unwrap();
        ms.run_all().unwrap();
        ms.finalize_stats();
        assert_eq!(ms.stats.idle_cycles, 50_000);
        assert!(ms.stats.busy_cycles() < ms.stats.cycles);
        assert_eq!(ms.stats.cycles, ms.stats.busy_cycles() + ms.stats.idle_cycles);

        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec::new(0, 2)).unwrap();
        ms.run_all().unwrap();
        ms.finalize_stats();
        assert_eq!(ms.stats.idle_cycles, 0);
        assert_eq!(ms.stats.busy_cycles(), ms.stats.cycles);
    }

    /// Drain a paged engine one outcome at a time, checking the
    /// page-table bijection (no shared frame, no double-free) before
    /// every step.
    fn run_all_with_invariants(ms: &mut MultiSim) -> Vec<StreamOutcome> {
        let mut out = Vec::new();
        loop {
            ms.assert_frame_invariants();
            match ms.step().unwrap() {
                Some(o) => out.push(o),
                None => break,
            }
        }
        ms.assert_frame_invariants();
        out
    }

    /// Tentpole equivalence: paging with one full-context page per
    /// stream (`kv_page_tokens = max_seq`) and no oversubscription is
    /// cycle-identical to the slot engine on arbitrary arrival traces —
    /// same admission stamps, same per-token finishes, same final
    /// clock. (Slot ids are excluded: paged slots are virtual.)
    #[test]
    fn paged_full_context_is_cycle_identical_over_random_traces() {
        use crate::util::prop::check;
        check("paged full-context equivalence", 10, |rng| {
            let k = 1 + rng.gen_range(3) as usize;
            let n_req = 1 + rng.gen_range(5);
            let chunk = 1 + rng.gen_range(8);
            let mut specs = Vec::new();
            for id in 0..n_req {
                let n_tokens = 1 + rng.gen_range(24);
                specs.push(StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(30_000),
                });
            }
            let run = |paged: bool| -> Result<(u64, Vec<(u64, u64, u64, Vec<u64>)>), String> {
                let m = by_name("gpt-nano").unwrap();
                let mut cfg = HwConfig::paper_baseline().with_max_streams(k);
                cfg.sched.prefill_chunk = chunk;
                if paged {
                    cfg.sched.kv_paging = true;
                    cfg.sched.kv_page_tokens = m.max_seq as u64;
                }
                let mut ms = MultiSim::new(&m, &cfg).map_err(|e| e.to_string())?;
                for s in &specs {
                    ms.submit(*s).map_err(|e| e.to_string())?;
                }
                let outcomes = if paged {
                    run_all_with_invariants(&mut ms)
                } else {
                    ms.run_all().map_err(|e| e.to_string())?
                };
                let mut rows: Vec<(u64, u64, u64, Vec<u64>)> = outcomes
                    .into_iter()
                    .filter_map(StreamOutcome::into_completed)
                    .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes))
                    .collect();
                rows.sort_by_key(|r| r.0);
                Ok((ms.clock(), rows))
            };
            let slot = run(false)?;
            let paged = run(true)?;
            if slot != paged {
                return Err("paged full-context run diverged from slot run".into());
            }
            Ok(())
        });
    }

    /// The same full-context equivalence holds with fused decode
    /// batching on: shareable nodes never touch the page table and
    /// per-member nodes resolve a single full-context page.
    #[test]
    fn paged_full_context_batched_is_cycle_identical() {
        let m = by_name("gpt-nano").unwrap();
        let run = |paged: bool| {
            let mut cfg =
                HwConfig::paper_baseline().with_max_streams(3).with_batch_decode(true);
            if paged {
                cfg.sched.kv_paging = true;
                cfg.sched.kv_page_tokens = m.max_seq as u64;
            }
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            for id in 0..3 {
                ms.submit(StreamSpec::with_prompt(id, 4, 12)).unwrap();
            }
            let mut rows: Vec<(u64, u64, u64, Vec<u64>)> = completed(ms.run_all().unwrap())
                .into_iter()
                .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes))
                .collect();
            rows.sort_by_key(|r| r.0);
            ms.finalize_stats();
            (ms.clock(), ms.stats.fused_sweeps, rows)
        };
        assert_eq!(run(false), run(true));
    }

    /// Multi-page tables without oversubscription: contexts span page
    /// boundaries and the table grows on demand, but `kv_oversub = 1`
    /// guarantees the free list never runs dry — zero faults, zero
    /// preemptions, exact completion.
    #[test]
    fn multi_page_tables_grow_without_faults() {
        let m = by_name("gpt-mini").unwrap(); // max_seq 256 -> 2 pages at P=128
        let mut cfg = HwConfig::paper_baseline().with_max_streams(2);
        cfg.sched.kv_paging = true;
        cfg.sched.kv_page_tokens = 128;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        assert_eq!(ms.kv_pages(), 4, "2 streams x 2 frames per 256-token context");
        for id in 0..2 {
            ms.submit(StreamSpec::with_prompt(id, 16, 184)).unwrap(); // 200 > 128 tokens
        }
        let results = completed(run_all_with_invariants(&mut ms));
        ms.finalize_stats();
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.tokens, 200);
            assert!(r.token_finishes.windows(2).all(|w| w[0] <= w[1]));
        }
        let s = &ms.stats;
        assert_eq!(s.kv_pages, 4);
        assert_eq!(s.peak_pages_in_use, 4, "both streams crossed the page boundary");
        assert_eq!((s.page_faults, s.preemptions, s.evicted_tokens), (0, 0, 0));
    }

    /// A paged engine whose frame pool was degraded below the
    /// worst-case demand (the whole point of paging), oversubscribed
    /// 2x. Built by squeezing DRAM capacity until the mapping grants
    /// fewer frames than `max_streams` full contexts need.
    fn degraded_paged_sim(oversub: f64, batch: bool) -> MultiSim {
        let m = by_name("gpt-mini").unwrap();
        for cap in [0.03, 0.04, 0.05, 0.06, 0.08, 0.1, 0.15] {
            let mut cfg = HwConfig::paper_baseline().with_max_streams(3);
            cfg.gddr6.capacity_gbit = cap;
            cfg.sched.kv_paging = true;
            cfg.sched.kv_page_tokens = 128;
            cfg.sched.kv_oversub = oversub;
            cfg.sched.batch_decode = batch;
            if let Ok(ms) = MultiSim::new(&m, &cfg) {
                if ms.kv_pages() >= 2 && ms.kv_pages() < 6 {
                    return ms;
                }
            }
        }
        panic!("no probed capacity produced a degraded paged pool");
    }

    /// Satellite: oversubscription faults, preempts a victim (possibly
    /// mid-step — its partial work is discarded), writes its context
    /// back, re-admits it with original stamps, and every stream still
    /// completes exactly — with the frame bijection intact at every
    /// step and the preemption counters reconciling.
    #[test]
    fn oversubscribed_pool_preempts_and_every_stream_completes() {
        let mut ms = degraded_paged_sim(2.0, false);
        let n_frames = ms.kv_pages() as u64;
        for id in 0..3 {
            // 256 tokens = 2 frames each: eventual demand 6 frames
            // against a pool of < 6 — growth must fault.
            ms.submit(StreamSpec::with_prompt(id, 32, 224)).unwrap();
        }
        let results = completed(run_all_with_invariants(&mut ms));
        ms.finalize_stats();
        assert_eq!(results.len(), 3, "every admitted stream eventually completes");
        for r in &results {
            assert_eq!(r.tokens, 256);
            assert_eq!(r.token_finishes.len(), 256);
            assert!(r.admitted_cycle >= r.arrival_cycle);
            assert!(r.token_finishes.windows(2).all(|w| w[0] <= w[1]));
            let decode = &r.token_finishes[r.prompt_tokens as usize - 1..];
            assert!(decode.windows(2).all(|w| w[0] < w[1]), "decode finishes strict");
        }
        let s = &ms.stats;
        assert!(s.page_faults >= 1, "an oversubscribed pool must fault");
        assert!(s.preemptions >= 1, "faults resolve by preemption");
        assert!(s.evicted_tokens >= 1, "victims had live context to write back");
        assert_eq!(s.streams.len(), 3);
        assert_eq!(s.rejected, 0);
        assert!(s.peak_pages_in_use <= n_frames);
        assert_eq!(s.kv_pages, n_frames);
        assert_eq!(ms.evicted_streams(), 0, "no stream left swapped out");
        assert_eq!(ms.free_kv_pages() as u64, n_frames, "all frames returned");
    }

    /// The preemption machinery also holds together under fused decode
    /// batching: victims that are mid-sweep force a dissolve, survivors
    /// re-arm, and everything still completes with the bijection intact.
    #[test]
    fn oversubscribed_pool_with_batching_completes() {
        let mut ms = degraded_paged_sim(2.0, true);
        for id in 0..3 {
            ms.submit(StreamSpec::with_prompt(id, 8, 248)).unwrap();
        }
        let results = completed(run_all_with_invariants(&mut ms));
        ms.finalize_stats();
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.tokens, 256);
            assert!(r.token_finishes.windows(2).all(|w| w[0] <= w[1]));
        }
        assert!(ms.stats.preemptions >= 1, "oversubscribed batched run must preempt");
        assert_eq!(ms.evicted_streams(), 0);
    }

    /// Re-admission preserves the victim's identity: original arrival
    /// and admission stamps survive the eviction round-trip (queueing
    /// is measured once, at first admission), and the pre-eviction
    /// token finishes are a prefix of the final history.
    #[test]
    fn eviction_round_trip_preserves_stamps() {
        let mut ms = degraded_paged_sim(2.0, false);
        ms.submit(StreamSpec::with_prompt(0, 32, 224)).unwrap();
        ms.submit(StreamSpec { id: 1, n_tokens: 256, prompt_tokens: 32, arrival_cycle: 5 })
            .unwrap();
        ms.submit(StreamSpec { id: 2, n_tokens: 256, prompt_tokens: 32, arrival_cycle: 9 })
            .unwrap();
        let mut results = completed(run_all_with_invariants(&mut ms));
        ms.finalize_stats();
        assert!(ms.stats.preemptions >= 1);
        results.sort_by_key(|r| r.id);
        for (r, arrival) in results.iter().zip([0u64, 5, 9]) {
            assert_eq!(r.arrival_cycle, arrival, "arrival stamp survives eviction");
            assert!(r.admitted_cycle >= arrival);
            assert_eq!(r.tokens, 256);
            // The stats row is derived from the same record, so the
            // queue/service split reconciles even across evictions.
            assert_eq!(r.queue_cycles() + r.service_cycles(), r.e2e_cycles());
        }
    }

    /// Satellite: the SLO admission estimate amortizes over the
    /// observed decode-batch occupancy — a request the raw estimate
    /// would shed is admitted once fusion demonstrably halves the
    /// per-stream sweep cost. Without batching the raw estimate stands.
    #[test]
    fn slo_estimate_amortizes_over_decode_batch_occupancy() {
        let m = by_name("gpt-nano").unwrap();
        let raw = {
            let mut probe = msim("gpt-nano", 2);
            probe.first_token_estimate(1).unwrap()
        };
        assert!(raw > 2);
        let budget = raw / 2 + 1; // rejects the raw estimate, admits raw/2
        let run = |batch: bool| {
            let mut cfg = HwConfig::paper_baseline().with_max_streams(2);
            cfg.sched.set_policy_str(&format!("slo:{budget}")).unwrap();
            cfg.sched.batch_decode = batch;
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            if batch {
                // Seed an observed mean sweep occupancy of 2.0, as a
                // warm serving run would have.
                ms.stats.fused_sweeps = 1;
                ms.stats.fused_streams = 2;
            }
            ms.submit(StreamSpec::new(0, 4)).unwrap();
            let outcomes = ms.run_all().unwrap();
            outcomes[0].as_rejected().is_some()
        };
        assert!(run(false), "raw estimate {raw} must bust budget {budget}");
        assert!(!run(true), "amortized estimate must fit budget {budget}");
    }
}
