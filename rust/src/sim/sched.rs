//! Multi-stream resource-reservation scheduler: interleaves the
//! instruction streams of up to K concurrent decode requests on the
//! shared PIM + ASIC hardware.
//!
//! The paper's simulator (and the seed's `Simulator`) executes one
//! program at a time, so the whole package idles whenever a single
//! request's ASIC op blocks its own critical path. Here each in-flight
//! request keeps its own dependency-tracking cursor over its compiled
//! program (served from the shared `ProgramCache`), and the scheduler
//! issues greedily across streams: at every step it picks the stream
//! whose next instruction has the earliest dependency-ready time (ties
//! break by admission order, keeping runs fully deterministic) and
//! issues it through the same `Resources::issue` path the single-stream
//! simulator uses. Resource contention needs no global event queue —
//! every channel bus, bank and the ASIC engine carries its own
//! `busy_until` and serializes whatever lands on it — so one request's
//! ASIC softmax naturally overlaps another's bank-level VMM.
//!
//! With `max_streams = 1` the scheduler degenerates to exactly the
//! in-order single-stream pass and reproduces `Simulator` cycle counts
//! token-for-token (`tests/integration_sched.rs`).
//!
//! Modeling note: concurrent streams time-share the *same* KV-cache
//! region (the mapping reserves one `max_seq` context per layer). The
//! cycle cost of KV reads/writes is per-stream correct; cross-stream
//! row-buffer interference on those shared rows is second-order and not
//! separated. Partitioned per-stream KV reservations are a ROADMAP item.

use std::collections::VecDeque;
use std::rc::Rc;

use super::resources::{empty_plan, IssueCtx, Resources};
use super::stats::{SimStats, StreamStats};
use crate::compiler::{ProgramCache, ProgramTemplate};
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::ModelMapping;
use crate::model::GptModel;
use crate::pim::VmmPlan;
use anyhow::{bail, Result};

/// One generation request, in simulator terms: decode positions
/// `0..n_tokens` (prompt prefill + new tokens both cost a decode step,
/// matching `PimGptSystem::generate`).
#[derive(Clone, Copy, Debug)]
pub struct StreamSpec {
    pub id: u64,
    pub n_tokens: u64,
}

/// Completion record of one stream.
#[derive(Clone, Debug)]
pub struct StreamResult {
    pub id: u64,
    /// Cycle the request entered the queue (`submit` time).
    pub submitted_cycle: u64,
    /// Cycle the scheduler admitted it to an execution slot.
    pub admitted_cycle: u64,
    /// Cycle its last token finished.
    pub finish_cycle: u64,
    pub tokens: u64,
    /// Finish cycle of each token (monotone; first entry >= admitted).
    pub token_finishes: Vec<u64>,
}

impl StreamResult {
    pub fn queue_cycles(&self) -> u64 {
        self.admitted_cycle - self.submitted_cycle
    }

    pub fn service_cycles(&self) -> u64 {
        self.finish_cycle - self.admitted_cycle
    }
}

/// An in-flight stream: program cursor + per-node timing state.
struct Stream {
    id: u64,
    tpl: Rc<ProgramTemplate>,
    /// Current decode position; `ltoken = pos + 1`.
    pos: u64,
    end_pos: u64,
    /// Next instruction index in the current token's program.
    next: usize,
    finish: Vec<u64>,
    first_ready: Vec<u64>,
    step_start: u64,
    /// Max finish among this token's issued nodes so far.
    step_finish: u64,
    submitted: u64,
    admitted: u64,
    token_finishes: Vec<u64>,
    instructions: u64,
    attributed: u64,
}

/// The interleaved multi-request engine.
pub struct MultiSim {
    pub cfg: HwConfig,
    pub model: GptModel,
    pub mapping: ModelMapping,
    t: TimingCycles,
    res: Resources,
    plan_scratch: VmmPlan,
    cache: ProgramCache,
    active: Vec<Stream>,
    queue: VecDeque<(StreamSpec, u64)>,
    clock: u64,
    pub stats: SimStats,
    max_streams: usize,
}

impl MultiSim {
    pub fn new(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        let mapping = ModelMapping::build(model, cfg)?;
        Ok(Self::from_mapping(model, cfg, mapping))
    }

    /// Build from an existing mapping (avoids re-running the Algorithm-3
    /// placement when the caller already holds one, e.g. the server's
    /// `PimGptSystem`).
    pub fn from_mapping(model: &GptModel, cfg: &HwConfig, mapping: ModelMapping) -> Self {
        Self {
            cfg: cfg.clone(),
            model: model.clone(),
            mapping,
            t: TimingCycles::from_config(cfg),
            res: Resources::new(cfg),
            plan_scratch: empty_plan(cfg),
            cache: ProgramCache::new(),
            active: Vec::new(),
            queue: VecDeque::new(),
            clock: 0,
            stats: SimStats::default(),
            max_streams: cfg.sched.max_streams.max(1),
        }
    }

    pub fn max_streams(&self) -> usize {
        self.max_streams
    }

    /// Current simulated time (max finish cycle issued so far).
    pub fn clock(&self) -> u64 {
        self.clock
    }

    pub fn active_streams(&self) -> usize {
        self.active.len()
    }

    pub fn queued_streams(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue a request (admitted when a slot frees up).
    pub fn submit(&mut self, spec: StreamSpec) -> Result<()> {
        if spec.n_tokens == 0 {
            bail!("request {} has zero tokens", spec.id);
        }
        if spec.n_tokens > self.model.max_seq as u64 {
            bail!(
                "request {} length {} exceeds max_seq {}",
                spec.id,
                spec.n_tokens,
                self.model.max_seq
            );
        }
        self.queue.push_back((spec, self.clock));
        Ok(())
    }

    fn admit(&mut self) -> Result<()> {
        while self.active.len() < self.max_streams {
            let Some((spec, submitted)) = self.queue.pop_front() else {
                break;
            };
            let tpl = self.cache.get(&self.model, &self.cfg, 0)?;
            let admitted = self.clock;
            self.active.push(Stream {
                id: spec.id,
                tpl,
                pos: 0,
                end_pos: spec.n_tokens,
                next: 0,
                finish: Vec::new(),
                first_ready: Vec::new(),
                step_start: admitted,
                step_finish: admitted,
                submitted,
                admitted,
                token_finishes: Vec::new(),
                instructions: 0,
                attributed: 0,
            });
        }
        Ok(())
    }

    /// Advance the simulation until the next stream completes; returns
    /// its result, or `None` when nothing is in flight or queued.
    pub fn step(&mut self) -> Result<Option<StreamResult>> {
        self.admit()?;
        if self.active.is_empty() {
            return Ok(None);
        }
        loop {
            // Greedy pick: the stream whose next instruction has the
            // earliest dependency-ready time (FCFS per resource); ties
            // break toward the earliest-admitted stream.
            let mut si = 0;
            let mut best_ready = u64::MAX;
            for (i, s) in self.active.iter().enumerate() {
                let mut ready = s.step_start;
                for &d in s.tpl.deps_of(s.next) {
                    ready = ready.max(s.finish[d]);
                }
                if ready < best_ready {
                    best_ready = ready;
                    si = i;
                }
            }

            // Issue it on the shared resources.
            let tpl = Rc::clone(&self.active[si].tpl);
            let (pos, step_start, next) = {
                let s = &self.active[si];
                (s.pos, s.step_start, s.next)
            };
            let instr = tpl.instr_at(next, pos + 1);
            let ctx = IssueCtx {
                cfg: &self.cfg,
                t: &self.t,
                model: &self.model,
                mapping: &self.mapping,
            };
            let out = {
                let s = &self.active[si];
                self.res.issue(
                    &ctx,
                    &mut self.plan_scratch,
                    &instr,
                    tpl.deps_of(next),
                    step_start,
                    &s.finish,
                    &s.first_ready,
                    pos,
                    pos + 1,
                )
            };

            self.stats.add_class(out.class, out.finish.saturating_sub(out.ready));
            self.stats.instructions += 1;
            self.clock = self.clock.max(out.finish);

            let token_done = {
                let s = &mut self.active[si];
                s.instructions += 1;
                s.attributed += out.finish.saturating_sub(out.ready);
                s.first_ready.push(out.first_ready);
                s.finish.push(out.finish);
                s.step_finish = s.step_finish.max(out.finish);
                s.next += 1;
                s.next == s.tpl.len()
            };
            if !token_done {
                continue;
            }

            self.stats.tokens += 1;
            let stream_done = {
                let s = &mut self.active[si];
                let fin = s.step_finish;
                s.token_finishes.push(fin);
                s.pos += 1;
                s.pos >= s.end_pos
            };
            if !stream_done {
                let tpl = self.cache.get(&self.model, &self.cfg, self.active[si].pos)?;
                let s = &mut self.active[si];
                s.tpl = tpl;
                s.step_start = s.step_finish;
                s.next = 0;
                s.finish.clear();
                s.first_ready.clear();
                continue;
            }

            // Retire the stream and backfill its slot from the queue.
            let s = self.active.remove(si);
            self.stats.streams.push(StreamStats {
                id: s.id,
                tokens: s.token_finishes.len() as u64,
                instructions: s.instructions,
                attributed_cycles: s.attributed,
                queue_cycles: s.admitted - s.submitted,
                service_cycles: s.step_finish - s.admitted,
            });
            let result = StreamResult {
                id: s.id,
                submitted_cycle: s.submitted,
                admitted_cycle: s.admitted,
                finish_cycle: s.step_finish,
                tokens: s.token_finishes.len() as u64,
                token_finishes: s.token_finishes,
            };
            self.admit()?;
            return Ok(Some(result));
        }
    }

    /// Drain everything: run until all submitted streams complete.
    /// Results are in completion order.
    pub fn run_all(&mut self) -> Result<Vec<StreamResult>> {
        let mut out = Vec::new();
        while let Some(r) = self.step()? {
            out.push(r);
        }
        Ok(out)
    }

    /// Fold resource counters into the stats (end of run).
    pub fn finalize_stats(&mut self) -> &SimStats {
        self.stats.cycles = self.clock;
        self.res.fold_stats(&mut self.stats);
        self.stats.program_cache_hits = self.cache.hits;
        self.stats.program_cache_misses = self.cache.misses;
        &self.stats
    }

    /// The compiled-program cache (hit/miss counters, entry count).
    pub fn program_cache(&self) -> &ProgramCache {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn msim(model: &str, k: usize) -> MultiSim {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        MultiSim::new(&m, &cfg).unwrap()
    }

    #[test]
    fn empty_engine_steps_to_none() {
        let mut ms = msim("gpt-nano", 2);
        assert!(ms.step().unwrap().is_none());
    }

    #[test]
    fn single_request_completes() {
        let mut ms = msim("gpt-nano", 2);
        ms.submit(StreamSpec { id: 7, n_tokens: 5 }).unwrap();
        let r = ms.step().unwrap().unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.tokens, 5);
        assert_eq!(r.token_finishes.len(), 5);
        assert!(r.token_finishes.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(r.queue_cycles(), 0);
        assert!(r.service_cycles() > 0);
        assert!(ms.step().unwrap().is_none());
    }

    #[test]
    fn submit_rejects_invalid_lengths() {
        let mut ms = msim("gpt-nano", 2); // max_seq 128
        assert!(ms.submit(StreamSpec { id: 0, n_tokens: 0 }).is_err());
        assert!(ms.submit(StreamSpec { id: 1, n_tokens: 129 }).is_err());
        assert!(ms.submit(StreamSpec { id: 2, n_tokens: 128 }).is_ok());
    }

    #[test]
    fn excess_requests_queue_and_report_waiting() {
        let mut ms = msim("gpt-nano", 2);
        for id in 0..4 {
            ms.submit(StreamSpec { id, n_tokens: 4 }).unwrap();
        }
        assert_eq!(ms.queued_streams(), 4);
        let results = ms.run_all().unwrap();
        assert_eq!(results.len(), 4);
        // First two admitted immediately; the last two waited.
        let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).queue_cycles(), 0);
        assert_eq!(by_id(1).queue_cycles(), 0);
        assert!(by_id(2).queue_cycles() > 0);
        assert!(by_id(3).queue_cycles() > 0);
    }

    #[test]
    fn interleaving_beats_fifo_on_makespan() {
        // Same request set, K=1 (FIFO) vs K=4: the interleaved schedule
        // must finish strictly earlier (it fills channel idle gaps with
        // the other streams' VMMs).
        let specs: Vec<StreamSpec> =
            (0..4).map(|id| StreamSpec { id, n_tokens: 4 + 2 * id }).collect();
        let mut fifo = msim("gpt2-small", 1);
        let mut inter = msim("gpt2-small", 4);
        for s in &specs {
            fifo.submit(*s).unwrap();
            inter.submit(*s).unwrap();
        }
        fifo.run_all().unwrap();
        inter.run_all().unwrap();
        assert!(
            inter.clock() < fifo.clock(),
            "interleaved {} !< fifo {}",
            inter.clock(),
            fifo.clock()
        );
    }

    #[test]
    fn deterministic_interleaving() {
        let run = || {
            let mut ms = msim("gpt2-small", 3);
            for id in 0..5 {
                ms.submit(StreamSpec { id, n_tokens: 3 + id }).unwrap();
            }
            let results = ms.run_all().unwrap();
            (ms.clock(), results.iter().map(|r| r.finish_cycle).collect::<Vec<_>>())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn per_stream_stats_recorded() {
        let mut ms = msim("gpt-nano", 2);
        for id in 0..3 {
            ms.submit(StreamSpec { id, n_tokens: 4 }).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        assert_eq!(ms.stats.streams.len(), 3);
        let total_tokens: u64 = ms.stats.streams.iter().map(|s| s.tokens).sum();
        assert_eq!(total_tokens, 12);
        assert_eq!(ms.stats.tokens, 12);
        for s in &ms.stats.streams {
            assert!(s.instructions > 0);
            assert!(s.attributed_cycles > 0);
            assert!(s.service_cycles > 0);
        }
    }
}
