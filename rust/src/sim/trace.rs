//! Deterministic event tracing for the cycle-accurate engines.
//!
//! `MultiSim` and `FleetSim` aggregate everything into `SimStats` — good
//! for end-of-run figures, useless for asking *which* stream waited,
//! *where* (queue vs fault writeback vs link hop) and *when*. This module
//! adds a structured trace layer that records a typed [`TraceEvent`] at
//! every request-lifecycle edge:
//!
//! | event           | edge                                                |
//! |-----------------|-----------------------------------------------------|
//! | `submit`        | request handed to the simulator                     |
//! | `release`       | arrival cycle reached — pending request became ready|
//! | `admit`         | scheduler granted a KV slot / page budget           |
//! | `reject`        | admission policy shed the request (predicted cost)  |
//! | `prefill_chunk` | one chunked-prefill program span (start/finish)     |
//! | `decode_step`   | one solo decode-token span                          |
//! | `fused_sweep`   | one cross-stream batched decode sweep (occupancy)   |
//! | `page_fault`    | frame demand found the free list empty              |
//! | `evict`         | victim preempted to resolve a fault                 |
//! | `writeback`     | victim KV pages drained to host (span)              |
//! | `restore`       | re-admitted victim's KV pages reloaded (span)       |
//! | `stream_retire` | last token produced                                 |
//! | `link_transfer` | inter-device hop in the fleet engine (span)         |
//!
//! # Sink contract and determinism rules
//!
//! Events flow into a [`TraceSink`]. Sinks are *observers*: they receive
//! `&TraceEvent`, buffer in memory, and render a `String` artifact after
//! the run — they cannot mutate the engine, perform IO, read clocks, or
//! otherwise perturb scheduling. Tracing **on** must not change a single
//! simulated cycle (pinned by `tests/integration_trace.rs`), and tracing
//! **off** is a `None` sink — one branch on the hot path, no allocation,
//! byte-identical to pre-trace behavior.
//!
//! Two concrete sinks ship here:
//! - [`JsonlSink`] — one JSON object per line, the machine-diffable log
//!   (and the calibration source for the planned fast-path metasim);
//! - [`ChromeSink`] — a Chrome-trace / Perfetto-loadable export mapping
//!   streams to tracks (`tid` = stream id, `pid` = device id) and spans
//!   to begin/end pairs.
//!
//! Independently of any sink, [`Tracer`] keeps [`TraceCounts`] — event
//! tallies that must reconcile exactly with the `SimStats` aggregates
//! ([`reconcile`]; checked under `debug_assertions` at finalize) — and an
//! optional windowed utilization [`Timeline`] (`sched.trace_window`)
//! whose per-window busy/idle/link cycles and pages-in-use land in
//! `SimStats::timeline` and feed `figures --fig timeline`.

use std::fmt;

use anyhow::{bail, Result};

use super::stats::SimStats;
use crate::util::json::Json;

/// One typed trace event. Point events carry `at`; span events carry
/// `start`/`finish` in simulated DRAM cycles. `device` is 0 for every
/// single-package engine and the fleet's device id otherwise.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// Request handed to the simulator front end.
    Submit { stream: u64, at: u64, arrival: u64, prompt_tokens: u64, tokens: u64 },
    /// Arrival cycle reached: pending request moved to the ready queue.
    Release { stream: u64, at: u64 },
    /// Scheduler admitted the request into a KV slot.
    Admit { stream: u64, at: u64, slot: u64 },
    /// Admission policy shed the request, with its predicted cost.
    Reject { stream: u64, at: u64, predicted_ttft: u64, ttft_budget: u64 },
    /// One chunked-prefill program: `positions` prompt tokens starting
    /// at position `pos`.
    PrefillChunk { stream: u64, device: u64, start: u64, finish: u64, pos: u64, positions: u64 },
    /// One solo (unfused) decode step producing the token at `pos`.
    DecodeStep { stream: u64, device: u64, start: u64, finish: u64, pos: u64 },
    /// One fused decode sweep; `streams` are the batch members
    /// (occupancy = `streams.len()`), one token each.
    FusedSweep { device: u64, start: u64, finish: u64, streams: Vec<u64> },
    /// On-demand frame allocation found the free list empty.
    PageFault { stream: u64, at: u64 },
    /// `victim` preempted (by stream `by`) to resolve a fault; `tokens`
    /// KV positions are scheduled for writeback.
    Evict { victim: u64, by: u64, at: u64, tokens: u64 },
    /// Victim KV writeback span on the channel buses.
    Writeback { stream: u64, start: u64, finish: u64, tokens: u64 },
    /// Re-admitted victim's KV restore span.
    Restore { stream: u64, start: u64, finish: u64, tokens: u64 },
    /// Last token produced; the stream left the engine.
    StreamRetire { stream: u64, at: u64, tokens: u64 },
    /// Inter-device activation/reduction hop (fleet engine).
    LinkTransfer { stream: u64, src: u64, dst: u64, start: u64, finish: u64 },
}

impl TraceEvent {
    /// Stable event-type name used by both sinks and the goldens.
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Submit { .. } => "submit",
            TraceEvent::Release { .. } => "release",
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::Reject { .. } => "reject",
            TraceEvent::PrefillChunk { .. } => "prefill_chunk",
            TraceEvent::DecodeStep { .. } => "decode_step",
            TraceEvent::FusedSweep { .. } => "fused_sweep",
            TraceEvent::PageFault { .. } => "page_fault",
            TraceEvent::Evict { .. } => "evict",
            TraceEvent::Writeback { .. } => "writeback",
            TraceEvent::Restore { .. } => "restore",
            TraceEvent::StreamRetire { .. } => "stream_retire",
            TraceEvent::LinkTransfer { .. } => "link_transfer",
        }
    }

    /// JSONL encoding: one flat object, `"ev"` first. Point events use
    /// `"t"`; span events use `"t0"`/`"t1"`.
    pub fn to_json(&self) -> Json {
        let ev = Json::from(self.name());
        match self {
            TraceEvent::Submit { stream, at, arrival, prompt_tokens, tokens } => Json::obj(vec![
                ("ev", ev),
                ("t", (*at).into()),
                ("stream", (*stream).into()),
                ("arrival", (*arrival).into()),
                ("prompt_tokens", (*prompt_tokens).into()),
                ("tokens", (*tokens).into()),
            ]),
            TraceEvent::Release { stream, at } => {
                Json::obj(vec![("ev", ev), ("t", (*at).into()), ("stream", (*stream).into())])
            }
            TraceEvent::Admit { stream, at, slot } => Json::obj(vec![
                ("ev", ev),
                ("t", (*at).into()),
                ("stream", (*stream).into()),
                ("slot", (*slot).into()),
            ]),
            TraceEvent::Reject { stream, at, predicted_ttft, ttft_budget } => Json::obj(vec![
                ("ev", ev),
                ("t", (*at).into()),
                ("stream", (*stream).into()),
                ("predicted_ttft", (*predicted_ttft).into()),
                ("ttft_budget", (*ttft_budget).into()),
            ]),
            TraceEvent::PrefillChunk { stream, device, start, finish, pos, positions } => {
                Json::obj(vec![
                    ("ev", ev),
                    ("t0", (*start).into()),
                    ("t1", (*finish).into()),
                    ("stream", (*stream).into()),
                    ("device", (*device).into()),
                    ("pos", (*pos).into()),
                    ("positions", (*positions).into()),
                ])
            }
            TraceEvent::DecodeStep { stream, device, start, finish, pos } => Json::obj(vec![
                ("ev", ev),
                ("t0", (*start).into()),
                ("t1", (*finish).into()),
                ("stream", (*stream).into()),
                ("device", (*device).into()),
                ("pos", (*pos).into()),
            ]),
            TraceEvent::FusedSweep { device, start, finish, streams } => Json::obj(vec![
                ("ev", ev),
                ("t0", (*start).into()),
                ("t1", (*finish).into()),
                ("device", (*device).into()),
                ("batch", streams.len().into()),
                ("streams", Json::Arr(streams.iter().map(|&s| s.into()).collect())),
            ]),
            TraceEvent::PageFault { stream, at } => {
                Json::obj(vec![("ev", ev), ("t", (*at).into()), ("stream", (*stream).into())])
            }
            TraceEvent::Evict { victim, by, at, tokens } => Json::obj(vec![
                ("ev", ev),
                ("t", (*at).into()),
                ("victim", (*victim).into()),
                ("by", (*by).into()),
                ("tokens", (*tokens).into()),
            ]),
            TraceEvent::Writeback { stream, start, finish, tokens }
            | TraceEvent::Restore { stream, start, finish, tokens } => Json::obj(vec![
                ("ev", ev),
                ("t0", (*start).into()),
                ("t1", (*finish).into()),
                ("stream", (*stream).into()),
                ("tokens", (*tokens).into()),
            ]),
            TraceEvent::StreamRetire { stream, at, tokens } => Json::obj(vec![
                ("ev", ev),
                ("t", (*at).into()),
                ("stream", (*stream).into()),
                ("tokens", (*tokens).into()),
            ]),
            TraceEvent::LinkTransfer { stream, src, dst, start, finish } => Json::obj(vec![
                ("ev", ev),
                ("t0", (*start).into()),
                ("t1", (*finish).into()),
                ("stream", (*stream).into()),
                ("src", (*src).into()),
                ("dst", (*dst).into()),
            ]),
        }
    }

    /// Inverse of [`TraceEvent::to_json`]: parse one JSONL object back
    /// into an event — the offline replay path of
    /// `profile::Profile::from_jsonl`.
    pub fn from_json(j: &Json) -> Result<TraceEvent, String> {
        fn field(j: &Json, k: &str) -> Result<u64, String> {
            let n = j
                .get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("missing numeric field '{k}'"))?;
            if n < 0.0 || n.fract() != 0.0 {
                return Err(format!("field '{k}' is not a non-negative integer: {n}"));
            }
            Ok(n as u64)
        }
        let ev = j.get("ev").and_then(Json::as_str).ok_or_else(|| "missing 'ev'".to_string())?;
        match ev {
            "submit" => Ok(TraceEvent::Submit {
                stream: field(j, "stream")?,
                at: field(j, "t")?,
                arrival: field(j, "arrival")?,
                prompt_tokens: field(j, "prompt_tokens")?,
                tokens: field(j, "tokens")?,
            }),
            "release" => {
                Ok(TraceEvent::Release { stream: field(j, "stream")?, at: field(j, "t")? })
            }
            "admit" => Ok(TraceEvent::Admit {
                stream: field(j, "stream")?,
                at: field(j, "t")?,
                slot: field(j, "slot")?,
            }),
            "reject" => Ok(TraceEvent::Reject {
                stream: field(j, "stream")?,
                at: field(j, "t")?,
                predicted_ttft: field(j, "predicted_ttft")?,
                ttft_budget: field(j, "ttft_budget")?,
            }),
            "prefill_chunk" => Ok(TraceEvent::PrefillChunk {
                stream: field(j, "stream")?,
                device: field(j, "device")?,
                start: field(j, "t0")?,
                finish: field(j, "t1")?,
                pos: field(j, "pos")?,
                positions: field(j, "positions")?,
            }),
            "decode_step" => Ok(TraceEvent::DecodeStep {
                stream: field(j, "stream")?,
                device: field(j, "device")?,
                start: field(j, "t0")?,
                finish: field(j, "t1")?,
                pos: field(j, "pos")?,
            }),
            "fused_sweep" => {
                let streams = j
                    .get("streams")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "fused_sweep missing 'streams'".to_string())?
                    .iter()
                    .map(|s| {
                        s.as_f64()
                            .map(|n| n as u64)
                            .ok_or_else(|| "non-numeric stream id".to_string())
                    })
                    .collect::<Result<Vec<u64>, String>>()?;
                Ok(TraceEvent::FusedSweep {
                    device: field(j, "device")?,
                    start: field(j, "t0")?,
                    finish: field(j, "t1")?,
                    streams,
                })
            }
            "page_fault" => {
                Ok(TraceEvent::PageFault { stream: field(j, "stream")?, at: field(j, "t")? })
            }
            "evict" => Ok(TraceEvent::Evict {
                victim: field(j, "victim")?,
                by: field(j, "by")?,
                at: field(j, "t")?,
                tokens: field(j, "tokens")?,
            }),
            "writeback" => Ok(TraceEvent::Writeback {
                stream: field(j, "stream")?,
                start: field(j, "t0")?,
                finish: field(j, "t1")?,
                tokens: field(j, "tokens")?,
            }),
            "restore" => Ok(TraceEvent::Restore {
                stream: field(j, "stream")?,
                start: field(j, "t0")?,
                finish: field(j, "t1")?,
                tokens: field(j, "tokens")?,
            }),
            "stream_retire" => Ok(TraceEvent::StreamRetire {
                stream: field(j, "stream")?,
                at: field(j, "t")?,
                tokens: field(j, "tokens")?,
            }),
            "link_transfer" => Ok(TraceEvent::LinkTransfer {
                stream: field(j, "stream")?,
                src: field(j, "src")?,
                dst: field(j, "dst")?,
                start: field(j, "t0")?,
                finish: field(j, "t1")?,
            }),
            other => Err(format!("unknown event type '{other}'")),
        }
    }
}

/// Observer of the engine's event stream. Implementations buffer in
/// memory and render a `String` artifact after the run; they must not
/// perform IO, read clocks, or feed anything back into scheduling (the
/// engine only ever hands out `&TraceEvent`).
pub trait TraceSink {
    fn event(&mut self, ev: &TraceEvent);
    /// Pages-in-use changed to `in_use` frames at cycle `at` (paged KV
    /// only). Default no-op: a counter sample, not an event, so sinks
    /// that only consume the event stream can ignore it.
    fn pages(&mut self, _at: u64, _in_use: u64) {}
    /// Render the buffered artifact. Called once, after the run; the
    /// *caller* (CLI/server) writes it to disk so engines stay IO-free.
    fn render(&mut self) -> String {
        String::new()
    }
}

/// Explicit no-op sink. The engines represent "tracing off" as a `None`
/// sink (cheaper still: the event is never even constructed), but the
/// type exists so external harnesses can satisfy the trait explicitly.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn event(&mut self, _ev: &TraceEvent) {}
}

/// JSON-lines event log: one `TraceEvent::to_json` object per line, in
/// emission order (which is deterministic simulation order).
#[derive(Debug, Default)]
pub struct JsonlSink {
    buf: String,
    events: u64,
}

impl JsonlSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn events(&self) -> u64 {
        self.events
    }
}

impl TraceSink for JsonlSink {
    fn event(&mut self, ev: &TraceEvent) {
        self.buf.push_str(&ev.to_json().to_string());
        self.buf.push('\n');
        self.events += 1;
    }

    fn render(&mut self) -> String {
        std::mem::take(&mut self.buf)
    }
}

/// Chrome-trace (catapult / Perfetto) exporter. Streams map to tracks
/// (`tid` = stream id), devices to processes (`pid`), span events to
/// `"B"`/`"E"` pairs and point events to thread-scoped instants (`"i"`).
/// Zero-length spans degrade to instants so every `"B"` always has a
/// matching later `"E"`. Events are buffered raw and ordered at render
/// time: per track by timestamp, with ends before begins at equal
/// stamps (so back-to-back spans never overlap) and longer spans opened
/// first (so equal-stamp nesting is well-formed).
/// Thread id the Perfetto counter tracks (`"ph":"C"`) render on — a
/// sentinel far above real stream ids, exactly representable as an f64
/// so it round-trips through the JSON number grammar.
pub const COUNTER_TID: u64 = 0xFFFF_FFFF;

#[derive(Debug, Default)]
pub struct ChromeSink {
    events: Vec<TraceEvent>,
    /// Pages-in-use counter samples (via the [`TraceSink::pages`] hook).
    pages: Vec<(u64, u64)>,
}

/// One flattened Chrome event plus its track sort key.
struct ChromeRow {
    pid: u64,
    tid: u64,
    ts: u64,
    /// 0 = end, 1 = instant, 2 = begin — ends sort first at equal ts.
    rank: u8,
    /// Equal-stamp tiebreak: begins open longest-first, ends close
    /// latest-started-first.
    tie: u64,
    json: Json,
}

impl ChromeSink {
    pub fn new() -> Self {
        Self::default()
    }

    fn instant(rows: &mut Vec<ChromeRow>, name: &str, pid: u64, tid: u64, ts: u64, args: Json) {
        let json = Json::obj(vec![
            ("name", name.into()),
            ("ph", "i".into()),
            ("ts", ts.into()),
            ("pid", pid.into()),
            ("tid", tid.into()),
            ("s", "t".into()),
            ("args", args),
        ]);
        rows.push(ChromeRow { pid, tid, ts, rank: 1, tie: 0, json });
    }

    #[allow(clippy::too_many_arguments)]
    fn span(
        rows: &mut Vec<ChromeRow>,
        name: &str,
        pid: u64,
        tid: u64,
        t0: u64,
        t1: u64,
        args: Json,
    ) {
        if t0 == t1 {
            Self::instant(rows, name, pid, tid, t0, args);
            return;
        }
        let begin = Json::obj(vec![
            ("name", name.into()),
            ("ph", "B".into()),
            ("ts", t0.into()),
            ("pid", pid.into()),
            ("tid", tid.into()),
            ("args", args),
        ]);
        let end = Json::obj(vec![
            ("name", name.into()),
            ("ph", "E".into()),
            ("ts", t1.into()),
            ("pid", pid.into()),
            ("tid", tid.into()),
        ]);
        // Longer spans open first; later-started spans close first.
        rows.push(ChromeRow { pid, tid, ts: t0, rank: 2, tie: u64::MAX - t1, json: begin });
        rows.push(ChromeRow { pid, tid, ts: t1, rank: 0, tie: u64::MAX - t0, json: end });
    }

    fn flatten(ev: &TraceEvent, rows: &mut Vec<ChromeRow>) {
        match ev {
            TraceEvent::Submit { stream, at, arrival, prompt_tokens, tokens } => {
                let args = Json::obj(vec![
                    ("arrival", (*arrival).into()),
                    ("prompt_tokens", (*prompt_tokens).into()),
                    ("tokens", (*tokens).into()),
                ]);
                Self::instant(rows, "submit", 0, *stream, *at, args);
            }
            TraceEvent::Release { stream, at } => {
                Self::instant(rows, "release", 0, *stream, *at, Json::obj(vec![]));
            }
            TraceEvent::Admit { stream, at, slot } => {
                let args = Json::obj(vec![("slot", (*slot).into())]);
                Self::instant(rows, "admit", 0, *stream, *at, args);
            }
            TraceEvent::Reject { stream, at, predicted_ttft, ttft_budget } => {
                let args = Json::obj(vec![
                    ("predicted_ttft", (*predicted_ttft).into()),
                    ("ttft_budget", (*ttft_budget).into()),
                ]);
                Self::instant(rows, "reject", 0, *stream, *at, args);
            }
            TraceEvent::PrefillChunk { stream, device, start, finish, pos, positions } => {
                let args =
                    Json::obj(vec![("pos", (*pos).into()), ("positions", (*positions).into())]);
                Self::span(rows, "prefill", *device, *stream, *start, *finish, args);
            }
            TraceEvent::DecodeStep { stream, device, start, finish, pos } => {
                let args = Json::obj(vec![("pos", (*pos).into())]);
                Self::span(rows, "decode", *device, *stream, *start, *finish, args);
            }
            TraceEvent::FusedSweep { device, start, finish, streams } => {
                // One span per member on its own track, labelled with
                // the sweep occupancy.
                let name = format!("fused(b={})", streams.len());
                for &member in streams {
                    let args = Json::obj(vec![("batch", streams.len().into())]);
                    Self::span(rows, &name, *device, member, *start, *finish, args);
                }
            }
            TraceEvent::PageFault { stream, at } => {
                Self::instant(rows, "page_fault", 0, *stream, *at, Json::obj(vec![]));
            }
            TraceEvent::Evict { victim, by, at, tokens } => {
                let args = Json::obj(vec![("by", (*by).into()), ("tokens", (*tokens).into())]);
                Self::instant(rows, "evict", 0, *victim, *at, args);
            }
            TraceEvent::Writeback { stream, start, finish, tokens } => {
                let args = Json::obj(vec![("tokens", (*tokens).into())]);
                Self::span(rows, "writeback", 0, *stream, *start, *finish, args);
            }
            TraceEvent::Restore { stream, start, finish, tokens } => {
                let args = Json::obj(vec![("tokens", (*tokens).into())]);
                Self::span(rows, "restore", 0, *stream, *start, *finish, args);
            }
            TraceEvent::StreamRetire { stream, at, tokens } => {
                let args = Json::obj(vec![("tokens", (*tokens).into())]);
                Self::instant(rows, "retire", 0, *stream, *at, args);
            }
            TraceEvent::LinkTransfer { stream, src, dst, start, finish } => {
                let name = format!("link d{src}->d{dst}");
                let args = Json::obj(vec![("src", (*src).into()), ("dst", (*dst).into())]);
                Self::span(rows, &name, *src, *stream, *start, *finish, args);
            }
        }
    }
}

impl ChromeSink {
    /// Perfetto counter rows: pages-in-use samples (pid 0 — paging is a
    /// single-package feature) and the decode-batch occupancy step
    /// function derived from buffered fused sweeps (+batch at sweep
    /// start, back down at finish, per device). Rendered only when
    /// counter data exists, so counter-free traces stay byte-identical.
    fn counter_rows(&self, rows: &mut Vec<ChromeRow>) {
        for &(at, in_use) in &self.pages {
            let json = Json::obj(vec![
                ("name", "pages_in_use".into()),
                ("ph", "C".into()),
                ("ts", at.into()),
                ("pid", 0u64.into()),
                ("tid", COUNTER_TID.into()),
                ("args", Json::obj(vec![("pages", in_use.into())])),
            ]);
            rows.push(ChromeRow { pid: 0, tid: COUNTER_TID, ts: at, rank: 1, tie: 0, json });
        }
        let mut deltas: Vec<(u64, u64, i64)> = Vec::new();
        for ev in &self.events {
            if let TraceEvent::FusedSweep { device, start, finish, streams } = ev {
                let k = streams.len() as i64;
                deltas.push((*device, *start, k));
                deltas.push((*device, *finish, -k));
            }
        }
        // Lexicographic order drops the counter to 0 before the next
        // sweep opens at the same stamp (-k sorts before +k).
        deltas.sort_unstable();
        let mut value: i64 = 0;
        let mut prev_device: Option<u64> = None;
        for (device, ts, d) in deltas {
            if prev_device != Some(device) {
                value = 0;
                prev_device = Some(device);
            }
            value += d;
            let json = Json::obj(vec![
                ("name", "decode_batch".into()),
                ("ph", "C".into()),
                ("ts", ts.into()),
                ("pid", device.into()),
                ("tid", COUNTER_TID.into()),
                ("args", Json::obj(vec![("occupancy", (value.max(0) as u64).into())])),
            ]);
            rows.push(ChromeRow { pid: device, tid: COUNTER_TID, ts, rank: 1, tie: 0, json });
        }
    }
}

impl TraceSink for ChromeSink {
    fn event(&mut self, ev: &TraceEvent) {
        self.events.push(ev.clone());
    }

    fn pages(&mut self, at: u64, in_use: u64) {
        self.pages.push((at, in_use));
    }

    fn render(&mut self) -> String {
        let mut rows: Vec<ChromeRow> = Vec::new();
        for ev in &self.events {
            Self::flatten(ev, &mut rows);
        }
        self.counter_rows(&mut rows);
        // Per-track timestamp order with deterministic tiebreaks; the
        // sort is stable so same-key rows keep emission order.
        rows.sort_by_key(|r| (r.pid, r.tid, r.ts, r.rank, r.tie));
        // Name the tracks: one process per device, one thread per
        // stream within it.
        let mut meta: Vec<Json> = Vec::new();
        let mut seen: Vec<(u64, u64)> = rows.iter().map(|r| (r.pid, r.tid)).collect();
        seen.sort_unstable();
        seen.dedup();
        let mut named_pid: Vec<u64> = Vec::new();
        for (pid, tid) in seen {
            if !named_pid.contains(&pid) {
                named_pid.push(pid);
                meta.push(Json::obj(vec![
                    ("name", "process_name".into()),
                    ("ph", "M".into()),
                    ("pid", pid.into()),
                    ("args", Json::obj(vec![("name", format!("device {pid}").into())])),
                ]));
            }
            let tname = if tid == COUNTER_TID {
                "counters".to_string()
            } else {
                format!("stream {tid}")
            };
            meta.push(Json::obj(vec![
                ("name", "thread_name".into()),
                ("ph", "M".into()),
                ("pid", pid.into()),
                ("tid", tid.into()),
                ("args", Json::obj(vec![("name", tname.into())])),
            ]));
        }
        meta.extend(rows.into_iter().map(|r| r.json));
        Json::obj(vec![("traceEvents", Json::Arr(meta))]).to_string()
    }
}

/// Structural validation of a rendered Chrome trace: parses, every
/// event carries `ph`/`ts`/`pid`/`tid`, per-track timestamps are
/// monotonically non-decreasing, and every `"B"` is closed by a
/// matching same-name `"E"` on its track. Returns the number of
/// non-metadata events.
pub fn validate_chrome(text: &str) -> Result<u64, String> {
    let root = Json::parse(text).map_err(|e| format!("chrome trace does not parse: {e}"))?;
    let events = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut last_ts: std::collections::BTreeMap<(u64, u64), u64> = Default::default();
    let mut stacks: std::collections::BTreeMap<(u64, u64), Vec<String>> = Default::default();
    let mut n = 0u64;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev.get("ph").and_then(Json::as_str).ok_or(format!("event {i}: missing ph"))?;
        let name =
            ev.get("name").and_then(Json::as_str).ok_or(format!("event {i}: missing name"))?;
        if ph == "M" {
            continue;
        }
        n += 1;
        let ts = ev.get("ts").and_then(Json::as_f64).ok_or(format!("event {i}: missing ts"))?;
        let pid =
            ev.get("pid").and_then(Json::as_f64).ok_or(format!("event {i}: missing pid"))? as u64;
        let tid =
            ev.get("tid").and_then(Json::as_f64).ok_or(format!("event {i}: missing tid"))? as u64;
        if ts < 0.0 || ts.fract() != 0.0 {
            return Err(format!("event {i}: non-integer ts {ts}"));
        }
        let ts = ts as u64;
        let track = (pid, tid);
        if let Some(&prev) = last_ts.get(&track) {
            if ts < prev {
                return Err(format!(
                    "event {i} ({name}): ts {ts} < {prev} on track pid={pid} tid={tid}"
                ));
            }
        }
        last_ts.insert(track, ts);
        match ph {
            "B" => stacks.entry(track).or_default().push(name.to_string()),
            "E" => match stacks.entry(track).or_default().pop() {
                Some(open) if open == name => {}
                Some(open) => {
                    return Err(format!(
                        "event {i}: E '{name}' closes '{open}' on track pid={pid} tid={tid}"
                    ))
                }
                None => {
                    return Err(format!(
                        "event {i}: E '{name}' with no open span on track pid={pid} tid={tid}"
                    ))
                }
            },
            "i" => {}
            // Counter samples only need the shared per-track monotonic
            // timestamp check above.
            "C" => {}
            other => return Err(format!("event {i}: unexpected ph '{other}'")),
        }
    }
    for ((pid, tid), stack) in stacks {
        if let Some(open) = stack.last() {
            return Err(format!("unclosed span '{open}' on track pid={pid} tid={tid}"));
        }
    }
    Ok(n)
}

/// Parsed `sched.trace` spec: `off`, `jsonl:<path>` or `chrome:<path>`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum TraceSpec {
    #[default]
    Off,
    Jsonl(String),
    Chrome(String),
}

impl TraceSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(TraceSpec::Off);
        }
        if let Some(path) = s.strip_prefix("jsonl:") {
            if path.is_empty() {
                bail!("trace spec 'jsonl:' needs a path, e.g. jsonl:events.jsonl");
            }
            return Ok(TraceSpec::Jsonl(path.to_string()));
        }
        if let Some(path) = s.strip_prefix("chrome:") {
            if path.is_empty() {
                bail!("trace spec 'chrome:' needs a path, e.g. chrome:trace.json");
            }
            return Ok(TraceSpec::Chrome(path.to_string()));
        }
        bail!("unknown trace spec '{s}' (expected off, jsonl:<path> or chrome:<path>)");
    }

    /// Artifact path, when tracing is on.
    pub fn path(&self) -> Option<&str> {
        match self {
            TraceSpec::Off => None,
            TraceSpec::Jsonl(p) | TraceSpec::Chrome(p) => Some(p),
        }
    }

    /// Build the sink this spec names (`None` when off).
    pub fn make_sink(&self) -> Option<Box<dyn TraceSink>> {
        match self {
            TraceSpec::Off => None,
            TraceSpec::Jsonl(_) => Some(Box::new(JsonlSink::new())),
            TraceSpec::Chrome(_) => Some(Box::new(ChromeSink::new())),
        }
    }
}

impl fmt::Display for TraceSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceSpec::Off => write!(f, "off"),
            TraceSpec::Jsonl(p) => write!(f, "jsonl:{p}"),
            TraceSpec::Chrome(p) => write!(f, "chrome:{p}"),
        }
    }
}

/// Event tallies kept by [`Tracer`] alongside (and independent of) the
/// sink. These must agree exactly with the `SimStats` aggregates — see
/// [`reconcile`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceCounts {
    pub submits: u64,
    pub releases: u64,
    pub admits: u64,
    pub rejects: u64,
    pub prefill_chunks: u64,
    pub solo_decode_steps: u64,
    pub fused_sweeps: u64,
    pub fused_streams: u64,
    /// Token positions produced: prefill-chunk positions + solo decode
    /// retires + fused-sweep members (mirrors `SimStats::tokens`).
    pub tokens: u64,
    pub page_faults: u64,
    pub evictions: u64,
    pub writebacks: u64,
    pub restores: u64,
    pub retires: u64,
    pub link_transfers: u64,
}

impl TraceCounts {
    fn absorb(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::Submit { .. } => self.submits += 1,
            TraceEvent::Release { .. } => self.releases += 1,
            TraceEvent::Admit { .. } => self.admits += 1,
            TraceEvent::Reject { .. } => self.rejects += 1,
            TraceEvent::PrefillChunk { positions, .. } => {
                self.prefill_chunks += 1;
                self.tokens += positions;
            }
            TraceEvent::DecodeStep { .. } => {
                self.solo_decode_steps += 1;
                self.tokens += 1;
            }
            TraceEvent::FusedSweep { streams, .. } => {
                self.fused_sweeps += 1;
                self.fused_streams += streams.len() as u64;
                self.tokens += streams.len() as u64;
            }
            TraceEvent::PageFault { .. } => self.page_faults += 1,
            TraceEvent::Evict { .. } => self.evictions += 1,
            TraceEvent::Writeback { .. } => self.writebacks += 1,
            TraceEvent::Restore { .. } => self.restores += 1,
            TraceEvent::StreamRetire { .. } => self.retires += 1,
            TraceEvent::LinkTransfer { .. } => self.link_transfers += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.submits
            + self.releases
            + self.admits
            + self.rejects
            + self.prefill_chunks
            + self.solo_decode_steps
            + self.fused_sweeps
            + self.page_faults
            + self.evictions
            + self.writebacks
            + self.restores
            + self.retires
            + self.link_transfers
    }
}

/// The reconciliation invariant: every traced tally must equal its
/// `SimStats` aggregate. A mismatch means an emission site was missed
/// (or double-fired) — checked under `debug_assertions` at stats
/// finalize and by the randomized property test.
pub fn reconcile(counts: &TraceCounts, stats: &SimStats) -> Result<(), String> {
    let checks: [(&str, u64, u64); 9] = [
        ("tokens", counts.tokens, stats.tokens),
        ("prefill_chunks", counts.prefill_chunks, stats.prefill_chunks),
        ("solo_decode_steps", counts.solo_decode_steps, stats.solo_decode_steps),
        ("fused_sweeps", counts.fused_sweeps, stats.fused_sweeps),
        ("fused_streams", counts.fused_streams, stats.fused_streams),
        ("page_faults", counts.page_faults, stats.page_faults),
        ("preemptions", counts.evictions, stats.preemptions),
        ("rejected", counts.rejects, stats.rejected),
        ("stream_retires", counts.retires, stats.streams.len() as u64),
    ];
    let bad: Vec<String> = checks
        .iter()
        .filter(|(_, a, b)| a != b)
        .map(|(k, a, b)| format!("{k}: traced {a} != stats {b}"))
        .collect();
    if bad.is_empty() {
        Ok(())
    } else {
        Err(format!("trace/stats reconciliation failed: {}", bad.join("; ")))
    }
}

/// One utilization window of the timeline (`[start, end)` cycles).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceWindow {
    pub start: u64,
    pub end: u64,
    /// Cycles the engine had work: `(end - start) - idle`.
    pub busy: u64,
    /// Cycles spent warped forward to the next arrival.
    pub idle: u64,
    /// Inter-device link cycles charged in this window (fleet only).
    pub link: u64,
    /// KV page frames in use at the window's end (carry-forward sample;
    /// 0 when paging is off).
    pub pages_in_use: u64,
}

impl TraceWindow {
    /// Busy fraction of the window (0.0 for an empty window).
    pub fn utilization(&self) -> f64 {
        let len = self.end.saturating_sub(self.start);
        if len == 0 {
            return 0.0;
        }
        self.busy as f64 / len as f64
    }
}

/// Windowed utilization accumulator: records idle spans, link charges
/// and pages-in-use changes during the run, then bins them into
/// `window`-cycle [`TraceWindow`]s at finalize.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    window: u64,
    idle: Vec<(u64, u64)>,
    link: Vec<(u64, u64)>,
    pages: Vec<(u64, u64)>,
}

impl Timeline {
    pub fn new(window: u64) -> Self {
        Self { window, ..Default::default() }
    }

    /// Record an idle warp `[start, end)`.
    pub fn idle_span(&mut self, start: u64, end: u64) {
        if end > start {
            self.idle.push((start, end));
        }
    }

    /// Charge `cycles` of link transfer at cycle `at`.
    pub fn link_cycles(&mut self, at: u64, cycles: u64) {
        if cycles > 0 {
            self.link.push((at, cycles));
        }
    }

    /// Record that `in_use` page frames are allocated as of cycle `at`.
    pub fn pages_sample(&mut self, at: u64, in_use: u64) {
        self.pages.push((at, in_use));
    }

    /// Bin everything into windows covering `[0, clock)`. The last
    /// window is truncated at the makespan.
    pub fn finish(&self, clock: u64) -> Vec<TraceWindow> {
        if self.window == 0 || clock == 0 {
            return Vec::new();
        }
        let n = clock.div_ceil(self.window);
        let mut out: Vec<TraceWindow> = (0..n)
            .map(|w| TraceWindow {
                start: w * self.window,
                end: ((w + 1) * self.window).min(clock),
                ..Default::default()
            })
            .collect();
        for &(s, e) in &self.idle {
            let (s, e) = (s.min(clock), e.min(clock));
            if e <= s {
                continue;
            }
            let (w0, w1) = ((s / self.window) as usize, ((e - 1) / self.window) as usize);
            for w in out.iter_mut().take(w1 + 1).skip(w0) {
                w.idle += e.min(w.end) - s.max(w.start);
            }
        }
        for &(at, cycles) in &self.link {
            let w = ((at / self.window) as usize).min(out.len() - 1);
            out[w].link += cycles;
        }
        // Pages: carry-forward step function sampled at each window end.
        let mut i = 0usize;
        let mut current = 0u64;
        for w in out.iter_mut() {
            while i < self.pages.len() && self.pages[i].0 < w.end {
                current = self.pages[i].1;
                i += 1;
            }
            w.pages_in_use = current;
            let len = w.end - w.start;
            w.busy = len - w.idle.min(len);
        }
        out
    }
}

/// The engine-side tracing front end: owns the optional sink, the
/// reconciliation tallies and the optional timeline. A default
/// (`Tracer::off()`) tracer is a pair of `None`s — the hot path pays
/// one branch and constructs nothing.
#[derive(Default)]
pub struct Tracer {
    spec: TraceSpec,
    sink: Option<Box<dyn TraceSink>>,
    /// Online profiler (`sched.profile`). A second, typed observer fed
    /// the same event stream as `sink` — kept separate so the engine
    /// can extract the finished `Profile` after the run.
    profile: Option<Box<super::profile::ProfileSink>>,
    counts: TraceCounts,
    timeline: Option<Timeline>,
}

impl Tracer {
    /// Tracing disabled (the default for every engine).
    pub fn off() -> Self {
        Self::default()
    }

    /// Build from an already-parsed spec and timeline window — the
    /// engine-side constructor (`cfg.sched.trace` / `trace_window` are
    /// validated at config-parse time, so this cannot fail).
    pub fn new(spec: TraceSpec, window: u64) -> Self {
        let sink = spec.make_sink();
        let timeline = (window > 0).then(|| Timeline::new(window));
        Self { spec, sink, profile: None, counts: TraceCounts::default(), timeline }
    }

    /// Build from the `sched.trace` / `sched.trace_window` string pair.
    pub fn from_config(spec: &str, window: u64) -> Result<Self> {
        Ok(Self::new(TraceSpec::parse(spec)?, window))
    }

    /// Replace the sink (test harnesses; keeps spec/timeline).
    pub fn set_sink(&mut self, sink: Box<dyn TraceSink>) {
        self.sink = Some(sink);
    }

    /// Attach an online profiler. Both observers see every event.
    pub fn set_profile(&mut self, profile: super::profile::ProfileSink) {
        self.profile = Some(Box::new(profile));
    }

    /// The attached profiler, if any (finalize it with
    /// `ProfileSink::finish` against the run's stats).
    pub fn profile_sink(&self) -> Option<&super::profile::ProfileSink> {
        self.profile.as_deref()
    }

    pub fn is_on(&self) -> bool {
        self.sink.is_some() || self.profile.is_some()
    }

    pub fn spec(&self) -> &TraceSpec {
        &self.spec
    }

    pub fn counts(&self) -> &TraceCounts {
        &self.counts
    }

    /// Emit an event. The closure only runs when a sink or profiler is
    /// attached, so the disabled path never constructs the event.
    /// Counts absorb exactly once however many observers are attached.
    #[inline]
    pub fn emit<F: FnOnce() -> TraceEvent>(&mut self, f: F) {
        if self.sink.is_none() && self.profile.is_none() {
            return;
        }
        let ev = f();
        self.counts.absorb(&ev);
        if let Some(sink) = self.sink.as_deref_mut() {
            sink.event(&ev);
        }
        if let Some(profile) = self.profile.as_deref_mut() {
            profile.event(&ev);
        }
    }

    /// Timeline hook: idle warp span.
    #[inline]
    pub fn idle_span(&mut self, start: u64, end: u64) {
        if let Some(t) = self.timeline.as_mut() {
            t.idle_span(start, end);
        }
    }

    /// Timeline hook: link cycles charged at `at`.
    #[inline]
    pub fn link_cycles(&mut self, at: u64, cycles: u64) {
        if let Some(t) = self.timeline.as_mut() {
            t.link_cycles(at, cycles);
        }
    }

    /// Pages-in-use changed: feeds the timeline and the sinks' counter
    /// hooks (the Chrome sink renders it as a Perfetto counter track).
    #[inline]
    pub fn pages_sample(&mut self, at: u64, in_use: u64) {
        if let Some(t) = self.timeline.as_mut() {
            t.pages_sample(at, in_use);
        }
        if let Some(sink) = self.sink.as_deref_mut() {
            sink.pages(at, in_use);
        }
        if let Some(profile) = self.profile.as_deref_mut() {
            profile.pages(at, in_use);
        }
    }

    /// Finalize the timeline into windows (empty when `trace_window`
    /// is 0).
    pub fn finish_timeline(&self, clock: u64) -> Vec<TraceWindow> {
        self.timeline.as_ref().map(|t| t.finish(clock)).unwrap_or_default()
    }

    /// Render the artifact: `(path, contents)` when a sink is attached.
    pub fn render(&mut self) -> Option<(String, String)> {
        let path = self.spec.path()?.to_string();
        let sink = self.sink.as_deref_mut()?;
        Some((path, sink.render()))
    }

    /// Check the reconciliation invariant against finalized stats.
    /// Trivially `Ok` when tracing is off.
    pub fn reconcile(&self, stats: &SimStats) -> Result<(), String> {
        if !self.is_on() {
            return Ok(());
        }
        reconcile(&self.counts, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Submit { stream: 0, at: 0, arrival: 0, prompt_tokens: 2, tokens: 4 },
            TraceEvent::Release { stream: 0, at: 0 },
            TraceEvent::Admit { stream: 0, at: 0, slot: 0 },
            TraceEvent::PrefillChunk {
                stream: 0,
                device: 0,
                start: 0,
                finish: 90,
                pos: 0,
                positions: 2,
            },
            TraceEvent::DecodeStep { stream: 0, device: 0, start: 90, finish: 130, pos: 2 },
            TraceEvent::PageFault { stream: 1, at: 130 },
            TraceEvent::Evict { victim: 0, by: 1, at: 130, tokens: 3 },
            TraceEvent::Writeback { stream: 0, start: 130, finish: 150, tokens: 3 },
            TraceEvent::Restore { stream: 0, start: 160, finish: 180, tokens: 3 },
            TraceEvent::FusedSweep { device: 0, start: 180, finish: 240, streams: vec![0, 1] },
            TraceEvent::StreamRetire { stream: 0, at: 240, tokens: 4 },
            TraceEvent::LinkTransfer { stream: 1, src: 0, dst: 1, start: 240, finish: 260 },
            TraceEvent::Reject { stream: 2, at: 260, predicted_ttft: 9000, ttft_budget: 100 },
        ]
    }

    #[test]
    fn spec_parse_and_display_round_trip() {
        for s in ["off", "jsonl:events.jsonl", "chrome:trace.json"] {
            let spec = TraceSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s);
        }
        assert_eq!(TraceSpec::parse("").unwrap(), TraceSpec::Off);
        assert_eq!(TraceSpec::parse("jsonl:a/b.jsonl").unwrap().path(), Some("a/b.jsonl"));
        assert!(TraceSpec::parse("jsonl:").is_err(), "empty path rejected");
        assert!(TraceSpec::parse("chrome:").is_err());
        assert!(TraceSpec::parse("perfetto:x").is_err(), "unknown format rejected");
        assert!(TraceSpec::Off.make_sink().is_none());
        assert!(TraceSpec::parse("chrome:t.json").unwrap().make_sink().is_some());
    }

    #[test]
    fn jsonl_sink_emits_one_parseable_object_per_line() {
        let mut sink = JsonlSink::new();
        let events = sample_events();
        for ev in &events {
            sink.event(ev);
        }
        assert_eq!(sink.events(), events.len() as u64);
        let text = sink.render();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), events.len());
        for (line, ev) in lines.iter().zip(&events) {
            let json = Json::parse(line).expect("line parses");
            assert_eq!(json.get("ev").and_then(Json::as_str), Some(ev.name()));
        }
        // Span events carry t0 <= t1; point events carry t.
        let j = Json::parse(lines[3]).unwrap();
        assert_eq!(j.get("t0").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.get("t1").and_then(Json::as_f64), Some(90.0));
        assert_eq!(j.get("positions").and_then(Json::as_f64), Some(2.0));
        // render() drains the buffer.
        assert!(sink.render().is_empty());
    }

    #[test]
    fn chrome_sink_is_well_formed_ordered_and_paired() {
        let mut sink = ChromeSink::new();
        for ev in sample_events() {
            sink.event(&ev);
        }
        let text = sink.render();
        let n = validate_chrome(&text).expect("valid chrome trace");
        assert!(n > 0);
        // The fused sweep fans out to one span per member track.
        let root = Json::parse(&text).unwrap();
        let events = root.get("traceEvents").and_then(Json::as_arr).unwrap();
        let fused: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("fused(b=2)"))
            .collect();
        assert_eq!(fused.len(), 4, "B+E on each of the two member tracks");
    }

    #[test]
    fn chrome_zero_length_span_degrades_to_instant() {
        let mut sink = ChromeSink::new();
        sink.event(&TraceEvent::DecodeStep { stream: 0, device: 0, start: 7, finish: 7, pos: 1 });
        let text = sink.render();
        validate_chrome(&text).unwrap();
        let root = Json::parse(&text).unwrap();
        let events = root.get("traceEvents").and_then(Json::as_arr).unwrap();
        let decode: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("decode"))
            .collect();
        assert_eq!(decode.len(), 1);
        assert_eq!(decode[0].get("ph").and_then(Json::as_str), Some("i"));
    }

    #[test]
    fn chrome_back_to_back_spans_close_before_opening() {
        let mut sink = ChromeSink::new();
        // Two abutting decode steps on one track: E@50 must precede B@50.
        sink.event(&TraceEvent::DecodeStep { stream: 3, device: 0, start: 10, finish: 50, pos: 1 });
        sink.event(&TraceEvent::DecodeStep { stream: 3, device: 0, start: 50, finish: 80, pos: 2 });
        let text = sink.render();
        validate_chrome(&text).expect("abutting spans stay paired");
    }

    #[test]
    fn validate_chrome_rejects_malformed_traces() {
        assert!(validate_chrome("not json").is_err());
        assert!(validate_chrome("{}").is_err(), "missing traceEvents");
        let unclosed = r#"{"traceEvents":[{"name":"x","ph":"B","ts":1,"pid":0,"tid":0}]}"#;
        assert!(validate_chrome(unclosed).unwrap_err().contains("unclosed"));
        let unordered = r#"{"traceEvents":[
            {"name":"a","ph":"i","ts":5,"pid":0,"tid":0,"s":"t"},
            {"name":"b","ph":"i","ts":4,"pid":0,"tid":0,"s":"t"}]}"#;
        assert!(validate_chrome(unordered).unwrap_err().contains("ts"));
        let crossed = r#"{"traceEvents":[
            {"name":"a","ph":"B","ts":1,"pid":0,"tid":0},
            {"name":"b","ph":"E","ts":2,"pid":0,"tid":0}]}"#;
        assert!(validate_chrome(crossed).unwrap_err().contains("closes"));
    }

    #[test]
    fn counts_absorb_and_reconcile() {
        let mut tracer = Tracer::from_config("jsonl:x.jsonl", 0).unwrap();
        for ev in sample_events() {
            tracer.emit(|| ev.clone());
        }
        let c = tracer.counts();
        assert_eq!(c.submits, 1);
        assert_eq!(c.prefill_chunks, 1);
        assert_eq!(c.solo_decode_steps, 1);
        assert_eq!(c.fused_sweeps, 1);
        assert_eq!(c.fused_streams, 2);
        assert_eq!(c.tokens, 2 + 1 + 2, "chunk positions + solo retire + fused members");
        assert_eq!(c.page_faults, 1);
        assert_eq!(c.evictions, 1);
        assert_eq!(c.rejects, 1);
        assert_eq!(c.retires, 1);
        assert_eq!(c.link_transfers, 1);

        let mut stats = SimStats {
            tokens: 5,
            prefill_chunks: 1,
            solo_decode_steps: 1,
            fused_sweeps: 1,
            fused_streams: 2,
            page_faults: 1,
            preemptions: 1,
            rejected: 1,
            ..Default::default()
        };
        stats.streams.push(Default::default());
        tracer.reconcile(&stats).expect("tallies match aggregates");
        stats.tokens = 6;
        let err = tracer.reconcile(&stats).unwrap_err();
        assert!(err.contains("tokens: traced 5 != stats 6"), "{err}");
    }

    #[test]
    fn tracer_off_is_inert() {
        let mut tracer = Tracer::off();
        assert!(!tracer.is_on());
        tracer.emit(|| panic!("event closure must not run when tracing is off"));
        assert_eq!(tracer.counts(), &TraceCounts::default());
        assert!(tracer.render().is_none());
        assert!(tracer.finish_timeline(1000).is_empty());
        tracer.reconcile(&SimStats { tokens: 99, ..Default::default() }).unwrap();
    }

    #[test]
    fn timeline_bins_idle_link_and_pages() {
        let mut t = Timeline::new(100);
        t.idle_span(50, 120); // 50 idle in w0, 20 in w1
        t.idle_span(250, 250); // empty span ignored
        t.link_cycles(130, 7);
        t.link_cycles(205, 3);
        t.pages_sample(10, 2);
        t.pages_sample(110, 5);
        t.pages_sample(180, 4);
        let w = t.finish(250);
        assert_eq!(w.len(), 3);
        assert_eq!((w[0].start, w[0].end), (0, 100));
        assert_eq!((w[2].start, w[2].end), (200, 250), "last window truncated at makespan");
        assert_eq!(w[0].idle, 50);
        assert_eq!(w[0].busy, 50);
        assert_eq!(w[1].idle, 20);
        assert_eq!(w[1].busy, 80);
        assert_eq!(w[1].link, 7);
        assert_eq!(w[2].link, 3);
        assert_eq!(w[0].pages_in_use, 2, "value at window end");
        assert_eq!(w[1].pages_in_use, 4, "last change before end wins");
        assert_eq!(w[2].pages_in_use, 4, "carried forward");
        assert!((w[1].utilization() - 0.8).abs() < 1e-12);
        assert!(Timeline::new(0).finish(1000).is_empty(), "window 0 = timeline off");
        assert!(Timeline::new(100).finish(0).is_empty());
    }

    #[test]
    fn timeline_clamps_idle_past_makespan() {
        let mut t = Timeline::new(100);
        t.idle_span(150, 900); // finalize at 200: only [150, 200) counts
        let w = t.finish(200);
        assert_eq!(w.len(), 2);
        assert_eq!(w[1].idle, 50);
        assert_eq!(w[1].busy, 0);
    }

    #[test]
    fn trace_event_json_round_trip() {
        for ev in sample_events() {
            let json = ev.to_json();
            let back = TraceEvent::from_json(&json)
                .unwrap_or_else(|e| panic!("{} round-trips: {e}", ev.name()));
            assert_eq!(back, ev, "{} survives to_json -> from_json", ev.name());
        }
        assert!(TraceEvent::from_json(&Json::parse(r#"{"ev":"nope"}"#).unwrap()).is_err());
        assert!(
            TraceEvent::from_json(&Json::parse(r#"{"ev":"release","stream":0}"#).unwrap())
                .is_err(),
            "missing field rejected"
        );
    }

    #[test]
    fn chrome_counter_tracks_render_and_validate() {
        let mut sink = ChromeSink::new();
        sink.pages(10, 2);
        sink.pages(50, 5);
        sink.event(&TraceEvent::FusedSweep { device: 0, start: 0, finish: 40, streams: vec![0, 1] });
        sink.event(&TraceEvent::FusedSweep {
            device: 0,
            start: 40,
            finish: 90,
            streams: vec![0, 1, 2],
        });
        let text = sink.render();
        validate_chrome(&text).expect("counter rows keep the trace valid");
        let root = Json::parse(&text).unwrap();
        let events = root.get("traceEvents").and_then(Json::as_arr).unwrap();
        let counters: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("C")).collect();
        // 2 pages samples + occupancy deltas at ts 0 (+2), 40 (-2,+3), 90 (-3).
        let pages: Vec<&&Json> = counters
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("pages_in_use"))
            .collect();
        let occ: Vec<&&Json> = counters
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("decode_batch"))
            .collect();
        assert_eq!(pages.len(), 2);
        assert_eq!(occ.len(), 4);
        // Abutting sweeps: at ts=40 occupancy dips to 0 then rises to 3.
        let occ_values: Vec<f64> = occ
            .iter()
            .map(|e| e.get("args").and_then(|a| a.get("occupancy")).and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(occ_values, vec![2.0, 0.0, 3.0, 0.0]);
        for e in &counters {
            assert_eq!(e.get("tid").and_then(Json::as_f64), Some(COUNTER_TID as f64));
        }
        let named_counters = events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("thread_name")
                && e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                    == Some("counters")
        });
        assert!(named_counters, "counter track is labeled");
    }

    #[test]
    fn profile_slot_observes_without_sink() {
        use crate::config::HwConfig;
        let model = crate::model::gpt::by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut tracer = Tracer::off();
        assert!(!tracer.is_on());
        tracer.set_profile(super::super::profile::ProfileSink::new(&model, &cfg));
        assert!(tracer.is_on(), "profile slot alone turns the tracer on");
        for ev in sample_events() {
            tracer.emit(|| ev.clone());
        }
        assert_eq!(tracer.counts().prefill_chunks, 1, "counts absorb with profile only");
        assert!(tracer.render().is_none(), "no sink, no rendered artifact");
        let profile = tracer.profile_sink().unwrap().finish(None, None);
        assert!(profile.attributed_cycles() > 0);
    }

    #[test]
    fn chrome_golden_single_stream() {
        // Pinned artifact for a tiny hand-built trace: any formatting or
        // mapping change must be deliberate.
        let mut sink = ChromeSink::new();
        sink.event(&TraceEvent::Admit { stream: 0, at: 0, slot: 0 });
        sink.event(&TraceEvent::DecodeStep { stream: 0, device: 0, start: 0, finish: 40, pos: 1 });
        let got = sink.render();
        let want = concat!(
            r#"{"traceEvents":["#,
            r#"{"args":{"name":"device 0"},"name":"process_name","ph":"M","pid":0},"#,
            r#"{"args":{"name":"stream 0"},"name":"thread_name","ph":"M","pid":0,"tid":0},"#,
            r#"{"args":{"slot":0},"name":"admit","ph":"i","pid":0,"s":"t","tid":0,"ts":0},"#,
            r#"{"args":{"pos":1},"name":"decode","ph":"B","pid":0,"tid":0,"ts":0},"#,
            r#"{"name":"decode","ph":"E","pid":0,"tid":0,"ts":40}"#,
            r#"]}"#,
        );
        assert_eq!(got, want);
    }
}
