//! Multi-device fleet engine: serve streams over a model partitioned
//! across N PIM-GPT devices (`mapping::partition`).
//!
//! `FleetSim` is the device-count-generic front end. At
//! `sched.devices = 1` it *contains* a [`MultiSim`] and delegates every
//! call — byte-identical to the single-package engine by construction
//! (and pinned on random traces, with batching and paging, in
//! `tests/integration_fleet.rs`). At `devices > 1` it runs the fleet
//! engine below.
//!
//! **The fleet engine is a calibrated step-cost composition**, the
//! first instalment of the ROADMAP "metasim" direction: each device's
//! per-step cost is measured *exactly* — the device slice's decode
//! graph is compiled (`compiler::compile`) and walked on scratch
//! [`Resources`] through the same `Resources::issue` path as the
//! cycle-accurate engine, then memoized per `(ltoken, passes, batch)`
//! — and steps are composed across devices at step granularity:
//!
//! * `layer_pipeline`: a step visits the stages in order; each stage
//!   waits for the previous stage's activations (plus one link hop)
//!   and for its own device to free up. Different streams overlap
//!   across stages — device 0 prefills stream B while device 1 runs
//!   stream A.
//! * `tensor_parallel`: all devices run the step in lockstep; the step
//!   costs the slowest device's compute plus the per-layer all-reduce
//!   and LM-head-gather link cycles.
//!
//! Interconnect cycles come from the `DevicePartition` link-cost model
//! (`sched.{link_gbit_s, link_hop_cycles}`) and are charged as
//! explicit transfer time between device programs — reported in
//! `SimStats::link_transfer_cycles`, never folded into compute.
//!
//! Cross-stream batched decode (`sched.batch_decode`) and paged KV
//! (`sched.kv_paging`) work per device at step granularity: fused
//! sweeps issue shareable (weight-stationary) nodes once with
//! `passes = k` while per-stream KV nodes issue serially (the
//! `compiler::template` sharing rule), and each device holds its own
//! KV frame pool — faults evict a `PickPolicy::pick_victim` victim
//! with modeled per-device writeback, honoring
//! `sched.kv_evict_watermark`.
//!
//! **Determinism rules**: admission is arrival-order (ties by id),
//! step selection is earliest-ready (ties by id), all state lives in
//! `Vec`/`BTreeMap` — no hashing, no RNG, no wall clock. Two runs of
//! the same trace are identical. Scope notes, in exchange for
//! composing at step granularity: the fleet path reports makespan,
//! latency percentiles, per-device busy and link cycles, and
//! instruction counts, but not the single-package micro-counters (row
//! hits, per-class cycles); SLO admission shedding stays a
//! single-device feature.

use std::collections::BTreeMap;

use super::policy::{self, IssueCandidate, PickPolicy};
use super::prefill;
use super::resources::{empty_plan, IssueCtx, Resources};
use super::sched::{MultiSim, StreamOutcome, StreamResult, StreamSpec};
use super::stats::{SimStats, StreamStats};
use super::trace::{TraceCounts, TraceEvent, TraceSink, Tracer};
use crate::asic::AsicOp;
use crate::compiler::{compile, Instr, Program};
use crate::config::HwConfig;
use crate::dram::TimingCycles;
use crate::mapping::{DevicePartition, ModelMapping, PartitionStrategy};
use crate::model::GptModel;
use anyhow::{anyhow, bail, Result};

/// Device-count-generic serving engine: a single-package [`MultiSim`]
/// at `sched.devices = 1`, the fleet step-composition engine above it.
pub struct FleetSim {
    inner: Inner,
}

enum Inner {
    Single(Box<MultiSim>),
    Multi(Box<FleetEngine>),
}

/// Pre-computed placement shared between repeated `FleetSim`
/// constructions of the same `(model, cfg)`: the Algorithm-3 mapping
/// (single package) or the partition pass plus every device mapping
/// (fleet). `figures --fig timeline` runs the same config twice (the
/// traced run and the plain re-run backing the makespan-equality
/// check); prebuilding stops it paying the placement twice.
#[derive(Clone, Debug)]
pub enum PrebuiltFleet {
    Single(ModelMapping),
    Multi { partition: DevicePartition, mappings: Vec<ModelMapping> },
}

impl FleetSim {
    pub fn new(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        let pre = Self::prebuild(model, cfg)?;
        Self::from_prebuilt(model, cfg, &pre)
    }

    /// Run the placement passes once, for reuse across several
    /// `from_prebuilt` constructions. The result is only valid for the
    /// same model and a config with the same device count/partition —
    /// scheduler knobs (trace, windows, policies) may differ freely.
    pub fn prebuild(model: &GptModel, cfg: &HwConfig) -> Result<PrebuiltFleet> {
        if cfg.sched.devices <= 1 {
            Ok(PrebuiltFleet::Single(ModelMapping::build(model, cfg)?))
        } else {
            let partition = DevicePartition::build(model, cfg)?;
            let mut mappings = Vec::with_capacity(partition.slices.len());
            for s in &partition.slices {
                let mapping = ModelMapping::build_device(&s.kv_model, cfg, &s.weights)
                    .map_err(|e| anyhow!("device {} of {}: {e}", s.device, partition.devices))?;
                mappings.push(mapping);
            }
            Ok(PrebuiltFleet::Multi { partition, mappings })
        }
    }

    /// Build from a [`PrebuiltFleet`] produced by [`FleetSim::prebuild`]
    /// for the same model/device configuration.
    pub fn from_prebuilt(model: &GptModel, cfg: &HwConfig, pre: &PrebuiltFleet) -> Result<Self> {
        let inner = match pre {
            PrebuiltFleet::Single(mapping) => {
                if cfg.sched.devices > 1 {
                    bail!("prebuilt single-package placement used with sched.devices > 1");
                }
                Inner::Single(Box::new(MultiSim::from_mapping(model, cfg, mapping.clone())))
            }
            PrebuiltFleet::Multi { partition, mappings } => {
                if cfg.sched.devices != partition.devices {
                    bail!(
                        "prebuilt partition holds {} devices but sched.devices = {}",
                        partition.devices,
                        cfg.sched.devices
                    );
                }
                Inner::Multi(Box::new(FleetEngine::from_parts(
                    model,
                    cfg,
                    partition.clone(),
                    mappings.clone(),
                )?))
            }
        };
        Ok(Self { inner })
    }

    /// Devices the model is partitioned across.
    pub fn devices(&self) -> usize {
        match &self.inner {
            Inner::Single(_) => 1,
            Inner::Multi(f) => f.partition.devices,
        }
    }

    /// Co-resident stream contexts (paged: page frames) *per device
    /// fleet*: the minimum over devices, since every device must hold
    /// its share of every active stream's KV.
    pub fn kv_slots(&self) -> usize {
        match &self.inner {
            Inner::Single(ms) => ms.kv_slots(),
            Inner::Multi(f) => f.pool,
        }
    }

    pub fn clock(&self) -> u64 {
        match &self.inner {
            Inner::Single(ms) => ms.clock(),
            Inner::Multi(f) => f.clock,
        }
    }

    pub fn submit(&mut self, spec: StreamSpec) -> Result<()> {
        match &mut self.inner {
            Inner::Single(ms) => ms.submit(spec),
            Inner::Multi(f) => f.submit(spec),
        }
    }

    /// Run every submitted stream to completion; outcomes in completion
    /// order.
    pub fn run_all(&mut self) -> Result<Vec<StreamOutcome>> {
        match &mut self.inner {
            Inner::Single(ms) => ms.run_all(),
            Inner::Multi(f) => f.run_all(),
        }
    }

    pub fn stats(&self) -> &SimStats {
        match &self.inner {
            Inner::Single(ms) => &ms.stats,
            Inner::Multi(f) => &f.stats,
        }
    }

    pub fn finalize_stats(&mut self) -> &SimStats {
        match &mut self.inner {
            Inner::Single(ms) => {
                ms.finalize_stats();
                ms.stats.devices = 1;
                &ms.stats
            }
            Inner::Multi(f) => f.finalize_stats(),
        }
    }

    /// Replace the trace sink (test harnesses; keeps the configured
    /// spec and timeline window).
    pub fn set_trace_sink(&mut self, sink: Box<dyn TraceSink>) {
        match &mut self.inner {
            Inner::Single(ms) => ms.set_trace_sink(sink),
            Inner::Multi(f) => f.trace.set_sink(sink),
        }
    }

    /// Reconciliation tallies of every event emitted so far.
    pub fn trace_counts(&self) -> &TraceCounts {
        match &self.inner {
            Inner::Single(ms) => ms.trace_counts(),
            Inner::Multi(f) => f.trace.counts(),
        }
    }

    /// Render the configured trace artifact as `(path, contents)`;
    /// `None` when tracing is off. The engine never touches the
    /// filesystem — the caller writes the file.
    pub fn render_trace(&mut self) -> Option<(String, String)> {
        match &mut self.inner {
            Inner::Single(ms) => ms.render_trace(),
            Inner::Multi(f) => f.trace.render(),
        }
    }

    /// Attach a profiler directly (test harnesses; runs normally use
    /// `cfg.sched.profile`).
    pub fn set_profile(&mut self, profile: super::profile::ProfileSink) {
        match &mut self.inner {
            Inner::Single(ms) => ms.set_profile(profile),
            Inner::Multi(f) => f.trace.set_profile(profile),
        }
    }

    /// Finished profile when a profiler is attached, reconciled against
    /// the run's busy/link cycles. Call after `finalize_stats`.
    pub fn profile_report(&self) -> Option<super::profile::Profile> {
        match &self.inner {
            Inner::Single(ms) => ms.profile_report(),
            Inner::Multi(f) => f.trace.profile_sink().map(|p| {
                p.finish(Some(f.stats.busy_cycles()), Some(f.stats.link_transfer_cycles))
            }),
        }
    }

    /// Render the profile artifact per `cfg.sched.profile`:
    /// `(path, contents)`. The caller writes the file.
    pub fn render_profile(&self) -> Option<(String, String)> {
        match &self.inner {
            Inner::Single(ms) => ms.render_profile(),
            Inner::Multi(f) => {
                let profile = self.profile_report()?;
                match &f.cfg.sched.profile {
                    super::profile::ProfileSpec::Off => None,
                    super::profile::ProfileSpec::Text(p) => {
                        Some((p.clone(), profile.render_text()))
                    }
                    super::profile::ProfileSpec::Json(p) => {
                        Some((p.clone(), profile.to_json().to_string() + "\n"))
                    }
                }
            }
        }
    }

    /// Install a calibrated cost table on the admission policy. SLO
    /// admission shedding is a single-device feature (see module docs);
    /// the fleet path ignores the table.
    pub fn set_cost_table(&mut self, table: super::profile::CostTable) {
        if let Inner::Single(ms) = &mut self.inner {
            ms.set_cost_table(table);
        }
    }
}

/// Memoized exact cost of one device's step program.
#[derive(Clone, Copy, Debug)]
struct StepCost {
    cycles: u64,
    instructions: u64,
}

struct DeviceState {
    /// The device's own channel/bank space (weights + its KV share).
    mapping: ModelMapping,
    /// Sub-model view consistent with the device graph's KV shapes.
    model_view: GptModel,
    /// Cycle the device finishes its last accepted work.
    free_at: u64,
    /// Compute cycles charged to this device (excludes link time).
    busy_cycles: u64,
    /// (ltoken, passes, batch) -> measured step cost.
    memo: BTreeMap<(u64, u64, u64), StepCost>,
}

struct FleetStream {
    spec: StreamSpec,
    /// Next position to execute (0-based; < prompt_tokens = prefill).
    pos: u64,
    /// Cycle this stream may start its next step.
    ready: u64,
    admitted_cycle: u64,
    token_finishes: Vec<u64>,
    /// Logical KV slot (non-paged) / stable victim id (paged).
    slot: usize,
    /// Page frames currently held on every device (paged mode).
    frames_held: usize,
    instructions: u64,
    attributed_cycles: u64,
}

struct FleetEngine {
    cfg: HwConfig,
    model: GptModel,
    partition: DevicePartition,
    t: TimingCycles,
    devices: Vec<DeviceState>,
    pick: Box<dyn PickPolicy>,
    /// Submitted, not yet admitted (arrival order, ties by id).
    queued: Vec<StreamSpec>,
    /// Evicted mid-flight, waiting to resume (keeps pos/finishes).
    preempted: Vec<FleetStream>,
    active: Vec<FleetStream>,
    outcomes: Vec<StreamOutcome>,
    clock: u64,
    /// Co-resident contexts (paged: physical page frames) — min over
    /// devices, clamped by `max_streams` in the slot path.
    pool: usize,
    /// Paged mode: tokens per frame (`None` = slot mode).
    page_tokens: Option<u64>,
    /// Paged mode: free physical frames (fleet-wide lockstep — every
    /// device allocates the same frame count per stream).
    frames_free: usize,
    /// Paged mode: virtual-frame admission budget
    /// (`floor(pool * kv_oversub)`) minus worst-case commitments.
    admit_frames_left: usize,
    slot_used: Vec<bool>,
    stats: SimStats,
    link_cycles: u64,
    /// Event tracing + windowed timeline (`sched.trace{,_window}`);
    /// off by default — one dead branch per lifecycle edge.
    trace: Tracer,
}

impl FleetEngine {
    /// Build from an already-run partition pass and per-device
    /// mappings (`FleetSim::prebuild` order: one mapping per slice).
    fn from_parts(
        model: &GptModel,
        cfg: &HwConfig,
        partition: DevicePartition,
        mappings: Vec<ModelMapping>,
    ) -> Result<Self> {
        if mappings.len() != partition.slices.len() {
            bail!(
                "partition holds {} device slices but {} mappings were prebuilt",
                partition.slices.len(),
                mappings.len()
            );
        }
        let devices: Vec<DeviceState> = partition
            .slices
            .iter()
            .zip(mappings)
            .map(|(s, mapping)| DeviceState {
                mapping,
                model_view: s.kv_model.clone(),
                free_at: 0,
                busy_cycles: 0,
                memo: BTreeMap::new(),
            })
            .collect();
        // Every device must hold its share of every active stream's
        // KV, so fleet capacity is the weakest device's pool.
        let pool_raw = devices
            .iter()
            .map(|d| d.mapping.kv.n_slots)
            .min()
            .expect("devices >= 1");
        let page_tokens = devices[0].mapping.kv.page_tokens;
        let pool = if page_tokens.is_some() {
            pool_raw
        } else {
            pool_raw.min(cfg.sched.max_streams.max(1))
        };
        let admit_frames_left = if page_tokens.is_some() {
            ((pool as f64) * cfg.sched.kv_oversub).floor() as usize
        } else {
            0
        };
        let (pick, _admission) = policy::build(&cfg.sched);
        let mut trace = Tracer::new(cfg.sched.trace.clone(), cfg.sched.trace_window);
        if cfg.sched.profile.is_on() {
            trace.set_profile(super::profile::ProfileSink::new(model, cfg));
        }
        Ok(Self {
            cfg: cfg.clone(),
            model: model.clone(),
            t: TimingCycles::from_config(cfg),
            devices,
            pick,
            queued: Vec::new(),
            preempted: Vec::new(),
            active: Vec::new(),
            outcomes: Vec::new(),
            clock: 0,
            pool,
            page_tokens,
            frames_free: if page_tokens.is_some() { pool } else { 0 },
            admit_frames_left,
            slot_used: vec![false; pool],
            stats: SimStats::default(),
            partition,
            link_cycles: 0,
            trace,
        })
    }

    fn submit(&mut self, spec: StreamSpec) -> Result<()> {
        if spec.n_tokens == 0 {
            bail!("request {} has zero tokens", spec.id);
        }
        if spec.n_tokens > self.model.max_seq as u64 {
            bail!(
                "request {} length {} exceeds max_seq {}",
                spec.id,
                spec.n_tokens,
                self.model.max_seq
            );
        }
        if spec.prompt_tokens == 0 || spec.prompt_tokens > spec.n_tokens {
            bail!(
                "request {} prompt {} outside [1, {}]",
                spec.id,
                spec.prompt_tokens,
                spec.n_tokens
            );
        }
        if let Some(p) = self.page_tokens {
            let need = crate::util::ceil_div(spec.n_tokens, p) as usize;
            if need > self.pool {
                bail!(
                    "request {} needs {need} KV page frames but every-device pool holds {}",
                    spec.id,
                    self.pool
                );
            }
        }
        self.trace.emit(|| TraceEvent::Submit {
            stream: spec.id,
            at: self.clock,
            arrival: spec.arrival_cycle,
            prompt_tokens: spec.prompt_tokens,
            tokens: spec.n_tokens,
        });
        self.queued.push(spec);
        self.queued.sort_by_key(|s| (s.arrival_cycle, s.id));
        Ok(())
    }

    fn frames_for(&self, tokens: u64) -> usize {
        match self.page_tokens {
            Some(p) => crate::util::ceil_div(tokens.max(1), p) as usize,
            None => 0,
        }
    }

    /// Worst-case frame commitment the admission budget charges — the
    /// request's full context (mirror of the single-device rule: no
    /// admitted set can exceed `kv_oversub` times the pool even if
    /// every stream runs to its end).
    fn admit_commit(&self, spec: &StreamSpec) -> usize {
        self.frames_for(spec.n_tokens)
    }

    /// Admit resumable preempted streams first, then arrived queued
    /// requests in arrival order, while capacity lasts.
    fn admit(&mut self) {
        // Resumed streams need their current context's frames back
        // before they can run (their budget commitment never lapsed).
        while !self.preempted.is_empty() {
            let need = self.frames_for(self.preempted[0].pos.max(1));
            if self.active.len() >= self.cfg.sched.max_streams.max(1)
                || need > self.frames_free
            {
                break;
            }
            let mut s = self.preempted.remove(0);
            self.frames_free -= need;
            s.frames_held = need;
            s.ready = s.ready.max(self.clock);
            // Modeled KV restore onto every device's channel buses.
            let restore_start = self.clock;
            let mut restore_done = restore_start;
            for dev in 0..self.devices.len() {
                let wb = self.device_kv_transfer_cycles(dev, s.pos);
                self.devices[dev].free_at = self.devices[dev].free_at.max(self.clock) + wb;
                restore_done = restore_done.max(self.devices[dev].free_at);
            }
            let (rid, rpos) = (s.spec.id, s.pos);
            self.trace.emit(|| TraceEvent::Restore {
                stream: rid,
                start: restore_start,
                finish: restore_done,
                tokens: rpos,
            });
            self.sample_pages();
            self.active.push(s);
        }
        // Strict arrival-order admission: a blocked head of line blocks
        // everyone behind it (no overtaking — determinism rule).
        loop {
            let Some(&spec) = self.queued.first() else { break };
            if spec.arrival_cycle > self.clock {
                break; // sorted: nothing further has arrived yet
            }
            let admitted = if self.active.len() >= self.cfg.sched.max_streams.max(1) {
                false
            } else if self.page_tokens.is_some() {
                let commit = self.admit_commit(&spec);
                let first = self.frames_for(spec.prompt_tokens);
                commit <= self.admit_frames_left && first <= self.frames_free
            } else {
                self.slot_used.iter().any(|u| !u)
            };
            if !admitted {
                break;
            }
            self.queued.remove(0);
            let (slot, frames) = if self.page_tokens.is_some() {
                let first = self.frames_for(spec.prompt_tokens);
                self.admit_frames_left -= self.admit_commit(&spec);
                self.frames_free -= first;
                (spec.id as usize, first)
            } else {
                let slot = self.slot_used.iter().position(|u| !u).expect("checked above");
                self.slot_used[slot] = true;
                (slot, 0)
            };
            let admitted_cycle = self.clock.max(spec.arrival_cycle);
            self.trace.emit(|| TraceEvent::Release { stream: spec.id, at: admitted_cycle });
            self.trace.emit(|| TraceEvent::Admit {
                stream: spec.id,
                at: admitted_cycle,
                slot: slot as u64,
            });
            self.sample_pages();
            self.active.push(FleetStream {
                spec,
                pos: 0,
                ready: admitted_cycle,
                admitted_cycle,
                token_finishes: Vec::with_capacity(spec.n_tokens as usize),
                slot,
                frames_held: frames,
                instructions: 0,
                attributed_cycles: 0,
            });
        }
        let blocked = self
            .queued
            .iter()
            .filter(|s| s.arrival_cycle <= self.clock)
            .count() as u64;
        self.stats.admission_blocked += blocked;
        let in_use = self.active.len() as u64;
        self.stats.peak_slots_in_use = self.stats.peak_slots_in_use.max(in_use);
    }

    /// Modeled KV writeback/restore time for `tokens` positions of one
    /// stream on device `dev`'s channel buses — the per-device mirror
    /// of the single-package `kv_transfer_cycles` (bf16 K + V rows of
    /// the device's KV share).
    fn device_kv_transfer_cycles(&self, dev: usize, tokens: u64) -> u64 {
        let m = &self.partition.slices[dev].kv_model;
        let bytes = tokens * m.n_layer as u64 * 2 * m.d_model as u64 * 2;
        let per_cycle =
            self.cfg.gddr6.channel_bytes_per_cycle() * self.cfg.gddr6.channels as f64;
        (bytes as f64 / per_cycle).ceil() as u64
    }

    /// A node is shareable across a fused decode batch iff it is
    /// ltoken- and slot-invariant — weight-stationary VMMs and
    /// elementwise ASIC ops. The rule mirrors
    /// `compiler::template::shareable_across_streams`: KV writes, KV
    /// VMMs, Scale/Softmax (score-length-shaped), and PartialSums fed
    /// by a KV VMM stay per-stream.
    fn shareable(program: &Program, i: usize) -> bool {
        match &program.nodes[i].instr {
            Instr::WriteK { .. } | Instr::WriteV { .. } => false,
            Instr::PimVmm { matrix, .. } => !matrix.kind.is_kv_cache(),
            Instr::Asic(op) => match op {
                AsicOp::Scale { .. } | AsicOp::Softmax { .. } => false,
                AsicOp::PartialSum { .. } => {
                    !program.nodes[i].deps.iter().any(|&d| {
                        matches!(&program.nodes[d].instr,
                            Instr::PimVmm { matrix, .. } if matrix.kind.is_kv_cache())
                    })
                }
                _ => true,
            },
        }
    }

    /// Exact cost of device `dev`'s step program at context `ltoken`,
    /// covering `passes` positions (prefill chunk; 1 = decode) for a
    /// fused batch of `batch` streams: compile the device graph, walk
    /// it on scratch `Resources` through the same `issue` path as the
    /// cycle-accurate engine, memoize. Slot/page base rows shift
    /// addresses, not uncontended cycle costs, so the scratch walk at
    /// slot 0 is exact for every stream.
    fn step_cost(&mut self, dev: usize, ltoken: u64, passes: u64, batch: u64) -> Result<StepCost> {
        let key = (ltoken, passes, batch);
        if let Some(c) = self.devices[dev].memo.get(&key) {
            return Ok(*c);
        }
        let graph = self.partition.device_graph(dev, ltoken - 1);
        let program = compile(&graph, &self.cfg)?;
        let cost = {
            let d = &self.devices[dev];
            let ctx = IssueCtx {
                cfg: &self.cfg,
                t: &self.t,
                model: &d.model_view,
                mapping: &d.mapping,
            };
            let mut res = Resources::new(&self.cfg);
            let mut plan = empty_plan(&self.cfg);
            let n = program.nodes.len();
            let mut finish: Vec<u64> = Vec::with_capacity(n);
            let mut first_ready: Vec<u64> = Vec::with_capacity(n);
            let mut instructions = 0u64;
            let mut step_finish = 0u64;
            let pos = ltoken - 1;
            // Paged mappings address KV through a page table; frame
            // identity shifts addresses, not uncontended cycle costs,
            // so the identity table covering `ltoken` is exact.
            let table: Option<Vec<u32>> = self
                .page_tokens
                .map(|p| (0..crate::util::ceil_div(ltoken, p) as u32).collect());
            let pages = table.as_deref();
            for i in 0..n {
                let node = &program.nodes[i];
                let fused = batch > 1 && Self::shareable(&program, i);
                let (node_finish, node_first) = if fused {
                    // One multi-pass weight sweep shared by the batch.
                    let out = res.issue(
                        &ctx,
                        &mut plan,
                        &node.instr,
                        &node.deps,
                        0,
                        &finish,
                        &first_ready,
                        pos,
                        ltoken,
                        passes * batch,
                        pages,
                    );
                    instructions += 1;
                    (out.finish, out.first_ready)
                } else {
                    // Per-stream nodes run once per batch member,
                    // serializing on the hardware they contend for.
                    let reps = batch.max(1);
                    let mut fin = 0u64;
                    let mut first = u64::MAX;
                    for _ in 0..reps {
                        let out = res.issue(
                            &ctx,
                            &mut plan,
                            &node.instr,
                            &node.deps,
                            0,
                            &finish,
                            &first_ready,
                            pos,
                            ltoken,
                            passes,
                            pages,
                        );
                        fin = fin.max(out.finish);
                        first = first.min(out.first_ready);
                        instructions += 1;
                    }
                    (fin, first)
                };
                finish.push(node_finish);
                first_ready.push(node_first);
                step_finish = step_finish.max(node_finish);
            }
            StepCost { cycles: step_finish, instructions }
        };
        self.devices[dev].memo.insert(key, cost);
        Ok(cost)
    }

    /// Index of an active stream by id (fleet sets are small — a scan
    /// keeps every reference stable across evictions, which remove
    /// from `active` and would invalidate raw indices).
    fn idx_of(&self, id: u64) -> usize {
        self.active.iter().position(|s| s.spec.id == id).expect("stream is active")
    }

    /// Grow stream `id`'s page tables to cover `ltoken`, faulting and
    /// evicting (policy victim, modeled writeback) when the free list
    /// runs dry. `protected` streams are never victims — they are
    /// about to run. Honors the `kv_evict_watermark` early-evict.
    fn grow_frames(&mut self, id: u64, ltoken: u64, protected: &[u64]) {
        if self.page_tokens.is_none() {
            return;
        }
        let wm = self.cfg.sched.kv_evict_watermark;
        if wm > 0.0 {
            let wm_frames = ((self.pool as f64) * wm).floor() as usize;
            while wm_frames > 0
                && self.frames_free < wm_frames
                && self.evict_victim(protected, id)
            {}
        }
        let need = self.frames_for(ltoken);
        while self.active[self.idx_of(id)].frames_held < need {
            if self.frames_free == 0 {
                self.stats.page_faults += 1;
                let at = self.clock;
                self.trace.emit(|| TraceEvent::PageFault { stream: id, at });
                if !self.evict_victim(protected, id) {
                    // Every peer is protected (e.g. the whole active set
                    // fused into this batch): run short — the step cost
                    // depends on `ltoken`, not frame identity, and the
                    // growth retries before the stream's next step.
                    break;
                }
                continue;
            }
            self.frames_free -= 1;
            let idx = self.idx_of(id);
            self.active[idx].frames_held += 1;
        }
        let in_use = (self.pool - self.frames_free) as u64;
        self.stats.peak_pages_in_use = self.stats.peak_pages_in_use.max(in_use);
        self.sample_pages();
    }

    /// Timeline hook: record the current frame occupancy at the engine
    /// clock (no-op in slot mode or unless `sched.trace_window > 0`).
    fn sample_pages(&mut self) {
        if self.page_tokens.is_some() {
            let in_use = (self.pool - self.frames_free) as u64;
            self.trace.pages_sample(self.clock, in_use);
        }
    }

    /// Evict one active stream (not in `protected`) chosen by the pick
    /// policy; returns false if none is evictable. The victim's frames
    /// return to the pool, its KV writes back on every device's
    /// channel buses, and it re-queues ahead of fresh arrivals. `by`
    /// is the growing stream whose allocation forced the eviction
    /// (trace attribution only).
    fn evict_victim(&mut self, protected: &[u64], by: u64) -> bool {
        let candidates: Vec<(usize, IssueCandidate)> = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, s)| !protected.contains(&s.spec.id))
            .map(|(i, s)| {
                (
                    i,
                    IssueCandidate {
                        id: s.spec.id,
                        slot: s.slot,
                        ready: s.ready,
                        remaining_tokens: s.spec.n_tokens - s.pos,
                        served_cycles: s.attributed_cycles,
                    },
                )
            })
            .collect();
        if candidates.is_empty() {
            return false;
        }
        let cands: Vec<IssueCandidate> = candidates.iter().map(|(_, c)| *c).collect();
        let victim = candidates[self.pick.pick_victim(&cands)].0;
        let mut s = self.active.remove(victim);
        self.frames_free += s.frames_held;
        s.frames_held = 0;
        self.stats.preemptions += 1;
        self.stats.evicted_tokens += s.pos;
        let wb_start = self.clock;
        let mut wb_done = wb_start;
        for dev in 0..self.devices.len() {
            let wb = self.device_kv_transfer_cycles(dev, s.pos);
            self.devices[dev].free_at = self.devices[dev].free_at.max(self.clock) + wb;
            wb_done = wb_done.max(self.devices[dev].free_at);
        }
        let (vid, vpos) = (s.spec.id, s.pos);
        self.trace.emit(|| TraceEvent::Evict { victim: vid, by, at: wb_start, tokens: vpos });
        self.trace.emit(|| TraceEvent::Writeback {
            stream: vid,
            start: wb_start,
            finish: wb_done,
            tokens: vpos,
        });
        self.sample_pages();
        self.preempted.push(s);
        self.preempted.sort_by_key(|s| (s.ready, s.spec.id));
        true
    }

    /// Execute one step for the streams in `batch` (ids; all at the
    /// same position when fused, singleton otherwise), composing
    /// per-device costs under the partition strategy. Returns the
    /// step's finish.
    fn exec_step(&mut self, batch: &[u64], pos: u64, passes: u64) -> Result<u64> {
        let ltoken = pos + passes;
        let k = batch.len() as u64;
        let ready = batch
            .iter()
            .map(|&id| self.active[self.idx_of(id)].ready)
            .max()
            .unwrap_or(self.clock);
        let n = self.devices.len();
        let mut instructions = 0u64;
        let finish = match self.partition.strategy {
            PartitionStrategy::LayerPipeline => {
                // Stage-serial within the step; per-device free_at lets
                // other streams' steps overlap on earlier stages.
                let mut acts_at = ready;
                let mut fin = ready;
                for dev in 0..n {
                    let cost = self.step_cost(dev, ltoken, passes, k)?;
                    let start = acts_at.max(self.devices[dev].free_at);
                    fin = start + cost.cycles;
                    self.devices[dev].free_at = fin;
                    self.devices[dev].busy_cycles += cost.cycles;
                    instructions += cost.instructions;
                    if dev + 1 < n {
                        let hop = self.partition.stage_hop_cycles(&self.cfg, passes * k);
                        self.link_cycles += hop;
                        let lead = batch[0];
                        self.trace.emit(|| TraceEvent::LinkTransfer {
                            stream: lead,
                            src: dev as u64,
                            dst: (dev + 1) as u64,
                            start: fin,
                            finish: fin + hop,
                        });
                        self.trace.link_cycles(fin, hop);
                        acts_at = fin + hop;
                    }
                }
                fin
            }
            PartitionStrategy::TensorParallel => {
                // Lockstep: every device runs the step; all-reduce and
                // gather link time extends the shared step.
                let start = self
                    .devices
                    .iter()
                    .map(|d| d.free_at)
                    .max()
                    .unwrap_or(0)
                    .max(ready);
                let mut worst = 0u64;
                for dev in 0..n {
                    let cost = self.step_cost(dev, ltoken, passes, k)?;
                    self.devices[dev].busy_cycles += cost.cycles;
                    instructions += cost.instructions;
                    worst = worst.max(cost.cycles);
                }
                let link = self.partition.step_link_cycles(&self.cfg, passes * k);
                self.link_cycles += link;
                let fin = start + worst + link;
                // The all-reduce + gather involves every device; it is
                // rendered as one collective span on device 0's link
                // track (src 0 -> last device).
                let lead = batch[0];
                self.trace.emit(|| TraceEvent::LinkTransfer {
                    stream: lead,
                    src: 0,
                    dst: (n - 1) as u64,
                    start: start + worst,
                    finish: fin,
                });
                self.trace.link_cycles(start + worst, link);
                for d in &mut self.devices {
                    d.free_at = fin;
                }
                fin
            }
        };
        let started = ready;
        // Step span (before member updates advance `pos`): a fused
        // sweep for multi-member batches, a prefill chunk or solo
        // decode step otherwise. Fleet steps span every device; the
        // span is attributed to device 0 (see sim/README.md).
        if self.trace.is_on() {
            let lead = batch[0];
            let in_prefill = {
                let s = &self.active[self.idx_of(lead)];
                s.pos < s.spec.prompt_tokens
            };
            if batch.len() > 1 {
                let ids = batch.to_vec();
                self.trace.emit(move || TraceEvent::FusedSweep {
                    device: 0,
                    start: started,
                    finish,
                    streams: ids,
                });
            } else if in_prefill {
                self.trace.emit(|| TraceEvent::PrefillChunk {
                    stream: lead,
                    device: 0,
                    start: started,
                    finish,
                    pos,
                    positions: passes,
                });
            } else {
                self.trace.emit(|| TraceEvent::DecodeStep {
                    stream: lead,
                    device: 0,
                    start: started,
                    finish,
                    pos,
                });
            }
        }
        for &id in batch {
            let i = self.idx_of(id);
            let s = &mut self.active[i];
            s.pos += passes;
            for _ in 0..passes {
                s.token_finishes.push(finish);
            }
            s.ready = finish;
            s.instructions += instructions / k.max(1);
            s.attributed_cycles += finish - started;
            self.stats.tokens += passes;
        }
        self.stats.instructions += instructions;
        self.clock = self.clock.max(finish);
        Ok(finish)
    }

    /// Retire every batch member that has finished its last position.
    fn retire_finished(&mut self, finish: u64) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].pos < self.active[i].spec.n_tokens {
                i += 1;
                continue;
            }
            let s = self.active.remove(i);
            if self.page_tokens.is_some() {
                self.frames_free += s.frames_held;
                self.admit_frames_left += self.admit_commit(&s.spec);
                self.sample_pages();
            } else {
                self.slot_used[s.slot] = false;
            }
            let (rid, rtok) = (s.spec.id, s.spec.n_tokens);
            let rat = finish.max(*s.token_finishes.last().unwrap_or(&finish));
            self.trace.emit(|| TraceEvent::StreamRetire { stream: rid, at: rat, tokens: rtok });
            let result = StreamResult {
                id: s.spec.id,
                arrival_cycle: s.spec.arrival_cycle,
                admitted_cycle: s.admitted_cycle,
                finish_cycle: finish.max(*s.token_finishes.last().unwrap_or(&finish)),
                tokens: s.spec.n_tokens,
                prompt_tokens: s.spec.prompt_tokens,
                kv_slot: s.slot,
                token_finishes: s.token_finishes,
            };
            self.stats.prefill_cycles += result.prefill_cycles();
            self.stats.decode_cycles += result.decode_cycles();
            self.stats
                .streams
                .push(StreamStats::from_result(&result, s.instructions, s.attributed_cycles));
            self.outcomes.push(StreamOutcome::Completed(result));
        }
    }

    fn run_all(&mut self) -> Result<Vec<StreamOutcome>> {
        loop {
            self.admit();
            if self.active.is_empty() {
                if self.queued.is_empty() && self.preempted.is_empty() {
                    break;
                }
                // Idle: warp to the next arrival (or resume point).
                let next = self
                    .queued
                    .iter()
                    .map(|s| s.arrival_cycle)
                    .chain(self.preempted.iter().map(|s| s.ready))
                    .min()
                    .expect("non-empty");
                let next = next.max(self.clock + 1);
                self.stats.idle_cycles += next - self.clock;
                self.trace.idle_span(self.clock, next);
                self.clock = next;
                continue;
            }
            // Earliest-ready stream (ties by id) leads the step.
            let lead = self
                .active
                .iter()
                .min_by_key(|s| (s.ready, s.spec.id))
                .expect("non-empty active set");
            let lead_id = lead.spec.id;
            let lead_ready = lead.ready;
            let pos = lead.pos;
            let in_prefill = pos < lead.spec.prompt_tokens;
            let passes = if in_prefill {
                prefill::chunk_at(pos, lead.spec.prompt_tokens, self.cfg.sched.prefill_chunk)
                    .map(|c| c.len)
                    .unwrap_or(1)
            } else {
                1
            };
            // Fuse same-position decode partners that are already ready
            // (iteration-level batching: batches form per sweep).
            let mut batch = vec![lead_id];
            if !in_prefill && self.cfg.sched.batch_decode {
                for p in &self.active {
                    if p.spec.id != lead_id
                        && p.pos == pos
                        && p.pos >= p.spec.prompt_tokens
                        && p.ready <= lead_ready
                    {
                        batch.push(p.spec.id);
                    }
                }
                batch.sort_unstable();
            }
            if in_prefill {
                self.stats.prefill_chunks += 1;
            } else if batch.len() > 1 {
                self.stats.fused_sweeps += 1;
                self.stats.fused_streams += batch.len() as u64;
                self.stats.max_decode_batch =
                    self.stats.max_decode_batch.max(batch.len() as u64);
            } else {
                self.stats.solo_decode_steps += 1;
            }
            for &id in &batch {
                self.grow_frames(id, pos + passes, &batch);
            }
            let finish = self.exec_step(&batch, pos, passes)?;
            self.retire_finished(finish);
        }
        Ok(std::mem::take(&mut self.outcomes))
    }

    fn finalize_stats(&mut self) -> &SimStats {
        self.stats.cycles = self.clock;
        self.stats.devices = self.partition.devices as u64;
        self.stats.link_transfer_cycles = self.link_cycles;
        self.stats.device_busy_cycles = self.devices.iter().map(|d| d.busy_cycles).collect();
        self.stats.kv_slots = self.pool as u64;
        if self.page_tokens.is_some() {
            self.stats.kv_pages = self.pool as u64;
        }
        self.stats.streams.sort_by_key(|s| s.id);
        self.stats.timeline = self.trace.finish_timeline(self.clock);
        // Same strict-reconcile contract as `MultiSim::finalize_stats`.
        match self.trace.reconcile(&self.stats) {
            Err(e) if self.cfg.sched.strict_reconcile => {
                self.stats.reconcile_error = Some(e);
            }
            #[cfg(debug_assertions)]
            Err(e) => panic!("fleet trace reconciliation failed: {e}"),
            _ => {}
        }
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn fleet_cfg(devices: usize, strategy: PartitionStrategy) -> HwConfig {
        HwConfig::paper_baseline().with_devices(devices).with_partition(strategy)
    }

    #[test]
    fn single_device_delegates_to_multisim() {
        let m = by_name("gpt-nano").unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut fleet = FleetSim::new(&m, &cfg).unwrap();
        let mut msim = MultiSim::new(&m, &cfg).unwrap();
        for spec in [StreamSpec::new(0, 3), StreamSpec::new(1, 2)] {
            fleet.submit(spec).unwrap();
            msim.submit(spec).unwrap();
        }
        fleet.run_all().unwrap();
        msim.run_all().unwrap();
        assert_eq!(fleet.clock(), msim.clock());
        assert_eq!(fleet.devices(), 1);
        let fs = fleet.finalize_stats();
        assert_eq!(fs.devices, 1);
        assert_eq!(fs.link_transfer_cycles, 0);
    }

    #[test]
    fn fleet_runs_both_strategies_and_charges_links() {
        let m = by_name("gpt-nano").unwrap(); // 2 layers, 4 heads
        for strategy in [PartitionStrategy::LayerPipeline, PartitionStrategy::TensorParallel] {
            let cfg = fleet_cfg(2, strategy);
            let mut fleet = FleetSim::new(&m, &cfg).unwrap();
            assert_eq!(fleet.devices(), 2);
            fleet.submit(StreamSpec::with_prompt(0, 4, 3)).unwrap();
            fleet.submit(StreamSpec::new(1, 2)).unwrap();
            let outcomes = fleet.run_all().unwrap();
            assert_eq!(outcomes.len(), 2);
            for o in &outcomes {
                let r = o.as_completed().expect("no shedding in the fleet path");
                assert_eq!(r.token_finishes.len() as u64, r.tokens);
                assert!(r.finish_cycle > 0);
            }
            let stats = fleet.finalize_stats();
            assert_eq!(stats.devices, 2);
            assert!(stats.link_transfer_cycles > 0, "{strategy}: links never charged");
            assert_eq!(stats.device_busy_cycles.len(), 2);
            assert!(stats.device_busy_cycles.iter().all(|&b| b > 0), "{strategy}");
            assert_eq!(stats.tokens, 7 + 2);
            assert!(stats.latency_report().is_some());
        }
    }

    #[test]
    fn pipeline_stages_overlap_across_streams() {
        // Two streams through a 2-stage pipeline must finish sooner
        // than strictly serializing both streams' full steps would
        // (device 0 starts stream 1 while device 1 still runs stream
        // 0), and decode is deterministic.
        let m = by_name("gpt-nano").unwrap();
        let cfg = fleet_cfg(2, PartitionStrategy::LayerPipeline);
        let run = |n_streams: u64| {
            let mut fleet = FleetSim::new(&m, &cfg).unwrap();
            for id in 0..n_streams {
                fleet.submit(StreamSpec::new(id, 4)).unwrap();
            }
            fleet.run_all().unwrap();
            fleet.clock()
        };
        let one = run(1);
        let two = run(2);
        assert_eq!(run(2), two, "deterministic");
        assert!(two < 2 * one, "no cross-stream stage overlap: {two} vs 2x{one}");
    }

    #[test]
    fn tensor_parallel_two_devices_beat_one_on_decode() {
        // The acceptance-criteria mechanism at unit scale: TP halves
        // per-device compute; with the default link budget the step
        // gets strictly faster 1 -> 2 devices. (gpt2-xl's 25 heads
        // don't shard — covered by the partition-pass rejection tests.)
        let m = by_name("gpt3-xl").unwrap(); // 24 heads, d=2048
        let decode_clock = |devices: usize| {
            let cfg = fleet_cfg(devices, PartitionStrategy::TensorParallel);
            let mut fleet = FleetSim::new(&m, &cfg).unwrap();
            fleet.submit(StreamSpec::new(0, 4)).unwrap();
            fleet.run_all().unwrap();
            fleet.clock()
        };
        let one = decode_clock(1);
        let two = decode_clock(2);
        assert!(two < one, "TP 1->2 regressed: {two} !< {one}");
    }

    #[test]
    fn fleet_batched_decode_fuses_and_paging_survives_pressure() {
        let m = by_name("gpt-mini").unwrap();
        let mut cfg = fleet_cfg(2, PartitionStrategy::LayerPipeline);
        cfg = cfg.with_max_streams(4).with_batch_decode(true);
        let mut fleet = FleetSim::new(&m, &cfg).unwrap();
        for id in 0..4 {
            fleet.submit(StreamSpec::new(id, 6)).unwrap();
        }
        fleet.run_all().unwrap();
        let stats = fleet.finalize_stats();
        assert!(stats.fused_sweeps > 0, "same-position decode streams must fuse");
        assert!(stats.max_decode_batch >= 2);
        // Paged mode on the same workload completes and reports pages.
        let cfg = cfg.with_kv_paging(true);
        let mut fleet = FleetSim::new(&m, &cfg).unwrap();
        for id in 0..4 {
            fleet.submit(StreamSpec::new(id, 6)).unwrap();
        }
        let outcomes = fleet.run_all().unwrap();
        assert_eq!(outcomes.len(), 4);
        let stats = fleet.finalize_stats();
        assert!(stats.kv_pages > 0);
    }
}
