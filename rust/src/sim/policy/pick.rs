//! Pick policies: which queued/active stream gets the next free
//! engine or KV slot.
//!
//! All three implementations are stateless: their decisions are pure
//! functions of the candidate lists, and ties always break by explicit
//! `(key, index)` ordering — see the determinism rules in the module
//! docs of `super`.

use super::{IssueCandidate, PickPolicy};
use crate::sim::sched::StreamSpec;

/// First-come-first-served: admit in arrival order, issue the stream
/// whose next instruction has the earliest dependency-ready time (ties
/// toward the earliest-admitted stream). This is the engine's
/// historical inline logic, extracted — with `fcfs` configured, runs
/// stay cycle-identical to the pre-policy scheduler.
pub struct Fcfs;

impl PickPolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn pick_admission(&mut self, _queue: &[StreamSpec]) -> usize {
        0
    }

    fn pick_issue(&mut self, candidates: &[IssueCandidate]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.ready, *i))
            .map(|(i, _)| i)
            .expect("pick_issue called with candidates")
    }
}

/// Shortest-remaining-first: the classic mean-latency optimization.
/// Admission prefers the queued request with the fewest total tokens;
/// issue prefers the active stream with the fewest remaining tokens
/// (ties by dependency-ready time, then admission order). Long requests
/// can starve under sustained short-request load — that is the policy's
/// documented trade-off, not a bug.
pub struct ShortestRemainingFirst;

impl PickPolicy for ShortestRemainingFirst {
    fn name(&self) -> &'static str {
        "srf"
    }

    fn pick_admission(&mut self, queue: &[StreamSpec]) -> usize {
        queue
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.n_tokens, *i))
            .map(|(i, _)| i)
            .expect("pick_admission called with a queue")
    }

    fn pick_issue(&mut self, candidates: &[IssueCandidate]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.remaining_tokens, c.ready, *i))
            .map(|(i, _)| i)
            .expect("pick_issue called with candidates")
    }

    /// Evict the stream with the *most* remaining tokens: it holds its
    /// KV frames the longest and is the least likely to finish soon, so
    /// preempting it frees capacity for the short work SRF favors (the
    /// preemption mirror of shortest-remaining-first issue). Ties break
    /// toward the latest-admitted candidate, matching the default rule.
    fn pick_victim(&mut self, candidates: &[IssueCandidate]) -> usize {
        candidates
            .iter()
            .enumerate()
            .max_by_key(|(i, c)| (c.remaining_tokens, *i))
            .map(|(i, _)| i)
            .expect("pick_victim called with candidates")
    }
}

/// Deficit round-robin over stream slots: every issue goes to the
/// active stream that has received the least attributed service so far
/// (its deficit versus the most-served stream is maximal), with ties by
/// dependency-ready time then admission order. Admission stays FCFS —
/// fairness is enforced at issue granularity, where the service is
/// actually handed out. Under identical-length streams this bounds the
/// spread of per-stream service cycles; under mixed loads it trades
/// some makespan for that bound.
pub struct FairShare;

impl PickPolicy for FairShare {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn pick_admission(&mut self, _queue: &[StreamSpec]) -> usize {
        0
    }

    fn pick_issue(&mut self, candidates: &[IssueCandidate]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.served_cycles, c.ready, *i))
            .map(|(i, _)| i)
            .expect("pick_issue called with candidates")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(ready: u64, remaining: u64, served: u64) -> IssueCandidate {
        IssueCandidate { id: 0, slot: 0, ready, remaining_tokens: remaining, served_cycles: served }
    }

    fn spec(id: u64, n_tokens: u64) -> StreamSpec {
        StreamSpec { id, n_tokens, prompt_tokens: 1, arrival_cycle: 0 }
    }

    #[test]
    fn fcfs_picks_queue_head_and_earliest_ready() {
        let mut p = Fcfs;
        assert_eq!(p.pick_admission(&[spec(3, 9), spec(4, 1)]), 0);
        // Earliest ready wins; ties break toward the lowest index.
        assert_eq!(p.pick_issue(&[cand(50, 1, 0), cand(10, 9, 0), cand(10, 2, 0)]), 1);
        assert_eq!(p.pick_issue(&[cand(7, 1, 0)]), 0);
    }

    #[test]
    fn srf_prefers_fewest_tokens() {
        let mut p = ShortestRemainingFirst;
        assert_eq!(p.pick_admission(&[spec(0, 9), spec(1, 2), spec(2, 2)]), 1, "tie -> earliest");
        // Remaining tokens dominate readiness...
        assert_eq!(p.pick_issue(&[cand(0, 9, 0), cand(100, 2, 0)]), 1);
        // ...and equal remaining falls back to the FCFS order.
        assert_eq!(p.pick_issue(&[cand(50, 2, 0), cand(10, 2, 0)]), 1);
    }

    #[test]
    fn srf_evicts_the_longest_remaining_stream() {
        let mut srf = ShortestRemainingFirst;
        // Remaining [5, 3, 1]: SRF preempts index 0 (most left to do);
        // the default recompute-last-admitted rule would pick index 2.
        let candidates = [cand(0, 5, 0), cand(0, 3, 0), cand(0, 1, 0)];
        assert_eq!(srf.pick_victim(&candidates), 0);
        let mut fcfs = Fcfs;
        assert_eq!(fcfs.pick_victim(&candidates), 2, "default rule diverges");
        // Equal remaining falls back to the latest-admitted default.
        let tied = [cand(0, 4, 0), cand(0, 4, 0)];
        assert_eq!(srf.pick_victim(&tied), 1);
    }

    #[test]
    fn fair_share_serves_the_most_deficient_stream() {
        let mut p = FairShare;
        assert_eq!(p.pick_admission(&[spec(0, 4), spec(1, 1)]), 0, "admission stays FCFS");
        assert_eq!(p.pick_issue(&[cand(0, 1, 500), cand(90, 9, 20)]), 1);
        // Equal service falls back to earliest-ready, then index.
        assert_eq!(p.pick_issue(&[cand(30, 1, 100), cand(20, 1, 100)]), 1);
        assert_eq!(p.pick_issue(&[cand(30, 1, 100), cand(30, 1, 100)]), 0);
    }
}
