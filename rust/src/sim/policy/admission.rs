//! Admission policies: whether a picked request is admitted at all.

use super::{AdmissionDecision, AdmissionPolicy};
use crate::sim::profile::CostTable;
use crate::sim::sched::StreamSpec;

/// Admit every request the moment a KV slot is free — the engine's
/// historical behavior (pure capacity-based admission).
pub struct AdmitAlways;

impl AdmissionPolicy for AdmitAlways {
    fn name(&self) -> &'static str {
        "admit-always"
    }

    fn decide(
        &mut self,
        _spec: &StreamSpec,
        _wait_cycles: u64,
        _first_token_est_cycles: u64,
    ) -> AdmissionDecision {
        AdmissionDecision::Admit
    }
}

/// SLO-aware admission: shed a request whose predicted TTFT — queue
/// wait so far plus the engine's conservative uncontended
/// first-*generated*-token cost (the chunked-prefill replay of the
/// request's actual prompt length, see
/// `MultiSim::first_token_estimate` / `sim::prefill`) — already
/// exceeds the configured budget.
///
/// The predictor is monotone in waiting time, so there is no point
/// deferring a busted request in the hope it improves: the reject
/// happens the first time a slot would have been available for it.
/// The first-token estimate is an *uncontended* (single active stream)
/// upper bound; with several concurrent streams the realized TTFT of an
/// admitted request can still exceed the budget through cross-stream
/// resource contention — the SLO is exact at effective K = 1 and
/// best-effort above it.
///
/// **Batch awareness.** With fused decode batching on
/// (`sched.batch_decode`), the uncontended bound is systematically
/// pessimistic: the weight-sweep cost it charges per stream is in fact
/// amortized over every stream fused into the sweep. The engine
/// corrects for this *before* calling `decide` — it divides the raw
/// estimate by the observed mean decode-batch occupancy
/// (`SimStats::mean_decode_batch`, floored at 1.0), so a warm serving
/// run that demonstrably fuses B streams per sweep sheds as if each
/// request cost 1/B of the solo sweep. The policy itself stays a pure
/// threshold on `wait + est`; the amortization is the engine's estimate
/// refinement, not a policy knob.
///
/// **Calibrated estimates.** When a trace-calibrated
/// `sim::profile::CostTable` is installed (`MultiSim::set_cost_table`),
/// the policy supplies `CostTable::predict`'s first-token cycles via
/// `first_token_override` instead of the engine's replay — same
/// occupancy amortization applies on top, but the base estimate now
/// reflects measured span costs rather than the conservative bound.
pub struct SloAdmission {
    /// TTFT budget in DRAM cycles (`sched.slo_ttft_cycles`,
    /// `--policy slo:<cycles>`).
    pub ttft_budget_cycles: u64,
    /// Optional calibrated per-span cost table (`pim-gpt profile
    /// --calibrate` is the producer).
    pub cost_table: Option<CostTable>,
}

impl AdmissionPolicy for SloAdmission {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn needs_estimate(&self) -> bool {
        true
    }

    fn decide(
        &mut self,
        _spec: &StreamSpec,
        wait_cycles: u64,
        first_token_est_cycles: u64,
    ) -> AdmissionDecision {
        let predicted = wait_cycles.saturating_add(first_token_est_cycles);
        if predicted > self.ttft_budget_cycles {
            AdmissionDecision::Reject {
                predicted_ttft_cycles: predicted,
                ttft_budget_cycles: self.ttft_budget_cycles,
            }
        } else {
            AdmissionDecision::Admit
        }
    }

    fn first_token_override(&self, spec: &StreamSpec) -> Option<u64> {
        let table = self.cost_table.as_ref()?;
        if table.is_empty() {
            return None;
        }
        Some(table.predict(spec)?.first_token_cycles())
    }

    fn install_cost_table(&mut self, table: CostTable) {
        self.cost_table = Some(table);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> StreamSpec {
        StreamSpec { id: 0, n_tokens: 4, prompt_tokens: 1, arrival_cycle: 0 }
    }

    #[test]
    fn admit_always_admits() {
        let mut p = AdmitAlways;
        assert!(!p.needs_estimate());
        assert_eq!(p.decide(&spec(), u64::MAX, 0), AdmissionDecision::Admit);
    }

    #[test]
    fn slo_rejects_exactly_past_the_budget() {
        let mut p = SloAdmission { ttft_budget_cycles: 1_000, cost_table: None };
        assert!(p.first_token_override(&spec()).is_none(), "no table installed");
        assert!(p.needs_estimate());
        // On-budget (wait + est == budget) still admits.
        assert_eq!(p.decide(&spec(), 400, 600), AdmissionDecision::Admit);
        assert_eq!(
            p.decide(&spec(), 401, 600),
            AdmissionDecision::Reject { predicted_ttft_cycles: 1_001, ttft_budget_cycles: 1_000 }
        );
        // Saturating prediction: an absurd wait cannot wrap around.
        assert_eq!(
            p.decide(&spec(), u64::MAX, 600),
            AdmissionDecision::Reject { predicted_ttft_cycles: u64::MAX, ttft_budget_cycles: 1_000 }
        );
    }
}
