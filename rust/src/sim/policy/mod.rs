//! Pluggable scheduling policies for the multi-stream engine.
//!
//! `MultiSim` makes exactly two kinds of scheduling decisions, and this
//! subsystem owns both:
//!
//! * **Picking** (`PickPolicy`) — *which* request runs next. The trait
//!   covers the two pick points of the engine: which *queued* (arrived,
//!   KV-blocked) request gets the next free KV slot
//!   (`pick_admission`), and which *active* stream issues its next
//!   instruction on the shared hardware (`pick_issue`). Implementations:
//!   `Fcfs` (the engine's historical behavior, extracted verbatim),
//!   `ShortestRemainingFirst` (fewest remaining tokens first) and
//!   `FairShare` (deficit round-robin: every issue goes to the stream
//!   that has received the least attributed service so far).
//! * **Admission control** (`AdmissionPolicy`) — *whether* a picked
//!   request runs at all. `AdmitAlways` reproduces the historical
//!   behavior; `SloAdmission` sheds load by rejecting a request whose
//!   predicted TTFT (queue wait so far + a conservative uncontended
//!   first-token cost derived from the compiled regime-0 program
//!   template) would exceed a configured budget. Rejected requests are
//!   first-class `StreamOutcome::Rejected` results, not errors.
//!
//! **Determinism rules.** The engine is seed-deterministic and policies
//! must keep it that way: a policy may hold internal state, but every
//! decision must be a pure function of the inputs it is handed plus
//! that state — no wall clock, no OS randomness, no hashing with
//! nondeterministic iteration order. Every built-in policy breaks ties
//! by explicit `(key, index)` ordering so equal keys can never produce
//! run-to-run divergence.
//!
//! **Equivalence contract.** With `sched.policy = fcfs` (the default)
//! the engine must stay cycle-identical to the pre-policy scheduler:
//! `Fcfs::pick_admission` returns the queue head and `Fcfs::pick_issue`
//! returns the earliest-dependency-ready stream (ties toward the
//! earliest-admitted), which is exactly the inline logic this subsystem
//! replaced. The pinned K=1 / batch-at-zero equivalence tests in
//! `tests/integration_sched.rs` enforce it.

mod admission;
mod pick;

pub use admission::{AdmitAlways, SloAdmission};
pub use pick::{FairShare, Fcfs, ShortestRemainingFirst};

use std::fmt;

use super::sched::StreamSpec;
use crate::config::SchedulerConfig;
use anyhow::{bail, ensure, Result};

/// Config-level policy selector (`sched.policy`, `--policy`).
///
/// `Slo` keeps FCFS picking and adds SLO admission control; its TTFT
/// budget lives in `SchedulerConfig::slo_ttft_cycles`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PolicySpec {
    /// First-come-first-served picking, admit always (the default — the
    /// engine's historical behavior).
    #[default]
    Fcfs,
    /// Shortest-remaining-first picking, admit always.
    Srf,
    /// Fair-share (deficit round-robin) picking, admit always.
    Fair,
    /// FCFS picking with SLO-aware admission control.
    Slo,
}

impl PolicySpec {
    /// Parse `fcfs | srf | fair | slo[:<ttft-cycles>]`. For
    /// `slo:<cycles>` the second return value carries the explicit TTFT
    /// budget override (in DRAM cycles); bare `slo` keeps the
    /// configured `sched.slo_ttft_cycles`.
    pub fn parse(s: &str) -> Result<(Self, Option<u64>)> {
        match s {
            "fcfs" => return Ok((Self::Fcfs, None)),
            "srf" => return Ok((Self::Srf, None)),
            "fair" => return Ok((Self::Fair, None)),
            "slo" => return Ok((Self::Slo, None)),
            _ => {}
        }
        if let Some(v) = s.strip_prefix("slo:") {
            let Ok(cycles) = v.parse::<u64>() else {
                bail!("slo:<ttft-cycles> needs an integer cycle budget, got '{v}'");
            };
            ensure!(cycles > 0, "slo TTFT budget must be >= 1 cycle");
            return Ok((Self::Slo, Some(cycles)));
        }
        bail!("unknown policy '{s}' (fcfs | srf | fair | slo[:<ttft-cycles>])")
    }
}

impl fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Fcfs => write!(f, "fcfs"),
            Self::Srf => write!(f, "srf"),
            Self::Fair => write!(f, "fair"),
            Self::Slo => write!(f, "slo"),
        }
    }
}

/// One active stream as the issue pick sees it. The engine rebuilds the
/// candidate list before every issue; indices into it are positions in
/// the engine's admission-ordered active list. Under batched decode a
/// fused batch contributes a *single* candidate (its lead member's id
/// and slot, the batch-wide ready/remaining/served aggregates) and its
/// members contribute none — the pick chooses between whole batches and
/// solo streams, never inside a batch.
#[derive(Clone, Copy, Debug)]
pub struct IssueCandidate {
    /// Request id (diagnostics; not a tie-breaker — ids are
    /// caller-chosen and need not be unique-ordered).
    pub id: u64,
    /// KV slot the stream occupies.
    pub slot: usize,
    /// Dependency-ready cycle of the stream's next instruction.
    pub ready: u64,
    /// Tokens the stream still has to produce (>= 1 while active).
    pub remaining_tokens: u64,
    /// Attributed service cycles the stream has received so far (the
    /// fair-share deficit key).
    pub served_cycles: u64,
}

/// Which queued/active stream gets the next free engine or KV slot.
///
/// Both methods are only called with non-empty inputs and must return
/// an in-range index (the engine asserts it). See the module docs for
/// the determinism rules implementations must follow.
pub trait PickPolicy {
    /// Short name for reports and metrics.
    fn name(&self) -> &'static str;

    /// Index into `queue` (arrived requests in arrival order) of the
    /// request to admit into the next free KV slot.
    fn pick_admission(&mut self, queue: &[StreamSpec]) -> usize;

    /// Index into `candidates` (active streams in admission order) of
    /// the stream whose next instruction issues now.
    fn pick_issue(&mut self, candidates: &[IssueCandidate]) -> usize;

    /// Index into `candidates` of the stream to *preempt* when the
    /// paged KV frame pool is exhausted (`sched.kv_paging`). The list
    /// is the faulting step's eviction candidates in admission order —
    /// never the faulting stream itself — and is always non-empty.
    ///
    /// The default picks the last (latest-admitted) candidate: evicting
    /// the newest stream preserves FCFS seniority and wastes the least
    /// restored context, the classic recompute-last-admitted rule.
    /// Overrides must follow the module determinism rules.
    fn pick_victim(&mut self, candidates: &[IssueCandidate]) -> usize {
        candidates.len() - 1
    }
}

/// Outcome of an admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    /// Shed the request (a first-class `StreamOutcome::Rejected`, not
    /// an error). Carries the prediction that triggered the rejection.
    Reject { predicted_ttft_cycles: u64, ttft_budget_cycles: u64 },
}

/// Whether a picked request is admitted at all.
///
/// `decide` runs at the moment a free KV slot is available for the
/// request: `wait_cycles` is the queue delay its admission stamp would
/// record, and `first_token_est_cycles` is the engine's conservative
/// uncontended first-*generated*-token cost — the chunked-prefill
/// replay of the request's *actual* prompt length
/// (`sim::prefill::isolated_prefill_cost` + warm-start padding), so
/// long prompts predict proportionally higher TTFT than short ones
/// (only computed when `needs_estimate` returns true; 0 otherwise).
pub trait AdmissionPolicy {
    /// Short name for reports and metrics.
    fn name(&self) -> &'static str;

    /// Whether `decide` wants the first-token cost estimate (computing
    /// it replays the regime-0 template once per engine, so policies
    /// that ignore it should leave this false).
    fn needs_estimate(&self) -> bool {
        false
    }

    /// Admit or reject `spec` at its prospective admission point.
    fn decide(
        &mut self,
        spec: &StreamSpec,
        wait_cycles: u64,
        first_token_est_cycles: u64,
    ) -> AdmissionDecision;

    /// Policy-supplied first-token estimate that replaces the engine's
    /// uncontended replay when `Some` (e.g. `SloAdmission` with a
    /// calibrated `CostTable` installed). The engine still applies its
    /// batch-occupancy amortization on top.
    fn first_token_override(&self, _spec: &StreamSpec) -> Option<u64> {
        None
    }

    /// Install a calibrated cost table (`sim::profile::CostTable`).
    /// Policies that don't price admission ignore it.
    fn install_cost_table(&mut self, _table: crate::sim::profile::CostTable) {}
}

/// Instantiate the pick + admission policy pair configured in `sched`.
pub fn build(sched: &SchedulerConfig) -> (Box<dyn PickPolicy>, Box<dyn AdmissionPolicy>) {
    let pick: Box<dyn PickPolicy> = match sched.policy {
        PolicySpec::Fcfs | PolicySpec::Slo => Box::new(Fcfs),
        PolicySpec::Srf => Box::new(ShortestRemainingFirst),
        PolicySpec::Fair => Box::new(FairShare),
    };
    let admission: Box<dyn AdmissionPolicy> = match sched.policy {
        PolicySpec::Slo => Box::new(SloAdmission {
            ttft_budget_cycles: sched.slo_ttft_cycles,
            cost_table: None,
        }),
        _ => Box::new(AdmitAlways),
    };
    (pick, admission)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_named_policies() {
        assert_eq!(PolicySpec::parse("fcfs").unwrap(), (PolicySpec::Fcfs, None));
        assert_eq!(PolicySpec::parse("srf").unwrap(), (PolicySpec::Srf, None));
        assert_eq!(PolicySpec::parse("fair").unwrap(), (PolicySpec::Fair, None));
        assert_eq!(PolicySpec::parse("slo").unwrap(), (PolicySpec::Slo, None));
        assert_eq!(PolicySpec::parse("slo:2000000").unwrap(), (PolicySpec::Slo, Some(2_000_000)));
    }

    #[test]
    fn parse_rejects_malformed_policies() {
        for bad in ["", "fifo", "FCFS", "srf:3", "slo:", "slo:0", "slo:-4", "slo:1.5", "sl0"] {
            assert!(PolicySpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn display_roundtrips_bare_names() {
        for s in ["fcfs", "srf", "fair", "slo"] {
            let (p, _) = PolicySpec::parse(s).unwrap();
            assert_eq!(p.to_string(), s);
        }
        assert_eq!(PolicySpec::default(), PolicySpec::Fcfs);
    }

    #[test]
    fn build_matches_spec() {
        let mut sched = SchedulerConfig::default();
        let (pick, adm) = build(&sched);
        assert_eq!((pick.name(), adm.name()), ("fcfs", "admit-always"));
        sched.policy = PolicySpec::Srf;
        assert_eq!(build(&sched).0.name(), "srf");
        sched.policy = PolicySpec::Fair;
        assert_eq!(build(&sched).0.name(), "fair");
        sched.policy = PolicySpec::Slo;
        let (pick, adm) = build(&sched);
        // SLO is an admission policy on top of FCFS picking.
        assert_eq!((pick.name(), adm.name()), ("fcfs", "slo"));
        assert!(adm.needs_estimate());
    }

    #[test]
    fn default_victim_is_latest_admitted() {
        // Every built-in policy inherits the recompute-last-admitted
        // default: the final candidate (admission order) is evicted.
        let cand = |id: u64| IssueCandidate {
            id,
            slot: id as usize,
            ready: 100 - id, // deliberately anti-correlated with order
            remaining_tokens: id + 1,
            served_cycles: id * 10,
        };
        let candidates: Vec<IssueCandidate> = (0..3).map(cand).collect();
        let mut sched = SchedulerConfig::default();
        // SRF overrides the victim rule (most-remaining-first) — see
        // `pick::tests::srf_evicts_the_longest_remaining_stream`.
        for spec in [PolicySpec::Fcfs, PolicySpec::Fair, PolicySpec::Slo] {
            sched.policy = spec;
            let (mut pick, _) = build(&sched);
            assert_eq!(pick.pick_victim(&candidates), 2, "{spec}");
            assert_eq!(pick.pick_victim(&candidates[..1]), 0, "{spec}");
        }
        sched.policy = PolicySpec::Srf;
        let (mut pick, _) = build(&sched);
        assert_eq!(pick.pick_victim(&candidates), 2, "remaining grows with id here");
        assert_eq!(pick.pick_victim(&candidates[..1]), 0);
    }
}
