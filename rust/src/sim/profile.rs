//! Trace-driven profiler: hierarchical cycle attribution, span-latency
//! histograms, and a calibrated per-span cost table.
//!
//! [`ProfileSink`] is a [`TraceSink`] observer: the engines feed it the
//! same `TraceEvent` stream the JSONL/Chrome sinks see (online, no
//! round-trip), and [`Profile::from_jsonl`] replays a recorded
//! `jsonl:` artifact through the identical path. From the spans it
//! builds:
//!
//! * **Cycle attribution** — a tree keyed phase (prefill / solo-decode
//!   / fused-sweep / writeback / restore) × position regime
//!   (gb-resident vs av-chunked) × decode-batch occupancy × device.
//!   Concurrent streams overlap, so naive span summing over-counts;
//!   instead the sweep partitions the *union* of compute spans into
//!   elementary intervals and charges each busy interval to exactly one
//!   covering span (highest-priority phase, then earliest start, then
//!   lowest stream id). Uncovered busy cycles land in an explicit
//!   residual leaf, so leaf sums + residual equal
//!   `SimStats::busy_cycles` cycle-for-cycle by construction. Link
//!   cycles are a separate additive axis keyed `(src, dst)`: the fleet
//!   engine emits one `link_transfer` span per charged hop, so the
//!   span-duration sum must equal `SimStats::link_transfer_cycles`
//!   exactly.
//! * **Latency histograms** — log₂-bucketed span durations with exact
//!   nearest-rank p50/p95/p99 per span class.
//! * **A [`CostTable`]** — per-span costs keyed (regime, passes,
//!   occupancy) with exact per-`ltoken` samples plus a least-squares
//!   linear fall-back, a `predict(StreamSpec)` replay, and a
//!   [`calibrate`] cross-validation mode that pins the predictor's
//!   per-request e2e error against the cycle-accurate engine. This is
//!   the calibration source the ROADMAP metasim item names, and
//!   `SloAdmission` consumes it as an optional first-token estimate.
//!
//! Like every sink, the profiler is a pure observer: profiling on must
//! not move a single simulated cycle (pinned by
//! `tests/integration_profile.rs`).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::prefill;
use super::sched::{MultiSim, StreamOutcome, StreamSpec};
use super::trace::{TraceEvent, TraceSink, TraceSpec};
use crate::config::HwConfig;
use crate::model::GptModel;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// Parsed `sched.profile` spec: `off`, `text:<path>` or `json:<path>`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum ProfileSpec {
    #[default]
    Off,
    Text(String),
    Json(String),
}

impl ProfileSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(ProfileSpec::Off);
        }
        if let Some(path) = s.strip_prefix("text:") {
            if path.is_empty() {
                bail!("profile spec 'text:' needs a path, e.g. text:profile.txt");
            }
            return Ok(ProfileSpec::Text(path.to_string()));
        }
        if let Some(path) = s.strip_prefix("json:") {
            if path.is_empty() {
                bail!("profile spec 'json:' needs a path, e.g. json:profile.json");
            }
            return Ok(ProfileSpec::Json(path.to_string()));
        }
        bail!("unknown profile spec '{s}' (expected off, text:<path> or json:<path>)");
    }

    pub fn is_on(&self) -> bool {
        !matches!(self, ProfileSpec::Off)
    }

    /// Artifact path, when profiling is on.
    pub fn path(&self) -> Option<&str> {
        match self {
            ProfileSpec::Off => None,
            ProfileSpec::Text(p) | ProfileSpec::Json(p) => Some(p),
        }
    }
}

impl fmt::Display for ProfileSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProfileSpec::Off => write!(f, "off"),
            ProfileSpec::Text(p) => write!(f, "text:{p}"),
            ProfileSpec::Json(p) => write!(f, "json:{p}"),
        }
    }
}

/// Attribution phase. Declaration order doubles as the overlap
/// priority: when spans overlap on the clock, the interval is charged
/// to the lowest variant (a fused sweep is the batch-wide work the
/// overlapping members describe per-stream; prefill/decode compute
/// outranks the KV traffic it overlaps).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    FusedSweep,
    Prefill,
    SoloDecode,
    Writeback,
    Restore,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::FusedSweep => "fused-sweep",
            Phase::Prefill => "prefill",
            Phase::SoloDecode => "solo-decode",
            Phase::Writeback => "writeback",
            Phase::Restore => "restore",
        }
    }
}

/// Display name of a position regime (`av_chunked` per
/// `compiler::template::PosRegime`).
pub fn regime_label(av_chunked: bool) -> &'static str {
    if av_chunked {
        "av-chunked"
    } else {
        "gb-resident"
    }
}

/// One leaf key of the attribution tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct AttrKey {
    pub device: u64,
    pub phase: Phase,
    pub av_chunked: bool,
    /// Decode-batch occupancy (1 for everything but fused sweeps).
    pub occupancy: u64,
}

/// One classified compute span, as the attribution sweep and the cost
/// table see it.
#[derive(Clone, Copy, Debug)]
struct SpanRec {
    start: u64,
    finish: u64,
    phase: Phase,
    av_chunked: bool,
    occupancy: u64,
    device: u64,
    /// Tie-break id (lead/lowest member for fused sweeps).
    stream: u64,
    /// Context length the span's KV reads use (the cost-table x value).
    ltoken: u64,
    /// Positions the span advances (chunk length; 1 per decode step; 0
    /// for KV traffic, which never feeds the cost table).
    passes: u64,
}

/// Span-duration histogram: exact samples, log₂ buckets for display.
#[derive(Clone, Debug, Default)]
pub struct Hist {
    samples: Vec<u64>,
}

impl Hist {
    fn add(&mut self, d: u64) {
        self.samples.push(d);
    }

    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    fn sorted(&self) -> Vec<u64> {
        let mut v = self.samples.clone();
        v.sort_unstable();
        v
    }

    fn rank(sorted: &[u64], q: f64) -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let n = sorted.len();
        let idx = ((q * n as f64).ceil() as usize).clamp(1, n);
        sorted[idx - 1]
    }

    /// Exact nearest-rank (p50, p95, p99).
    pub fn percentiles(&self) -> (u64, u64, u64) {
        let s = self.sorted();
        (Self::rank(&s, 0.50), Self::rank(&s, 0.95), Self::rank(&s, 0.99))
    }

    /// Non-empty log₂ buckets as `(lo, hi, count)` with inclusive
    /// bounds: bucket 0 holds duration 0, bucket i holds
    /// `[2^(i-1), 2^i)`.
    pub fn buckets(&self) -> Vec<(u64, u64, u64)> {
        let mut counts: BTreeMap<u32, u64> = BTreeMap::new();
        for &d in &self.samples {
            let b = if d == 0 { 0 } else { 64 - d.leading_zeros() };
            *counts.entry(b).or_insert(0) += 1;
        }
        counts
            .into_iter()
            .map(|(b, c)| {
                if b == 0 {
                    return (0, 0, c);
                }
                let lo = 1u64 << (b - 1);
                let hi = if b >= 64 { u64::MAX } else { (1u64 << b) - 1 };
                (lo, hi, c)
            })
            .collect()
    }
}

/// Least-squares linear model `cycles ≈ a + b·ltoken`.
#[derive(Clone, Copy, Debug)]
pub struct LinFit {
    pub a: f64,
    pub b: f64,
    pub n: u64,
    pub min_x: u64,
    pub max_x: u64,
}

impl LinFit {
    /// Fit over `(ltoken, cycles)` samples (caller guarantees
    /// non-empty). Degenerates to the mean when every x is equal.
    fn fit(samples: &[(u64, u64)]) -> LinFit {
        let n = samples.len() as f64;
        let (mut sx, mut sy, mut sxx, mut sxy) = (0f64, 0f64, 0f64, 0f64);
        let (mut min_x, mut max_x) = (u64::MAX, 0u64);
        for &(x, y) in samples {
            let (xf, yf) = (x as f64, y as f64);
            sx += xf;
            sy += yf;
            sxx += xf * xf;
            sxy += xf * yf;
            min_x = min_x.min(x);
            max_x = max_x.max(x);
        }
        let denom = n * sxx - sx * sx;
        let (a, b) = if denom.abs() < 1e-9 {
            (sy / n, 0.0)
        } else {
            let b = (n * sxy - sx * sy) / denom;
            ((sy - b * sx) / n, b)
        };
        LinFit { a, b, n: samples.len() as u64, min_x, max_x }
    }

    pub fn eval(&self, ltoken: u64) -> f64 {
        (self.a + self.b * ltoken as f64).max(0.0)
    }
}

/// One cost-table entry: exact per-`ltoken` means where the trace
/// observed that context length, the linear fit everywhere else.
#[derive(Clone, Debug)]
pub struct CostEntry {
    pub fit: LinFit,
    /// `ltoken -> mean observed cycles` (uncontended spans are
    /// deterministic per ltoken, so exact lookup beats the fit).
    exact: BTreeMap<u64, u64>,
}

impl CostEntry {
    fn build(samples: &[(u64, u64)]) -> CostEntry {
        let mut acc: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
        for &(x, y) in samples {
            let e = acc.entry(x).or_insert((0, 0));
            e.0 += y;
            e.1 += 1;
        }
        let exact = acc.into_iter().map(|(x, (sum, n))| (x, sum / n.max(1))).collect();
        CostEntry { fit: LinFit::fit(samples), exact }
    }

    pub fn eval(&self, ltoken: u64) -> f64 {
        if let Some(&d) = self.exact.get(&ltoken) {
            return d as f64;
        }
        self.fit.eval(ltoken)
    }
}

/// `(av_chunked, passes, occupancy)` — the per-model cost-table key.
pub type CostKey = (bool, u64, u64);

/// Calibrated per-span cost table extracted from a profile, keyed
/// (model, regime, chunk/passes, occupancy). `predict` replays a
/// request's deterministic chunk/step schedule against the table.
#[derive(Clone, Debug)]
pub struct CostTable {
    pub model: String,
    /// Prefill chunk size the prediction replay uses
    /// (`sched.prefill_chunk` of the profiled run).
    pub chunk: u64,
    /// Largest gb-resident context length (`gb_elems / n_head`);
    /// ltokens above it are av-chunked.
    pub regime_boundary: u64,
    pub entries: BTreeMap<CostKey, CostEntry>,
}

impl CostTable {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn av_chunked(&self, ltoken: u64) -> bool {
        ltoken > self.regime_boundary
    }

    /// Cost of one span. Exact key first; otherwise the nearest key
    /// (same occupancy, then same regime, then closest passes) scaled
    /// by the passes ratio — chunk cost is one pass per position.
    fn span_cost(&self, av: bool, passes: u64, occupancy: u64, ltoken: u64) -> Option<f64> {
        if let Some(e) = self.entries.get(&(av, passes, occupancy)) {
            return Some(e.eval(ltoken));
        }
        let mut best: Option<((u64, u64, u64, u64), (u64, &CostEntry))> = None;
        for (&(r, p, occ), e) in &self.entries {
            let score = (occ.abs_diff(occupancy), u64::from(r != av), p.abs_diff(passes), p);
            let better = match &best {
                None => true,
                Some((s, _)) => score < *s,
            };
            if better {
                best = Some((score, (p, e)));
            }
        }
        let (_, (p, e)) = best?;
        Some(e.eval(ltoken) * passes as f64 / p.max(1) as f64)
    }

    /// Replay `spec`'s deterministic chunked-prefill + decode schedule
    /// against the table. `None` only when the table is empty.
    pub fn predict(&self, spec: &StreamSpec) -> Option<PredictedCost> {
        if self.entries.is_empty() {
            return None;
        }
        let mut prefill_cycles = 0f64;
        for c in prefill::chunks(spec.prompt_tokens, self.chunk) {
            let lt = c.ltoken_end();
            prefill_cycles += self.span_cost(self.av_chunked(lt), c.len, 1, lt)?;
        }
        let mut decode_cycles = 0f64;
        for pos in spec.prompt_tokens..spec.n_tokens {
            let lt = pos + 1;
            decode_cycles += self.span_cost(self.av_chunked(lt), 1, 1, lt)?;
        }
        Some(PredictedCost {
            prefill_cycles: prefill_cycles.round() as u64,
            decode_cycles: decode_cycles.round() as u64,
        })
    }

    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|(&(av, passes, occ), e)| {
                Json::obj(vec![
                    ("regime", regime_label(av).into()),
                    ("passes", passes.into()),
                    ("occupancy", occ.into()),
                    ("samples", e.fit.n.into()),
                    ("ltoken_min", e.fit.min_x.into()),
                    ("ltoken_max", e.fit.max_x.into()),
                    ("a", e.fit.a.into()),
                    ("b", e.fit.b.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", self.model.as_str().into()),
            ("chunk", self.chunk.into()),
            ("regime_boundary", self.regime_boundary.into()),
            ("entries", Json::Arr(entries)),
        ])
    }
}

/// Predicted per-request cost from [`CostTable::predict`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PredictedCost {
    pub prefill_cycles: u64,
    pub decode_cycles: u64,
}

impl PredictedCost {
    /// Uncontended first-generated-token estimate (the prompt's last
    /// position produces the first token).
    pub fn first_token_cycles(&self) -> u64 {
        self.prefill_cycles
    }

    pub fn e2e_cycles(&self) -> u64 {
        self.prefill_cycles + self.decode_cycles
    }
}

/// Online profiling sink: classifies the engine's span events as they
/// are emitted. A pure observer — it never feeds anything back.
#[derive(Clone, Debug)]
pub struct ProfileSink {
    model: String,
    chunk: u64,
    regime_boundary: u64,
    /// Next position each stream will produce (fused sweeps carry no
    /// positions, so the sink replays them from the per-stream event
    /// order).
    next_pos: BTreeMap<u64, u64>,
    spans: Vec<SpanRec>,
    links: BTreeMap<(u64, u64), u64>,
    hists: BTreeMap<&'static str, Hist>,
}

impl ProfileSink {
    pub fn new(model: &GptModel, cfg: &HwConfig) -> Self {
        Self {
            model: model.name.to_string(),
            chunk: cfg.sched.prefill_chunk,
            regime_boundary: cfg.pim.gb_elems() as u64 / (model.n_head as u64).max(1),
            next_pos: BTreeMap::new(),
            spans: Vec::new(),
            links: BTreeMap::new(),
            hists: BTreeMap::new(),
        }
    }

    fn record(&mut self, class: &'static str, dur: u64) {
        self.hists.entry(class).or_default().add(dur);
    }

    /// Partition the union of compute spans over elementary intervals:
    /// each covered interval is charged to one covering span (lowest
    /// `Phase`, then earliest start, then lowest stream id). Returns
    /// the leaves and the total covered cycles.
    fn attribute(&self) -> (BTreeMap<AttrKey, u64>, u64) {
        let mut spans: Vec<&SpanRec> = self.spans.iter().filter(|s| s.finish > s.start).collect();
        spans.sort_by_key(|s| (s.start, s.finish, s.stream));
        let mut cuts: Vec<u64> = Vec::with_capacity(spans.len() * 2);
        for s in &spans {
            cuts.push(s.start);
            cuts.push(s.finish);
        }
        cuts.sort_unstable();
        cuts.dedup();
        let mut leaves: BTreeMap<AttrKey, u64> = BTreeMap::new();
        let mut covered = 0u64;
        let mut active: Vec<&SpanRec> = Vec::new();
        let mut next = 0usize;
        for w in cuts.windows(2) {
            let (a, b) = (w[0], w[1]);
            while next < spans.len() && spans[next].start <= a {
                active.push(spans[next]);
                next += 1;
            }
            active.retain(|s| s.finish > a);
            if let Some(best) = active.iter().min_by_key(|s| (s.phase, s.start, s.stream)) {
                let key = AttrKey {
                    device: best.device,
                    phase: best.phase,
                    av_chunked: best.av_chunked,
                    occupancy: best.occupancy,
                };
                *leaves.entry(key).or_insert(0) += b - a;
                covered += b - a;
            }
        }
        (leaves, covered)
    }

    fn cost_table(&self) -> CostTable {
        let mut samples: BTreeMap<CostKey, Vec<(u64, u64)>> = BTreeMap::new();
        for s in &self.spans {
            if !matches!(s.phase, Phase::Prefill | Phase::SoloDecode | Phase::FusedSweep) {
                continue;
            }
            samples
                .entry((s.av_chunked, s.passes, s.occupancy))
                .or_default()
                .push((s.ltoken, s.finish - s.start));
        }
        CostTable {
            model: self.model.clone(),
            chunk: self.chunk,
            regime_boundary: self.regime_boundary,
            entries: samples.into_iter().map(|(k, v)| (k, CostEntry::build(&v))).collect(),
        }
    }

    /// Finalize into a [`Profile`]. `busy_cycles` /`link_cycles` are
    /// the `SimStats` reconciliation targets; `None` (offline JSONL
    /// replay, where no stats exist) pins them to the traced sums.
    pub fn finish(&self, busy_cycles: Option<u64>, link_cycles: Option<u64>) -> Profile {
        let (leaves, covered) = self.attribute();
        let busy = busy_cycles.unwrap_or(covered);
        let traced_link: u64 = self.links.values().sum();
        let link = link_cycles.unwrap_or(traced_link);
        Profile {
            model: self.model.clone(),
            leaves: leaves.into_iter().collect(),
            residual: busy as i64 - covered as i64,
            busy_cycles: busy,
            links: self.links.iter().map(|(&k, &v)| (k, v)).collect(),
            link_cycles: link,
            link_residual: link as i64 - traced_link as i64,
            histograms: self.hists.iter().map(|(&k, v)| (k.to_string(), v.clone())).collect(),
            cost_table: self.cost_table(),
        }
    }
}

impl TraceSink for ProfileSink {
    fn event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::PrefillChunk { stream, device, start, finish, pos, positions } => {
                let positions = (*positions).max(1);
                let lt = pos + positions;
                self.next_pos.insert(*stream, lt);
                self.record("prefill_chunk", finish - start);
                self.spans.push(SpanRec {
                    start: *start,
                    finish: *finish,
                    phase: Phase::Prefill,
                    av_chunked: lt > self.regime_boundary,
                    occupancy: 1,
                    device: *device,
                    stream: *stream,
                    ltoken: lt,
                    passes: positions,
                });
            }
            TraceEvent::DecodeStep { stream, device, start, finish, pos } => {
                let lt = pos + 1;
                self.next_pos.insert(*stream, lt);
                self.record("decode_step", finish - start);
                self.spans.push(SpanRec {
                    start: *start,
                    finish: *finish,
                    phase: Phase::SoloDecode,
                    av_chunked: lt > self.regime_boundary,
                    occupancy: 1,
                    device: *device,
                    stream: *stream,
                    ltoken: lt,
                    passes: 1,
                });
            }
            TraceEvent::FusedSweep { device, start, finish, streams } => {
                let occ = streams.len().max(1) as u64;
                let mut lt = 1u64;
                let mut lead = u64::MAX;
                for &s in streams {
                    let p = self.next_pos.entry(s).or_insert(0);
                    lt = lt.max(*p + 1);
                    lead = lead.min(s);
                    *p += 1;
                }
                self.record("fused_sweep", finish - start);
                self.spans.push(SpanRec {
                    start: *start,
                    finish: *finish,
                    phase: Phase::FusedSweep,
                    av_chunked: lt > self.regime_boundary,
                    occupancy: occ,
                    device: *device,
                    stream: lead,
                    ltoken: lt,
                    passes: 1,
                });
            }
            TraceEvent::Writeback { stream, start, finish, tokens } => {
                let lt = (*tokens).max(1);
                self.record("writeback", finish - start);
                self.spans.push(SpanRec {
                    start: *start,
                    finish: *finish,
                    phase: Phase::Writeback,
                    av_chunked: lt > self.regime_boundary,
                    occupancy: 1,
                    device: 0,
                    stream: *stream,
                    ltoken: lt,
                    passes: 0,
                });
            }
            TraceEvent::Restore { stream, start, finish, tokens } => {
                let lt = (*tokens).max(1);
                self.record("restore", finish - start);
                self.spans.push(SpanRec {
                    start: *start,
                    finish: *finish,
                    phase: Phase::Restore,
                    av_chunked: lt > self.regime_boundary,
                    occupancy: 1,
                    device: 0,
                    stream: *stream,
                    ltoken: lt,
                    passes: 0,
                });
            }
            TraceEvent::LinkTransfer { src, dst, start, finish, .. } => {
                self.record("link_transfer", finish - start);
                *self.links.entry((*src, *dst)).or_insert(0) += finish - start;
            }
            _ => {}
        }
    }
}

/// Finalized profile: the attribution tree, histograms and cost table,
/// plus the reconciliation targets they were closed against.
#[derive(Clone, Debug)]
pub struct Profile {
    pub model: String,
    pub leaves: Vec<(AttrKey, u64)>,
    /// Busy cycles no compute span covered (>= 0 on a healthy trace;
    /// negative means spans overlapped idle time — an engine bug).
    pub residual: i64,
    /// `SimStats::busy_cycles` target the leaves + residual sum to.
    pub busy_cycles: u64,
    pub links: Vec<((u64, u64), u64)>,
    /// `SimStats::link_transfer_cycles` target.
    pub link_cycles: u64,
    /// `link_cycles` minus the traced link-span sum (must be 0).
    pub link_residual: i64,
    pub histograms: Vec<(String, Hist)>,
    pub cost_table: CostTable,
}

impl Profile {
    /// Sum over the attribution leaves (excluding the residual).
    pub fn attributed_cycles(&self) -> u64 {
        self.leaves.iter().map(|(_, c)| c).sum()
    }

    /// The reconciliation invariants: leaves + residual == busy cycles
    /// with a non-negative residual, and link spans sum exactly to the
    /// charged link cycles.
    pub fn check(&self) -> Result<(), String> {
        let attributed = self.attributed_cycles();
        if self.residual < 0 {
            return Err(format!(
                "attribution overruns busy cycles: covered {attributed} > busy {}",
                self.busy_cycles
            ));
        }
        if attributed + self.residual as u64 != self.busy_cycles {
            return Err(format!(
                "attribution total {attributed} + residual {} != busy {}",
                self.residual, self.busy_cycles
            ));
        }
        if self.link_residual != 0 {
            return Err(format!(
                "link spans sum to {} but stats charge {}",
                self.link_cycles as i64 - self.link_residual,
                self.link_cycles
            ));
        }
        Ok(())
    }

    /// Replay a recorded `jsonl:` trace through the same classification
    /// path. No `SimStats` exist offline, so the reconciliation targets
    /// pin to the traced sums (residual 0 by construction).
    pub fn from_jsonl(text: &str, model: &GptModel, cfg: &HwConfig) -> Result<Profile> {
        let mut sink = ProfileSink::new(model, cfg);
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let json = Json::parse(line).map_err(|e| anyhow!("trace line {}: {e}", i + 1))?;
            let ev = TraceEvent::from_json(&json).map_err(|e| anyhow!("trace line {}: {e}", i + 1))?;
            sink.event(&ev);
        }
        Ok(sink.finish(None, None))
    }

    fn share(&self, cycles: f64) -> String {
        format!("{:.1}%", 100.0 * cycles / self.busy_cycles.max(1) as f64)
    }

    pub fn render_text(&self) -> String {
        let mut out = format!(
            "profile: {} (busy {} cycles, link {} cycles)\n\ncycle attribution (device x phase x regime x occupancy)\n",
            self.model, self.busy_cycles, self.link_cycles
        );
        let mut t = Table::new(vec!["device", "phase", "regime", "occ", "cycles", "share"]);
        for (k, c) in &self.leaves {
            t.row(vec![
                k.device.to_string(),
                k.phase.label().to_string(),
                regime_label(k.av_chunked).to_string(),
                k.occupancy.to_string(),
                c.to_string(),
                self.share(*c as f64),
            ]);
        }
        t.row(vec![
            "-".to_string(),
            "unattributed".to_string(),
            "-".to_string(),
            "-".to_string(),
            self.residual.to_string(),
            self.share(self.residual as f64),
        ]);
        out.push_str(&t.render());
        if !self.links.is_empty() {
            out.push_str("\nlink transfer cycles (src -> dst)\n");
            let mut t = Table::new(vec!["src", "dst", "cycles"]);
            for &((s, d), c) in &self.links {
                t.row(vec![s.to_string(), d.to_string(), c.to_string()]);
            }
            out.push_str(&t.render());
        }
        if !self.histograms.is_empty() {
            out.push_str("\nspan latency (cycles)\n");
            let mut t = Table::new(vec!["class", "count", "p50", "p95", "p99", "log2 buckets"]);
            for (class, h) in &self.histograms {
                let (p50, p95, p99) = h.percentiles();
                let buckets = h
                    .buckets()
                    .into_iter()
                    .map(|(lo, hi, n)| format!("[{lo}..{hi}]x{n}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                t.row(vec![
                    class.clone(),
                    h.count().to_string(),
                    p50.to_string(),
                    p95.to_string(),
                    p99.to_string(),
                    buckets,
                ]);
            }
            out.push_str(&t.render());
        }
        if !self.cost_table.is_empty() {
            out.push_str("\ncost table (cycles = a + b * ltoken; exact samples preferred)\n");
            let mut t =
                Table::new(vec!["regime", "passes", "occ", "samples", "ltoken range", "a", "b"]);
            for (&(av, passes, occ), e) in &self.cost_table.entries {
                t.row(vec![
                    regime_label(av).to_string(),
                    passes.to_string(),
                    occ.to_string(),
                    e.fit.n.to_string(),
                    format!("{}..{}", e.fit.min_x, e.fit.max_x),
                    format!("{:.1}", e.fit.a),
                    format!("{:.3}", e.fit.b),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let attribution = self
            .leaves
            .iter()
            .map(|(k, c)| {
                Json::obj(vec![
                    ("device", k.device.into()),
                    ("phase", k.phase.label().into()),
                    ("regime", regime_label(k.av_chunked).into()),
                    ("occupancy", k.occupancy.into()),
                    ("cycles", (*c).into()),
                ])
            })
            .collect();
        let links = self
            .links
            .iter()
            .map(|&((s, d), c)| {
                Json::obj(vec![("src", s.into()), ("dst", d.into()), ("cycles", c.into())])
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(class, h)| {
                let (p50, p95, p99) = h.percentiles();
                let buckets = h
                    .buckets()
                    .into_iter()
                    .map(|(lo, hi, n)| {
                        Json::obj(vec![
                            ("lo", lo.into()),
                            ("hi", hi.into()),
                            ("count", n.into()),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("class", class.as_str().into()),
                    ("count", h.count().into()),
                    ("p50", p50.into()),
                    ("p95", p95.into()),
                    ("p99", p99.into()),
                    ("buckets", Json::Arr(buckets)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", self.model.as_str().into()),
            ("busy_cycles", self.busy_cycles.into()),
            ("attributed_cycles", self.attributed_cycles().into()),
            ("residual_cycles", (self.residual as f64).into()),
            ("link_cycles", self.link_cycles.into()),
            ("attribution", Json::Arr(attribution)),
            ("links", Json::Arr(links)),
            ("histograms", Json::Arr(histograms)),
            ("cost_table", self.cost_table.to_json()),
        ])
    }
}

/// One validation request of a calibration run.
#[derive(Clone, Copy, Debug)]
pub struct CalibrationRow {
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    pub predicted_cycles: u64,
    pub actual_cycles: u64,
    pub rel_err: f64,
}

/// Cross-validation of [`CostTable::predict`] against the
/// cycle-accurate engine.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub model: String,
    pub n_train: usize,
    pub rows: Vec<CalibrationRow>,
    pub mean_rel_err: f64,
    pub max_rel_err: f64,
}

impl CalibrationReport {
    pub fn render_text(&self) -> String {
        let mut out = format!(
            "calibration: {} ({} train requests, {} validation requests)\n",
            self.model,
            self.n_train,
            self.rows.len()
        );
        let mut t = Table::new(vec!["prompt", "gen", "predicted", "actual", "rel err"]);
        for r in &self.rows {
            t.row(vec![
                r.prompt_tokens.to_string(),
                r.gen_tokens.to_string(),
                r.predicted_cycles.to_string(),
                r.actual_cycles.to_string(),
                format!("{:.2}%", 100.0 * r.rel_err),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "mean rel err {:.2}%  max rel err {:.2}%\n",
            100.0 * self.mean_rel_err,
            100.0 * self.max_rel_err
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("prompt_tokens", r.prompt_tokens.into()),
                    ("gen_tokens", r.gen_tokens.into()),
                    ("predicted_cycles", r.predicted_cycles.into()),
                    ("actual_cycles", r.actual_cycles.into()),
                    ("rel_err", r.rel_err.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", self.model.as_str().into()),
            ("n_train", self.n_train.into()),
            ("n_validate", self.rows.len().into()),
            ("mean_rel_err", self.mean_rel_err.into()),
            ("max_rel_err", self.max_rel_err.into()),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Train a [`CostTable`] on a deterministic grid of uncontended
/// requests, then cross-validate `predict` on `n_validate` seeded
/// random shapes, each replayed on a fresh single-request engine
/// (arrival 0, so e2e cycles are pure service time).
pub fn calibrate(
    model: &GptModel,
    cfg: &HwConfig,
    seed: u64,
    n_validate: usize,
) -> Result<CalibrationReport> {
    ensure!(n_validate > 0, "calibration needs at least one validation request");
    let mut cfg = cfg.clone();
    cfg.sched.devices = 1;
    cfg.sched.max_streams = 1;
    cfg.sched.batch_decode = false;
    cfg.sched.kv_paging = false;
    cfg.sched.policy = super::policy::PolicySpec::Fcfs;
    cfg.sched.trace = TraceSpec::Off;
    cfg.sched.trace_window = 0;
    cfg.sched.profile = ProfileSpec::Off;
    let max_total = (model.max_seq as u64).min(96).max(4);
    // Training grid: totals span [2, max_total]; shapes rotate between
    // balanced, decode-heavy (prompt 1, which alone covers every decode
    // ltoken up to its total) and prefill-heavy (chunk passes + odd
    // remainders).
    let n_train = 8u64;
    let mut ms = MultiSim::new(model, &cfg)?;
    ms.set_profile(ProfileSink::new(model, &cfg));
    for i in 0..n_train {
        let total = 2 + (max_total - 2) * i / (n_train - 1);
        let prompt = match i % 3 {
            0 => (total / 2).max(1),
            1 => 1,
            _ => total - 1,
        };
        ms.submit(StreamSpec { id: i, n_tokens: total, prompt_tokens: prompt, arrival_cycle: 0 })?;
    }
    ms.run_all()?;
    ms.finalize_stats();
    let profile = ms.profile_report().context("training run carries a profile sink")?;
    let table = profile.cost_table;
    ensure!(!table.is_empty(), "calibration training produced no cost samples");
    let mut rng = Rng::new(seed);
    let mut rows: Vec<CalibrationRow> = Vec::with_capacity(n_validate);
    for i in 0..n_validate {
        let total = 2 + rng.gen_range(max_total - 1);
        let prompt = 1 + rng.gen_range(total - 1);
        let spec =
            StreamSpec { id: i as u64, n_tokens: total, prompt_tokens: prompt, arrival_cycle: 0 };
        let predicted = table.predict(&spec).context("cost table covers validation shapes")?;
        let mut vms = MultiSim::new(model, &cfg)?;
        vms.submit(spec)?;
        let outcomes = vms.run_all()?;
        let r = outcomes
            .into_iter()
            .filter_map(StreamOutcome::into_completed)
            .next()
            .context("single uncontended request completes")?;
        let actual = r.e2e_cycles();
        let rel_err =
            (predicted.e2e_cycles() as f64 - actual as f64).abs() / actual.max(1) as f64;
        rows.push(CalibrationRow {
            prompt_tokens: prompt,
            gen_tokens: total - prompt,
            predicted_cycles: predicted.e2e_cycles(),
            actual_cycles: actual,
            rel_err,
        });
    }
    let mean_rel_err = rows.iter().map(|r| r.rel_err).sum::<f64>() / rows.len() as f64;
    let max_rel_err = rows.iter().map(|r| r.rel_err).fold(0.0, f64::max);
    Ok(CalibrationReport {
        model: model.name.to_string(),
        n_train: n_train as usize,
        rows,
        mean_rel_err,
        max_rel_err,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    fn sink() -> ProfileSink {
        let m = model::gpt::by_name("gpt2-small").unwrap();
        ProfileSink::new(&m, &HwConfig::paper_baseline())
    }

    #[test]
    fn spec_parse_and_display_round_trip() {
        for s in ["off", "text:profile.txt", "json:profile.json"] {
            let spec = ProfileSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s);
        }
        assert_eq!(ProfileSpec::parse("").unwrap(), ProfileSpec::Off);
        assert!(!ProfileSpec::Off.is_on());
        assert!(ProfileSpec::parse("json:p.json").unwrap().is_on());
        assert_eq!(ProfileSpec::parse("text:a/b.txt").unwrap().path(), Some("a/b.txt"));
        assert!(ProfileSpec::parse("text:").is_err(), "empty path rejected");
        assert!(ProfileSpec::parse("json:").is_err());
        assert!(ProfileSpec::parse("yaml:x").is_err(), "unknown format rejected");
    }

    #[test]
    fn hist_buckets_and_percentiles() {
        let mut h = Hist::default();
        for d in [0, 1, 2, 3, 7, 1000] {
            h.add(d);
        }
        assert_eq!(h.count(), 6);
        let (p50, p95, p99) = h.percentiles();
        assert_eq!(p50, 2, "nearest rank at q=0.5 over 6 samples");
        assert_eq!(p95, 1000);
        assert_eq!(p99, 1000);
        let buckets = h.buckets();
        assert_eq!(buckets[0], (0, 0, 1), "duration 0 bucket");
        assert_eq!(buckets[1], (1, 1, 1));
        assert_eq!(buckets[2], (2, 3, 2));
        assert_eq!(buckets[3], (4, 7, 1));
        assert_eq!(buckets[4], (512, 1023, 1));
        assert!(Hist::default().percentiles() == (0, 0, 0));
    }

    #[test]
    fn attribution_partitions_overlaps_by_priority() {
        let mut s = sink();
        s.event(&TraceEvent::PrefillChunk {
            stream: 0,
            device: 0,
            start: 0,
            finish: 100,
            pos: 0,
            positions: 10,
        });
        s.event(&TraceEvent::DecodeStep { stream: 1, device: 0, start: 50, finish: 150, pos: 20 });
        s.event(&TraceEvent::FusedSweep { device: 0, start: 90, finish: 120, streams: vec![0, 1] });
        let p = s.finish(Some(160), None);
        let by_phase = |ph: Phase| -> u64 {
            p.leaves.iter().filter(|(k, _)| k.phase == ph).map(|(_, c)| c).sum()
        };
        assert_eq!(by_phase(Phase::Prefill), 90, "prefill outranks the overlapping solo decode");
        assert_eq!(by_phase(Phase::FusedSweep), 30, "fused sweep outranks everything");
        assert_eq!(by_phase(Phase::SoloDecode), 30);
        assert_eq!(p.attributed_cycles(), 150);
        assert_eq!(p.residual, 10, "10 busy cycles no span covered");
        p.check().expect("leaves + residual == busy");
        let fused_key = p.leaves.iter().find(|(k, _)| k.phase == Phase::FusedSweep).unwrap().0;
        assert_eq!(fused_key.occupancy, 2);
        // Busy below coverage means spans overlapped idle time: an error.
        assert!(s.finish(Some(140), None).check().is_err());
    }

    #[test]
    fn link_spans_reconcile_additively() {
        let mut s = sink();
        s.event(&TraceEvent::LinkTransfer { stream: 0, src: 0, dst: 1, start: 10, finish: 30 });
        s.event(&TraceEvent::LinkTransfer { stream: 1, src: 0, dst: 1, start: 40, finish: 45 });
        s.event(&TraceEvent::LinkTransfer { stream: 0, src: 1, dst: 2, start: 30, finish: 37 });
        let p = s.finish(Some(0), Some(32));
        assert_eq!(p.links, vec![((0, 1), 25), ((1, 2), 7)]);
        assert_eq!(p.link_residual, 0);
        p.check().unwrap();
        assert!(s.finish(Some(0), Some(30)).check().is_err(), "link mismatch is loud");
    }

    #[test]
    fn cost_table_predicts_linear_costs_exactly() {
        let mut s = sink();
        // Solo decode steps with cost 100 + 5 * ltoken at ltoken 2..=21.
        let mut t = 0u64;
        for pos in 1..=20u64 {
            let dur = 100 + 5 * (pos + 1);
            s.event(&TraceEvent::DecodeStep {
                stream: 0,
                device: 0,
                start: t,
                finish: t + dur,
                pos,
            });
            t += dur;
        }
        let p = s.finish(None, None);
        let table = &p.cost_table;
        assert!(!table.is_empty());
        // Prompt 1 has no prefill sample: the nearest-key fallback lands
        // on the decode entry, whose linear fit extrapolates ltoken 1.
        let spec = StreamSpec { id: 0, n_tokens: 21, prompt_tokens: 1, arrival_cycle: 0 };
        let pred = table.predict(&spec).unwrap();
        let want: u64 = (1..=21u64).map(|lt| 100 + 5 * lt).sum();
        assert_eq!(pred.e2e_cycles(), want);
        assert_eq!(pred.first_token_cycles(), 105);
        assert!(CostTable {
            model: "m".into(),
            chunk: 32,
            regime_boundary: 8,
            entries: BTreeMap::new()
        }
        .predict(&spec)
        .is_none());
    }

    #[test]
    fn fused_sweeps_replay_member_positions() {
        let mut s = sink();
        s.event(&TraceEvent::PrefillChunk {
            stream: 0,
            device: 0,
            start: 0,
            finish: 10,
            pos: 0,
            positions: 4,
        });
        s.event(&TraceEvent::PrefillChunk {
            stream: 1,
            device: 0,
            start: 10,
            finish: 20,
            pos: 0,
            positions: 4,
        });
        s.event(&TraceEvent::FusedSweep { device: 0, start: 20, finish: 30, streams: vec![0, 1] });
        s.event(&TraceEvent::FusedSweep { device: 0, start: 30, finish: 40, streams: vec![0, 1] });
        let p = s.finish(None, None);
        let key: Vec<&CostKey> =
            p.cost_table.entries.keys().filter(|(_, _, occ)| *occ == 2).collect();
        assert_eq!(key.len(), 1, "both sweeps share the occupancy-2 key");
        let e = &p.cost_table.entries[key[0]];
        assert_eq!(e.fit.n, 2);
        assert_eq!((e.fit.min_x, e.fit.max_x), (5, 6), "positions advanced between sweeps");
    }

    #[test]
    fn from_jsonl_matches_online_profile() {
        let events = vec![
            TraceEvent::Submit { stream: 0, at: 0, arrival: 0, prompt_tokens: 4, tokens: 6 },
            TraceEvent::Admit { stream: 0, at: 0, slot: 0 },
            TraceEvent::PrefillChunk {
                stream: 0,
                device: 0,
                start: 0,
                finish: 90,
                pos: 0,
                positions: 4,
            },
            TraceEvent::DecodeStep { stream: 0, device: 0, start: 90, finish: 130, pos: 4 },
            TraceEvent::FusedSweep { device: 0, start: 130, finish: 170, streams: vec![0, 1] },
            TraceEvent::Writeback { stream: 1, start: 170, finish: 180, tokens: 3 },
            TraceEvent::Restore { stream: 1, start: 185, finish: 195, tokens: 3 },
            TraceEvent::LinkTransfer { stream: 0, src: 0, dst: 1, start: 170, finish: 190 },
            TraceEvent::StreamRetire { stream: 0, at: 195, tokens: 6 },
        ];
        let mut online = sink();
        let mut jsonl = String::new();
        for ev in &events {
            online.event(ev);
            jsonl.push_str(&ev.to_json().to_string());
            jsonl.push('\n');
        }
        let m = model::gpt::by_name("gpt2-small").unwrap();
        let replayed = Profile::from_jsonl(&jsonl, &m, &HwConfig::paper_baseline()).unwrap();
        assert_eq!(replayed.to_json(), online.finish(None, None).to_json());
        assert_eq!(replayed.residual, 0, "offline targets pin to the traced sums");
        replayed.check().unwrap();
        assert!(Profile::from_jsonl("not json\n", &m, &HwConfig::paper_baseline()).is_err());
    }
}
