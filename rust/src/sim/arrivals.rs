//! Open-loop arrival engines: deterministic request-arrival traces for
//! serving experiments (tail-latency percentiles vs offered load).
//!
//! The paper's evaluation is closed-loop (fixed batches, makespan); a
//! serving system is measured open-loop: requests arrive on their own
//! clock and the numbers that matter are the queue/TTFT/end-to-end
//! percentiles under a given offered load. This module produces the
//! arrival side of those experiments:
//!
//! * **batch** — every request present at cycle 0 (the closed-loop
//!   behavior every pinned K=1 equivalence test runs under);
//! * **fixed:`<cycles>`** — one request every `interval` cycles;
//! * **poisson:`<rate>`** — exponential inter-arrivals at `rate`
//!   requests per simulated second, sampled by a splitmix64-seeded
//!   xorshift64* stream ([`crate::util::rng::Rng`]; the repo is offline,
//!   so there is no `rand` — and no OS entropy: identical seeds replay
//!   identical traces);
//! * **trace:`<file>`** — a JSON file replayed through [`crate::util::json`].
//!
//! Trace-file schema (`n_tokens >= 1` total positions, of which the
//! leading `prompt_tokens` are prompt — optional, default 1, must stay
//! within `n_tokens`; requests sorted by `arrival_cycle`; empty
//! traces, out-of-order arrivals and unknown keys are rejected so a
//! typo or corrupted file cannot silently change an experiment; a
//! total exceeding the model's `max_seq` is rejected at submit with
//! the offending request's index):
//!
//! ```json
//! {"requests": [
//!   {"arrival_cycle": 0,    "n_tokens": 16, "prompt_tokens": 8},
//!   {"arrival_cycle": 4096, "n_tokens": 8}
//! ]}
//! ```

use std::fmt;

use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};

/// An arrival process, parseable from `--arrivals` / `sched.arrival`.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum ArrivalSpec {
    /// All requests arrive at cycle 0 (closed-loop batch).
    #[default]
    Batch,
    /// One request every `interval_cycles` DRAM cycles.
    Fixed { interval_cycles: u64 },
    /// Poisson process at `rate_per_s` requests per simulated second.
    Poisson { rate_per_s: f64 },
    /// Replay a JSON trace file (carries its own token counts).
    Trace { path: String },
}

impl ArrivalSpec {
    /// Parse `batch`, `fixed:<cycles>`, `poisson:<req/s>` or
    /// `trace:<file>`.
    pub fn parse(s: &str) -> Result<Self> {
        if s == "batch" {
            return Ok(Self::Batch);
        }
        if let Some(v) = s.strip_prefix("fixed:") {
            let Ok(interval_cycles) = v.parse::<u64>() else {
                bail!("fixed:<cycles> needs an integer, got '{v}'");
            };
            ensure!(interval_cycles > 0, "fixed arrival interval must be >= 1 cycle");
            return Ok(Self::Fixed { interval_cycles });
        }
        if let Some(v) = s.strip_prefix("poisson:") {
            let Ok(rate_per_s) = v.parse::<f64>() else {
                bail!("poisson:<rate> needs a number, got '{v}'");
            };
            ensure!(
                rate_per_s.is_finite() && rate_per_s > 0.0,
                "poisson rate must be a positive finite req/s, got {rate_per_s}"
            );
            return Ok(Self::Poisson { rate_per_s });
        }
        if let Some(path) = s.strip_prefix("trace:") {
            ensure!(!path.is_empty(), "trace:<file> needs a path");
            return Ok(Self::Trace { path: path.to_string() });
        }
        bail!("unknown arrival spec '{s}' (batch | fixed:<cycles> | poisson:<req/s> | trace:<file>)")
    }
}

impl fmt::Display for ArrivalSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Batch => write!(f, "batch"),
            Self::Fixed { interval_cycles } => write!(f, "fixed:{interval_cycles}"),
            Self::Poisson { rate_per_s } => write!(f, "poisson:{rate_per_s}"),
            Self::Trace { path } => write!(f, "trace:{path}"),
        }
    }
}

/// splitmix64 finalizer: decorrelates nearby seeds (1, 2, 3...) before
/// they feed the xorshift64* stream.
fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Arrival cycles (nondecreasing, length `n`) for a non-trace spec at
/// `freq_ghz` DRAM clock. Deterministic: same `(spec, n, freq, seed)`
/// always yields the same trace. Trace specs carry their own request
/// list — use [`load_trace`] instead.
pub fn generate(spec: &ArrivalSpec, n: usize, freq_ghz: f64, seed: u64) -> Result<Vec<u64>> {
    ensure!(freq_ghz > 0.0, "freq_ghz must be positive");
    Ok(match spec {
        ArrivalSpec::Batch => vec![0; n],
        ArrivalSpec::Fixed { interval_cycles } => {
            let mut out = Vec::with_capacity(n);
            let mut t = 0u64;
            for i in 0..n {
                if i > 0 {
                    t = match t.checked_add(*interval_cycles) {
                        Some(next) => next,
                        None => bail!("fixed:{interval_cycles} overflows u64 at request {i}"),
                    };
                }
                out.push(t);
            }
            out
        }
        ArrivalSpec::Poisson { rate_per_s } => {
            let mean_cycles = freq_ghz * 1e9 / rate_per_s;
            let mut rng = Rng::new(splitmix64(seed));
            let mut t = 0.0f64;
            (0..n)
                .map(|_| {
                    // Inverse-CDF exponential; u in [0, 1) keeps ln finite.
                    t += -mean_cycles * (1.0 - rng.f64()).ln();
                    t as u64
                })
                .collect()
        }
        ArrivalSpec::Trace { path } => {
            bail!("trace '{path}' carries its own request list; use arrivals::load_trace")
        }
    })
}

/// One request of a replayed trace file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceRequest {
    pub arrival_cycle: u64,
    /// Total positions (prompt + generated), >= 1.
    pub n_tokens: u64,
    /// Leading positions that are prompt (prefill), in
    /// `[1, n_tokens]`. Optional in the file; defaults to 1, the
    /// historical no-split behavior.
    pub prompt_tokens: u64,
}

/// Parse the trace-file schema (see the module docs). Rejects empty
/// traces, zero-token requests, unknown keys and out-of-order
/// `arrival_cycle` values (a trace is a recording of an arrival
/// process, so it must be sorted by arrival; an unsorted file is far
/// more likely a corrupted or hand-mangled trace than intent, and
/// silently reordering it would change which request gets each id —
/// and therefore every per-request stat downstream).
pub fn parse_trace(json: &Json) -> Result<Vec<TraceRequest>> {
    let reqs = match json.get("requests").and_then(Json::as_arr) {
        Some(r) => r,
        None => bail!("trace must be an object with a \"requests\" array"),
    };
    ensure!(!reqs.is_empty(), "trace has no requests — an empty replay would serve nothing");
    let mut out = Vec::with_capacity(reqs.len());
    for (i, e) in reqs.iter().enumerate() {
        let obj = match e.as_obj() {
            Some(o) => o,
            None => bail!("trace request {i} must be an object"),
        };
        for key in obj.keys() {
            if key != "arrival_cycle" && key != "n_tokens" && key != "prompt_tokens" {
                bail!(
                    "trace request {i}: unknown key '{key}' (schema: arrival_cycle, \
                     n_tokens, prompt_tokens)"
                );
            }
        }
        // JSON numbers are f64: demand exactly-representable integers
        // (< 2^53), mirroring the `sched.seed` guard — a rounded cycle
        // would silently replay the trace at the wrong time.
        let int = |key: &str| -> Result<u64> {
            let v = obj
                .get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("trace request {i}: '{key}' must be a number"))?;
            if v < 0.0 || v.fract() != 0.0 || v >= 9_007_199_254_740_992.0 {
                bail!("trace request {i}: '{key}' must be an exact integer < 2^53, got {v}");
            }
            Ok(v as u64)
        };
        let arrival_cycle = int("arrival_cycle")?;
        let n_tokens = int("n_tokens")?;
        ensure!(n_tokens >= 1, "trace request {i}: n_tokens must be >= 1");
        let prompt_tokens =
            if obj.contains_key("prompt_tokens") { int("prompt_tokens")? } else { 1 };
        ensure!(
            prompt_tokens >= 1,
            "trace request {i}: prompt_tokens must be >= 1 (every request prefills at \
             least one position)"
        );
        ensure!(
            prompt_tokens <= n_tokens,
            "trace request {i}: prompt_tokens {prompt_tokens} exceeds n_tokens {n_tokens} \
             (n_tokens counts total positions, prompt included)"
        );
        if let Some(prev) = out.last() {
            ensure!(
                arrival_cycle >= prev.arrival_cycle,
                "trace request {i}: arrival_cycle {arrival_cycle} precedes request {}'s {} — \
                 traces must be sorted by arrival",
                i - 1,
                prev.arrival_cycle
            );
        }
        out.push(TraceRequest { arrival_cycle, n_tokens, prompt_tokens });
    }
    Ok(out)
}

/// Read + parse a trace file.
pub fn load_trace(path: &str) -> Result<Vec<TraceRequest>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let json = Json::parse(&text).with_context(|| format!("parsing trace {path}"))?;
    parse_trace(&json).with_context(|| format!("validating trace {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["batch", "fixed:4096", "poisson:250000", "trace:reqs.json"] {
            let spec = ArrivalSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s);
            assert_eq!(ArrivalSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "poison:100",
            "poisson:",
            "poisson:-5",
            "poisson:0",
            "poisson:inf",
            "fixed:",
            "fixed:0",
            "fixed:1.5",
            "trace:",
            "uniform:10",
            "",
        ] {
            assert!(ArrivalSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn batch_and_fixed_shapes() {
        assert_eq!(generate(&ArrivalSpec::Batch, 3, 1.0, 0).unwrap(), vec![0, 0, 0]);
        let fixed = ArrivalSpec::Fixed { interval_cycles: 500 };
        assert_eq!(generate(&fixed, 4, 1.0, 0).unwrap(), vec![0, 500, 1000, 1500]);
    }

    #[test]
    fn fixed_interval_overflow_fails_loudly() {
        // A wrap would yield a *decreasing* trace and corrupt every
        // percentile downstream; it must be an error instead.
        let huge = ArrivalSpec::Fixed { interval_cycles: u64::MAX };
        assert!(generate(&huge, 3, 1.0, 0).is_err());
        // One request never multiplies the interval; still fine.
        assert_eq!(generate(&huge, 1, 1.0, 0).unwrap(), vec![0]);
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let spec = ArrivalSpec::Poisson { rate_per_s: 1_000_000.0 };
        let a = generate(&spec, 64, 1.0, 7).unwrap();
        let b = generate(&spec, 64, 1.0, 7).unwrap();
        assert_eq!(a, b, "same seed must replay the same trace");
        let c = generate(&spec, 64, 1.0, 8).unwrap();
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals must be nondecreasing");
    }

    #[test]
    fn poisson_mean_interarrival_tracks_rate() {
        // 1 GHz, 1e6 req/s -> mean inter-arrival 1000 cycles; the mean
        // of 4000 exponential draws sits within ~2% (10% bound is slack).
        let spec = ArrivalSpec::Poisson { rate_per_s: 1_000_000.0 };
        let a = generate(&spec, 4000, 1.0, 42).unwrap();
        let mean = *a.last().unwrap() as f64 / a.len() as f64;
        assert!((mean - 1000.0).abs() < 100.0, "mean inter-arrival {mean}");
    }

    #[test]
    fn trace_generate_is_rejected() {
        let spec = ArrivalSpec::Trace { path: "x.json".into() };
        assert!(generate(&spec, 4, 1.0, 0).is_err());
    }

    #[test]
    fn trace_schema_parses() {
        let j = Json::parse(
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 16},
                             {"arrival_cycle": 4096, "n_tokens": 8}]}"#,
        )
        .unwrap();
        let t = parse_trace(&j).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0], TraceRequest { arrival_cycle: 0, n_tokens: 16, prompt_tokens: 1 });
        assert_eq!(t[1], TraceRequest { arrival_cycle: 4096, n_tokens: 8, prompt_tokens: 1 });
    }

    /// Satellite: equal arrivals are fine (a burst), strictly decreasing
    /// ones are a corrupted trace and must fail loudly with the
    /// offending indices — not silently produce nonsense queue stats.
    #[test]
    fn trace_schema_rejects_out_of_order_arrivals() {
        let ok = Json::parse(
            r#"{"requests": [{"arrival_cycle": 5, "n_tokens": 1},
                             {"arrival_cycle": 5, "n_tokens": 2},
                             {"arrival_cycle": 9, "n_tokens": 1}]}"#,
        )
        .unwrap();
        assert_eq!(parse_trace(&ok).unwrap().len(), 3);
        let bad = Json::parse(
            r#"{"requests": [{"arrival_cycle": 100, "n_tokens": 1},
                             {"arrival_cycle": 40, "n_tokens": 1}]}"#,
        )
        .unwrap();
        let err = parse_trace(&bad).unwrap_err().to_string();
        assert!(err.contains("sorted by arrival"), "{err}");
        assert!(err.contains("request 1") && err.contains("40"), "{err}");
        // The empty-trace rejection stays loud too.
        let err = parse_trace(&Json::parse(r#"{"requests": []}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no requests"), "{err}");
    }

    /// Satellite: the optional `prompt_tokens` key parses with its
    /// default of 1, validates against the request's total, and keeps
    /// the unknown-key rejection intact.
    #[test]
    fn trace_schema_prompt_tokens() {
        let j = Json::parse(
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 16, "prompt_tokens": 8},
                             {"arrival_cycle": 10, "n_tokens": 4},
                             {"arrival_cycle": 20, "n_tokens": 5, "prompt_tokens": 5}]}"#,
        )
        .unwrap();
        let t = parse_trace(&j).unwrap();
        assert_eq!(t[0], TraceRequest { arrival_cycle: 0, n_tokens: 16, prompt_tokens: 8 });
        assert_eq!(t[1].prompt_tokens, 1, "absent key defaults to 1-token prompt");
        assert_eq!(t[2].prompt_tokens, 5, "pure-prefill requests are legal");
        // Invalid splits fail loudly with the offending index.
        let bad = Json::parse(
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 4},
                             {"arrival_cycle": 5, "n_tokens": 4, "prompt_tokens": 5}]}"#,
        )
        .unwrap();
        let err = parse_trace(&bad).unwrap_err().to_string();
        assert!(err.contains("request 1") && err.contains("exceeds n_tokens"), "{err}");
        for bad in [
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 4, "prompt_tokens": 0}]}"#,
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 4, "prompt_tokens": 1.5}]}"#,
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 4, "promt_tokens": 2}]}"#,
        ] {
            assert!(parse_trace(&Json::parse(bad).unwrap()).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn trace_schema_rejects_bad_inputs() {
        for bad in [
            r#"{"requests": []}"#,
            r#"{"reqs": [{"arrival_cycle": 0, "n_tokens": 1}]}"#,
            r#"{"requests": [{"arrival_cycle": 0}]}"#,
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 0}]}"#,
            r#"{"requests": [{"arrival_cycle": -5, "n_tokens": 1}]}"#,
            r#"{"requests": [{"arival_cycle": 0, "n_tokens": 1}]}"#,
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 1, "prio": 3}]}"#,
            r#"{"requests": [7]}"#,
            r#"[1, 2]"#,
            // f64 cannot hold these exactly; silent rounding would
            // replay the trace at the wrong cycle (see sched.seed).
            r#"{"requests": [{"arrival_cycle": 1.5, "n_tokens": 1}]}"#,
            r#"{"requests": [{"arrival_cycle": 9007199254740993, "n_tokens": 1}]}"#,
            r#"{"requests": [{"arrival_cycle": 0, "n_tokens": 1e300}]}"#,
        ] {
            assert!(parse_trace(&Json::parse(bad).unwrap()).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn load_trace_roundtrips_through_a_file() {
        let path = std::env::temp_dir().join(format!("pim_trace_{}.json", std::process::id()));
        std::fs::write(&path, r#"{"requests": [{"arrival_cycle": 12, "n_tokens": 3}]}"#).unwrap();
        let t = load_trace(path.to_str().unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t, vec![TraceRequest { arrival_cycle: 12, n_tokens: 3, prompt_tokens: 1 }]);
        assert!(load_trace("/nonexistent/trace.json").is_err());
    }
}
