//! Event-driven clock-cycle-accurate simulator of the PIM-GPT system.

pub mod engine;
pub mod stats;

pub use engine::{Simulator, StepResult};
pub use stats::{LatClass, SimStats};
