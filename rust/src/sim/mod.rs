//! Event-driven clock-cycle-accurate simulator of the PIM-GPT system.
//!
//! Layered as: explicit hardware resources with `busy_until`
//! reservations ([`resources`]), a single-stream front end ([`engine`],
//! the paper's simulator) and a multi-request interleaving scheduler
//! ([`sched`]) — both front ends execute instructions through the same
//! `Resources::issue` path, so K = 1 interleaved scheduling reproduces
//! the single-stream simulator exactly. Requests carry an explicit
//! prompt/generation split: prompts run as batched *prefill chunks*
//! ([`prefill`] — matrix-matrix programs that amortize DRAM row
//! activations and ASIC pipeline fills over the chunk), generation as
//! per-token decode steps, and TTFT measures the real first *generated*
//! token. Open-loop request arrivals (batch / fixed / Poisson / trace
//! replay) come from [`arrivals`] and feed the tail-latency percentiles
//! in [`stats`]; *which* request runs next — and whether it is admitted
//! at all under a latency SLO — is the pluggable policy subsystem in
//! [`policy`]. With `sched.batch_decode` on, the scheduler additionally
//! fuses ready decode tokens *across* streams into one multi-pass
//! weight sweep (continuous batching): weight-stationary VMMs issue
//! once with `passes = K` while per-stream KV attention stays separate,
//! amortizing DRAM row activations and ASIC pipeline fills over the
//! batch. With `sched.devices > 1`, [`fleet`] partitions the model
//! across several PIM packages (layer-pipeline or tensor-parallel, see
//! `mapping::partition`) and composes calibrated per-device step costs
//! with modeled interconnect transfers. Every lifecycle edge in both
//! engines can be recorded by the deterministic event-tracing layer in
//! [`trace`] (`sched.trace = off|jsonl:<path>|chrome:<path>`), which
//! also bins a windowed utilization timeline into `SimStats` when
//! `sched.trace_window > 0`. The profiling observer in [`profile`]
//! (`sched.profile = off|text:<path>|json:<path>`) aggregates the same
//! event stream online into an exactly-reconciling cycle-attribution
//! tree, span-latency histograms and a calibrated per-span cost table
//! (`pim-gpt profile`, `figures --fig profile`). See `sim/README.md`.

pub mod arrivals;
pub mod engine;
pub mod fleet;
pub mod policy;
pub mod prefill;
pub mod profile;
pub mod resources;
pub mod sched;
pub mod stats;
pub mod trace;

pub use arrivals::{ArrivalSpec, TraceRequest};
pub use engine::{Simulator, StepResult};
pub use fleet::{FleetSim, PrebuiltFleet};
pub use policy::{AdmissionPolicy, PickPolicy, PolicySpec};
pub use prefill::Chunk;
pub use profile::{
    calibrate, CalibrationReport, CostTable, PredictedCost, Profile, ProfileSink, ProfileSpec,
};
pub use resources::Resources;
pub use sched::{MultiSim, RejectedStream, StreamOutcome, StreamResult, StreamSpec};
pub use stats::{LatClass, LatencyReport, Percentiles, SimStats, StreamStats};
pub use trace::{
    validate_chrome, ChromeSink, JsonlSink, NullSink, TraceCounts, TraceEvent, TraceSink,
    TraceSpec, TraceWindow, Tracer,
};
