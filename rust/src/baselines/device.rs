//! Analytical device models for the GPU/CPU baselines.
//!
//! Per-token latency of eager-mode transformer decoding on a
//! throughput-oriented device is dominated by three terms:
//!
//! ```text
//! t_token = fixed + n_kernels * dispatch + max(bytes/bw_eff, flops/tput_eff)
//! ```
//!
//! * `fixed` — per-token framework overhead (python, sampling, cache
//!   bookkeeping);
//! * `dispatch` — per-kernel launch/dispatch latency; eager GPT decoding
//!   launches ~15 kernels per layer;
//! * the roofline term — weight + KV traffic at *effective* bandwidth
//!   (skinny VMMs stream weights with poor utilization), or compute at
//!   effective throughput, whichever dominates. Batch-1 decoding is
//!   always memory-bound on these devices (Fig. 1b), which is the
//!   paper's motivation.

use crate::model::GptModel;

/// An analytical baseline device.
#[derive(Clone, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Peak memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Effective fraction of peak bandwidth for batch-1 VMM streaming.
    pub mem_eff: f64,
    /// Peak compute, FLOP/s (fp16 for GPU, fp32 AVX-512 for CPU).
    pub flops: f64,
    /// Effective fraction of peak compute for skinny VMMs.
    pub flops_eff: f64,
    /// Per-kernel dispatch overhead, seconds.
    pub dispatch_s: f64,
    /// Kernels launched per transformer layer in eager decoding.
    pub kernels_per_layer: f64,
    /// Fixed per-token overhead, seconds.
    pub fixed_s: f64,
    /// Average device power during decoding, watts (measured dynamic
    /// power in the paper's setup).
    pub power_w: f64,
    /// Bytes per weight element (fp16 on GPU, fp32 on CPU torch).
    pub bytes_per_param: f64,
}

impl DeviceModel {
    /// Latency of decoding one token at context length `ltoken`.
    pub fn token_latency_s(&self, m: &GptModel, ltoken: u64) -> f64 {
        let weight_bytes = m.n_params() as f64 * self.bytes_per_param;
        // KV cache read+write traffic at this context length.
        let kv_bytes = (2 * m.n_layer * m.d_model) as f64 * ltoken as f64 * self.bytes_per_param;
        let bytes = weight_bytes + kv_bytes;
        let flops = m.flops_per_token(ltoken) as f64;
        let roofline = (bytes / (self.mem_bw * self.mem_eff))
            .max(flops / (self.flops * self.flops_eff));
        let kernels = self.kernels_per_layer * m.n_layer as f64 + 10.0;
        self.fixed_s + kernels * self.dispatch_s + roofline
    }

    /// Total latency of generating `n_tokens` from an empty context.
    pub fn run_latency_s(&self, m: &GptModel, n_tokens: u64) -> f64 {
        // Sum over token positions; the roofline term varies only through
        // the KV traffic, which is linear in position -> use the exact
        // arithmetic-series midpoint instead of an O(n) loop.
        let mid = (n_tokens.saturating_sub(1)) / 2;
        self.token_latency_s(m, mid.max(1)) * n_tokens as f64
    }

    /// Energy of the run: measured-style dynamic power x latency.
    pub fn run_energy_j(&self, m: &GptModel, n_tokens: u64) -> f64 {
        self.run_latency_s(m, n_tokens) * self.power_w
    }
}

/// NVIDIA T4 (GDDR6, 320 GB/s peak, 65 TFLOPS fp16) under eager torch.
/// Calibrated once against the paper's Table II anchor (GPT2-medium:
/// ~89x speedup, ~618x energy over this baseline); `mem_eff = 0.25` is
/// the measured effective bandwidth of batch-1 fp16 decoding on T4-class
/// parts, `dispatch_s` the eager-mode kernel launch cost.
pub fn gpu_t4() -> DeviceModel {
    DeviceModel {
        name: "gpu-t4",
        mem_bw: 320e9,
        mem_eff: 0.25,
        flops: 65e12,
        flops_eff: 0.10,
        dispatch_s: 45e-6,
        kernels_per_layer: 15.0,
        fixed_s: 2.0e-3,
        power_w: 70.0,
        bytes_per_param: 2.0,
    }
}

/// Intel Xeon Gold 6154 (18 cores, ~120 GB/s peak) under fp32 eager
/// torch. The paper's python/s-tui setup measures very low effective
/// bandwidth (strided fp32 weight streaming thrashing caches) and a
/// small above-idle *dynamic* power delta during memory-stall-bound
/// decoding; both constants are fixed jointly so the CPU speedup and
/// energy bands of Fig. 8/9 are reproduced by one parameter set.
pub fn cpu_xeon_6154() -> DeviceModel {
    DeviceModel {
        name: "cpu-xeon-6154",
        mem_bw: 120e9,
        mem_eff: 0.07,
        flops: 2.6e12,
        flops_eff: 0.05,
        dispatch_s: 150e-6,
        kernels_per_layer: 15.0,
        fixed_s: 20.0e-3,
        power_w: 13.0,
        bytes_per_param: 4.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::model::PAPER_MODELS;

    #[test]
    fn gpu_latency_grows_with_model() {
        let gpu = gpu_t4();
        let s = gpu.token_latency_s(&by_name("gpt2-small").unwrap(), 512);
        let xl = gpu.token_latency_s(&by_name("gpt3-xl").unwrap(), 512);
        assert!(xl > 2.0 * s);
    }

    #[test]
    fn cpu_slower_than_gpu() {
        let gpu = gpu_t4();
        let cpu = cpu_xeon_6154();
        for m in &PAPER_MODELS {
            assert!(
                cpu.run_latency_s(m, 64) > gpu.run_latency_s(m, 64),
                "{}", m.name
            );
        }
    }

    #[test]
    fn gpu_token_latency_order_of_magnitude() {
        // T4 eager GPT2-medium decoding is ~tens of ms per token.
        let t = gpu_t4().token_latency_s(&by_name("gpt2-medium").unwrap(), 512);
        assert!(t > 5e-3 && t < 60e-3, "{t}");
    }

    #[test]
    fn memory_bound_not_compute_bound() {
        // Fig. 1b motivation: batch-1 GPT decoding is memory-bound.
        let gpu = gpu_t4();
        for m in &PAPER_MODELS {
            let bytes = m.n_params() as f64 * 2.0;
            let mem_t = bytes / (gpu.mem_bw * gpu.mem_eff);
            let comp_t = m.flops_per_token(1024) as f64 / (gpu.flops * gpu.flops_eff);
            assert!(mem_t > comp_t, "{} compute-bound?", m.name);
        }
    }

    #[test]
    fn energy_proportional_to_latency() {
        let gpu = gpu_t4();
        let m = by_name("gpt2-small").unwrap();
        let e1 = gpu.run_energy_j(&m, 64);
        let e2 = gpu.run_energy_j(&m, 128);
        assert!((e2 / e1 - 2.0).abs() < 0.2);
    }
}
