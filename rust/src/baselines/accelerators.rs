//! Reported numbers of prior GPT accelerators (paper Table II) — used to
//! regenerate the comparison table.

/// A prior accelerator's published results.
#[derive(Clone, Copy, Debug)]
pub struct PriorAccel {
    pub name: &'static str,
    pub memory: &'static str,
    pub end_to_end: bool,
    pub pim: bool,
    pub data_type: &'static str,
    pub largest_model: &'static str,
    pub longest_token: Option<u64>,
    /// Speedup over their GPU baseline.
    pub speedup: f64,
    /// Energy efficiency over their GPU baseline (None = not reported).
    pub energy_eff: Option<f64>,
}

/// Table II rows for SpAtten, TransPIM and DFX (as published).
pub const PRIOR_ACCELERATORS: [PriorAccel; 3] = [
    PriorAccel {
        name: "SpAtten",
        memory: "HBM",
        end_to_end: false,
        pim: false,
        data_type: "INT",
        largest_model: "GPT2-medium",
        longest_token: Some(32),
        speedup: 35.0,
        energy_eff: Some(382.0),
    },
    PriorAccel {
        name: "TransPIM",
        memory: "HBM",
        end_to_end: false,
        pim: true,
        data_type: "INT",
        largest_model: "GPT2-medium",
        longest_token: None,
        speedup: 33.0,
        energy_eff: Some(250.0),
    },
    PriorAccel {
        name: "DFX",
        memory: "HBM+DDR",
        end_to_end: true,
        pim: false,
        data_type: "FP16",
        largest_model: "GPT2-XL",
        longest_token: Some(128),
        speedup: 3.2,
        energy_eff: Some(3.99),
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_static_data() {
        assert_eq!(PRIOR_ACCELERATORS.len(), 3);
        let spatten = &PRIOR_ACCELERATORS[0];
        assert_eq!(spatten.speedup, 35.0);
        let dfx = &PRIOR_ACCELERATORS[2];
        assert!(dfx.end_to_end);
        assert_eq!(dfx.longest_token, Some(128));
    }
}
