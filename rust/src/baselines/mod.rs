//! GPU / CPU baselines and prior-accelerator comparison data.
//!
//! The paper *measures* an NVIDIA T4 (torch + `torch.cuda.Event`/pynvml)
//! and an Intel Xeon Gold 6154 (`time.time()` + s-tui). Neither device
//! is available offline, so these are analytical roofline+overhead
//! models whose constants were calibrated once against the paper's
//! anchor (GPT2-medium: 89x speedup, 618x energy vs T4 — Table II) and
//! then *held fixed* across all 8 models; every per-model number is
//! therefore a prediction of the model, not a fit (DESIGN.md §5-6).

pub mod accelerators;
pub mod device;

pub use accelerators::{PriorAccel, PRIOR_ACCELERATORS};
pub use device::{cpu_xeon_6154, gpu_t4, DeviceModel};
