//! Minimal JSON parser + emitter (offline stand-in for serde_json, see
//! DESIGN.md §5). Supports the full JSON grammar minus `\u` surrogate
//! pairs; numbers are f64. Used for artifact metadata, config overrides
//! and machine-readable report output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience that threads Options.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code)
                            .ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 in place.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
                None => return Err(self.err("eof in string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.'
            || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn prop_roundtrip_random_trees() {
        fn random_json(rng: &mut crate::util::rng::Rng, depth: u32) -> Json {
            match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool()),
                2 => Json::Num((rng.gen_range(2_000_001) as f64 - 1e6) / 8.0),
                3 => Json::Str(format!("s{}", rng.gen_range(1000))),
                4 => Json::Arr((0..rng.gen_range(4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect()),
                _ => Json::Obj((0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect()),
            }
        }
        check("json display/parse roundtrip", 200, |rng| {
            let v = random_json(rng, 3);
            let v2 = Json::parse(&v.to_string())
                .map_err(|e| format!("{e} in {v}"))?;
            if v == v2 { Ok(()) } else { Err(format!("{v} != {v2}")) }
        });
    }
}
