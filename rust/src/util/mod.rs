//! Small self-contained utilities.
//!
//! The offline build environment provides no serde/clap/criterion/proptest,
//! so this module carries minimal replacements (documented in DESIGN.md §5):
//! a JSON parser/emitter, a seeded xorshift RNG, a tiny property-test
//! harness, an ascii table formatter and a wall-clock bench harness.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `n` up to the next multiple of `mult`.
#[inline]
pub fn pad_to(n: u64, mult: u64) -> u64 {
    ceil_div(n, mult) * mult
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn pad_to_basics() {
        assert_eq!(pad_to(0, 16), 0);
        assert_eq!(pad_to(1, 16), 16);
        assert_eq!(pad_to(16, 16), 16);
        assert_eq!(pad_to(17, 16), 32);
    }
}
