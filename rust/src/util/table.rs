//! Ascii table formatting for the figure/table reports.

/// A simple left-aligned ascii table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s.push('\n');
            s
        };
        let sep = {
            let mut s = String::from("+");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push_str(&line(&self.header));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out.push_str(&sep);
        out
    }
}

/// Format a f64 with engineering-style precision (3 significant digits).
pub fn sig3(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 100.0 {
        format!("{v:.0}")
    } else if a >= 10.0 {
        format!("{v:.1}")
    } else if a >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

/// Human latency: picks ns/us/ms/s.
pub fn fmt_time_s(seconds: f64) -> String {
    let a = seconds.abs();
    if a >= 1.0 {
        format!("{} s", sig3(seconds))
    } else if a >= 1e-3 {
        format!("{} ms", sig3(seconds * 1e3))
    } else if a >= 1e-6 {
        format!("{} us", sig3(seconds * 1e6))
    } else {
        format!("{} ns", sig3(seconds * 1e9))
    }
}

/// Human energy: picks pJ/nJ/uJ/mJ/J.
pub fn fmt_energy_j(joules: f64) -> String {
    let a = joules.abs();
    if a >= 1.0 {
        format!("{} J", sig3(joules))
    } else if a >= 1e-3 {
        format!("{} mJ", sig3(joules * 1e3))
    } else if a >= 1e-6 {
        format!("{} uJ", sig3(joules * 1e6))
    } else if a >= 1e-9 {
        format!("{} nJ", sig3(joules * 1e9))
    } else {
        format!("{} pJ", sig3(joules * 1e12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["model", "x"]);
        t.row(vec!["gpt2-small", "1"]);
        t.row(vec!["a", "1234"]);
        let s = t.render();
        assert!(s.contains("| model      | x    |"), "{s}");
        assert!(s.contains("| gpt2-small | 1    |"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(sig3(0.0), "0");
        assert_eq!(sig3(123.4), "123");
        assert_eq!(sig3(12.34), "12.3");
        assert_eq!(fmt_time_s(0.0025), "2.50 ms");
        assert_eq!(fmt_energy_j(3.3e-7), "330 nJ");
    }
}
