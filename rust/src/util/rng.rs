//! Seeded xorshift64* RNG — deterministic, dependency-free.
//!
//! Used for synthetic workload generation, the property-test harness and
//! anywhere the simulator needs reproducible randomness. Not cryptographic.

/// xorshift64* PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)` (usize convenience).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
