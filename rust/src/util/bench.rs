//! Wall-clock micro-bench harness (offline stand-in for criterion, see
//! DESIGN.md §5). Used by the `rust/benches/*` targets, which are plain
//! `harness = false` binaries run by `cargo bench`.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub total_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  min {:>12}  max {:>12}",
            self.name,
            self.iters,
            super::table::fmt_time_s(self.mean_s),
            super::table::fmt_time_s(self.min_s),
            super::table::fmt_time_s(self.max_s),
        )
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let total_s = start.elapsed().as_secs_f64();
    let mean_s = times.iter().sum::<f64>() / times.len().max(1) as f64;
    let min_s = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_s = times.iter().cloned().fold(0.0, f64::max);
    let r = BenchResult { name: name.to_string(), iters, total_s, mean_s, min_s, max_s };
    println!("{}", r.report());
    r
}

/// Opaque value sink preventing the optimizer from eliding benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
    }
}
