//! Miniature property-based testing harness (offline stand-in for
//! `proptest`, see DESIGN.md §5).
//!
//! ```no_run
//! use pim_gpt::util::prop::check;
//! check("addition commutes", 200, |rng| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```
//!
//! Each case gets an independently-seeded RNG; on failure the panic message
//! carries the case seed so the exact input can be replayed.

use super::rng::Rng;

/// Run `iters` random cases of `f`. Panics (test failure) on the first
/// `Err`, reporting the failing seed.
pub fn check<F>(name: &str, iters: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..iters {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("xor involution", 100, |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            if (x ^ k) ^ k == x { Ok(()) } else { Err(format!("{x} {k}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics() {
        check("always fails", 10, |_| Err("nope".into()));
    }
}
