//! Hardware and simulation configuration — paper Table I is the default.
//!
//! Every sensitivity/scalability experiment (Fig. 12/13/15) is a pure
//! config transformation: ASIC frequency scaling, memory-interface data
//! rate, MAC width and channel count are all knobs here. Configs can be
//! overridden from a JSON file (`HwConfig::from_json`), giving the
//! "real config system" of the launcher.

use crate::mapping::PartitionStrategy;
use crate::sim::arrivals::ArrivalSpec;
use crate::sim::policy::PolicySpec;
use crate::sim::profile::ProfileSpec;
use crate::sim::trace::TraceSpec;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// GDDR6 timing constraints, in nanoseconds (1 cycle = 1 ns @ 1 GHz).
/// Values from Table I; tRAS is not published there — we use a
/// conservative GDDR5-class 28 ns (documented assumption, DESIGN.md §6).
#[derive(Clone, Debug, PartialEq)]
pub struct TimingConfig {
    pub trcd: u64,
    pub trp: u64,
    pub tccd: u64,
    pub twr: u64,
    pub trfc: u64,
    pub trefi: u64,
    pub tras: u64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self { trcd: 12, trp: 12, tccd: 1, twr: 12, trfc: 455, trefi: 6825, tras: 28 }
    }
}

/// DRAM IDD current values (mA), Table I (DDR5 datasheet-derived).
#[derive(Clone, Debug, PartialEq)]
pub struct IddConfig {
    pub idd2n: f64,
    pub idd3n: f64,
    pub idd0: f64,
    pub idd4r: f64,
    pub idd4w: f64,
    pub idd5b: f64,
}

impl Default for IddConfig {
    fn default() -> Self {
        Self { idd2n: 92.0, idd3n: 142.0, idd0: 122.0, idd4r: 530.0, idd4w: 470.0, idd5b: 277.0 }
    }
}

/// GDDR6 geometry + interface (Table I).
#[derive(Clone, Debug, PartialEq)]
pub struct Gddr6Config {
    pub channels: usize,
    pub banks_per_channel: usize,
    /// Per-channel capacity in gigabits.
    pub capacity_gbit: f64,
    /// Bytes per DRAM row (2 KB -> 1024 bf16 values).
    pub row_bytes: usize,
    /// DRAM core frequency in GHz (1 cycle = 1/freq ns).
    pub freq_ghz: f64,
    pub pins_per_channel: usize,
    /// Interface data rate per pin, Gb/s (Fig. 13 sweeps this).
    pub gbps_per_pin: f64,
    /// Supply voltage (GDDR6: 1.25 V).
    pub vdd: f64,
}

impl Default for Gddr6Config {
    fn default() -> Self {
        Self {
            channels: 8,
            banks_per_channel: 16,
            capacity_gbit: 4.0,
            row_bytes: 2048,
            freq_ghz: 1.0,
            pins_per_channel: 16,
            gbps_per_pin: 16.0,
            vdd: 1.25,
        }
    }
}

impl Gddr6Config {
    /// Rows per bank, derived: capacity / banks / row size. DRAM capacity
    /// is binary: 4 Gb = 4 x 2^30 bits -> 16384 rows (paper: "16k").
    pub fn rows_per_bank(&self) -> u64 {
        let bytes_per_channel = (self.capacity_gbit * (1u64 << 30) as f64 / 8.0) as u64;
        bytes_per_channel / self.banks_per_channel as u64 / self.row_bytes as u64
    }

    /// bf16 values per row.
    pub fn row_elems(&self) -> u64 {
        (self.row_bytes / 2) as u64
    }

    /// Per-channel interface bandwidth in bytes/second.
    pub fn channel_bw_bytes_per_s(&self) -> f64 {
        self.pins_per_channel as f64 * self.gbps_per_pin * 1e9 / 8.0
    }

    /// Interface bytes transferred per DRAM clock cycle per channel.
    pub fn channel_bytes_per_cycle(&self) -> f64 {
        self.channel_bw_bytes_per_s() / (self.freq_ghz * 1e9)
    }
}

/// PIM extensions to the DRAM chip (Table I).
#[derive(Clone, Debug, PartialEq)]
pub struct PimConfig {
    /// Global buffer per channel, bytes (2 KB).
    pub gb_bytes: usize,
    /// Multiplier lanes per bank MAC unit (16; Fig. 15a sweeps 16..64).
    pub mac_lanes: usize,
    /// MAC power for the 16 units of one channel, mW (synthesized, x1.5
    /// routing margin — paper §V.A).
    pub mac_power_mw_per_channel: f64,
    /// MAC pipeline depth: multiplier stage + log2(lanes) adder-tree
    /// stages; affects only the fill latency of each segment.
    pub pipeline_fill: u64,
}

impl Default for PimConfig {
    fn default() -> Self {
        Self { gb_bytes: 2048, mac_lanes: 16, mac_power_mw_per_channel: 149.29, pipeline_fill: 5 }
    }
}

impl PimConfig {
    /// bf16 elements the global buffer can hold.
    pub fn gb_elems(&self) -> usize {
        self.gb_bytes / 2
    }
}

/// ASIC configuration (Table I + synthesis results §V.A).
#[derive(Clone, Debug, PartialEq)]
pub struct AsicConfig {
    /// Clock in GHz (Fig. 12 sweeps 1.0 down to 0.1).
    pub freq_ghz: f64,
    pub sram_kb: usize,
    pub n_adders: usize,
    pub n_multipliers: usize,
    pub area_mm2: f64,
    /// Peak power, mW.
    pub power_mw: f64,
}

impl Default for AsicConfig {
    fn default() -> Self {
        Self { freq_ghz: 1.0, sram_kb: 128, n_adders: 256, n_multipliers: 128, area_mm2: 0.64, power_mw: 304.59 }
    }
}

/// Request-scheduling configuration (multi-stream serving; not a paper
/// knob — the paper simulates one sequence at a time, which is K = 1).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// Maximum decode streams interleaved on the hardware at once. The
    /// mapping reserves one disjoint `max_seq` KV context per stream
    /// (`mapping::KvReservation`); if DRAM rows cannot hold that many
    /// next to the weights, the effective concurrency degrades to the
    /// largest count that fits (`ModelMapping::kv_shortfall`).
    pub max_streams: usize,
    /// Open-loop arrival process for serving experiments (JSON string
    /// key `sched.arrival`: `batch`, `fixed:<cycles>`,
    /// `poisson:<req/s>` or `trace:<file>`). `batch` reproduces the
    /// paper's closed-loop behavior.
    pub arrival: ArrivalSpec,
    /// Seed for stochastic arrival generators (Poisson). Identical
    /// seeds replay identical traces — the simulator never consults a
    /// wall clock or OS RNG.
    pub seed: u64,
    /// Scheduling policy (JSON string key `sched.policy`: `fcfs`,
    /// `srf`, `fair`, `slo` or `slo:<ttft-cycles>`; CLI `--policy`).
    /// `fcfs` reproduces the pre-policy scheduler cycle-for-cycle —
    /// see `sim::policy`.
    pub policy: PolicySpec,
    /// TTFT budget (DRAM cycles) the SLO admission policy judges
    /// against; only consulted when `policy` is `slo`. `slo:<cycles>`
    /// and JSON `sched.slo_ttft_cycles` both override it. The default
    /// is 2 ms at the 1 GHz Table I clock.
    pub slo_ttft_cycles: u64,
    /// Prefill chunk size (JSON key `sched.prefill_chunk`): how many
    /// consecutive prompt positions one prefill program covers
    /// (`sim::prefill`). Larger chunks amortize more DRAM row
    /// activations / GB staging / ASIC pipeline fills over the prompt
    /// but hold shared resources longer per instruction (head-of-line
    /// blocking for concurrent streams). 1 = token-by-token prefill,
    /// cycle-identical to the historical no-prefill engine.
    pub prefill_chunk: u64,
    /// Cross-stream batched decode (JSON key `sched.batch_decode`, 0 or
    /// 1; CLI `serve --batch-decode on|off`). When on, active streams
    /// whose next step is a decode token in the same position regime
    /// are fused into one multi-pass weight sweep: the
    /// weight-stationary VMMs and fixed-size ASIC ops issue once with
    /// `passes = K` (one ACT/PRE sweep, one ASIC pipeline fill shared
    /// by all K tokens) while per-stream KV attention stays separate
    /// (slots are disjoint). Off (the default) is cycle-identical to
    /// the unbatched engine on any arrival trace.
    pub batch_decode: bool,
    /// Paged KV cache (JSON key `sched.kv_paging`, 0 or 1; CLI
    /// `serve --kv-paging on|off`). When on, the per-stream KV row
    /// budget is carved into fixed-size page frames
    /// (`kv_page_tokens` positions each) held in a free list; each
    /// stream owns a page table, KV reads/writes resolve through it at
    /// issue time, frames are allocated on demand as decode advances,
    /// and exhaustion preempts a victim stream (modeled
    /// writeback/restore cost) — `sim::sched`. Off (the default) keeps
    /// the static contiguous per-stream slot and is cycle-identical to
    /// the historical engine on any arrival trace. Paging with page
    /// size = `max_seq` and `kv_oversub` = 1 is also cycle-identical
    /// (one frame == one slot) — the pinned equivalence anchor.
    pub kv_paging: bool,
    /// KV page size in token positions (JSON key
    /// `sched.kv_page_tokens`). Rounded up to a multiple of the unit
    /// count and capped at (padded) `max_seq` at mapping time
    /// (`mapping::kv_reserve::round_page_tokens`), so the
    /// token-to-unit interleave is page-invariant. Only consulted when
    /// `kv_paging` is on.
    pub kv_page_tokens: u64,
    /// KV oversubscription ratio >= 1.0 (JSON key `sched.kv_oversub`).
    /// Admission commits streams against `floor(n_frames *
    /// kv_oversub)` worst-case frames, betting that most streams
    /// finish before reaching `max_seq`; a lost bet is a page fault
    /// resolved by preempting a victim. 1.0 (the default) can never
    /// fault. Only consulted when `kv_paging` is on.
    pub kv_oversub: f64,
    /// Paged-KV eviction low watermark in [0, 1] (JSON key
    /// `sched.kv_evict_watermark`). When > 0, a faulting stream keeps
    /// evicting victims until `ceil(watermark * n_frames)` frames are
    /// free (not just one), so eviction stops competing with admission
    /// for the same frames on every subsequent fault — the swap-thrash
    /// cliff smoother. 0.0 (the default) evicts exactly one victim per
    /// fault, cycle-identical to the historical paged engine. Only
    /// consulted when `kv_paging` is on.
    pub kv_evict_watermark: f64,
    /// Number of PIM-GPT devices (packages) the model is partitioned
    /// across (JSON key `sched.devices`). 1 (the default) is the
    /// paper's single 8-channel package, byte-identical to the
    /// historical engine. N > 1 splits the model with the
    /// `partition` strategy (`mapping::DevicePartition`) and runs the
    /// fleet engine (`sim::fleet::FleetSim`) with modeled interconnect
    /// hops (`link_gbit_s`, `link_hop_cycles`).
    pub devices: usize,
    /// Device-partitioning strategy (JSON string key `sched.partition`:
    /// `layer_pipeline` or `tensor_parallel`). Only consulted when
    /// `devices > 1`.
    pub partition: PartitionStrategy,
    /// Inter-device link bandwidth in Gbit/s (JSON key
    /// `sched.link_gbit_s`). The default 256 Gbit/s = 32 B/cycle at
    /// the 1 GHz Table I clock — one channel's interface bandwidth,
    /// a conservative package-to-package serdes.
    pub link_gbit_s: f64,
    /// Fixed per-hop link latency in DRAM cycles (JSON key
    /// `sched.link_hop_cycles`): serialization/protocol overhead paid
    /// once per transfer on top of the byte cost.
    pub link_hop_cycles: u64,
    /// Event tracing (JSON string key `sched.trace`: `off`,
    /// `jsonl:<path>` or `chrome:<path>`; CLI `serve --trace`). When
    /// on, the engine records a typed event at every request-lifecycle
    /// edge (`sim::trace`) and renders the artifact after the run; the
    /// CLI/server writes it to the named path. `off` (the default) is
    /// byte-identical and allocation-free — and tracing on never
    /// changes a simulated cycle (sinks are pure observers).
    pub trace: TraceSpec,
    /// Utilization-timeline window in DRAM cycles (JSON key
    /// `sched.trace_window`). When > 0, `SimStats::timeline` gets one
    /// row per window with busy/idle/link cycles and pages-in-use
    /// (`figures --fig timeline`). 0 (the default) disables the
    /// timeline. Independent of `trace`: either can be on alone.
    pub trace_window: u64,
    /// Online profiling (JSON string key `sched.profile`: `off`,
    /// `text:<path>` or `json:<path>`; CLI `serve --profile`). When
    /// on, a `sim::profile::ProfileSink` rides the tracer and
    /// aggregates spans into the hierarchical cycle-attribution tree,
    /// span-latency histograms and the calibrated `CostTable`
    /// (`pim-gpt profile`). Like `trace`, it is a pure observer:
    /// profiling on never changes a simulated cycle.
    pub profile: ProfileSpec,
    /// Run the trace-vs-stats reconciliation tallies in release builds
    /// too (JSON key `sched.strict_reconcile`, 0/1). Debug builds
    /// always reconcile and panic on mismatch; with this on, release
    /// builds record a structured `SimStats::reconcile_error` instead
    /// of panicking, and the server surfaces it in `ServerMetrics`.
    pub strict_reconcile: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_streams: 4,
            arrival: ArrivalSpec::Batch,
            seed: 0x5EED,
            policy: PolicySpec::Fcfs,
            slo_ttft_cycles: 2_000_000,
            prefill_chunk: 32,
            batch_decode: false,
            kv_paging: false,
            kv_page_tokens: 128,
            kv_oversub: 1.0,
            kv_evict_watermark: 0.0,
            devices: 1,
            partition: PartitionStrategy::LayerPipeline,
            link_gbit_s: 256.0,
            link_hop_cycles: 250,
            trace: TraceSpec::Off,
            trace_window: 0,
            profile: ProfileSpec::Off,
            strict_reconcile: false,
        }
    }
}

impl SchedulerConfig {
    /// Apply a policy string (`fcfs | srf | fair | slo[:<ttft-cycles>]`,
    /// the shared CLI/JSON spelling); `slo:<cycles>` also overrides
    /// `slo_ttft_cycles`.
    pub fn set_policy_str(&mut self, s: &str) -> Result<()> {
        let (policy, budget) = PolicySpec::parse(s)?;
        self.policy = policy;
        if let Some(cycles) = budget {
            self.slo_ttft_cycles = cycles;
        }
        Ok(())
    }
}

/// Full system configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HwConfig {
    pub timing: TimingConfig,
    pub idd: IddConfig,
    pub gddr6: Gddr6Config,
    pub pim: PimConfig,
    pub asic: AsicConfig,
    pub sched: SchedulerConfig,
}

impl HwConfig {
    /// Paper Table I baseline.
    pub fn paper_baseline() -> Self {
        Self::default()
    }

    /// Total MAC units in the system.
    pub fn total_mac_units(&self) -> usize {
        self.gddr6.channels * self.gddr6.banks_per_channel
    }

    /// Fig. 12 knob: scale ASIC frequency.
    pub fn with_asic_freq_ghz(mut self, f: f64) -> Self {
        assert!(f > 0.0);
        self.asic.freq_ghz = f;
        self
    }

    /// Fig. 13 knob: memory interface data rate (Gb/s/pin).
    pub fn with_data_rate_gbps(mut self, r: f64) -> Self {
        assert!(r > 0.0);
        self.gddr6.gbps_per_pin = r;
        self
    }

    /// Fig. 15a knob: MAC lanes per bank.
    pub fn with_mac_lanes(mut self, lanes: usize) -> Self {
        assert!(lanes.is_power_of_two());
        self.pim.mac_lanes = lanes;
        self.pim.pipeline_fill = 1 + (lanes as f64).log2() as u64;
        self
    }

    /// Fig. 15b knob: number of PIM channels.
    pub fn with_channels(mut self, ch: usize) -> Self {
        assert!(ch > 0);
        self.gddr6.channels = ch;
        self
    }

    /// Serving knob: concurrent decode streams (K). K = 1 reproduces the
    /// paper's single-sequence FIFO behavior exactly.
    pub fn with_max_streams(mut self, k: usize) -> Self {
        assert!(k > 0);
        self.sched.max_streams = k;
        self
    }

    /// Serving knob: open-loop arrival process.
    pub fn with_arrival(mut self, spec: ArrivalSpec) -> Self {
        self.sched.arrival = spec;
        self
    }

    /// Serving knob: arrival-generator seed.
    pub fn with_arrival_seed(mut self, seed: u64) -> Self {
        self.sched.seed = seed;
        self
    }

    /// Serving knob: prefill chunk size (positions per chunk program;
    /// 1 = token-by-token prefill, the historical behavior).
    pub fn with_prefill_chunk(mut self, chunk: u64) -> Self {
        assert!(chunk >= 1);
        self.sched.prefill_chunk = chunk;
        self
    }

    /// Serving knob: cross-stream batched decode (off reproduces the
    /// unbatched engine cycle-for-cycle).
    pub fn with_batch_decode(mut self, on: bool) -> Self {
        self.sched.batch_decode = on;
        self
    }

    /// Serving knob: paged KV cache (off reproduces the static-slot
    /// engine cycle-for-cycle).
    pub fn with_kv_paging(mut self, on: bool) -> Self {
        self.sched.kv_paging = on;
        self
    }

    /// Serving knob: KV page size in token positions (rounded up to
    /// the unit count and capped at `max_seq` at mapping time).
    pub fn with_kv_page_tokens(mut self, tokens: u64) -> Self {
        assert!(tokens >= 1);
        self.sched.kv_page_tokens = tokens;
        self
    }

    /// Serving knob: KV oversubscription ratio (>= 1.0; 1.0 never
    /// faults).
    pub fn with_kv_oversub(mut self, ratio: f64) -> Self {
        assert!(ratio >= 1.0);
        self.sched.kv_oversub = ratio;
        self
    }

    /// Serving knob: paged-KV eviction low watermark (fraction of the
    /// frame pool kept free by faulting streams; 0.0 = evict exactly
    /// one victim per fault, the historical behavior).
    pub fn with_kv_evict_watermark(mut self, watermark: f64) -> Self {
        assert!((0.0..=1.0).contains(&watermark));
        self.sched.kv_evict_watermark = watermark;
        self
    }

    /// Fleet knob: number of PIM-GPT devices the model is partitioned
    /// across (1 = the paper's single package).
    pub fn with_devices(mut self, devices: usize) -> Self {
        assert!(devices >= 1);
        self.sched.devices = devices;
        self
    }

    /// Fleet knob: device-partitioning strategy (only consulted when
    /// `devices > 1`).
    pub fn with_partition(mut self, strategy: PartitionStrategy) -> Self {
        self.sched.partition = strategy;
        self
    }

    /// Fleet knob: inter-device link bandwidth (Gbit/s).
    pub fn with_link_gbit_s(mut self, gbit_s: f64) -> Self {
        assert!(gbit_s > 0.0);
        self.sched.link_gbit_s = gbit_s;
        self
    }

    /// Fleet knob: fixed per-hop link latency (DRAM cycles).
    pub fn with_link_hop_cycles(mut self, cycles: u64) -> Self {
        self.sched.link_hop_cycles = cycles;
        self
    }

    /// Observability knob: event-trace sink spec (`off`, `jsonl:<path>`
    /// or `chrome:<path>` — the `serve --trace` spelling). Panics on a
    /// malformed spec, like the other asserting builders; config files
    /// and the CLI go through the error-returning parse instead.
    pub fn with_trace(mut self, spec: &str) -> Self {
        self.sched.trace = TraceSpec::parse(spec).expect("valid trace spec");
        self
    }

    /// Observability knob: utilization-timeline window in cycles
    /// (0 = timeline off).
    pub fn with_trace_window(mut self, window: u64) -> Self {
        self.sched.trace_window = window;
        self
    }

    /// Observability knob: online-profiler spec (`off`, `text:<path>`
    /// or `json:<path>` — the `serve --profile` spelling). Panics on a
    /// malformed spec, like `with_trace`.
    pub fn with_profile(mut self, spec: &str) -> Self {
        self.sched.profile = ProfileSpec::parse(spec).expect("valid profile spec");
        self
    }

    /// Observability knob: reconcile trace tallies against `SimStats`
    /// in release builds too, recording a structured error instead of
    /// panicking.
    pub fn with_strict_reconcile(mut self, on: bool) -> Self {
        self.sched.strict_reconcile = on;
        self
    }

    /// Apply overrides from a JSON object, e.g.
    /// `{"asic": {"freq_ghz": 0.5}, "gddr6": {"channels": 16}}`.
    pub fn from_json(json: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        cfg.apply_json(json)?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        Self::from_json(&json)
    }

    pub fn apply_json(&mut self, json: &Json) -> Result<()> {
        let obj = match json.as_obj() {
            Some(o) => o,
            None => bail!("config root must be an object"),
        };
        for (section, value) in obj {
            let fields = value
                .as_obj()
                .with_context(|| format!("section '{section}' must be an object"))?;
            for (key, v) in fields {
                if let Some(s) = v.as_str() {
                    self.set_str_field(section, key, s)?;
                    continue;
                }
                let n = v
                    .as_f64()
                    .with_context(|| format!("{section}.{key} must be a number"))?;
                self.set_field(section, key, n)?;
            }
        }
        Ok(())
    }

    /// String-valued fields. Unknown keys are rejected (never ignored):
    /// a typo'd `sched.arrival`/`sched.seed` must fail loudly, not
    /// silently run the default experiment.
    fn set_str_field(&mut self, section: &str, key: &str, s: &str) -> Result<()> {
        match (section, key) {
            ("sched", "arrival") => {
                self.sched.arrival =
                    ArrivalSpec::parse(s).with_context(|| format!("sched.arrival = '{s}'"))?;
                Ok(())
            }
            ("sched", "policy") => {
                self.sched
                    .set_policy_str(s)
                    .with_context(|| format!("sched.policy = '{s}'"))?;
                Ok(())
            }
            ("sched", "partition") => {
                self.sched.partition = PartitionStrategy::parse(s)
                    .with_context(|| format!("sched.partition = '{s}'"))?;
                Ok(())
            }
            ("sched", "trace") => {
                self.sched.trace =
                    TraceSpec::parse(s).with_context(|| format!("sched.trace = '{s}'"))?;
                Ok(())
            }
            ("sched", "profile") => {
                self.sched.profile =
                    ProfileSpec::parse(s).with_context(|| format!("sched.profile = '{s}'"))?;
                Ok(())
            }
            _ => {
                // Tell a type error on a known numeric field apart from
                // a genuinely unknown key (probe a scratch copy; 1.0 is
                // in-range for every validated numeric field, unlike 0).
                let mut probe = self.clone();
                if probe.set_field(section, key, 1.0).is_ok() {
                    bail!("{section}.{key} must be a number, got string '{s}'");
                }
                bail!("unknown config field {section}.{key}")
            }
        }
    }

    fn set_field(&mut self, section: &str, key: &str, n: f64) -> Result<()> {
        macro_rules! set {
            ($field:expr, u64) => { $field = n as u64 };
            ($field:expr, usize) => { $field = n as usize };
            ($field:expr, f64) => { $field = n };
        }
        match (section, key) {
            ("timing", "trcd") => set!(self.timing.trcd, u64),
            ("timing", "trp") => set!(self.timing.trp, u64),
            ("timing", "tccd") => set!(self.timing.tccd, u64),
            ("timing", "twr") => set!(self.timing.twr, u64),
            ("timing", "trfc") => set!(self.timing.trfc, u64),
            ("timing", "trefi") => set!(self.timing.trefi, u64),
            ("timing", "tras") => set!(self.timing.tras, u64),
            ("idd", "idd2n") => set!(self.idd.idd2n, f64),
            ("idd", "idd3n") => set!(self.idd.idd3n, f64),
            ("idd", "idd0") => set!(self.idd.idd0, f64),
            ("idd", "idd4r") => set!(self.idd.idd4r, f64),
            ("idd", "idd4w") => set!(self.idd.idd4w, f64),
            ("idd", "idd5b") => set!(self.idd.idd5b, f64),
            ("gddr6", "channels") => set!(self.gddr6.channels, usize),
            ("gddr6", "banks_per_channel") => set!(self.gddr6.banks_per_channel, usize),
            ("gddr6", "capacity_gbit") => set!(self.gddr6.capacity_gbit, f64),
            ("gddr6", "row_bytes") => set!(self.gddr6.row_bytes, usize),
            ("gddr6", "freq_ghz") => set!(self.gddr6.freq_ghz, f64),
            ("gddr6", "pins_per_channel") => set!(self.gddr6.pins_per_channel, usize),
            ("gddr6", "gbps_per_pin") => set!(self.gddr6.gbps_per_pin, f64),
            ("gddr6", "vdd") => set!(self.gddr6.vdd, f64),
            ("pim", "gb_bytes") => set!(self.pim.gb_bytes, usize),
            ("pim", "mac_lanes") => set!(self.pim.mac_lanes, usize),
            ("pim", "mac_power_mw_per_channel") => set!(self.pim.mac_power_mw_per_channel, f64),
            ("pim", "pipeline_fill") => set!(self.pim.pipeline_fill, u64),
            ("sched", "max_streams") => set!(self.sched.max_streams, usize),
            ("sched", "seed") => {
                // JSON numbers are f64: accept only values a f64 holds
                // exactly, so a config-file seed replays the same trace
                // as the identical `--seed` on the CLI. The bound is
                // inclusive because 2^53 + 1 already rounded to 2^53 at
                // parse time — any seed landing on it is suspect.
                if n < 0.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.seed must be a non-negative integer < 2^53, got {n}");
                }
                self.sched.seed = n as u64;
            }
            ("sched", "arrival") => {
                bail!("sched.arrival must be a string like \"poisson:250000\"")
            }
            ("sched", "policy") => {
                bail!("sched.policy must be a string like \"srf\" or \"slo:2000000\"")
            }
            ("sched", "slo_ttft_cycles") => {
                // Same exactness contract as `sched.seed`: a JSON f64
                // must hold the budget exactly, and a 0-cycle budget
                // (which would reject everything) is a config mistake.
                if n < 1.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.slo_ttft_cycles must be an integer in [1, 2^53), got {n}");
                }
                self.sched.slo_ttft_cycles = n as u64;
            }
            ("sched", "prefill_chunk") => {
                // Same exactness contract; a 0-position chunk is a
                // config mistake (1 = token-by-token prefill).
                if n < 1.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.prefill_chunk must be an integer in [1, 2^53), got {n}");
                }
                self.sched.prefill_chunk = n as u64;
            }
            ("sched", "batch_decode") => {
                // JSON has no bool path in this config system; the knob
                // is 0 (off) / 1 (on) like a hardware strap.
                if n != 0.0 && n != 1.0 {
                    bail!("sched.batch_decode must be 0 (off) or 1 (on), got {n}");
                }
                self.sched.batch_decode = n == 1.0;
            }
            ("sched", "kv_paging") => {
                // Same 0/1 strap as batch_decode.
                if n != 0.0 && n != 1.0 {
                    bail!("sched.kv_paging must be 0 (off) or 1 (on), got {n}");
                }
                self.sched.kv_paging = n == 1.0;
            }
            ("sched", "kv_page_tokens") => {
                // Same exactness contract as `sched.seed`; a 0-token
                // page is a config mistake (the mapper rounds up to
                // the unit count anyway).
                if n < 1.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.kv_page_tokens must be an integer in [1, 2^53), got {n}");
                }
                self.sched.kv_page_tokens = n as u64;
            }
            ("sched", "kv_oversub") => {
                // A ratio below 1 would deny frames streams are
                // entitled to; 1.0 (no oversubscription) never faults.
                if !(n >= 1.0) || !n.is_finite() {
                    bail!("sched.kv_oversub must be a finite ratio >= 1.0, got {n}");
                }
                self.sched.kv_oversub = n;
            }
            ("sched", "kv_evict_watermark") => {
                // A fraction of the frame pool; 0.0 (off) evicts one
                // victim per fault, 1.0 would drain every peer.
                if !(0.0..=1.0).contains(&n) || !n.is_finite() {
                    bail!("sched.kv_evict_watermark must be a fraction in [0, 1], got {n}");
                }
                self.sched.kv_evict_watermark = n;
            }
            ("sched", "devices") => {
                // Same exactness contract as `sched.seed`; 0 devices
                // cannot hold a model (1 = the single-package paper
                // system).
                if n < 1.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.devices must be an integer in [1, 2^53), got {n}");
                }
                self.sched.devices = n as usize;
            }
            ("sched", "partition") => {
                bail!("sched.partition must be a string: \"layer_pipeline\" or \"tensor_parallel\"")
            }
            ("sched", "trace") => {
                bail!(
                    "sched.trace must be a string: \"off\", \"jsonl:<path>\" or \"chrome:<path>\""
                )
            }
            ("sched", "profile") => {
                bail!(
                    "sched.profile must be a string: \"off\", \"text:<path>\" or \"json:<path>\""
                )
            }
            ("sched", "strict_reconcile") => {
                // Same 0/1 strap as batch_decode.
                if n != 0.0 && n != 1.0 {
                    bail!("sched.strict_reconcile must be 0 (off) or 1 (on), got {n}");
                }
                self.sched.strict_reconcile = n == 1.0;
            }
            ("sched", "trace_window") => {
                // Same exactness contract as `sched.seed`; 0 disables
                // the utilization timeline.
                if n < 0.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.trace_window must be an integer in [0, 2^53), got {n}");
                }
                self.sched.trace_window = n as u64;
            }
            ("sched", "link_gbit_s") => {
                // A zero-bandwidth link would stall every hop forever.
                if !(n > 0.0) || !n.is_finite() {
                    bail!("sched.link_gbit_s must be a finite bandwidth > 0, got {n}");
                }
                self.sched.link_gbit_s = n;
            }
            ("sched", "link_hop_cycles") => {
                // Same exactness contract as `sched.seed`; 0 (a free
                // hop) is a legitimate idealized-interconnect setting.
                if n < 0.0 || n.fract() != 0.0 || n >= 9_007_199_254_740_992.0 {
                    bail!("sched.link_hop_cycles must be an integer in [0, 2^53), got {n}");
                }
                self.sched.link_hop_cycles = n as u64;
            }
            ("asic", "freq_ghz") => set!(self.asic.freq_ghz, f64),
            ("asic", "sram_kb") => set!(self.asic.sram_kb, usize),
            ("asic", "n_adders") => set!(self.asic.n_adders, usize),
            ("asic", "n_multipliers") => set!(self.asic.n_multipliers, usize),
            ("asic", "area_mm2") => set!(self.asic.area_mm2, f64),
            ("asic", "power_mw") => set!(self.asic.power_mw, f64),
            _ => bail!("unknown config field {section}.{key}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_derived_values() {
        let cfg = HwConfig::paper_baseline();
        // 4 Gb / 16 banks / 2 KB rows = 16384 rows per bank (paper: 16k)
        assert_eq!(cfg.gddr6.rows_per_bank(), 16384);
        assert_eq!(cfg.gddr6.row_elems(), 1024);
        // 16 pins x 16 Gb/s = 32 GB/s per channel
        assert!((cfg.gddr6.channel_bw_bytes_per_s() - 32e9).abs() < 1e-3);
        assert_eq!(cfg.total_mac_units(), 128);
        assert_eq!(cfg.pim.gb_elems(), 1024);
    }

    #[test]
    fn knobs() {
        let cfg = HwConfig::paper_baseline()
            .with_asic_freq_ghz(0.2)
            .with_data_rate_gbps(2.0)
            .with_mac_lanes(64)
            .with_channels(16);
        assert_eq!(cfg.asic.freq_ghz, 0.2);
        assert_eq!(cfg.gddr6.gbps_per_pin, 2.0);
        assert_eq!(cfg.pim.mac_lanes, 64);
        assert_eq!(cfg.pim.pipeline_fill, 7);
        assert_eq!(cfg.gddr6.channels, 16);
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(r#"{"asic": {"freq_ghz": 0.5}, "timing": {"trcd": 14}}"#).unwrap();
        let cfg = HwConfig::from_json(&j).unwrap();
        assert_eq!(cfg.asic.freq_ghz, 0.5);
        assert_eq!(cfg.timing.trcd, 14);
        assert_eq!(cfg.timing.trp, 12); // untouched default
    }

    #[test]
    fn scheduler_config_defaults_and_overrides() {
        assert_eq!(HwConfig::paper_baseline().sched.max_streams, 4);
        assert_eq!(HwConfig::paper_baseline().with_max_streams(1).sched.max_streams, 1);
        assert_eq!(HwConfig::paper_baseline().sched.arrival, ArrivalSpec::Batch);
        let j = Json::parse(r#"{"sched": {"max_streams": 8}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.max_streams, 8);
    }

    #[test]
    fn json_unknown_field_rejected() {
        let j = Json::parse(r#"{"asic": {"nope": 1}}"#).unwrap();
        assert!(HwConfig::from_json(&j).is_err());
    }

    #[test]
    fn sched_arrival_and_seed_overrides() {
        let src = r#"{"sched": {"arrival": "poisson:250000", "seed": 42, "max_streams": 2}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.arrival, ArrivalSpec::Poisson { rate_per_s: 250000.0 });
        assert_eq!(cfg.sched.seed, 42);
        assert_eq!(cfg.sched.max_streams, 2);
        let j = Json::parse(r#"{"sched": {"arrival": "fixed:5000"}}"#).unwrap();
        let cfg = HwConfig::from_json(&j).unwrap();
        assert_eq!(cfg.sched.arrival, ArrivalSpec::Fixed { interval_cycles: 5000 });
        assert_eq!(cfg.sched.seed, 0x5EED, "seed untouched by arrival override");
        let cfg = HwConfig::paper_baseline()
            .with_arrival(ArrivalSpec::parse("trace:t.json").unwrap())
            .with_arrival_seed(9);
        assert_eq!(cfg.sched.arrival, ArrivalSpec::Trace { path: "t.json".into() });
        assert_eq!(cfg.sched.seed, 9);
    }

    #[test]
    fn sched_policy_and_slo_overrides() {
        use crate::sim::policy::PolicySpec;
        let cfg = HwConfig::paper_baseline();
        assert_eq!(cfg.sched.policy, PolicySpec::Fcfs, "fcfs is the default");
        assert_eq!(cfg.sched.slo_ttft_cycles, 2_000_000);
        let src = r#"{"sched": {"policy": "srf"}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.policy, PolicySpec::Srf);
        let src = r#"{"sched": {"policy": "slo:123456"}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.policy, PolicySpec::Slo);
        assert_eq!(cfg.sched.slo_ttft_cycles, 123_456, "slo:<n> carries the budget");
        let src = r#"{"sched": {"policy": "slo", "slo_ttft_cycles": 777}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.policy, PolicySpec::Slo);
        assert_eq!(cfg.sched.slo_ttft_cycles, 777);
        // The budget key alone leaves the policy untouched.
        let src = r#"{"sched": {"slo_ttft_cycles": 99, "policy": "fair"}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.policy, PolicySpec::Fair);
        assert_eq!(cfg.sched.slo_ttft_cycles, 99);
        // Builder-style mutation used by the CLI.
        let mut sched = SchedulerConfig::default();
        sched.set_policy_str("slo:42").unwrap();
        assert_eq!((sched.policy, sched.slo_ttft_cycles), (PolicySpec::Slo, 42));
        sched.set_policy_str("fcfs").unwrap();
        assert_eq!(sched.slo_ttft_cycles, 42, "budget survives a policy switch");
    }

    #[test]
    fn sched_policy_bad_values_rejected() {
        for bad in [
            r#"{"sched": {"policy": "fifo"}}"#,
            r#"{"sched": {"policy": "slo:"}}"#,
            r#"{"sched": {"policy": "slo:0"}}"#,
            r#"{"sched": {"polcy": "srf"}}"#,
            r#"{"sched": {"slo_ttft_cycles": 0}}"#,
            r#"{"sched": {"slo_ttft_cycles": -8}}"#,
            r#"{"sched": {"slo_ttft_cycles": 1.5}}"#,
            r#"{"sched": {"slo_ttft_cycles": 9007199254740993}}"#,
            r#"{"sched": {"slo_ttft_cycles": "777"}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // A number where the policy string is required names the
        // expectation.
        let j = Json::parse(r#"{"sched": {"policy": 3}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a string"), "{err}");
    }

    #[test]
    fn sched_prefill_chunk_overrides() {
        assert_eq!(HwConfig::paper_baseline().sched.prefill_chunk, 32, "default chunk");
        let j = Json::parse(r#"{"sched": {"prefill_chunk": 128}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.prefill_chunk, 128);
        let j = Json::parse(r#"{"sched": {"prefill_chunk": 1}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.prefill_chunk, 1);
        assert_eq!(HwConfig::paper_baseline().with_prefill_chunk(8).sched.prefill_chunk, 8);
        // Typos, zero, fractional, out-of-range and string-typed values
        // are rejected loudly, like every other sched key.
        for bad in [
            r#"{"sched": {"prefill_chunk": 0}}"#,
            r#"{"sched": {"prefill_chunk": -4}}"#,
            r#"{"sched": {"prefill_chunk": 2.5}}"#,
            r#"{"sched": {"prefill_chunk": 9007199254740993}}"#,
            r#"{"sched": {"prefill_chunk": "32"}}"#,
            r#"{"sched": {"prefil_chunk": 32}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        let j = Json::parse(r#"{"sched": {"prefill_chunk": "32"}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a number"), "{err}");
    }

    #[test]
    fn sched_batch_decode_overrides() {
        assert!(!HwConfig::paper_baseline().sched.batch_decode, "off by default");
        let j = Json::parse(r#"{"sched": {"batch_decode": 1}}"#).unwrap();
        assert!(HwConfig::from_json(&j).unwrap().sched.batch_decode);
        let j = Json::parse(r#"{"sched": {"batch_decode": 0}}"#).unwrap();
        assert!(!HwConfig::from_json(&j).unwrap().sched.batch_decode);
        assert!(HwConfig::paper_baseline().with_batch_decode(true).sched.batch_decode);
        // Anything but the 0/1 strap values is rejected loudly, like
        // every other sched key.
        for bad in [
            r#"{"sched": {"batch_decode": 2}}"#,
            r#"{"sched": {"batch_decode": -1}}"#,
            r#"{"sched": {"batch_decode": 0.5}}"#,
            r#"{"sched": {"batch_decode": "on"}}"#,
            r#"{"sched": {"batch_decod": 1}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        let j = Json::parse(r#"{"sched": {"batch_decode": "on"}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a number"), "{err}");
    }

    #[test]
    fn sched_kv_paging_overrides() {
        let base = HwConfig::paper_baseline();
        assert!(!base.sched.kv_paging, "off by default");
        assert_eq!(base.sched.kv_page_tokens, 128, "default page size");
        assert_eq!(base.sched.kv_oversub, 1.0, "no oversubscription by default");
        let j = Json::parse(r#"{"sched": {"kv_paging": 1}}"#).unwrap();
        assert!(HwConfig::from_json(&j).unwrap().sched.kv_paging);
        let j = Json::parse(r#"{"sched": {"kv_paging": 0}}"#).unwrap();
        assert!(!HwConfig::from_json(&j).unwrap().sched.kv_paging);
        let src = r#"{"sched": {"kv_paging": 1, "kv_page_tokens": 256, "kv_oversub": 1.5}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert!(cfg.sched.kv_paging);
        assert_eq!(cfg.sched.kv_page_tokens, 256);
        assert_eq!(cfg.sched.kv_oversub, 1.5);
        let cfg = HwConfig::paper_baseline()
            .with_kv_paging(true)
            .with_kv_page_tokens(64)
            .with_kv_oversub(2.0);
        assert!(cfg.sched.kv_paging);
        assert_eq!(cfg.sched.kv_page_tokens, 64);
        assert_eq!(cfg.sched.kv_oversub, 2.0);
        // Anything but the 0/1 strap, non-integer page sizes, and
        // ratios below 1 are rejected loudly, like every other sched
        // key.
        for bad in [
            r#"{"sched": {"kv_paging": 2}}"#,
            r#"{"sched": {"kv_paging": 0.5}}"#,
            r#"{"sched": {"kv_paging": "on"}}"#,
            r#"{"sched": {"kv_pagin": 1}}"#,
            r#"{"sched": {"kv_page_tokens": 0}}"#,
            r#"{"sched": {"kv_page_tokens": -128}}"#,
            r#"{"sched": {"kv_page_tokens": 2.5}}"#,
            r#"{"sched": {"kv_page_tokens": 9007199254740993}}"#,
            r#"{"sched": {"kv_page_tokens": "128"}}"#,
            r#"{"sched": {"kv_oversub": 0.9}}"#,
            r#"{"sched": {"kv_oversub": -1}}"#,
            r#"{"sched": {"kv_oversub": "1.5"}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        let j = Json::parse(r#"{"sched": {"kv_paging": "on"}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a number"), "{err}");
    }

    #[test]
    fn sched_kv_evict_watermark_overrides() {
        let base = HwConfig::paper_baseline();
        assert_eq!(base.sched.kv_evict_watermark, 0.0, "off by default");
        let j = Json::parse(r#"{"sched": {"kv_evict_watermark": 0.25}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.kv_evict_watermark, 0.25);
        // The whole inclusive range parses (1.0 is also the probe value
        // the string-key path uses on every numeric field).
        let j = Json::parse(r#"{"sched": {"kv_evict_watermark": 1}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.kv_evict_watermark, 1.0);
        let cfg = HwConfig::paper_baseline().with_kv_evict_watermark(0.5);
        assert_eq!(cfg.sched.kv_evict_watermark, 0.5);
        for bad in [
            r#"{"sched": {"kv_evict_watermark": -0.1}}"#,
            r#"{"sched": {"kv_evict_watermark": 1.1}}"#,
            r#"{"sched": {"kv_evict_watermark": "0.5"}}"#,
            r#"{"sched": {"kv_evict_watermrk": 0.5}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn sched_sharding_overrides() {
        let base = HwConfig::paper_baseline();
        assert_eq!(base.sched.devices, 1, "single package by default");
        assert_eq!(base.sched.partition, PartitionStrategy::LayerPipeline);
        assert_eq!(base.sched.link_gbit_s, 256.0);
        assert_eq!(base.sched.link_hop_cycles, 250);
        let src = r#"{"sched": {"devices": 4, "partition": "tensor_parallel",
                      "link_gbit_s": 512, "link_hop_cycles": 100}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.devices, 4);
        assert_eq!(cfg.sched.partition, PartitionStrategy::TensorParallel);
        assert_eq!(cfg.sched.link_gbit_s, 512.0);
        assert_eq!(cfg.sched.link_hop_cycles, 100);
        // A free hop (0 cycles) is a legitimate idealized interconnect.
        let j = Json::parse(r#"{"sched": {"link_hop_cycles": 0}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.link_hop_cycles, 0);
        let cfg = HwConfig::paper_baseline()
            .with_devices(2)
            .with_partition(PartitionStrategy::TensorParallel)
            .with_link_gbit_s(128.0)
            .with_link_hop_cycles(500);
        assert_eq!(cfg.sched.devices, 2);
        assert_eq!(cfg.sched.partition, PartitionStrategy::TensorParallel);
        assert_eq!(cfg.sched.link_gbit_s, 128.0);
        assert_eq!(cfg.sched.link_hop_cycles, 500);
        // Typos, zero/fractional devices, bad strategies, non-positive
        // bandwidth and mistyped values are rejected loudly.
        for bad in [
            r#"{"sched": {"devices": 0}}"#,
            r#"{"sched": {"devices": -2}}"#,
            r#"{"sched": {"devices": 1.5}}"#,
            r#"{"sched": {"devices": "2"}}"#,
            r#"{"sched": {"devicess": 2}}"#,
            r#"{"sched": {"partition": "pipeline"}}"#,
            r#"{"sched": {"partition": "tensor"}}"#,
            r#"{"sched": {"link_gbit_s": 0}}"#,
            r#"{"sched": {"link_gbit_s": -256}}"#,
            r#"{"sched": {"link_gbit_s": "256"}}"#,
            r#"{"sched": {"link_hop_cycles": -1}}"#,
            r#"{"sched": {"link_hop_cycles": 2.5}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // A number where the strategy string is required names the
        // expectation.
        let j = Json::parse(r#"{"sched": {"partition": 2}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a string"), "{err}");
    }

    #[test]
    fn sched_trace_overrides() {
        use crate::sim::trace::TraceSpec;
        let base = HwConfig::paper_baseline();
        assert_eq!(base.sched.trace, TraceSpec::Off, "tracing off by default");
        assert_eq!(base.sched.trace_window, 0, "timeline off by default");
        let src = r#"{"sched": {"trace": "jsonl:events.jsonl", "trace_window": 100000}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.trace, TraceSpec::Jsonl("events.jsonl".into()));
        assert_eq!(cfg.sched.trace_window, 100_000);
        let j = Json::parse(r#"{"sched": {"trace": "chrome:trace.json"}}"#).unwrap();
        let cfg = HwConfig::from_json(&j).unwrap();
        assert_eq!(cfg.sched.trace, TraceSpec::Chrome("trace.json".into()));
        let j = Json::parse(r#"{"sched": {"trace": "off"}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.trace, TraceSpec::Off);
        let cfg = HwConfig::paper_baseline().with_trace("jsonl:x.jsonl").with_trace_window(500);
        assert_eq!(cfg.sched.trace, TraceSpec::Jsonl("x.jsonl".into()));
        assert_eq!(cfg.sched.trace_window, 500);
        // Unknown formats, empty paths, mistyped values and typo'd keys
        // are rejected loudly, like every other sched key.
        for bad in [
            r#"{"sched": {"trace": "perfetto:x"}}"#,
            r#"{"sched": {"trace": "jsonl:"}}"#,
            r#"{"sched": {"trace": "chrome:"}}"#,
            r#"{"sched": {"trace": 1}}"#,
            r#"{"sched": {"trce": "off"}}"#,
            r#"{"sched": {"trace_window": -1}}"#,
            r#"{"sched": {"trace_window": 2.5}}"#,
            r#"{"sched": {"trace_window": 9007199254740993}}"#,
            r#"{"sched": {"trace_window": "100"}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // A number where the trace spec string is required names the
        // expectation.
        let j = Json::parse(r#"{"sched": {"trace": 1}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a string"), "{err}");
    }

    #[test]
    fn sched_profile_overrides() {
        use crate::sim::profile::ProfileSpec;
        let base = HwConfig::paper_baseline();
        assert_eq!(base.sched.profile, ProfileSpec::Off, "profiling off by default");
        let src = r#"{"sched": {"profile": "json:profile.json"}}"#;
        let cfg = HwConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sched.profile, ProfileSpec::Json("profile.json".into()));
        let j = Json::parse(r#"{"sched": {"profile": "text:p.txt"}}"#).unwrap();
        assert_eq!(
            HwConfig::from_json(&j).unwrap().sched.profile,
            ProfileSpec::Text("p.txt".into())
        );
        let j = Json::parse(r#"{"sched": {"profile": "off"}}"#).unwrap();
        assert_eq!(HwConfig::from_json(&j).unwrap().sched.profile, ProfileSpec::Off);
        let cfg = HwConfig::paper_baseline().with_profile("json:x.json");
        assert_eq!(cfg.sched.profile, ProfileSpec::Json("x.json".into()));
        // Unknown formats, empty paths, mistyped values and typo'd keys
        // are rejected loudly, like every other sched key.
        for bad in [
            r#"{"sched": {"profile": "csv:x"}}"#,
            r#"{"sched": {"profile": "text:"}}"#,
            r#"{"sched": {"profile": "json:"}}"#,
            r#"{"sched": {"profile": 1}}"#,
            r#"{"sched": {"profil": "off"}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        let j = Json::parse(r#"{"sched": {"profile": 1}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a string"), "{err}");
    }

    #[test]
    fn sched_strict_reconcile_overrides() {
        assert!(!HwConfig::paper_baseline().sched.strict_reconcile, "off by default");
        let j = Json::parse(r#"{"sched": {"strict_reconcile": 1}}"#).unwrap();
        assert!(HwConfig::from_json(&j).unwrap().sched.strict_reconcile);
        let j = Json::parse(r#"{"sched": {"strict_reconcile": 0}}"#).unwrap();
        assert!(!HwConfig::from_json(&j).unwrap().sched.strict_reconcile);
        assert!(
            HwConfig::paper_baseline().with_strict_reconcile(true).sched.strict_reconcile
        );
        // 0/1 strap like batch_decode; anything else rejected loudly.
        for bad in [
            r#"{"sched": {"strict_reconcile": 2}}"#,
            r#"{"sched": {"strict_reconcile": 0.5}}"#,
            r#"{"sched": {"strict_reconcile": "on"}}"#,
            r#"{"sched": {"strict_reconcil": 1}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    /// Satellite: typo'd or mistyped `sched` keys must be rejected with
    /// a clear error, never silently ignored.
    #[test]
    fn sched_unknown_or_mistyped_keys_rejected() {
        for bad in [
            r#"{"sched": {"arival": "poisson:1000"}}"#,
            r#"{"sched": {"sead": 42}}"#,
            r#"{"sched": {"max_streems": 2}}"#,
            r#"{"sched": {"arrival": "poison:1000"}}"#,
            r#"{"sched": {"seed": "42"}}"#,
            r#"{"shced": {"max_streams": 2}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // A number where a string is required names the expectation.
        let j = Json::parse(r#"{"sched": {"arrival": 5}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a string"), "{err}");
        // ...and a string on a known numeric field names it too (not
        // "unknown field").
        let j = Json::parse(r#"{"asic": {"freq_ghz": "0.5"}}"#).unwrap();
        let err = HwConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("must be a number"), "{err}");
        // Seeds a f64 cannot hold exactly are rejected, not rounded —
        // a config-file seed must replay the same trace as --seed.
        for bad in [
            r#"{"sched": {"seed": -1}}"#,
            r#"{"sched": {"seed": 1.5}}"#,
            r#"{"sched": {"seed": 9007199254740993}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HwConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }
}
