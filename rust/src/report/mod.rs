//! Figure/table regeneration harness: one function per experiment in the
//! paper's evaluation (see DESIGN.md §4 for the index).

pub mod figures;

pub use figures::{
    fig1_model_zoo, fig10_breakdown, fig11_locality, fig12_asic_freq, fig13_bandwidth,
    fig14_long_token, fig15_scalability, fig8_9_speedup_energy, fig_batching,
    fig_paging, fig_policy_comparison, fig_prefill, fig_profile, fig_serving_tail_latency,
    fig_sharding, fig_timeline, table1_config, table2_comparison, FigureReport, RunSummary,
};
