//! Regenerates every table and figure of the paper's evaluation section.
//!
//! Each function runs the simulator (plus baselines where relevant) and
//! returns a `FigureReport`: a rendered ascii table for the console and
//! a JSON object for machine consumption. Absolute numbers depend on
//! the simulated substrate; the *shape* of each result (who wins, by
//! what factor, where crossovers fall) is what reproduces the paper —
//! EXPERIMENTS.md records paper-vs-measured per experiment.

use crate::baselines::{cpu_xeon_6154, gpu_t4};
use crate::config::HwConfig;
use crate::energy::SystemEnergy;
use crate::mapping::{ModelMapping, PartitionStrategy};
use crate::model::gpt::by_name;
use crate::model::{GptModel, PAPER_MODELS};
use crate::sim::arrivals::{self, ArrivalSpec};
use crate::sim::{
    FleetSim, LatencyReport, MultiSim, ProfileSink, Simulator, StreamOutcome, StreamSpec,
    TraceWindow,
};
use crate::util::json::Json;
use crate::util::table::{fmt_time_s, sig3, Table};
use anyhow::{anyhow, Result};

/// A regenerated figure/table.
#[derive(Clone, Debug)]
pub struct FigureReport {
    pub id: &'static str,
    pub title: String,
    pub rendered: String,
    pub json: Json,
}

/// Summary of one simulated run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub model: String,
    pub tokens: u64,
    pub sim_seconds: f64,
    pub energy_j: f64,
    pub row_hit_rate: f64,
    pub bytes_moved: u64,
    pub vmm_fraction: f64,
    pub class_seconds: Vec<(String, f64)>,
}

/// Run `model` for `n_tokens` under `cfg`.
pub fn run_model(model: &GptModel, cfg: &HwConfig, n_tokens: u64) -> Result<RunSummary> {
    let mut sim = Simulator::new(model, cfg)?;
    sim.generate(n_tokens)?;
    sim.finalize_stats();
    let freq = cfg.gddr6.freq_ghz;
    let energy = SystemEnergy::from_sim(&sim);
    let class_seconds = sim
        .stats
        .class_cycles
        .iter()
        .map(|(c, cyc)| (c.label(), *cyc as f64 / (freq * 1e9)))
        .collect();
    Ok(RunSummary {
        model: model.name.to_string(),
        tokens: n_tokens,
        sim_seconds: sim.stats.seconds(freq),
        energy_j: energy.total_j(),
        row_hit_rate: sim.stats.row_hit_rate(),
        bytes_moved: sim.stats.bytes_moved(),
        vmm_fraction: sim.stats.vmm_fraction(),
        class_seconds,
    })
}

/// Fig. 1: parameters and ops/parameter of the model zoo (vs ResNet-18).
pub fn fig1_model_zoo() -> FigureReport {
    let mut t = Table::new(vec!["model", "params (M)", "GFLOPs/token", "ops/param"]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        let p = m.n_params() as f64;
        let f = m.flops_per_token(1024) as f64;
        t.row(vec![
            m.name.to_string(),
            format!("{:.0}", p / 1e6),
            format!("{:.1}", f / 1e9),
            format!("{:.2}", f / p),
        ]);
        arr.push(Json::obj(vec![
            ("model", m.name.into()),
            ("params", p.into()),
            ("flops_per_token", f.into()),
            ("ops_per_param", (f / p).into()),
        ]));
    }
    // ResNet-18 reference point (paper Fig. 1): 11.7M params, ~1.8 GFLOPs
    // per 224x224 image -> ops/param ~ 48.3... wait, x2 for MACs? The
    // paper quotes 48.3; 1.8e9 * 2 / 11.7e6 = 308?? They use
    // ops-per-inference / params with their own convention; we record
    // the published 48.3 directly.
    t.row(vec!["resnet-18 (ref)".into(), "11.7".to_string(), "-".into(), "48.3".into()]);
    FigureReport {
        id: "fig1",
        title: "Fig. 1: params & ops/param — GPT vs CNN".into(),
        rendered: t.render(),
        json: Json::Arr(arr),
    }
}

/// Fig. 8 + Fig. 9: speedup and energy efficiency vs GPU/CPU, 8 models.
pub fn fig8_9_speedup_energy(n_tokens: u64) -> Result<FigureReport> {
    let cfg = HwConfig::paper_baseline();
    let gpu = gpu_t4();
    let cpu = cpu_xeon_6154();
    let mut t = Table::new(vec![
        "model", "pim us/tok", "speedup vs GPU", "speedup vs CPU", "energy-eff vs GPU", "energy-eff vs CPU",
    ]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        let r = run_model(m, &cfg, n_tokens)?;
        let g_lat = gpu.run_latency_s(m, n_tokens);
        let c_lat = cpu.run_latency_s(m, n_tokens);
        let g_en = gpu.run_energy_j(m, n_tokens);
        let c_en = cpu.run_energy_j(m, n_tokens);
        let row = [
            g_lat / r.sim_seconds,
            c_lat / r.sim_seconds,
            g_en / r.energy_j,
            c_en / r.energy_j,
        ];
        t.row(vec![
            m.name.to_string(),
            sig3(r.sim_seconds * 1e6 / n_tokens as f64),
            format!("{:.1}x", row[0]),
            format!("{:.1}x", row[1]),
            format!("{:.1}x", row[2]),
            format!("{:.1}x", row[3]),
        ]);
        arr.push(Json::obj(vec![
            ("model", m.name.into()),
            ("pim_s", r.sim_seconds.into()),
            ("speedup_gpu", row[0].into()),
            ("speedup_cpu", row[1].into()),
            ("energy_eff_gpu", row[2].into()),
            ("energy_eff_cpu", row[3].into()),
        ]));
    }
    Ok(FigureReport {
        id: "fig8-9",
        title: format!("Fig. 8/9: speedup & energy efficiency ({n_tokens} tokens; paper: GPU 41-137x / CPU 631-1074x; energy GPU 339-1085x / CPU 890-1632x)"),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Fig. 10: layer-wise latency breakdown (GPT3-small and GPT3-XL).
pub fn fig10_breakdown(n_tokens: u64) -> Result<FigureReport> {
    let cfg = HwConfig::paper_baseline();
    let mut t = Table::new(vec!["model", "class", "share %"]);
    let mut arr = Vec::new();
    for name in ["gpt3-small", "gpt3-xl"] {
        let m = by_name(name).unwrap();
        let r = run_model(&m, &cfg, n_tokens)?;
        let total: f64 = r.class_seconds.iter().map(|(_, s)| s).sum();
        // Aggregate VMM classes for the headline split.
        let vmm: f64 = r.class_seconds.iter().filter(|(c, _)| c.starts_with("vmm")).map(|(_, s)| s).sum();
        t.row(vec![name.to_string(), "vmm (all)".into(), format!("{:.2}", 100.0 * vmm / total)]);
        for (c, s) in r.class_seconds.iter().filter(|(c, _)| !c.starts_with("vmm")) {
            t.row(vec![name.to_string(), c.clone(), format!("{:.2}", 100.0 * s / total)]);
        }
        let arith: f64 = r
            .class_seconds
            .iter()
            .filter(|(c, _)| ["softmax", "layernorm", "gelu", "residual", "partialsum", "biasscale"].contains(&c.as_str()))
            .map(|(_, s)| s)
            .sum();
        // KV write-back is attributed separately: the column-major V
        // write serializes ACT + WR + PRE per element over the channel
        // bus (paper §IV.B), a real share at short contexts.
        let kvwrite: f64 = r
            .class_seconds
            .iter()
            .filter(|(c, _)| c.as_str() == "kvwrite")
            .map(|(_, s)| s)
            .sum();
        arr.push(Json::obj(vec![
            ("model", name.into()),
            ("vmm_share", (vmm / total).into()),
            ("arith_share", (arith / total).into()),
            ("kvwrite_share", (kvwrite / total).into()),
        ]));
    }
    Ok(FigureReport {
        id: "fig10",
        title: format!("Fig. 10: layer-wise latency breakdown ({n_tokens} tokens; paper: VMM dominates, arithmetic ~1.16% on GPT3-XL)"),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Fig. 11: (a) row hit rate, (b) data movement reduction.
pub fn fig11_locality(n_tokens: u64) -> Result<FigureReport> {
    let cfg = HwConfig::paper_baseline();
    let mut t = Table::new(vec!["model", "row hit %", "moved MB", "baseline MB", "reduction"]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        let r = run_model(m, &cfg, n_tokens)?;
        // Processor-centric baseline traffic: all weights per token plus
        // the KV cache read+write per token.
        let kv_per_tok = (2 * m.n_layer * m.d_model) as f64 * (n_tokens as f64 / 2.0) * 2.0;
        let baseline = (m.weight_bytes() as f64 + kv_per_tok) * n_tokens as f64;
        let reduction = baseline / r.bytes_moved as f64;
        t.row(vec![
            m.name.to_string(),
            format!("{:.2}", 100.0 * r.row_hit_rate),
            format!("{:.1}", r.bytes_moved as f64 / 1e6),
            format!("{:.0}", baseline / 1e6),
            format!("{:.0}x", reduction),
        ]);
        arr.push(Json::obj(vec![
            ("model", m.name.into()),
            ("row_hit_rate", r.row_hit_rate.into()),
            ("bytes_moved", (r.bytes_moved as f64).into()),
            ("reduction", reduction.into()),
        ]));
    }
    Ok(FigureReport {
        id: "fig11",
        title: format!("Fig. 11: row hit rate & data movement reduction ({n_tokens} tokens; paper: ~98%, 110-259x)"),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Fig. 12: sensitivity to ASIC clock frequency (1 GHz -> 100 MHz).
pub fn fig12_asic_freq(n_tokens: u64) -> Result<FigureReport> {
    let freqs = [1.0, 0.5, 0.2, 0.1];
    let mut t = Table::new(vec!["model", "1 GHz", "500 MHz", "200 MHz", "100 MHz"]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        let mut cells = vec![m.name.to_string()];
        let mut norm = Vec::new();
        let base = run_model(m, &HwConfig::paper_baseline(), n_tokens)?.sim_seconds;
        for f in freqs {
            let cfg = HwConfig::paper_baseline().with_asic_freq_ghz(f);
            let s = run_model(m, &cfg, n_tokens)?.sim_seconds;
            norm.push(s / base);
            cells.push(format!("{:.3}", s / base));
        }
        t.row(cells);
        arr.push(Json::obj(vec![
            ("model", m.name.into()),
            ("normalized", Json::Arr(norm.into_iter().map(Json::from).collect())),
        ]));
    }
    Ok(FigureReport {
        id: "fig12",
        title: format!("Fig. 12: latency vs ASIC frequency, normalized to 1 GHz ({n_tokens} tokens; paper: worst +20% at 100 MHz, larger models less sensitive)"),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Fig. 13: sensitivity to memory-interface data rate (16 -> 1 Gb/s/pin).
pub fn fig13_bandwidth(n_tokens: u64) -> Result<FigureReport> {
    let rates = [16.0, 8.0, 4.0, 2.0, 1.0];
    let mut t = Table::new(vec!["model", "16 Gb/s", "8 Gb/s", "4 Gb/s", "2 Gb/s", "1 Gb/s"]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        let base = run_model(m, &HwConfig::paper_baseline(), n_tokens)?.sim_seconds;
        let mut cells = vec![m.name.to_string()];
        let mut norm = Vec::new();
        for r in rates {
            let cfg = HwConfig::paper_baseline().with_data_rate_gbps(r);
            let s = run_model(m, &cfg, n_tokens)?.sim_seconds;
            norm.push(s / base);
            cells.push(format!("{:.2}", s / base));
        }
        t.row(cells);
        arr.push(Json::obj(vec![
            ("model", m.name.into()),
            ("normalized", Json::Arr(norm.into_iter().map(Json::from).collect())),
        ]));
    }
    Ok(FigureReport {
        id: "fig13",
        title: format!("Fig. 13: latency vs interface data rate, normalized to 16 Gb/s ({n_tokens} tokens; paper: ~1.5x at 2 Gb/s, ~2x at 1 Gb/s)"),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Fig. 14: latency growth with generated token length (GPT3-XL to 8k).
pub fn fig14_long_token(lengths: &[u64]) -> Result<FigureReport> {
    // GPT3-XL with an extended context window (paper: >8k supported).
    let mut m = by_name("gpt3-xl").unwrap();
    m.max_seq = *lengths.iter().max().unwrap() as usize;
    let cfg = HwConfig::paper_baseline();
    let base = run_model(&m, &cfg, lengths[0])?.sim_seconds;
    let mut t = Table::new(vec!["tokens", "sim seconds", "normalized vs 1k"]);
    let mut arr = Vec::new();
    for &n in lengths {
        let s = run_model(&m, &cfg, n)?.sim_seconds;
        t.row(vec![n.to_string(), sig3(s), format!("{:.2}", s / base)]);
        arr.push(Json::obj(vec![("tokens", n.into()), ("seconds", s.into()), ("normalized", (s / base).into())]));
    }
    Ok(FigureReport {
        id: "fig14",
        title: "Fig. 14: GPT3-XL latency vs token length (paper: super-linear growth, 8k+ supported)".into(),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Fig. 15: scalability with (a) MAC width 16->64, (b) channel count.
pub fn fig15_scalability(n_tokens: u64) -> Result<FigureReport> {
    let mut t = Table::new(vec!["model", "knob", "value", "speedup vs base"]);
    let mut arr = Vec::new();
    for name in ["gpt3-small", "gpt3-xl"] {
        let m = by_name(name).unwrap();
        let base = run_model(&m, &HwConfig::paper_baseline(), n_tokens)?.sim_seconds;
        for lanes in [16usize, 32, 64] {
            let cfg = HwConfig::paper_baseline().with_mac_lanes(lanes);
            let s = run_model(&m, &cfg, n_tokens)?.sim_seconds;
            t.row(vec![name.to_string(), "mac-lanes".into(), lanes.to_string(), format!("{:.2}x", base / s)]);
            arr.push(Json::obj(vec![
                ("model", name.into()),
                ("knob", "mac_lanes".into()),
                ("value", lanes.into()),
                ("speedup", (base / s).into()),
            ]));
        }
        for ch in [8usize, 16, 32] {
            let cfg = HwConfig::paper_baseline().with_channels(ch);
            let s = run_model(&m, &cfg, n_tokens)?.sim_seconds;
            t.row(vec![name.to_string(), "channels".into(), ch.to_string(), format!("{:.2}x", base / s)]);
            arr.push(Json::obj(vec![
                ("model", name.into()),
                ("knob", "channels".into()),
                ("value", ch.into()),
                ("speedup", (base / s).into()),
            ]));
        }
    }
    Ok(FigureReport {
        id: "fig15",
        title: format!("Fig. 15: scalability — MAC width (paper: 1.8-2.0x at 64) and channels (near-linear) ({n_tokens} tokens)"),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Table I: the hardware configuration in force.
pub fn table1_config(cfg: &HwConfig) -> FigureReport {
    let mut t = Table::new(vec!["section", "parameter", "value"]);
    let rows: Vec<(&str, &str, String)> = vec![
        ("timing", "tRCD/tRP/tCCD/tWR", format!("{}/{}/{}/{} ns", cfg.timing.trcd, cfg.timing.trp, cfg.timing.tccd, cfg.timing.twr)),
        ("timing", "tRFC/tREFI", format!("{}/{} ns", cfg.timing.trfc, cfg.timing.trefi)),
        ("idd", "IDD0/2N/3N", format!("{}/{}/{} mA", cfg.idd.idd0, cfg.idd.idd2n, cfg.idd.idd3n)),
        ("idd", "IDD4R/4W/5B", format!("{}/{}/{} mA", cfg.idd.idd4r, cfg.idd.idd4w, cfg.idd.idd5b)),
        ("gddr6", "channels x banks", format!("{} x {}", cfg.gddr6.channels, cfg.gddr6.banks_per_channel)),
        ("gddr6", "capacity/channel", format!("{} Gb", cfg.gddr6.capacity_gbit)),
        ("gddr6", "row size / rows", format!("{} B / {}", cfg.gddr6.row_bytes, cfg.gddr6.rows_per_bank())),
        ("gddr6", "interface", format!("{} pins x {} Gb/s", cfg.gddr6.pins_per_channel, cfg.gddr6.gbps_per_pin)),
        ("pim", "GB / MAC lanes", format!("{} B / {}", cfg.pim.gb_bytes, cfg.pim.mac_lanes)),
        ("pim", "MAC power", format!("{} mW/channel", cfg.pim.mac_power_mw_per_channel)),
        ("asic", "freq / SRAM", format!("{} GHz / {} KB", cfg.asic.freq_ghz, cfg.asic.sram_kb)),
        ("asic", "adders / multipliers", format!("{} / {}", cfg.asic.n_adders, cfg.asic.n_multipliers)),
        ("asic", "area / power", format!("{} mm2 / {} mW", cfg.asic.area_mm2, cfg.asic.power_mw)),
    ];
    for (s, p, v) in rows {
        t.row(vec![s.to_string(), p.to_string(), v]);
    }
    FigureReport {
        id: "table1",
        title: "Table I: PIM-GPT hardware configuration".into(),
        rendered: t.render(),
        json: Json::Null,
    }
}

/// Table II: comparison with prior GPT accelerators.
pub fn table2_comparison(n_tokens: u64) -> Result<FigureReport> {
    let cfg = HwConfig::paper_baseline();
    let gpu = gpu_t4();
    // The paper's Table II row for PIM-GPT is GPT2-medium at 1024 tokens.
    let m = by_name("gpt2-medium").unwrap();
    let r = run_model(&m, &cfg, n_tokens)?;
    let speedup = gpu.run_latency_s(&m, n_tokens) / r.sim_seconds;
    let energy = gpu.run_energy_j(&m, n_tokens) / r.energy_j;

    let mut t = Table::new(vec!["accel", "memory", "end-to-end", "pim", "dtype", "largest", "longest tok", "speedup", "energy eff"]);
    for a in &crate::baselines::PRIOR_ACCELERATORS {
        t.row(vec![
            a.name.to_string(),
            a.memory.to_string(),
            if a.end_to_end { "yes" } else { "no" }.into(),
            if a.pim { "yes" } else { "no" }.into(),
            a.data_type.to_string(),
            a.largest_model.to_string(),
            a.longest_token.map(|t| t.to_string()).unwrap_or("-".into()),
            format!("{}x", a.speedup),
            a.energy_eff.map(|e| format!("{e}x")).unwrap_or("-".into()),
        ]);
    }
    t.row(vec![
        "PIM-GPT (ours)".into(),
        "GDDR6".into(),
        "yes".into(),
        "yes".into(),
        "BF16".into(),
        "GPT2/3-XL".into(),
        "8096".into(),
        format!("{speedup:.0}x"),
        format!("{energy:.0}x"),
    ]);
    Ok(FigureReport {
        id: "table2",
        title: format!("Table II: vs prior accelerators (PIM-GPT measured on GPT2-medium, {n_tokens} tokens; paper: 89x / 618x)"),
        rendered: t.render(),
        json: Json::obj(vec![("speedup", speedup.into()), ("energy_eff", energy.into())]),
    })
}

/// Serving experiment (beyond the paper): tail latency vs offered load,
/// open-loop. For each paper model the capacity is measured first — the
/// batch-at-zero makespan of `n_requests` decode requests of `n_tokens`
/// at the baseline K = 4 — then Poisson arrivals are replayed at each
/// load factor in `loads` (offered rate = load x n_requests / makespan)
/// and the per-stream latency percentiles reported. Queue and TTFT are
/// measured from each request's own arrival; past load 1.0 the tail
/// should blow up, which is exactly what an SLO-aware admission policy
/// would act on. Fully deterministic for a given `seed`.
pub fn fig_serving_tail_latency(
    n_requests: usize,
    n_tokens: u64,
    loads: &[f64],
    seed: u64,
) -> Result<FigureReport> {
    let cfg = HwConfig::paper_baseline();
    let freq_hz = cfg.gddr6.freq_ghz * 1e9;
    let fmt = |cycles: u64| fmt_time_s(cycles as f64 / freq_hz);
    let mut t =
        Table::new(vec!["model", "load", "req/s", "queue p99", "ttft p50", "ttft p99", "e2e p99"]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        // One Algorithm-3 placement per model, shared by every run.
        let mapping = ModelMapping::build(m, &cfg)?;
        let run = |arrival_cycles: &[u64]| -> Result<(u64, LatencyReport)> {
            let mut ms = MultiSim::from_mapping(m, &cfg, mapping.clone());
            for (id, &at) in arrival_cycles.iter().enumerate() {
                let id = id as u64;
                ms.submit(StreamSpec { id, n_tokens, prompt_tokens: 1, arrival_cycle: at })?;
            }
            ms.run_all()?;
            ms.finalize_stats();
            let lat = ms.stats.latency_report().ok_or_else(|| anyhow!("no streams retired"))?;
            Ok((ms.clock(), lat))
        };
        let (makespan, _) = run(&vec![0u64; n_requests])?;
        for &load in loads {
            let rate_per_s = load * n_requests as f64 * freq_hz / makespan as f64;
            let spec = ArrivalSpec::Poisson { rate_per_s };
            let at = arrivals::generate(&spec, n_requests, cfg.gddr6.freq_ghz, seed)?;
            let (_, lat) = run(&at)?;
            t.row(vec![
                m.name.to_string(),
                format!("{load:.2}"),
                format!("{rate_per_s:.0}"),
                fmt(lat.queue.p99),
                fmt(lat.ttft.p50),
                fmt(lat.ttft.p99),
                fmt(lat.e2e.p99),
            ]);
            arr.push(Json::obj(vec![
                ("model", m.name.into()),
                ("load", load.into()),
                ("rate_per_s", rate_per_s.into()),
                ("queue_p99_cycles", lat.queue.p99.into()),
                ("ttft_p50_cycles", lat.ttft.p50.into()),
                ("ttft_p95_cycles", lat.ttft.p95.into()),
                ("ttft_p99_cycles", lat.ttft.p99.into()),
                ("e2e_p99_cycles", lat.e2e.p99.into()),
            ]));
        }
    }
    Ok(FigureReport {
        id: "serving",
        title: format!(
            "Serving: tail latency vs offered load (open-loop Poisson, K=4, \
             {n_requests} reqs x {n_tokens} tokens, seed {seed})"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Scheduling-policy comparison (beyond the paper): p99 TTFT, makespan
/// and shed requests across the pluggable policies (`fcfs`, `srf`,
/// `fair`, `slo`) at a fixed Poisson load, over the paper models. The
/// request set mixes short/medium/long lengths (`n_tokens` x {1, 2, 3}
/// cycling by id) so the reordering policies have something to reorder;
/// capacity is calibrated like the serving figure (batch-at-zero
/// makespan of the same mix at the baseline K = 4, offered rate = load
/// x n_requests / makespan). The SLO TTFT budget is four mean batch
/// service shares (`4 * makespan / n_requests`) — tight enough to shed
/// load past saturation, loose enough to admit wait-free requests.
/// Fully deterministic for a given `seed`.
pub fn fig_policy_comparison(
    n_requests: usize,
    n_tokens: u64,
    load: f64,
    seed: u64,
) -> Result<FigureReport> {
    anyhow::ensure!(n_requests >= 1, "need at least one request");
    anyhow::ensure!(n_tokens >= 1, "need at least one token per request");
    let base = HwConfig::paper_baseline();
    let freq_hz = base.gddr6.freq_ghz * 1e9;
    let fmt = |cycles: u64| fmt_time_s(cycles as f64 / freq_hz);
    let lens: Vec<u64> = (0..n_requests).map(|i| n_tokens * (1 + (i % 3) as u64)).collect();
    let mut t = Table::new(vec![
        "model", "policy", "rejected", "ttft p50", "ttft p99", "e2e p99", "makespan",
    ]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        // One Algorithm-3 placement per model, shared by every run.
        let mapping = ModelMapping::build(m, &base)?;
        let run = |cfg: &HwConfig, at: &[u64]| -> Result<(u64, Option<LatencyReport>, u64)> {
            let mut ms = MultiSim::from_mapping(m, cfg, mapping.clone());
            for (id, (&n, &a)) in lens.iter().zip(at.iter()).enumerate() {
                let spec =
                    StreamSpec { id: id as u64, n_tokens: n, prompt_tokens: 1, arrival_cycle: a };
                ms.submit(spec)?;
            }
            ms.run_all()?;
            ms.finalize_stats();
            Ok((ms.clock(), ms.stats.latency_report(), ms.stats.rejected))
        };
        let (makespan, _, _) = run(&base, &vec![0u64; n_requests])?;
        let rate_per_s = load * n_requests as f64 * freq_hz / makespan as f64;
        let at = arrivals::generate(
            &ArrivalSpec::Poisson { rate_per_s },
            n_requests,
            base.gddr6.freq_ghz,
            seed,
        )?;
        let budget = (makespan / n_requests as u64).saturating_mul(4).max(1);
        let slo = format!("slo:{budget}");
        for policy in ["fcfs", "srf", "fair", slo.as_str()] {
            let mut cfg = base.clone();
            cfg.sched.set_policy_str(policy)?;
            let (mk, lat, rejected) = run(&cfg, &at)?;
            let lat = lat.ok_or_else(|| {
                anyhow!("{}/{policy}: every request rejected — budget {budget} too tight", m.name)
            })?;
            let label = cfg.sched.policy.to_string();
            t.row(vec![
                m.name.to_string(),
                label.clone(),
                rejected.to_string(),
                fmt(lat.ttft.p50),
                fmt(lat.ttft.p99),
                fmt(lat.e2e.p99),
                fmt(mk),
            ]);
            arr.push(Json::obj(vec![
                ("model", m.name.into()),
                ("policy", label.as_str().into()),
                ("load", load.into()),
                ("slo_ttft_budget_cycles", budget.into()),
                ("rejected", rejected.into()),
                ("ttft_p50_cycles", lat.ttft.p50.into()),
                ("ttft_p99_cycles", lat.ttft.p99.into()),
                ("e2e_p99_cycles", lat.e2e.p99.into()),
                ("makespan_cycles", mk.into()),
            ]));
        }
    }
    Ok(FigureReport {
        id: "policies",
        title: format!(
            "Serving: scheduling policies at Poisson load {load:.2} (K=4, {n_requests} reqs x \
             {n_tokens}-token mix, seed {seed})"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Chunked-prefill figure (beyond the paper): true TTFT (first
/// *generated* token = prompt prefill completion) and end-to-end
/// makespan versus prefill chunk size and prompt length, over the 8
/// paper models. Each cell serves one `prompt`-token request generating
/// `gen_tokens` new tokens on an uncontended K=1 engine; `chunk = 1` is
/// the historical token-by-token prefill, so the speedup column is the
/// activation/fill-amortization win the chunked programs buy. Prompts
/// are clamped to each model's `max_seq - gen_tokens`. Fully
/// deterministic (no arrivals, no RNG).
pub fn fig_prefill(gen_tokens: u64, chunks: &[u64], prompts: &[u64]) -> Result<FigureReport> {
    anyhow::ensure!(!chunks.is_empty() && !prompts.is_empty(), "need chunk and prompt lists");
    let cfg = HwConfig::paper_baseline();
    let freq_hz = cfg.gddr6.freq_ghz * 1e9;
    let fmt = |cycles: u64| fmt_time_s(cycles as f64 / freq_hz);
    let mut t = Table::new(vec![
        "model", "prompt", "chunk", "ttft", "e2e", "ttft speedup vs chunk=1",
    ]);
    let mut arr = Vec::new();
    for m in &PAPER_MODELS {
        // One Algorithm-3 placement per model, shared by every run.
        let mapping = ModelMapping::build(m, &cfg)?;
        for &prompt in prompts {
            let prompt = prompt.min(m.max_seq as u64 - gen_tokens).max(1);
            let run_one = |chunk: u64| -> Result<(u64, u64)> {
                let mut run_cfg = cfg.clone();
                run_cfg.sched.prefill_chunk = chunk;
                let mut ms = MultiSim::from_mapping(m, &run_cfg, mapping.clone());
                ms.submit(StreamSpec::with_prompt(0, prompt, gen_tokens))?;
                let results: Vec<_> = ms
                    .run_all()?
                    .into_iter()
                    .filter_map(StreamOutcome::into_completed)
                    .collect();
                let r = results.first().ok_or_else(|| anyhow!("no stream retired"))?;
                Ok((r.ttft_cycles(), r.e2e_cycles()))
            };
            // The speedup baseline is always the token-by-token run,
            // whether or not chunk = 1 appears in the sweep list.
            let (ttft_base, e2e_base) = run_one(1)?;
            for &chunk in chunks {
                let chunk = chunk.max(1);
                let (ttft, e2e) =
                    if chunk == 1 { (ttft_base, e2e_base) } else { run_one(chunk)? };
                let speedup = ttft_base as f64 / ttft.max(1) as f64;
                t.row(vec![
                    m.name.to_string(),
                    prompt.to_string(),
                    chunk.to_string(),
                    fmt(ttft),
                    fmt(e2e),
                    format!("{speedup:.2}x"),
                ]);
                arr.push(Json::obj(vec![
                    ("model", m.name.into()),
                    ("prompt_tokens", prompt.into()),
                    ("gen_tokens", gen_tokens.into()),
                    ("prefill_chunk", chunk.into()),
                    ("ttft_cycles", ttft.into()),
                    ("e2e_cycles", e2e.into()),
                    ("ttft_speedup_vs_chunk1", speedup.into()),
                ]));
            }
        }
    }
    Ok(FigureReport {
        id: "prefill",
        title: format!(
            "Prefill: TTFT (first generated token) & makespan vs chunk size \
             (uncontended K=1, +{gen_tokens} generated tokens)"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Cross-stream batched decode: aggregate decode throughput
/// (busy-cycle basis) at K concurrent streams, batching on vs off.
/// Saturated closed-loop load — K identical 1-token-prompt requests
/// present at cycle 0, so the engine always holds K ready decode
/// tokens and the fused sweeps run at full occupancy. K = 1 pins the
/// equivalence (speedup exactly 1.0 — the batched engine replays the
/// unbatched schedule); K >= 2 shows the ACT/PRE + pipeline-fill
/// amortization. `models` filters the paper zoo by name (empty = all
/// 8 — the CI smoke runs one model via `--models`).
pub fn fig_batching(gen_tokens: u64, ks: &[usize], models: &[String]) -> Result<FigureReport> {
    anyhow::ensure!(!ks.is_empty(), "need a K list");
    anyhow::ensure!(gen_tokens >= 1, "need at least one generated token");
    for name in models {
        anyhow::ensure!(
            PAPER_MODELS.iter().any(|m| m.name == name),
            "unknown model '{name}' in --models"
        );
    }
    let max_k = *ks.iter().max().expect("ks checked non-empty");
    let base = HwConfig::paper_baseline();
    let freq = base.gddr6.freq_ghz;
    let mut t = Table::new(vec![
        "model", "K", "unbatched tok/s", "batched tok/s", "speedup", "mean batch", "max batch",
    ]);
    let mut arr = Vec::new();
    let selected = PAPER_MODELS
        .iter()
        .filter(|m| models.is_empty() || models.iter().any(|n| n == m.name));
    for m in selected {
        // One Algorithm-3 placement per model (sized for the largest
        // K), shared by every run.
        let map_cfg = base.clone().with_max_streams(max_k);
        let mapping = ModelMapping::build(m, &map_cfg)?;
        for &k in ks {
            anyhow::ensure!(k >= 1, "K must be >= 1");
            let run_one = |batch: bool| -> Result<(f64, f64, u64)> {
                let run_cfg = base.clone().with_max_streams(k).with_batch_decode(batch);
                let mut ms = MultiSim::from_mapping(m, &run_cfg, mapping.clone());
                for id in 0..k as u64 {
                    ms.submit(StreamSpec::new(id, 1 + gen_tokens))?;
                }
                let done = ms.run_all()?.len();
                anyhow::ensure!(done == k, "{done} of {k} streams retired");
                ms.finalize_stats();
                let tput = ms.stats.tokens as f64 / ms.stats.busy_seconds(freq);
                Ok((tput, ms.stats.mean_decode_batch(), ms.stats.max_decode_batch))
            };
            let (off_tput, _, _) = run_one(false)?;
            let (on_tput, mean_batch, max_batch) = run_one(true)?;
            let speedup = on_tput / off_tput;
            t.row(vec![
                m.name.to_string(),
                k.to_string(),
                format!("{off_tput:.0}"),
                format!("{on_tput:.0}"),
                format!("{speedup:.2}x"),
                format!("{mean_batch:.2}"),
                max_batch.to_string(),
            ]);
            arr.push(Json::obj(vec![
                ("model", m.name.into()),
                ("k", (k as u64).into()),
                ("gen_tokens", gen_tokens.into()),
                ("unbatched_tokens_per_s", off_tput.into()),
                ("batched_tokens_per_s", on_tput.into()),
                ("speedup", speedup.into()),
                ("mean_decode_batch", mean_batch.into()),
                ("max_decode_batch", max_batch.into()),
            ]));
        }
    }
    Ok(FigureReport {
        id: "batching",
        title: format!(
            "Batched decode: saturated throughput (busy-cycle basis) vs K, \
             batching on/off (+{gen_tokens} generated tokens per stream)"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Paged-KV figure (beyond the paper): makespan and p99 TTFT with the
/// static slot engine vs the paged engine (128-token pages, 1.5x
/// oversubscription) on two workload mixes — *many short chats* (2K
/// requests, 8-token prompts) and *few long documents* (2 requests,
/// long prompts) — under KV-constrained capacity. Each model's DRAM
/// capacity is squeezed (a deterministic descending scan) until the
/// slot engine grants fewer than K = 4 contexts; the paged engine then
/// out-admits it on the short mix because admission commits *expected*
/// (per-frame) footprint, not worst-case whole contexts. Models where
/// no scanned capacity degrades the slot grant run at the baseline
/// (both engines behave identically there — the equivalence contract).
/// `models` filters the paper zoo (empty = all 8; the CI smoke runs
/// one model via `--models`). Fully deterministic (closed loop, no RNG).
pub fn fig_paging(gen_tokens: u64, models: &[String]) -> Result<FigureReport> {
    anyhow::ensure!(gen_tokens >= 1, "need at least one generated token");
    for name in models {
        anyhow::ensure!(
            PAPER_MODELS.iter().any(|m| m.name == name),
            "unknown model '{name}' in --models"
        );
    }
    const K: usize = 4;
    let base = HwConfig::paper_baseline();
    let freq_hz = base.gddr6.freq_ghz * 1e9;
    let fmt = |cycles: u64| fmt_time_s(cycles as f64 / freq_hz);
    let mut t = Table::new(vec![
        "model", "mix", "engine", "grant", "peak", "preempt", "ttft p99", "makespan",
    ]);
    let mut arr = Vec::new();
    let selected = PAPER_MODELS
        .iter()
        .filter(|m| models.is_empty() || models.iter().any(|n| n == m.name));
    for m in selected {
        // Deterministic capacity squeeze: the first (largest) scanned
        // capacity whose *slot* grant falls below K makes KV rows the
        // binding constraint; baseline if none does.
        let mut capacity = base.gddr6.capacity_gbit;
        for factor in [0.5, 0.35, 0.25, 0.18, 0.12, 0.08, 0.05, 0.03, 0.02] {
            let mut cfg = base.clone().with_max_streams(K);
            cfg.gddr6.capacity_gbit = base.gddr6.capacity_gbit * factor;
            let Ok(mapping) = ModelMapping::build(m, &cfg) else { continue };
            if (1..K).contains(&mapping.kv.n_slots) {
                capacity = cfg.gddr6.capacity_gbit;
                break;
            }
        }
        let long_prompt = (m.max_seq as u64 / 4).clamp(8, 128);
        let mixes: [(&str, Vec<StreamSpec>); 2] = [
            (
                "short-chats",
                (0..2 * K as u64)
                    .map(|id| StreamSpec::with_prompt(id, 8, gen_tokens))
                    .collect(),
            ),
            (
                "long-docs",
                (0..2u64)
                    .map(|id| StreamSpec::with_prompt(id, long_prompt, 2 * gen_tokens))
                    .collect(),
            ),
        ];
        for (mix, specs) in &mixes {
            for paged in [false, true] {
                let mut cfg = base.clone().with_max_streams(K);
                cfg.gddr6.capacity_gbit = capacity;
                if paged {
                    cfg.sched.kv_paging = true;
                    cfg.sched.kv_page_tokens = 128;
                    cfg.sched.kv_oversub = 1.5;
                }
                let mut ms = MultiSim::new(m, &cfg)?;
                for spec in specs {
                    ms.submit(*spec)?;
                }
                let done = ms.run_all()?.len();
                anyhow::ensure!(done == specs.len(), "{done} of {} streams retired", specs.len());
                ms.finalize_stats();
                let s = &ms.stats;
                let lat =
                    s.latency_report().ok_or_else(|| anyhow!("no streams retired"))?;
                let (engine, grant) =
                    if paged { ("pages", s.kv_pages) } else { ("slots", s.kv_slots) };
                let peak = if paged { s.peak_pages_in_use } else { s.peak_slots_in_use };
                t.row(vec![
                    m.name.to_string(),
                    mix.to_string(),
                    engine.into(),
                    grant.to_string(),
                    peak.to_string(),
                    s.preemptions.to_string(),
                    fmt(lat.ttft.p99),
                    fmt(ms.clock()),
                ]);
                arr.push(Json::obj(vec![
                    ("model", m.name.into()),
                    ("mix", (*mix).into()),
                    ("engine", engine.into()),
                    ("capacity_gbit", capacity.into()),
                    ("grant", grant.into()),
                    ("peak_in_use", peak.into()),
                    ("peak_streams", s.peak_slots_in_use.into()),
                    ("page_faults", s.page_faults.into()),
                    ("preemptions", s.preemptions.into()),
                    ("evicted_tokens", s.evicted_tokens.into()),
                    ("ttft_p99_cycles", lat.ttft.p99.into()),
                    ("e2e_p99_cycles", lat.e2e.p99.into()),
                    ("makespan_cycles", ms.clock().into()),
                ]));
            }
        }
    }
    Ok(FigureReport {
        id: "paging",
        title: format!(
            "Paged KV: slot vs paged engine under KV-constrained capacity \
             (K={K}, 128-token pages, oversub 1.5, +{gen_tokens} generated tokens)"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Multi-device sharding figure (beyond the paper): serve a small
/// closed-loop workload on N in {1, 2, 4} devices under both partition
/// strategies, reporting aggregate throughput, decode latency,
/// co-resident stream capacity, per-device utilization and the modeled
/// interconnect cycles (`SimStats::link_transfer_cycles` — never folded
/// into compute). Layer-pipeline rows need N <= n_layer and
/// tensor-parallel rows need n_head % N == 0 (the partition pass
/// rejects the rest loudly); unviable combinations are skipped here,
/// not silently zeroed — gpt2-xl's 25 heads make it pipeline-only.
/// `models` filters the paper zoo (empty = all 8; the CI smoke runs one
/// model via `--models`). Fully deterministic (closed loop, no RNG).
pub fn fig_sharding(gen_tokens: u64, models: &[String]) -> Result<FigureReport> {
    anyhow::ensure!(gen_tokens >= 1, "need at least one generated token");
    for name in models {
        anyhow::ensure!(
            PAPER_MODELS.iter().any(|m| m.name == name),
            "unknown model '{name}' in --models"
        );
    }
    const K: usize = 2;
    let base = HwConfig::paper_baseline();
    let freq = base.gddr6.freq_ghz;
    let mut t = Table::new(vec![
        "model", "devices", "strategy", "streams", "tok/s", "decode c/tok", "link cycles",
        "device util",
    ]);
    let mut arr = Vec::new();
    let selected = PAPER_MODELS
        .iter()
        .filter(|m| models.is_empty() || models.iter().any(|n| n == m.name));
    for m in selected {
        for devices in [1usize, 2, 4] {
            let strategies: &[PartitionStrategy] = if devices == 1 {
                // Both strategies are the identity partition at N = 1.
                &[PartitionStrategy::LayerPipeline]
            } else {
                &[PartitionStrategy::LayerPipeline, PartitionStrategy::TensorParallel]
            };
            for &strategy in strategies {
                let viable = match strategy {
                    PartitionStrategy::LayerPipeline => devices <= m.n_layer,
                    PartitionStrategy::TensorParallel => m.n_head % devices == 0,
                };
                if !viable {
                    continue;
                }
                let cfg = base
                    .clone()
                    .with_max_streams(K)
                    .with_devices(devices)
                    .with_partition(strategy);
                let mut fleet = FleetSim::new(m, &cfg)?;
                for id in 0..K as u64 {
                    fleet.submit(StreamSpec::new(id, 1 + gen_tokens))?;
                }
                let done = fleet.run_all()?.len();
                anyhow::ensure!(done == K, "{done} of {K} streams retired");
                let clock = fleet.clock();
                let streams = fleet.kv_slots();
                let s = fleet.finalize_stats();
                let decode_per_tok = s.decode_cycles as f64 / (K as u64 * gen_tokens) as f64;
                let tput = s.tokens as f64 / (clock as f64 / (freq * 1e9));
                let label =
                    if devices == 1 { "single".to_string() } else { strategy.to_string() };
                let utils: Vec<f64> =
                    (0..s.device_busy_cycles.len()).map(|d| s.device_utilization(d)).collect();
                let util_str = if utils.is_empty() {
                    "-".to_string()
                } else {
                    utils.iter().map(|u| format!("{u:.2}")).collect::<Vec<_>>().join("/")
                };
                t.row(vec![
                    m.name.to_string(),
                    devices.to_string(),
                    label.clone(),
                    streams.to_string(),
                    format!("{tput:.0}"),
                    format!("{decode_per_tok:.0}"),
                    s.link_transfer_cycles.to_string(),
                    util_str,
                ]);
                arr.push(Json::obj(vec![
                    ("model", m.name.into()),
                    ("devices", devices.into()),
                    ("strategy", label.into()),
                    ("kv_streams", streams.into()),
                    ("gen_tokens", gen_tokens.into()),
                    ("tokens_per_s", tput.into()),
                    ("decode_cycles_per_token", decode_per_tok.into()),
                    ("link_transfer_cycles", s.link_transfer_cycles.into()),
                    ("makespan_cycles", clock.into()),
                    (
                        "device_utilization",
                        Json::Arr(utils.iter().map(|&u| u.into()).collect()),
                    ),
                ]));
            }
        }
    }
    Ok(FigureReport {
        id: "sharding",
        title: format!(
            "Multi-device sharding: throughput, decode latency and link cycles \
             vs device count and partition strategy (K={K}, +{gen_tokens} \
             generated tokens per stream)"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Windowed utilization timeline: bin busy / idle / link cycles and
/// pages-in-use over the makespan of a staggered-arrival serving run.
///
/// Each configuration runs twice. An untraced calibration run fixes the
/// makespan; the window is then set to an eighth of it and the run
/// repeats with the timeline on. The two makespans are asserted equal —
/// the observer-effect-free contract of the tracing subsystem, enforced
/// on every figure regeneration. Device 1 runs paged (pages column
/// populates); device 2 runs layer-pipelined (link column populates).
/// The partition/mapping build is shared across the pair via
/// `FleetSim::prebuild` — the trace window does not affect placement,
/// so the second run reuses the first run's mappings instead of paying
/// the row-allocation pass again.
pub fn fig_timeline(gen_tokens: u64, models: &[String]) -> Result<FigureReport> {
    anyhow::ensure!(gen_tokens >= 1, "need at least one generated token");
    for name in models {
        anyhow::ensure!(
            PAPER_MODELS.iter().any(|m| m.name == name),
            "unknown model '{name}' in --models"
        );
    }
    const K: usize = 4;
    const WINDOWS: u64 = 8;
    let base = HwConfig::paper_baseline();
    let mut t = Table::new(vec![
        "model", "devices", "window", "busy", "idle", "link", "pages", "util",
    ]);
    let mut arr = Vec::new();
    let selected = PAPER_MODELS
        .iter()
        .filter(|m| models.is_empty() || models.iter().any(|n| n == m.name));
    for m in selected {
        for devices in [1usize, 2] {
            if devices > m.n_layer {
                continue;
            }
            let mut cfg = base.clone().with_max_streams(K);
            if devices == 1 {
                cfg.sched.kv_paging = true;
                cfg.sched.kv_page_tokens = 128;
            } else {
                cfg = cfg.with_devices(devices).with_partition(PartitionStrategy::LayerPipeline);
            }
            // Staggered arrivals so the timeline shows idle gaps, not a
            // solid busy bar.
            let specs: Vec<StreamSpec> = (0..K as u64)
                .map(|id| {
                    let mut s = StreamSpec::with_prompt(id, 4, gen_tokens);
                    s.arrival_cycle = id * 5_000;
                    s
                })
                .collect();
            let pre = FleetSim::prebuild(m, &cfg)?;
            let run = |cfg: &HwConfig| -> Result<(u64, Vec<TraceWindow>)> {
                let mut fleet = FleetSim::from_prebuilt(m, cfg, &pre)?;
                for spec in &specs {
                    fleet.submit(*spec)?;
                }
                let done = fleet.run_all()?.len();
                anyhow::ensure!(done == K, "{done} of {K} streams retired");
                let clock = fleet.clock();
                let timeline = fleet.finalize_stats().timeline.clone();
                Ok((clock, timeline))
            };
            let (makespan, _) = run(&cfg)?;
            let window = (makespan / WINDOWS).max(1);
            let (traced_makespan, timeline) = run(&cfg.clone().with_trace_window(window))?;
            anyhow::ensure!(
                traced_makespan == makespan,
                "timeline binning changed the simulated makespan on {}: {traced_makespan} != \
                 {makespan}",
                m.name
            );
            anyhow::ensure!(!timeline.is_empty(), "empty timeline for {}", m.name);
            for w in &timeline {
                t.row(vec![
                    m.name.to_string(),
                    devices.to_string(),
                    format!("[{}, {})", w.start, w.end),
                    w.busy.to_string(),
                    w.idle.to_string(),
                    w.link.to_string(),
                    w.pages_in_use.to_string(),
                    format!("{:.2}", w.utilization()),
                ]);
                arr.push(Json::obj(vec![
                    ("model", m.name.into()),
                    ("devices", devices.into()),
                    ("start", w.start.into()),
                    ("end", w.end.into()),
                    ("busy_cycles", w.busy.into()),
                    ("idle_cycles", w.idle.into()),
                    ("link_cycles", w.link.into()),
                    ("pages_in_use", w.pages_in_use.into()),
                    ("utilization", w.utilization().into()),
                ]));
            }
        }
    }
    Ok(FigureReport {
        id: "timeline",
        title: format!(
            "Utilization timeline: busy/idle/link cycles and pages-in-use per \
             window (K={K}, staggered arrivals, +{gen_tokens} generated tokens \
             per stream, {WINDOWS} windows per run)"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

/// Profile attribution stacks: where the busy cycles of a profiled
/// serving run go — phase x position-regime, collapsed over per-device
/// occupancy — for every paper model at 1 and 2 devices. Each cell's
/// attribution is hard-checked (leaf sums + residual == busy cycles,
/// link spans == charged link cycles) before it is rendered, so
/// regenerating this figure re-proves the profiler's reconciliation
/// invariant across the whole model zoo. Devices = 2 runs
/// layer-pipelined, populating the link column from the same profile.
pub fn fig_profile(gen_tokens: u64, models: &[String]) -> Result<FigureReport> {
    anyhow::ensure!(gen_tokens >= 1, "need at least one generated token");
    for name in models {
        anyhow::ensure!(
            PAPER_MODELS.iter().any(|m| m.name == name),
            "unknown model '{name}' in --models"
        );
    }
    const K: usize = 3;
    let base = HwConfig::paper_baseline();
    let mut t = Table::new(vec!["model", "devices", "phase", "regime", "cycles", "share"]);
    let mut arr = Vec::new();
    let selected = PAPER_MODELS
        .iter()
        .filter(|m| models.is_empty() || models.iter().any(|n| n == m.name));
    for m in selected {
        for devices in [1usize, 2] {
            if devices > m.n_layer {
                continue;
            }
            let mut cfg = base.clone().with_max_streams(K);
            if devices > 1 {
                cfg = cfg.with_devices(devices).with_partition(PartitionStrategy::LayerPipeline);
            }
            let mut fleet = FleetSim::new(m, &cfg)?;
            fleet.set_profile(ProfileSink::new(m, &cfg));
            for id in 0..K as u64 {
                fleet.submit(StreamSpec::with_prompt(id, 6, gen_tokens))?;
            }
            let done = fleet.run_all()?.len();
            anyhow::ensure!(done == K, "{done} of {K} streams retired on {}", m.name);
            fleet.finalize_stats();
            let profile = fleet
                .profile_report()
                .ok_or_else(|| anyhow!("{}: profiler detached mid-run", m.name))?;
            profile.check().map_err(|e| {
                anyhow!("{} devices={devices}: attribution failed to reconcile: {e}", m.name)
            })?;
            let busy = profile.busy_cycles.max(1) as f64;
            // Collapse the attribution tree over device and occupancy
            // into the (phase, regime) stack the figure plots.
            let mut stack: std::collections::BTreeMap<(&str, &str), u64> =
                std::collections::BTreeMap::new();
            for (k, c) in &profile.leaves {
                let regime = crate::sim::profile::regime_label(k.av_chunked);
                *stack.entry((k.phase.label(), regime)).or_insert(0) += c;
            }
            for (&(phase, regime), &cycles) in &stack {
                t.row(vec![
                    m.name.to_string(),
                    devices.to_string(),
                    phase.to_string(),
                    regime.to_string(),
                    cycles.to_string(),
                    format!("{:.1}%", 100.0 * cycles as f64 / busy),
                ]);
                arr.push(Json::obj(vec![
                    ("model", m.name.into()),
                    ("devices", devices.into()),
                    ("phase", phase.into()),
                    ("regime", regime.into()),
                    ("cycles", cycles.into()),
                    ("busy_cycles", profile.busy_cycles.into()),
                    ("residual_cycles", (profile.residual.max(0) as u64).into()),
                    ("link_cycles", profile.link_cycles.into()),
                ]));
            }
            t.row(vec![
                m.name.to_string(),
                devices.to_string(),
                "unattributed".to_string(),
                "-".to_string(),
                profile.residual.to_string(),
                format!("{:.1}%", 100.0 * profile.residual as f64 / busy),
            ]);
        }
    }
    Ok(FigureReport {
        id: "profile",
        title: format!(
            "Profile attribution stacks: busy-cycle share per phase x regime \
             (K={K}, +{gen_tokens} generated tokens per stream, devices 1 and \
             2, reconciliation hard-checked per cell)"
        ),
        rendered: t.render(),
        json: Json::Arr(arr),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_all_models() {
        let r = fig1_model_zoo();
        assert_eq!(r.json.as_arr().unwrap().len(), 8);
        assert!(r.rendered.contains("resnet-18"));
    }

    #[test]
    fn fig8_9_bands_hold_small_run() {
        // Short run (8 tokens) — ratios are looser than at 1024 tokens
        // but the ordering (GPU < CPU, small > xl speedup) must hold.
        let r = fig8_9_speedup_energy(8).unwrap();
        let arr = r.json.as_arr().unwrap();
        let get = |i: usize, k: &str| arr[i].get(k).unwrap().as_f64().unwrap();
        for i in 0..arr.len() {
            assert!(get(i, "speedup_cpu") > get(i, "speedup_gpu"));
            assert!(get(i, "speedup_gpu") > 10.0);
        }
        // speedup decreases with model size within a family
        assert!(get(0, "speedup_gpu") > get(3, "speedup_gpu"));
    }

    #[test]
    fn fig10_vmm_dominates() {
        let r = fig10_breakdown(4).unwrap();
        for row in r.json.as_arr().unwrap() {
            assert!(row.get("vmm_share").unwrap().as_f64().unwrap() > 0.7);
        }
    }

    /// Acceptance: the prefill figure renders a row for every paper
    /// model x chunk, and chunked prefill strictly beats token-by-token
    /// TTFT on every model (the amortization headline).
    #[test]
    fn fig_prefill_renders_all_models_with_amortization() {
        let r = fig_prefill(2, &[1, 16], &[48]).unwrap();
        let arr = r.json.as_arr().unwrap();
        assert_eq!(arr.len(), 8 * 2, "8 models x 2 chunk sizes");
        for m in &PAPER_MODELS {
            assert!(r.rendered.contains(m.name), "{} missing", m.name);
            let rows: Vec<_> = arr
                .iter()
                .filter(|e| e.get("model").unwrap().as_str().unwrap() == m.name)
                .collect();
            let ttft = |chunk: f64| {
                rows.iter()
                    .find(|e| e.get("prefill_chunk").unwrap().as_f64().unwrap() == chunk)
                    .unwrap()
                    .get("ttft_cycles")
                    .unwrap()
                    .as_f64()
                    .unwrap()
            };
            assert!(
                ttft(16.0) < ttft(1.0),
                "{}: chunk 16 ttft {} !< token-by-token {}",
                m.name,
                ttft(16.0),
                ttft(1.0)
            );
        }
    }

    /// Acceptance: the batching figure pins the equivalence and speedup
    /// contracts — K=1 has speedup exactly 1.0 (batching never engages),
    /// K=2 fuses (mean batch >= 2) and strictly beats unbatched
    /// busy-cycle throughput.
    #[test]
    fn fig_batching_k1_identity_and_k2_speedup() {
        let r = fig_batching(3, &[1, 2], &["gpt2-small".to_string()]).unwrap();
        let arr = r.json.as_arr().unwrap();
        assert_eq!(arr.len(), 2, "1 model x 2 Ks");
        let get = |i: usize, k: &str| arr[i].get(k).unwrap().as_f64().unwrap();
        // K=1: batching can never engage, so the runs are cycle-identical.
        assert_eq!(get(0, "k"), 1.0);
        assert_eq!(get(0, "speedup"), 1.0, "K=1 must be cycle-identical");
        assert_eq!(get(0, "mean_decode_batch"), 0.0);
        // K=2: fused sweeps engage and amortize the weight sweep.
        assert_eq!(get(1, "k"), 2.0);
        assert!(get(1, "speedup") > 1.0, "K=2 speedup {}", get(1, "speedup"));
        assert!(get(1, "mean_decode_batch") >= 2.0);
        assert!(r.rendered.contains("gpt2-small"));
    }

    #[test]
    fn fig_batching_rejects_unknown_model() {
        assert!(fig_batching(2, &[1], &["no-such-model".to_string()]).is_err());
    }

    /// Acceptance: under KV-constrained capacity the paged engine
    /// out-admits the slot engine on the many-short-chats mix (peak
    /// concurrent streams strictly higher) and its makespan is no worse.
    #[test]
    fn fig_paging_short_chats_beat_slots_under_pressure() {
        let r = fig_paging(2, &["gpt2-small".to_string()]).unwrap();
        let arr = r.json.as_arr().unwrap();
        assert_eq!(arr.len(), 4, "2 mixes x 2 engines");
        let find = |mix: &str, engine: &str| {
            arr.iter()
                .find(|e| {
                    e.get("mix").unwrap().as_str().unwrap() == mix
                        && e.get("engine").unwrap().as_str().unwrap() == engine
                })
                .unwrap()
        };
        let slots = find("short-chats", "slots");
        let pages = find("short-chats", "pages");
        let f = |e: &Json, k: &str| e.get(k).unwrap().as_f64().unwrap();
        assert!(
            f(slots, "grant") < 4.0,
            "capacity squeeze must bind the slot grant, got {}",
            f(slots, "grant")
        );
        assert!(
            f(pages, "peak_streams") > f(slots, "peak_in_use"),
            "paged short-chat concurrency {} !> slot concurrency {}",
            f(pages, "peak_streams"),
            f(slots, "peak_in_use")
        );
        assert!(
            f(pages, "makespan_cycles") <= f(slots, "makespan_cycles"),
            "paged makespan {} !<= slot makespan {}",
            f(pages, "makespan_cycles"),
            f(slots, "makespan_cycles")
        );
        assert!(r.rendered.contains("short-chats") && r.rendered.contains("long-docs"));
    }

    #[test]
    fn fig_paging_rejects_unknown_model() {
        assert!(fig_paging(2, &["no-such-model".to_string()]).is_err());
    }

    /// Acceptance: the sharding figure covers N = 1/2/4 for a
    /// TP-capable model, reports link cycles only when devices move
    /// activations, and per-device utilization matches the device count.
    #[test]
    fn fig_sharding_covers_strategies_and_links() {
        let r = fig_sharding(2, &["gpt2-small".to_string()]).unwrap();
        let arr = r.json.as_arr().unwrap();
        // gpt2-small: 12 layers, 12 heads — every combination viable:
        // N=1 (single) + N=2 x 2 strategies + N=4 x 2 strategies.
        assert_eq!(arr.len(), 5);
        let f = |e: &Json, k: &str| e.get(k).unwrap().as_f64().unwrap();
        let single = &arr[0];
        assert_eq!(f(single, "devices"), 1.0);
        assert_eq!(f(single, "link_transfer_cycles"), 0.0, "N=1 has no links");
        assert!(single.get("device_utilization").unwrap().as_arr().unwrap().is_empty());
        for e in &arr[1..] {
            let n = f(e, "devices") as usize;
            assert!(f(e, "link_transfer_cycles") > 0.0, "N={n} never paid links");
            assert_eq!(e.get("device_utilization").unwrap().as_arr().unwrap().len(), n);
            assert!(f(e, "tokens_per_s") > 0.0);
        }
        assert!(r.rendered.contains("tensor_parallel") && r.rendered.contains("layer_pipeline"));
    }

    /// Acceptance: the timeline figure produces contiguous windows from
    /// cycle 0, the paged single-device run shows pages in use, and the
    /// two-device pipeline run shows link cycles. The figure itself
    /// asserts the traced makespan equals the untraced one.
    #[test]
    fn fig_timeline_windows_are_contiguous_and_populated() {
        let r = fig_timeline(4, &["gpt2-small".to_string()]).unwrap();
        let arr = r.json.as_arr().unwrap();
        assert!(!arr.is_empty());
        let f = |e: &Json, k: &str| e.get(k).unwrap().as_f64().unwrap();
        for devices in [1.0, 2.0] {
            let rows: Vec<&Json> =
                arr.iter().filter(|e| f(e, "devices") == devices).collect();
            assert!(!rows.is_empty(), "no windows for devices={devices}");
            assert_eq!(f(rows[0], "start"), 0.0);
            for pair in rows.windows(2) {
                assert_eq!(f(pair[0], "end"), f(pair[1], "start"), "windows not contiguous");
            }
            for e in &rows {
                assert_eq!(
                    f(e, "busy_cycles") + f(e, "idle_cycles"),
                    f(e, "end") - f(e, "start"),
                    "busy+idle must fill the window exactly"
                );
            }
            let total = |k: &str| rows.iter().map(|e| f(e, k)).sum::<f64>();
            assert!(total("busy_cycles") > 0.0, "devices={devices} never busy");
            if devices == 1.0 {
                assert!(
                    rows.iter().any(|e| f(e, "pages_in_use") > 0.0),
                    "paged run shows no pages in use"
                );
            } else {
                assert!(total("link_cycles") > 0.0, "pipeline run paid no link cycles");
            }
        }
        assert!(r.rendered.contains("gpt2-small"));
    }

    #[test]
    fn fig_timeline_rejects_unknown_model() {
        assert!(fig_timeline(2, &["no-such-model".to_string()]).is_err());
    }

    /// Acceptance: the profile figure's stacks cover the busy cycles
    /// exactly (cycles + residual == busy in every device group) and
    /// the two-device pipeline run attributes link cycles — the figure
    /// itself hard-checks reconciliation per cell before rendering.
    #[test]
    fn fig_profile_stacks_reconcile_and_cover_both_devices() {
        let r = fig_profile(2, &["gpt2-small".to_string()]).unwrap();
        let arr = r.json.as_arr().unwrap();
        assert!(!arr.is_empty());
        let f = |e: &Json, k: &str| e.get(k).unwrap().as_f64().unwrap();
        for devices in [1.0, 2.0] {
            let rows: Vec<&Json> =
                arr.iter().filter(|e| f(e, "devices") == devices).collect();
            assert!(!rows.is_empty(), "no stack rows for devices={devices}");
            let covered: f64 = rows.iter().map(|e| f(e, "cycles")).sum();
            assert_eq!(
                covered + f(rows[0], "residual_cycles"),
                f(rows[0], "busy_cycles"),
                "stack does not cover busy cycles at devices={devices}"
            );
            assert!(
                rows.iter().any(|e| e.get("phase").unwrap().as_str().unwrap() == "prefill"),
                "no prefill share at devices={devices}"
            );
            if devices == 2.0 {
                assert!(f(rows[0], "link_cycles") > 0.0, "pipeline run paid no link cycles");
            }
        }
        assert!(r.rendered.contains("unattributed"));
        assert!(fig_profile(2, &["no-such-model".to_string()]).is_err());
    }

    #[test]
    fn fig_sharding_skips_indivisible_tensor_parallel() {
        // gpt2-xl has 25 heads: no TP at N = 2 or 4, and the pipeline
        // rows still appear — 1 + 2 rows in total.
        let r = fig_sharding(1, &["gpt2-xl".to_string()]).unwrap();
        let arr = r.json.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(!r.rendered.contains("tensor_parallel"));
    }

    #[test]
    fn table1_renders() {
        let r = table1_config(&HwConfig::paper_baseline());
        assert!(r.rendered.contains("16 pins x 16 Gb/s"));
    }

    #[test]
    fn table2_includes_ours() {
        let r = table2_comparison(8).unwrap();
        assert!(r.rendered.contains("PIM-GPT (ours)"));
        assert!(r.json.get("speedup").unwrap().as_f64().unwrap() > 10.0);
    }
}
