//! Device partitioning: split one model across N PIM-GPT devices.
//!
//! The paper evaluates a single 8-channel package; this pass lifts that
//! assumption into an explicit compiler stage (the shape of
//! berkeley-emulation-engine's `passes/partition.rs`, which splits one
//! netlist across boards). `DevicePartition::build` consumes the model
//! plus `sched.{devices, partition}` and emits one [`DeviceSlice`] per
//! device: the weight matrices that device stores (device-local
//! `MatrixId`s and shapes), the sub-model view that sizes its KV
//! reservation, and a per-device decode graph builder. Each slice maps
//! onto its *own* channel/bank space (`ModelMapping::build_device`) —
//! a model that degrades to 2 KV slots on one device fits full contexts
//! across 2 devices because both weights and KV shrink per device.
//!
//! Two strategies (`sched.partition`):
//!
//! * **`layer_pipeline`** — contiguous layer ranges per device
//!   (remainder layers go to the earliest devices), activations hop
//!   device-to-device between stages (`d_model` elements per pass).
//!   Only the last device stores the LM head. Requires
//!   `devices <= n_layer`.
//! * **`tensor_parallel`** — every device holds all layers but a
//!   `1/N` column shard of each (Megatron-style): `n_head / N`
//!   attention heads (Wqkv columns, KV cache, softmax groups) and
//!   `d_ff / N` FFN columns. Row-parallel matrices (Wo, W2) produce
//!   partial sums, so every layer pays two all-reduce hops; the LM
//!   head is vocab-sharded and gathered once per step. Requires
//!   `n_head % devices == 0`.
//!
//! Interconnect cost mirrors `MultiSim::kv_transfer_cycles`' explicit
//! accounting: a hop of `b` bytes costs `link_hop_cycles +
//! ceil(b * 8 * freq_ghz / link_gbit_s)` DRAM cycles
//! (`sched.{link_gbit_s, link_hop_cycles}`), charged by the fleet
//! engine as transfer edges between device programs — never hidden
//! inside compute costs.
//!
//! Element conservation is exact and property-tested: the union of the
//! per-device weight lists covers every weight element of the
//! single-device model exactly once, under both strategies.

use anyhow::{bail, ensure, Result};

use crate::asic::AsicOp;
use crate::config::HwConfig;
use crate::model::{DecodeGraph, GptModel, GraphNode, GraphOp, MatrixId, MatrixKind, VmmClass};
use crate::util::ceil_div;

/// How a model is split across devices (`sched.partition`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Contiguous layer ranges per device; activations hop between
    /// pipeline stages.
    #[default]
    LayerPipeline,
    /// Attention heads / FFN columns split per layer; two all-reduce
    /// hops per layer plus an LM-head gather.
    TensorParallel,
}

impl PartitionStrategy {
    /// Parse the JSON/CLI spelling: `layer_pipeline | tensor_parallel`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "layer_pipeline" => Ok(Self::LayerPipeline),
            "tensor_parallel" => Ok(Self::TensorParallel),
            _ => bail!("unknown partition strategy '{s}' (layer_pipeline | tensor_parallel)"),
        }
    }
}

impl std::fmt::Display for PartitionStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::LayerPipeline => write!(f, "layer_pipeline"),
            Self::TensorParallel => write!(f, "tensor_parallel"),
        }
    }
}

/// One device's share of the model.
#[derive(Clone, Debug)]
pub struct DeviceSlice {
    /// Device index in [0, devices).
    pub device: usize,
    /// Global layer range this device computes (`layer_pipeline`:
    /// its contiguous stage; `tensor_parallel`: all layers).
    pub layers: std::ops::Range<usize>,
    /// Weight matrices this device stores, with *device-local* layer
    /// ids (0-based within `layers`) and device-local shapes — the
    /// input of `ModelMapping::build_device` and exactly the matrices
    /// this device's decode graph references.
    pub weights: Vec<(MatrixId, u64, u64)>,
    /// Sub-model view sizing this device's KV reservation: layer count
    /// (`layer_pipeline`) or `d_model`/`n_head` shard
    /// (`tensor_parallel`) shrink per device; `max_seq` never does.
    pub kv_model: GptModel,
}

/// Per-layer operand shapes of one device's decode graph. For
/// `layer_pipeline` the shard equals the full width (a stage computes
/// whole layers); for `tensor_parallel` the sharded dims are `1/N`.
struct LayerShape {
    /// Full residual width (LayerNorm/residual ops replicate).
    d: u64,
    /// This device's attention width shard (`n_head_shard * d_head`).
    d_sh: u64,
    /// This device's attention head count.
    h_sh: u64,
    /// This device's FFN width shard.
    ff_sh: u64,
}

/// The partitioning pass output: one slice per device.
#[derive(Clone, Debug)]
pub struct DevicePartition {
    pub model: GptModel,
    pub strategy: PartitionStrategy,
    pub devices: usize,
    pub slices: Vec<DeviceSlice>,
}

impl DevicePartition {
    /// Partition `model` across `cfg.sched.devices` devices under
    /// `cfg.sched.partition`. Fails loudly on shapes the strategy
    /// cannot split (more pipeline stages than layers; heads not
    /// divisible by the device count) — silent remainder devices would
    /// corrupt every downstream capacity and cost number.
    pub fn build(model: &GptModel, cfg: &HwConfig) -> Result<Self> {
        let n = cfg.sched.devices;
        ensure!(n >= 1, "sched.devices must be >= 1, got {n}");
        let strategy = cfg.sched.partition;
        let slices = match strategy {
            PartitionStrategy::LayerPipeline => {
                ensure!(
                    n <= model.n_layer,
                    "layer_pipeline cannot split {} layers across {n} devices \
                     ({}); use fewer devices or tensor_parallel",
                    model.n_layer,
                    model.name,
                );
                (0..n).map(|i| Self::pipeline_slice(model, n, i)).collect()
            }
            PartitionStrategy::TensorParallel => {
                ensure!(
                    model.n_head % n == 0,
                    "tensor_parallel needs n_head divisible by the device count: \
                     {} has {} heads, devices = {n}",
                    model.name,
                    model.n_head,
                );
                (0..n).map(|i| Self::tensor_slice(model, n, i)).collect()
            }
        };
        Ok(Self { model: model.clone(), strategy, devices: n, slices })
    }

    /// Contiguous layer range of pipeline stage `i` (remainder layers
    /// go to the earliest stages: 12 layers / 5 devices -> 3,3,2,2,2).
    fn pipeline_layers(n_layer: usize, n: usize, i: usize) -> std::ops::Range<usize> {
        let base = n_layer / n;
        let rem = n_layer % n;
        let start = i * base + i.min(rem);
        let len = base + (i < rem) as usize;
        start..start + len
    }

    fn pipeline_slice(m: &GptModel, n: usize, i: usize) -> DeviceSlice {
        let d = m.d_model as u64;
        let ff = m.d_ff() as u64;
        let layers = Self::pipeline_layers(m.n_layer, n, i);
        let mut weights = Vec::new();
        for l in 0..layers.len() {
            weights.push((MatrixId::new(l, MatrixKind::Wqkv), d, 3 * d));
            weights.push((MatrixId::new(l, MatrixKind::Wo), d, d));
            weights.push((MatrixId::new(l, MatrixKind::W1), d, ff));
            weights.push((MatrixId::new(l, MatrixKind::W2), ff, d));
        }
        if i == n - 1 {
            weights.push((MatrixId::new(0, MatrixKind::Wte), d, m.vocab as u64));
        }
        let kv_model = GptModel { n_layer: layers.len(), ..m.clone() };
        DeviceSlice { device: i, layers, weights, kv_model }
    }

    /// Vocab column range of tensor-parallel device `i` (ceil split —
    /// device 0 holds the largest shard, so symmetric-cost bounds use
    /// device 0).
    fn vocab_cols(vocab: u64, n: usize, i: usize) -> u64 {
        let per = ceil_div(vocab, n as u64);
        let lo = (i as u64 * per).min(vocab);
        let hi = ((i as u64 + 1) * per).min(vocab);
        hi - lo
    }

    fn tensor_slice(m: &GptModel, n: usize, i: usize) -> DeviceSlice {
        let d = m.d_model as u64;
        let d_sh = d / n as u64; // exact: d = n_head * d_head, n | n_head
        let ff_sh = m.d_ff() as u64 / n as u64;
        let v_sh = Self::vocab_cols(m.vocab as u64, n, i);
        let mut weights = Vec::new();
        for l in 0..m.n_layer {
            weights.push((MatrixId::new(l, MatrixKind::Wqkv), d, 3 * d_sh));
            weights.push((MatrixId::new(l, MatrixKind::Wo), d_sh, d));
            weights.push((MatrixId::new(l, MatrixKind::W1), d, ff_sh));
            weights.push((MatrixId::new(l, MatrixKind::W2), ff_sh, d));
        }
        weights.push((MatrixId::new(0, MatrixKind::Wte), d, v_sh));
        let kv_model = GptModel {
            d_model: d_sh as usize,
            n_head: m.n_head / n,
            ..m.clone()
        };
        DeviceSlice { device: i, layers: 0..m.n_layer, weights, kv_model }
    }

    /// Build device `dev`'s decode graph for generating the token at
    /// position `pos` — the per-device mirror of `DecodeGraph::build`,
    /// with sharded operand shapes and only the ops this device runs.
    /// Every graph starts with an ingress residual-add (device 0: the
    /// embedding lookup; later pipeline stages: merging the hopped
    /// activation into the residual stream; tensor-parallel replicas:
    /// the replicated embedding). Only the device holding an LM-head
    /// shard emits the final LayerNorm + LM-head VMM.
    pub fn device_graph(&self, dev: usize, pos: u64) -> DecodeGraph {
        let m = &self.model;
        let slice = &self.slices[dev];
        let ltoken = pos + 1;
        let d = m.d_model as u64;
        let shape = match self.strategy {
            PartitionStrategy::LayerPipeline => LayerShape {
                d,
                d_sh: d,
                h_sh: m.n_head as u64,
                ff_sh: m.d_ff() as u64,
            },
            PartitionStrategy::TensorParallel => LayerShape {
                d,
                d_sh: slice.kv_model.d_model as u64,
                h_sh: slice.kv_model.n_head as u64,
                ff_sh: m.d_ff() as u64 / self.devices as u64,
            },
        };
        let lm_head_cols = slice
            .weights
            .iter()
            .find(|(id, _, _)| id.kind == MatrixKind::Wte)
            .map(|(_, _, cols)| *cols);
        let mut nodes: Vec<GraphNode> = Vec::with_capacity(slice.layers.len() * 20 + 3);
        let mut push = |nodes: &mut Vec<GraphNode>, op: GraphOp, deps: Vec<usize>| -> usize {
            nodes.push(GraphNode { op, deps });
            nodes.len() - 1
        };

        // Ingress: embedding lookup (device 0 / replicas) or the hopped
        // stage activation merged into the residual stream.
        let mut prev = push(&mut nodes, GraphOp::Asic(AsicOp::ResidualAdd { n: shape.d }), vec![]);

        for l in 0..slice.layers.len() {
            let ln1 =
                push(&mut nodes, GraphOp::Asic(AsicOp::LayerNorm { n: shape.d }), vec![prev]);
            let qkv = push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::Wqkv),
                    class: VmmClass::Qkv,
                    in_elems: shape.d,
                    out_elems: 3 * shape.d_sh,
                },
                vec![ln1],
            );
            let bias =
                push(&mut nodes, GraphOp::Asic(AsicOp::BiasAdd { n: 3 * shape.d_sh }), vec![qkv]);
            let wk = push(&mut nodes, GraphOp::WriteK { layer: l, elems: shape.d_sh }, vec![bias]);
            let wv = push(&mut nodes, GraphOp::WriteV { layer: l, elems: shape.d_sh }, vec![bias]);
            let score = push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::KCache),
                    class: VmmClass::Score,
                    in_elems: shape.d_sh,
                    out_elems: shape.h_sh * ltoken,
                },
                vec![bias, wk],
            );
            let scale = push(
                &mut nodes,
                GraphOp::Asic(AsicOp::Scale { n: shape.h_sh * ltoken }),
                vec![score],
            );
            let softmax = push(
                &mut nodes,
                GraphOp::Asic(AsicOp::Softmax { n: shape.h_sh * ltoken, groups: shape.h_sh }),
                vec![scale],
            );
            let av = push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::VCache),
                    class: VmmClass::AttnV,
                    in_elems: shape.h_sh * ltoken,
                    out_elems: shape.d_sh,
                },
                vec![softmax, wv],
            );
            let concat =
                push(&mut nodes, GraphOp::Asic(AsicOp::Concat { n: shape.d_sh }), vec![av]);
            let proj = push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::Wo),
                    class: VmmClass::Proj,
                    in_elems: shape.d_sh,
                    out_elems: shape.d,
                },
                vec![concat],
            );
            let bias2 = push(&mut nodes, GraphOp::Asic(AsicOp::BiasAdd { n: shape.d }), vec![proj]);
            let res1 = push(
                &mut nodes,
                GraphOp::Asic(AsicOp::ResidualAdd { n: shape.d }),
                vec![bias2, prev],
            );
            let ln2 = push(&mut nodes, GraphOp::Asic(AsicOp::LayerNorm { n: shape.d }), vec![res1]);
            let fc1 = push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::W1),
                    class: VmmClass::Fc1,
                    in_elems: shape.d,
                    out_elems: shape.ff_sh,
                },
                vec![ln2],
            );
            let bias3 =
                push(&mut nodes, GraphOp::Asic(AsicOp::BiasAdd { n: shape.ff_sh }), vec![fc1]);
            let gelu =
                push(&mut nodes, GraphOp::Asic(AsicOp::Gelu { n: shape.ff_sh }), vec![bias3]);
            let fc2 = push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(l, MatrixKind::W2),
                    class: VmmClass::Fc2,
                    in_elems: shape.ff_sh,
                    out_elems: shape.d,
                },
                vec![gelu],
            );
            let bias4 = push(&mut nodes, GraphOp::Asic(AsicOp::BiasAdd { n: shape.d }), vec![fc2]);
            prev = push(
                &mut nodes,
                GraphOp::Asic(AsicOp::ResidualAdd { n: shape.d }),
                vec![bias4, res1],
            );
        }

        if let Some(cols) = lm_head_cols {
            let lnf = push(&mut nodes, GraphOp::Asic(AsicOp::LayerNorm { n: shape.d }), vec![prev]);
            push(
                &mut nodes,
                GraphOp::Vmm {
                    matrix: MatrixId::new(0, MatrixKind::Wte),
                    class: VmmClass::LmHead,
                    in_elems: shape.d,
                    out_elems: cols,
                },
                vec![lnf],
            );
        }

        DecodeGraph { nodes, ltoken }
    }

    /// Cycles one link hop of `bytes` costs: fixed hop latency plus the
    /// serialized byte time at `sched.link_gbit_s`, in DRAM cycles —
    /// the interconnect mirror of `kv_transfer_cycles`.
    pub fn link_cycles(cfg: &HwConfig, bytes: u64) -> u64 {
        let bit_cycles = bytes as f64 * 8.0 * cfg.gddr6.freq_ghz / cfg.sched.link_gbit_s;
        cfg.sched.link_hop_cycles + bit_cycles.ceil() as u64
    }

    /// Link cycles one pipeline-stage boundary costs for `passes`
    /// activation vectors (`d_model` bf16 elements each).
    pub fn stage_hop_cycles(&self, cfg: &HwConfig, passes: u64) -> u64 {
        Self::link_cycles(cfg, passes * self.model.d_model as u64 * 2)
    }

    /// Link cycles one tensor-parallel all-reduce of `d_model` partial
    /// sums costs for `passes` vectors: each device moves
    /// `2 * (N-1) / N` of the vector over its link (reduce-scatter +
    /// all-gather), paid once per row-parallel matrix (Wo, W2).
    pub fn all_reduce_cycles(&self, cfg: &HwConfig, passes: u64) -> u64 {
        let n = self.devices as u64;
        let bytes = passes * self.model.d_model as u64 * 2;
        Self::link_cycles(cfg, 2 * bytes * (n - 1) / n)
    }

    /// Link cycles the LM-head logit gather costs for `passes` vectors
    /// (each device contributes its vocab shard; `(N-1)/N` of the full
    /// logit vector crosses links).
    pub fn lm_gather_cycles(&self, cfg: &HwConfig, passes: u64) -> u64 {
        let n = self.devices as u64;
        let bytes = passes * self.model.vocab as u64 * 2;
        Self::link_cycles(cfg, bytes * (n - 1) / n)
    }

    /// Total link-transfer cycles one decode/prefill step pays beyond
    /// per-device compute: the fleet engine charges these as explicit
    /// transfer edges between device programs. 0 for a single device.
    pub fn step_link_cycles(&self, cfg: &HwConfig, passes: u64) -> u64 {
        if self.devices == 1 {
            return 0;
        }
        match self.strategy {
            // N-1 stage boundaries, one activation hop each.
            PartitionStrategy::LayerPipeline => {
                (self.devices as u64 - 1) * self.stage_hop_cycles(cfg, passes)
            }
            // Two all-reduces per layer (Wo, W2) + one logit gather.
            PartitionStrategy::TensorParallel => {
                2 * self.model.n_layer as u64 * self.all_reduce_cycles(cfg, passes)
                    + self.lm_gather_cycles(cfg, passes)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use std::collections::BTreeMap;

    fn partition(model: &str, n: usize, strategy: PartitionStrategy) -> DevicePartition {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline().with_devices(n).with_partition(strategy);
        DevicePartition::build(&m, &cfg).unwrap()
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["layer_pipeline", "tensor_parallel"] {
            assert_eq!(PartitionStrategy::parse(s).unwrap().to_string(), s);
        }
        for bad in ["", "pipeline", "tensor", "LAYER_PIPELINE", "tp"] {
            assert!(PartitionStrategy::parse(bad).is_err(), "accepted '{bad}'");
        }
        assert_eq!(PartitionStrategy::default(), PartitionStrategy::LayerPipeline);
    }

    #[test]
    fn single_device_is_the_whole_model() {
        for strategy in [PartitionStrategy::LayerPipeline, PartitionStrategy::TensorParallel] {
            let p = partition("gpt2-small", 1, strategy);
            assert_eq!(p.slices.len(), 1);
            assert_eq!(p.slices[0].layers, 0..12);
            let m = by_name("gpt2-small").unwrap();
            assert_eq!(p.slices[0].weights, DecodeGraph::weight_matrices(&m));
            assert_eq!(p.slices[0].kv_model, m);
            assert_eq!(p.step_link_cycles(&HwConfig::paper_baseline(), 1), 0);
        }
    }

    /// Satellite edge case: uneven pipeline splits put the remainder on
    /// the earliest devices, covering every layer exactly once.
    #[test]
    fn pipeline_uneven_split_covers_all_layers() {
        let m = by_name("gpt2-small").unwrap(); // 12 layers
        let cfg = HwConfig::paper_baseline().with_devices(5);
        let p = DevicePartition::build(&m, &cfg).unwrap();
        let lens: Vec<usize> = p.slices.iter().map(|s| s.layers.len()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2, 2]);
        let mut next = 0;
        for s in &p.slices {
            assert_eq!(s.layers.start, next, "contiguous, in order");
            next = s.layers.end;
            assert_eq!(s.kv_model.n_layer, s.layers.len());
        }
        assert_eq!(next, 12);
        // Only the last stage stores the LM head.
        for s in &p.slices {
            let has_wte = s.weights.iter().any(|(id, _, _)| id.kind == MatrixKind::Wte);
            assert_eq!(has_wte, s.device == 4, "device {}", s.device);
        }
    }

    /// Satellite edge case: more pipeline stages than layers is a loud
    /// config error, not a silent empty device.
    #[test]
    fn pipeline_more_devices_than_layers_fails_loudly() {
        let m = by_name("gpt2-small").unwrap(); // 12 layers
        let cfg = HwConfig::paper_baseline().with_devices(13);
        let err = DevicePartition::build(&m, &cfg).unwrap_err().to_string();
        assert!(err.contains("12 layers"), "{err}");
        assert!(err.contains("13 devices"), "{err}");
    }

    #[test]
    fn tensor_parallel_indivisible_heads_fails_loudly() {
        let m = by_name("gpt2-xl").unwrap(); // 25 heads
        let cfg = HwConfig::paper_baseline()
            .with_devices(2)
            .with_partition(PartitionStrategy::TensorParallel);
        let err = DevicePartition::build(&m, &cfg).unwrap_err().to_string();
        assert!(err.contains("25 heads"), "{err}");
    }

    /// Per-device weight lists are element-conserving: the union over
    /// devices stores every weight element of the single-device model
    /// exactly once (per matrix kind and layer), under both strategies.
    fn assert_element_conserving(model: &str, n: usize, strategy: PartitionStrategy) {
        let m = by_name(model).unwrap();
        let p = partition(model, n, strategy);
        // Per-(global layer, kind) element totals across devices.
        let mut got: BTreeMap<MatrixId, u64> = BTreeMap::new();
        for s in &p.slices {
            for (id, d_in, d_out) in &s.weights {
                let global = if id.kind == MatrixKind::Wte {
                    MatrixId::new(0, MatrixKind::Wte)
                } else {
                    MatrixId::new(s.layers.start + id.layer, id.kind)
                };
                *got.entry(global).or_insert(0) += d_in * d_out;
            }
        }
        let want: BTreeMap<MatrixId, u64> = DecodeGraph::weight_matrices(&m)
            .into_iter()
            .map(|(id, d_in, d_out)| (id, d_in * d_out))
            .collect();
        assert_eq!(got, want, "{model} x{n} {strategy}");
    }

    #[test]
    fn prop_weight_elements_conserved_across_devices() {
        for model in ["gpt2-small", "gpt2-xl", "gpt3-xl"] {
            for n in [1usize, 2, 4] {
                assert_element_conserving(model, n, PartitionStrategy::LayerPipeline);
                let heads = by_name(model).unwrap().n_head;
                if heads % n == 0 {
                    assert_element_conserving(model, n, PartitionStrategy::TensorParallel);
                }
            }
        }
        // Uneven pipeline split + a head count with larger divisors.
        assert_element_conserving("gpt2-small", 5, PartitionStrategy::LayerPipeline);
        assert_element_conserving("gpt3-xl", 8, PartitionStrategy::TensorParallel);
    }

    /// Device graphs reference exactly the weight matrices their slice
    /// stores (a missing id would panic at issue time) and mirror the
    /// single-device node count in total.
    #[test]
    fn device_graphs_reference_only_stored_weights() {
        for (model, strategy) in [
            ("gpt2-small", PartitionStrategy::LayerPipeline),
            ("gpt2-medium", PartitionStrategy::TensorParallel),
        ] {
            let p = partition(model, 4, strategy);
            let mut weight_vmms = 0usize;
            for s in &p.slices {
                let stored: Vec<MatrixId> = s.weights.iter().map(|(id, _, _)| *id).collect();
                let g = p.device_graph(s.device, 7);
                for node in &g.nodes {
                    if let GraphOp::Vmm { matrix, .. } = node.op {
                        if !matrix.kind.is_kv_cache() {
                            assert!(
                                stored.contains(&matrix),
                                "device {} graph reads unstored {matrix:?}",
                                s.device
                            );
                            weight_vmms += 1;
                        } else {
                            assert!(
                                matrix.layer < s.kv_model.n_layer,
                                "KV layer out of the device's reservation"
                            );
                        }
                    }
                }
            }
            let m = by_name(model).unwrap();
            // 4 weight (non-KV) VMMs per layer: Wqkv, Wo, W1, W2.
            let single = 4 * m.n_layer + 1;
            let want = match strategy {
                // Layers covered once; one LM head total.
                PartitionStrategy::LayerPipeline => single,
                // Every device runs every layer's (sharded) VMMs and an
                // LM-head shard.
                PartitionStrategy::TensorParallel => single * 4,
            };
            assert_eq!(weight_vmms, want, "{model} {strategy}");
        }
    }

    #[test]
    fn tensor_shapes_are_megatron_sharded() {
        let m = by_name("gpt3-xl").unwrap(); // 24 heads, d=2048
        let p = partition("gpt3-xl", 4, PartitionStrategy::TensorParallel);
        let s = &p.slices[1];
        assert_eq!(s.kv_model.n_head, 6);
        assert_eq!(s.kv_model.d_model, 512);
        assert_eq!(s.kv_model.max_seq, m.max_seq, "full context per device");
        let d = m.d_model as u64;
        for (id, d_in, d_out) in &s.weights {
            match id.kind {
                MatrixKind::Wqkv => assert_eq!((*d_in, *d_out), (d, 3 * d / 4)),
                MatrixKind::Wo => assert_eq!((*d_in, *d_out), (d / 4, d)),
                MatrixKind::W1 => assert_eq!((*d_in, *d_out), (d, d)),
                MatrixKind::W2 => assert_eq!((*d_in, *d_out), (d, d)),
                MatrixKind::Wte => assert_eq!(*d_in, d),
                _ => panic!("unexpected {id:?}"),
            }
        }
        // Vocab shards sum to the full vocab (ceil split, device 0
        // largest).
        let total: u64 = (0..4).map(|i| DevicePartition::vocab_cols(m.vocab as u64, 4, i)).sum();
        assert_eq!(total, m.vocab as u64);
        assert!(DevicePartition::vocab_cols(m.vocab as u64, 4, 0) >= total / 4);
    }

    #[test]
    fn link_cost_model() {
        let cfg = HwConfig::paper_baseline(); // 256 Gbit/s, 250-cycle hop
        // 32 bytes = 256 bits = 1 cycle at 256 Gbit/s and 1 GHz.
        assert_eq!(DevicePartition::link_cycles(&cfg, 32), 251);
        assert_eq!(DevicePartition::link_cycles(&cfg, 0), 250);
        // Pipeline step: N-1 activation hops.
        let p = partition("gpt2-small", 4, PartitionStrategy::LayerPipeline);
        let hop = p.stage_hop_cycles(&cfg, 1);
        assert_eq!(hop, DevicePartition::link_cycles(&cfg, 768 * 2));
        assert_eq!(p.step_link_cycles(&cfg, 1), 3 * hop);
        // Bytes scale with passes; the fixed hop is paid once per hop.
        assert!(p.stage_hop_cycles(&cfg, 8) < 8 * hop);
        // Tensor-parallel step: 2 all-reduces per layer + the gather.
        let p = partition("gpt2-small", 4, PartitionStrategy::TensorParallel);
        let step = p.step_link_cycles(&cfg, 1);
        assert_eq!(
            step,
            2 * 12 * p.all_reduce_cycles(&cfg, 1) + p.lm_gather_cycles(&cfg, 1)
        );
        assert!(step > 0);
    }
}
