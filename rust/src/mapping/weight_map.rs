//! Weight mapping (paper Algorithm 3 lines 1-7, Fig. 6).
//!
//! For every VMM block the attention heads are already concatenated along
//! the column direction (`maxRowHit` — Fig. 6a: e.g. GPT2-XL heads of 64
//! columns fill the 1024-element rows), then the concatenated matrix is
//! split *evenly across all channels and banks* by output columns
//! (`maxParallel` — Fig. 6b). Each unit's chunk is stored row-major in
//! consecutive DRAM rows, so a VMM sweeps fully-packed rows: one ACT per
//! row, 64 hit accesses per ACT.
//!
//! The per-unit column count mirrors `python/compile/kernels/pim_vmm.py::
//! bank_partition` — the Pallas kernel and the simulator must slice
//! matrices identically (cross-checked in unit tests on both sides).

use std::collections::BTreeMap;

use super::layout::{BankAllocator, CapacityError};
use crate::config::HwConfig;
use crate::dram::bank::RowBlock;
use crate::model::{DecodeGraph, GptModel, MatrixId};
use crate::util::pad_to;

/// Columns per unit of the padded even partition (mirror of the Pallas
/// `bank_partition` — keep in sync).
pub fn columns_per_unit(d_out: u64, n_units: u64) -> u64 {
    pad_to(d_out, n_units) / n_units
}

/// Placement of one matrix across all units.
#[derive(Clone, Debug)]
pub struct MatrixPlacement {
    /// Row block per unit (index = linear unit id). Units beyond the
    /// matrix's column count hold nothing.
    pub per_unit: Vec<RowBlock>,
    /// Output columns owned by each unit.
    pub out_cols: Vec<u64>,
    pub d_in: u64,
    pub d_out: u64,
}

impl MatrixPlacement {
    /// Total elements stored (== d_in * d_out).
    pub fn total_elems(&self, row_elems: u32) -> u64 {
        self.per_unit.iter().map(|b| b.total_elems(row_elems)).sum()
    }
}

/// Report emitted when DRAM rows could not hold the requested number of
/// per-stream KV slots and the mapping degraded to fewer (the model and
/// at least one full context still fit). Under paged KV
/// (`sched.kv_paging`) the counts are page *frames* rather than
/// contiguous stream slots — same degradation contract, finer currency.
#[derive(Clone, Debug)]
pub struct KvSlotReport {
    /// Slots requested (`cfg.sched.max_streams`; paged: frames to hold
    /// `max_streams` full contexts).
    pub requested: usize,
    /// Slots actually reserved (>= 1).
    pub granted: usize,
    /// The capacity error the originally requested slot count hit.
    pub cause: CapacityError,
}

impl std::fmt::Display for KvSlotReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV capacity: {} of {} requested stream slots fit ({})",
            self.granted, self.requested, self.cause
        )
    }
}

/// Full model mapping: every weight matrix placed, KV regions reserved.
#[derive(Clone, Debug)]
pub struct ModelMapping {
    pub matrices: BTreeMap<MatrixId, MatrixPlacement>,
    pub kv: super::KvReservation,
    pub n_channels: usize,
    pub banks_per_channel: usize,
    /// Peak bank fill fraction after mapping.
    pub fill: f64,
    /// Row imbalance across units after mapping (rows).
    pub imbalance_rows: u32,
    /// Present when fewer KV slots than `cfg.sched.max_streams` fit.
    pub kv_shortfall: Option<KvSlotReport>,
}

impl ModelMapping {
    /// Map `model` onto the PIM system (Algorithm 3), reserving one KV
    /// slot per requested stream (`cfg.sched.max_streams`). If weights +
    /// K slots exceed DRAM capacity, the build degrades to the largest
    /// slot count that fits — computed in closed form from a weights-only
    /// scratch placement and the uniform per-slot KV footprint
    /// (`kv_reserve::slot_rows_per_unit`), not by retrying the whole
    /// placement per candidate count — and records a `KvSlotReport`.
    /// Only a model that cannot fit even a single context fails.
    pub fn build(model: &GptModel, cfg: &HwConfig) -> Result<Self, CapacityError> {
        Self::build_for(model, cfg, &DecodeGraph::weight_matrices(model))
    }

    /// Map one *device slice* of a partitioned model
    /// (`mapping::partition`): `kv_model` is the device's sub-model
    /// view — layer count (pipeline) or head/width shard
    /// (tensor-parallel) — which sizes its KV reservation, and
    /// `weights` are exactly the matrices this device stores, with
    /// device-local ids and shapes. Each device gets its own
    /// channel/bank space, so the degradation contract (and the paged
    /// frame pool) applies per device: a model that degrades to 2
    /// slots on one device can grant full contexts on each of 2.
    /// `build` is the trivial single-device slice.
    pub fn build_device(
        kv_model: &GptModel,
        cfg: &HwConfig,
        weights: &[(MatrixId, u64, u64)],
    ) -> Result<Self, CapacityError> {
        Self::build_for(kv_model, cfg, weights)
    }

    fn build_for(
        model: &GptModel,
        cfg: &HwConfig,
        weights: &[(MatrixId, u64, u64)],
    ) -> Result<Self, CapacityError> {
        if cfg.sched.kv_paging {
            return Self::build_paged(model, cfg, weights);
        }
        let requested = cfg.sched.max_streams.max(1);
        match Self::build_with_slots(model, cfg, requested, weights) {
            Ok(mm) => Ok(mm),
            // A pattern overflow is independent of the slot count —
            // fewer slots cannot help.
            Err(e @ CapacityError::Pattern { .. }) => Err(e),
            Err(cause) => {
                let mut scratch = BankAllocator::new(cfg);
                Self::place_weights(cfg, &mut scratch, weights)?;
                let per_slot =
                    super::kv_reserve::slot_rows_per_unit(model, cfg, scratch.n_units()).max(1);
                let granted = (scratch.min_free_rows() / per_slot) as usize;
                // The requested count just failed, so the fit is
                // strictly below it whatever the arithmetic says.
                let granted = granted.min(requested - 1);
                if granted == 0 {
                    return Err(cause);
                }
                let mut mm = Self::build_with_slots(model, cfg, granted, weights)?;
                mm.kv_shortfall = Some(KvSlotReport { requested, granted, cause });
                Ok(mm)
            }
        }
    }

    /// Paged-KV mapping (`sched.kv_paging`): the KV budget is a pool of
    /// fixed-size page frames instead of `max_streams` contiguous
    /// slots. The requested pool holds `max_streams` *worst-case*
    /// contexts (`ceil(max_seq / P)` frames each); under row pressure
    /// it degrades in single-frame steps — far finer than the
    /// whole-context steps of the slot path, which is exactly why
    /// paging sustains more short streams on a capacity-squeezed model.
    /// The degradation arithmetic mirrors the slot path: weights-only
    /// scratch placement + closed-form per-frame footprint
    /// (`kv_reserve::frame_rows_per_unit`). Only a model whose weights
    /// leave no room for even one frame fails.
    fn build_paged(
        model: &GptModel,
        cfg: &HwConfig,
        weights: &[(MatrixId, u64, u64)],
    ) -> Result<Self, CapacityError> {
        let n_units = cfg.gddr6.channels * cfg.gddr6.banks_per_channel;
        let max_seq = model.max_seq as u64;
        let p = super::kv_reserve::round_page_tokens(cfg.sched.kv_page_tokens, n_units, max_seq);
        let frames_per_context = crate::util::ceil_div(max_seq.max(1), p) as usize;
        let requested = (cfg.sched.max_streams.max(1) * frames_per_context).max(1);
        match Self::build_with_frames(model, cfg, requested, p, weights) {
            Ok(mm) => Ok(mm),
            Err(e @ CapacityError::Pattern { .. }) => Err(e),
            Err(cause) => {
                let mut scratch = BankAllocator::new(cfg);
                Self::place_weights(cfg, &mut scratch, weights)?;
                let per_frame =
                    super::kv_reserve::frame_rows_per_unit(model, cfg, scratch.n_units(), p).max(1);
                let granted = (scratch.min_free_rows() / per_frame) as usize;
                // The requested count just failed, so the fit is
                // strictly below it whatever the arithmetic says.
                let granted = granted.min(requested - 1);
                if granted == 0 {
                    return Err(cause);
                }
                let mut mm = Self::build_with_frames(model, cfg, granted, p, weights)?;
                mm.kv_shortfall = Some(KvSlotReport { requested, granted, cause });
                Ok(mm)
            }
        }
    }

    /// One paged mapping attempt at a fixed frame count.
    fn build_with_frames(
        model: &GptModel,
        cfg: &HwConfig,
        n_frames: usize,
        page_tokens: u64,
        weights: &[(MatrixId, u64, u64)],
    ) -> Result<Self, CapacityError> {
        let mut alloc = BankAllocator::new(cfg);
        // Frames first, weights second — same ordering as the slot path
        // so the paged base rows at `P = max_seq` coincide with the
        // slot base rows (the pinned cycle-equivalence anchor).
        let kv =
            super::KvReservation::build_paged(model, cfg, &mut alloc, n_frames, page_tokens)?;
        let matrices = Self::place_weights(cfg, &mut alloc, weights)?;
        Ok(Self {
            matrices,
            kv,
            n_channels: cfg.gddr6.channels,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            fill: alloc.max_fill(),
            imbalance_rows: alloc.imbalance_rows(),
            kv_shortfall: None,
        })
    }

    /// One mapping attempt at a fixed KV slot count.
    fn build_with_slots(
        model: &GptModel,
        cfg: &HwConfig,
        n_slots: usize,
        weights: &[(MatrixId, u64, u64)],
    ) -> Result<Self, CapacityError> {
        let mut alloc = BankAllocator::new(cfg);

        // Reserve KV regions first (Algorithm 3 lines 8-14): their layout
        // is position-indexed, so a stable base address is required.
        let kv = super::KvReservation::build(model, cfg, &mut alloc, n_slots)?;

        // Map weights (lines 1-7).
        let matrices = Self::place_weights(cfg, &mut alloc, weights)?;

        Ok(Self {
            matrices,
            kv,
            n_channels: cfg.gddr6.channels,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            fill: alloc.max_fill(),
            imbalance_rows: alloc.imbalance_rows(),
            kv_shortfall: None,
        })
    }

    /// Place the given weight matrices (Algorithm 3 lines 1-7) into
    /// `alloc` — the full model's list for a single device, or one
    /// device's slice of a partitioned model.
    fn place_weights(
        cfg: &HwConfig,
        alloc: &mut BankAllocator,
        weights: &[(MatrixId, u64, u64)],
    ) -> Result<BTreeMap<MatrixId, MatrixPlacement>, CapacityError> {
        let row_elems = cfg.gddr6.row_elems();
        let n_units = alloc.n_units() as u64;
        let mut matrices = BTreeMap::new();
        for &(id, d_in, d_out) in weights {
            let cols_pu = columns_per_unit(d_out, n_units);
            let mut per_unit = Vec::with_capacity(n_units as usize);
            let mut out_cols = Vec::with_capacity(n_units as usize);
            for u in 0..n_units {
                let col_lo = (u * cols_pu).min(d_out);
                let col_hi = ((u + 1) * cols_pu).min(d_out);
                let cols = col_hi - col_lo;
                let elems = d_in * cols;
                let full_rows = (elems / row_elems) as u32;
                let tail_elems = (elems % row_elems) as u32;
                let rows = full_rows + (tail_elems > 0) as u32;
                let base_row = if rows > 0 { alloc.alloc(alloc.unit(u as usize), rows)? } else { 0 };
                per_unit.push(RowBlock { base_row, full_rows, tail_elems });
                out_cols.push(cols);
            }
            matrices.insert(id, MatrixPlacement { per_unit, out_cols, d_in, d_out });
        }
        Ok(matrices)
    }

    /// Linear unit index range of one channel.
    pub fn channel_units(&self, channel: usize) -> std::ops::Range<usize> {
        let b = self.banks_per_channel;
        channel * b..(channel + 1) * b
    }

    /// Output elements a channel produces for `matrix` (drain size).
    pub fn channel_out_elems(&self, matrix: &MatrixId, channel: usize) -> u64 {
        let p = &self.matrices[matrix];
        self.channel_units(channel).map(|u| p.out_cols[u]).sum()
    }

    /// Bound on rows a weight VMM touches in one unit (load-balance
    /// metric; the even split keeps the spread <= 1 row + tail effects).
    pub fn rows_per_unit(&self, matrix: &MatrixId) -> (u32, u32) {
        let p = &self.matrices[matrix];
        let rows: Vec<u32> = p.per_unit.iter().map(|b| b.total_rows()).collect();
        (*rows.iter().min().unwrap(), *rows.iter().max().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::model::MatrixKind;
    use crate::util::prop::check;

    fn map(model: &str) -> ModelMapping {
        let m = by_name(model).unwrap();
        ModelMapping::build(&m, &HwConfig::paper_baseline()).unwrap()
    }

    #[test]
    fn columns_per_unit_matches_pallas() {
        // Mirror of python test_kernel.py::test_bank_partition_matches_rust_mapper
        assert_eq!(columns_per_unit(2304, 128), 18);
        assert_eq!(columns_per_unit(768, 128), 6);
        assert_eq!(columns_per_unit(50257, 128), 393);
        assert_eq!(columns_per_unit(1, 128), 1);
        assert_eq!(columns_per_unit(129, 128), 2);
        assert_eq!(columns_per_unit(512, 8), 64);
    }

    #[test]
    fn every_weight_element_stored_exactly_once() {
        let mm = map("gpt2-small");
        let m = by_name("gpt2-small").unwrap();
        for (id, d_in, d_out) in DecodeGraph::weight_matrices(&m) {
            let p = &mm.matrices[&id];
            assert_eq!(p.total_elems(1024), d_in * d_out, "{id:?}");
            let cols: u64 = p.out_cols.iter().sum();
            assert_eq!(cols, d_out, "{id:?}");
        }
    }

    #[test]
    fn distribution_is_balanced() {
        // Even split: every unit except possibly the last (padding
        // remainder, same as the Pallas kernel) holds the same number of
        // rows, and the last never holds more.
        let mm = map("gpt2-medium");
        for (id, p) in &mm.matrices {
            let rows: Vec<u32> = p.per_unit.iter().map(|b| b.total_rows()).collect();
            let max = *rows.iter().max().unwrap();
            let uneven = rows[..rows.len() - 1].iter().filter(|&&r| max - r > 1).count();
            assert_eq!(uneven, 0, "{id:?}: {rows:?}");
            assert!(*rows.last().unwrap() <= max, "{id:?}");
        }
    }

    #[test]
    fn all_paper_models_fit() {
        for m in &crate::model::PAPER_MODELS {
            let mm = ModelMapping::build(m, &HwConfig::paper_baseline()).unwrap();
            assert!(mm.fill <= 1.0, "{}: fill {}", m.name, mm.fill);
            assert!(mm.kv.n_slots >= 1, "{}", m.name);
        }
    }

    #[test]
    fn small_model_gets_all_requested_slots() {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(4);
        let mm = ModelMapping::build(&m, &cfg).unwrap();
        assert_eq!(mm.kv.n_slots, 4);
        assert!(mm.kv_shortfall.is_none());
    }

    #[test]
    fn capacity_pressure_degrades_slot_count_with_report() {
        // Shrink per-channel DRAM until only ~2 of 4 requested contexts
        // fit next to the weights: the build must degrade (not fail) and
        // say why.
        let m = by_name("gpt2-small").unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
        cfg.gddr6.capacity_gbit = 0.34; // ~1392 rows/bank
        let mm = ModelMapping::build(&m, &cfg).unwrap();
        assert!(mm.kv.n_slots < 4, "expected degradation, got {} slots", mm.kv.n_slots);
        assert!(mm.kv.n_slots >= 1);
        let report = mm.kv_shortfall.as_ref().expect("shortfall report");
        assert_eq!(report.requested, 4);
        assert_eq!(report.granted, mm.kv.n_slots);
        assert!(matches!(report.cause, CapacityError::Rows { .. }));
        // Display is the operator-facing message; it must name the counts.
        let msg = report.to_string();
        assert!(msg.contains("of 4 requested"), "{msg}");
    }

    #[test]
    fn paged_full_context_pool_matches_slot_build() {
        // P = max_seq: one frame per full context, frames-first
        // allocation order — the paged pool must be the slot build
        // address-for-address (the cycle-equivalence anchor).
        let m = by_name("gpt2-small").unwrap();
        let slot_cfg = HwConfig::paper_baseline().with_max_streams(4);
        let paged_cfg = slot_cfg
            .clone()
            .with_kv_paging(true)
            .with_kv_page_tokens(m.max_seq as u64);
        let slot = ModelMapping::build(&m, &slot_cfg).unwrap();
        let paged = ModelMapping::build(&m, &paged_cfg).unwrap();
        assert_eq!(paged.kv.n_slots, 4, "one frame per requested context");
        assert_eq!(paged.kv.page_tokens, Some(m.max_seq as u64));
        assert!(paged.kv_shortfall.is_none());
        assert_eq!(paged.kv.k_base, slot.kv.k_base);
        assert_eq!(paged.kv.v_base, slot.kv.v_base);
        for (id, p) in &slot.matrices {
            let q = &paged.matrices[id];
            for (a, b) in p.per_unit.iter().zip(&q.per_unit) {
                assert_eq!(a.base_row, b.base_row, "{id:?}");
            }
        }
    }

    #[test]
    fn paged_pool_sized_in_frames() {
        // Default P = 128 on a 1024-token context: 8 frames per
        // worst-case stream.
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(2).with_kv_paging(true);
        let mm = ModelMapping::build(&m, &cfg).unwrap();
        assert_eq!(mm.kv.n_slots, 16, "2 streams x 8 frames");
        assert_eq!(mm.kv.page_tokens, Some(128));
        // An oversized page clamps to the padded full context.
        let cfg = cfg.with_kv_page_tokens(10 * m.max_seq as u64);
        let mm = ModelMapping::build(&m, &cfg).unwrap();
        assert_eq!(mm.kv.page_tokens, Some(m.max_seq as u64));
        assert_eq!(mm.kv.n_slots, 2);
    }

    #[test]
    fn paged_degradation_outgrants_whole_slots() {
        // gpt2-xl under the Table I baseline fits only 2 of 4 whole
        // contexts; the paged pool degrades frame-by-frame and must
        // grant at least 3 short (<= 128-token) streams' worth — the
        // concurrency headline of paging.
        let m = by_name("gpt2-xl").unwrap();
        let slot_cfg = HwConfig::paper_baseline().with_max_streams(4);
        let slot = ModelMapping::build(&m, &slot_cfg).unwrap();
        assert!(slot.kv.n_slots < 4, "premise: xl is capacity-squeezed");
        let paged_cfg = slot_cfg.clone().with_kv_paging(true);
        let paged = ModelMapping::build(&m, &paged_cfg).unwrap();
        let report = paged.kv_shortfall.as_ref().expect("frame shortfall report");
        assert_eq!(report.requested, 4 * 8, "4 streams x 8 frames of 128");
        assert_eq!(report.granted, paged.kv.n_slots);
        assert!(
            paged.kv.n_slots >= 3,
            "expected >= 3 frames (>= 3 short streams), got {}",
            paged.kv.n_slots
        );
        assert!(paged.fill <= 1.0);
    }

    #[test]
    fn largest_model_fill_high_but_fits() {
        let mm = map("gpt2-xl"); // 1.56B params * 2B = 3.1 GB of 4 GiB
        assert!(mm.fill > 0.7, "fill {}", mm.fill);
        assert!(mm.fill <= 1.0);
    }

    #[test]
    fn channel_out_elems_sum_to_d_out() {
        let mm = map("gpt2-small");
        let id = MatrixId::new(0, MatrixKind::Wqkv);
        let total: u64 = (0..8).map(|c| mm.channel_out_elems(&id, c)).sum();
        assert_eq!(total, 3 * 768);
    }

    /// Device mappings are row-conserving: the union of the per-device
    /// placements stores exactly the single-device element footprint
    /// (rows may carry per-unit tail padding, so the exact invariant is
    /// in elements; padded-row slack is bounded by one row per unit per
    /// matrix and checked as an upper bound).
    #[test]
    fn prop_device_mappings_conserve_single_device_footprint() {
        use crate::mapping::partition::{DevicePartition, PartitionStrategy};
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline();
        let row_elems = cfg.gddr6.row_elems();
        let single = ModelMapping::build(&m, &cfg).unwrap();
        let single_elems: u64 =
            single.matrices.values().map(|p| p.total_elems(row_elems as u32)).sum();
        let single_rows: u64 = single
            .matrices
            .values()
            .flat_map(|p| p.per_unit.iter().map(|b| b.total_rows() as u64))
            .sum();
        for strategy in [PartitionStrategy::LayerPipeline, PartitionStrategy::TensorParallel] {
            for n in [2usize, 4] {
                let pcfg = cfg.clone().with_devices(n).with_partition(strategy);
                let p = DevicePartition::build(&m, &pcfg).unwrap();
                let maps: Vec<ModelMapping> = p
                    .slices
                    .iter()
                    .map(|s| ModelMapping::build_device(&s.kv_model, &pcfg, &s.weights).unwrap())
                    .collect();
                let elems: u64 = maps
                    .iter()
                    .flat_map(|mm| mm.matrices.values().map(|p| p.total_elems(row_elems as u32)))
                    .sum();
                assert_eq!(elems, single_elems, "{strategy} x{n}");
                // Row slack from finer column shards: at most one padded
                // tail row per unit per stored matrix.
                let rows: u64 = maps
                    .iter()
                    .flat_map(|mm| {
                        mm.matrices
                            .values()
                            .flat_map(|p| p.per_unit.iter().map(|b| b.total_rows() as u64))
                    })
                    .sum();
                let stored: u64 = maps.iter().map(|mm| mm.matrices.len() as u64).sum();
                let n_units = (cfg.gddr6.channels * cfg.gddr6.banks_per_channel) as u64;
                assert!(rows >= single_rows, "{strategy} x{n}: lost rows");
                assert!(
                    rows <= single_rows + stored * n_units,
                    "{strategy} x{n}: rows {rows} vs single {single_rows}"
                );
                // Per-device placements stay disjoint within each
                // device's own bank space by allocator construction;
                // out_cols per matrix sum to that device's shard width.
                for (mm, s) in maps.iter().zip(&p.slices) {
                    for (id, d_in, d_out) in &s.weights {
                        let pl = &mm.matrices[id];
                        assert_eq!((pl.d_in, pl.d_out), (*d_in, *d_out));
                        assert_eq!(pl.out_cols.iter().sum::<u64>(), *d_out);
                    }
                }
            }
        }
    }

    /// The capacity headline of sharding: gpt2-xl degrades to 2 of 4
    /// slots on one device, but each of 2 pipeline-stage devices grants
    /// all 4 full-context slots (weights and KV both halve per device).
    #[test]
    fn xl_pipeline_devices_outgrant_single_device_slots() {
        use crate::mapping::partition::DevicePartition;
        let m = by_name("gpt2-xl").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(4);
        let single = ModelMapping::build(&m, &cfg).unwrap();
        assert!(single.kv.n_slots < 4, "premise: xl is capacity-squeezed");
        let pcfg = cfg.clone().with_devices(2);
        let p = DevicePartition::build(&m, &pcfg).unwrap();
        for s in &p.slices {
            let mm = ModelMapping::build_device(&s.kv_model, &pcfg, &s.weights).unwrap();
            assert!(
                mm.kv.n_slots > single.kv.n_slots,
                "device {}: {} slots vs single {}",
                s.device,
                mm.kv.n_slots,
                single.kv.n_slots
            );
            assert!(mm.kv_shortfall.is_none(), "device {}", s.device);
        }
    }

    #[test]
    fn prop_partition_covers_all_columns() {
        check("even partition covers matrix", 300, |rng| {
            let d_out = rng.gen_range(100_000) + 1;
            let n_units = rng.gen_range(511) + 1;
            let cols = columns_per_unit(d_out, n_units);
            let mut total = 0u64;
            for u in 0..n_units {
                let lo = (u * cols).min(d_out);
                let hi = ((u + 1) * cols).min(d_out);
                total += hi - lo;
            }
            if total == d_out { Ok(()) } else { Err(format!("{total} != {d_out}")) }
        });
    }
}
