//! Weight mapping (paper Algorithm 3 lines 1-7, Fig. 6).
//!
//! For every VMM block the attention heads are already concatenated along
//! the column direction (`maxRowHit` — Fig. 6a: e.g. GPT2-XL heads of 64
//! columns fill the 1024-element rows), then the concatenated matrix is
//! split *evenly across all channels and banks* by output columns
//! (`maxParallel` — Fig. 6b). Each unit's chunk is stored row-major in
//! consecutive DRAM rows, so a VMM sweeps fully-packed rows: one ACT per
//! row, 64 hit accesses per ACT.
//!
//! The per-unit column count mirrors `python/compile/kernels/pim_vmm.py::
//! bank_partition` — the Pallas kernel and the simulator must slice
//! matrices identically (cross-checked in unit tests on both sides).

use std::collections::BTreeMap;

use super::layout::{BankAllocator, CapacityError};
use crate::config::HwConfig;
use crate::dram::bank::RowBlock;
use crate::model::{DecodeGraph, GptModel, MatrixId};
use crate::util::pad_to;

/// Columns per unit of the padded even partition (mirror of the Pallas
/// `bank_partition` — keep in sync).
pub fn columns_per_unit(d_out: u64, n_units: u64) -> u64 {
    pad_to(d_out, n_units) / n_units
}

/// Placement of one matrix across all units.
#[derive(Clone, Debug)]
pub struct MatrixPlacement {
    /// Row block per unit (index = linear unit id). Units beyond the
    /// matrix's column count hold nothing.
    pub per_unit: Vec<RowBlock>,
    /// Output columns owned by each unit.
    pub out_cols: Vec<u64>,
    pub d_in: u64,
    pub d_out: u64,
}

impl MatrixPlacement {
    /// Total elements stored (== d_in * d_out).
    pub fn total_elems(&self, row_elems: u32) -> u64 {
        self.per_unit.iter().map(|b| b.total_elems(row_elems)).sum()
    }
}

/// Full model mapping: every weight matrix placed, KV regions reserved.
#[derive(Clone, Debug)]
pub struct ModelMapping {
    pub matrices: BTreeMap<MatrixId, MatrixPlacement>,
    pub kv: super::KvReservation,
    pub n_channels: usize,
    pub banks_per_channel: usize,
    /// Peak bank fill fraction after mapping.
    pub fill: f64,
    /// Row imbalance across units after mapping (rows).
    pub imbalance_rows: u32,
}

impl ModelMapping {
    /// Map `model` onto the PIM system (Algorithm 3).
    pub fn build(model: &GptModel, cfg: &HwConfig) -> Result<Self, CapacityError> {
        let mut alloc = BankAllocator::new(cfg);
        let row_elems = cfg.gddr6.row_elems();
        let n_units = alloc.n_units() as u64;

        // Reserve KV regions first (Algorithm 3 lines 8-14): their layout
        // is position-indexed, so a stable base address is required.
        let kv = super::KvReservation::build(model, cfg, &mut alloc)?;

        // Map weights (lines 1-7).
        let mut matrices = BTreeMap::new();
        for (id, d_in, d_out) in DecodeGraph::weight_matrices(model) {
            let cols_pu = columns_per_unit(d_out, n_units);
            let mut per_unit = Vec::with_capacity(n_units as usize);
            let mut out_cols = Vec::with_capacity(n_units as usize);
            for u in 0..n_units {
                let col_lo = (u * cols_pu).min(d_out);
                let col_hi = ((u + 1) * cols_pu).min(d_out);
                let cols = col_hi - col_lo;
                let elems = d_in * cols;
                let full_rows = (elems / row_elems) as u32;
                let tail_elems = (elems % row_elems) as u32;
                let rows = full_rows + (tail_elems > 0) as u32;
                let base_row = if rows > 0 { alloc.alloc(alloc.unit(u as usize), rows)? } else { 0 };
                per_unit.push(RowBlock { base_row, full_rows, tail_elems });
                out_cols.push(cols);
            }
            matrices.insert(id, MatrixPlacement { per_unit, out_cols, d_in, d_out });
        }

        Ok(Self {
            matrices,
            kv,
            n_channels: cfg.gddr6.channels,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            fill: alloc.max_fill(),
            imbalance_rows: alloc.imbalance_rows(),
        })
    }

    /// Linear unit index range of one channel.
    pub fn channel_units(&self, channel: usize) -> std::ops::Range<usize> {
        let b = self.banks_per_channel;
        channel * b..(channel + 1) * b
    }

    /// Output elements a channel produces for `matrix` (drain size).
    pub fn channel_out_elems(&self, matrix: &MatrixId, channel: usize) -> u64 {
        let p = &self.matrices[matrix];
        self.channel_units(channel).map(|u| p.out_cols[u]).sum()
    }

    /// Bound on rows a weight VMM touches in one unit (load-balance
    /// metric; the even split keeps the spread <= 1 row + tail effects).
    pub fn rows_per_unit(&self, matrix: &MatrixId) -> (u32, u32) {
        let p = &self.matrices[matrix];
        let rows: Vec<u32> = p.per_unit.iter().map(|b| b.total_rows()).collect();
        (*rows.iter().min().unwrap(), *rows.iter().max().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::model::MatrixKind;
    use crate::util::prop::check;

    fn map(model: &str) -> ModelMapping {
        let m = by_name(model).unwrap();
        ModelMapping::build(&m, &HwConfig::paper_baseline()).unwrap()
    }

    #[test]
    fn columns_per_unit_matches_pallas() {
        // Mirror of python test_kernel.py::test_bank_partition_matches_rust_mapper
        assert_eq!(columns_per_unit(2304, 128), 18);
        assert_eq!(columns_per_unit(768, 128), 6);
        assert_eq!(columns_per_unit(50257, 128), 393);
        assert_eq!(columns_per_unit(1, 128), 1);
        assert_eq!(columns_per_unit(129, 128), 2);
        assert_eq!(columns_per_unit(512, 8), 64);
    }

    #[test]
    fn every_weight_element_stored_exactly_once() {
        let mm = map("gpt2-small");
        let m = by_name("gpt2-small").unwrap();
        for (id, d_in, d_out) in DecodeGraph::weight_matrices(&m) {
            let p = &mm.matrices[&id];
            assert_eq!(p.total_elems(1024), d_in * d_out, "{id:?}");
            let cols: u64 = p.out_cols.iter().sum();
            assert_eq!(cols, d_out, "{id:?}");
        }
    }

    #[test]
    fn distribution_is_balanced() {
        // Even split: every unit except possibly the last (padding
        // remainder, same as the Pallas kernel) holds the same number of
        // rows, and the last never holds more.
        let mm = map("gpt2-medium");
        for (id, p) in &mm.matrices {
            let rows: Vec<u32> = p.per_unit.iter().map(|b| b.total_rows()).collect();
            let max = *rows.iter().max().unwrap();
            let uneven = rows[..rows.len() - 1].iter().filter(|&&r| max - r > 1).count();
            assert_eq!(uneven, 0, "{id:?}: {rows:?}");
            assert!(*rows.last().unwrap() <= max, "{id:?}");
        }
    }

    #[test]
    fn all_paper_models_fit() {
        for m in &crate::model::PAPER_MODELS {
            let mm = ModelMapping::build(m, &HwConfig::paper_baseline()).unwrap();
            assert!(mm.fill <= 1.0, "{}: fill {}", m.name, mm.fill);
        }
    }

    #[test]
    fn largest_model_fill_high_but_fits() {
        let mm = map("gpt2-xl"); // 1.56B params * 2B = 3.1 GB of 4 GiB
        assert!(mm.fill > 0.7, "fill {}", mm.fill);
        assert!(mm.fill <= 1.0);
    }

    #[test]
    fn channel_out_elems_sum_to_d_out() {
        let mm = map("gpt2-small");
        let id = MatrixId::new(0, MatrixKind::Wqkv);
        let total: u64 = (0..8).map(|c| mm.channel_out_elems(&id, c)).sum();
        assert_eq!(total, 3 * 768);
    }

    #[test]
    fn prop_partition_covers_all_columns() {
        check("even partition covers matrix", 300, |rng| {
            let d_out = rng.gen_range(100_000) + 1;
            let n_units = rng.gen_range(511) + 1;
            let cols = columns_per_unit(d_out, n_units);
            let mut total = 0u64;
            for u in 0..n_units {
                let lo = (u * cols).min(d_out);
                let hi = ((u + 1) * cols).min(d_out);
                total += hi - lo;
            }
            if total == d_out { Ok(()) } else { Err(format!("{total} != {d_out}")) }
        });
    }
}
