//! Model mapping (paper Algorithm 3, §IV): weight placement with
//! multi-head concatenation and even channel/bank distribution, plus
//! KV-cache region reservation (K row-major, V column-major).

pub mod kv_reserve;
pub mod layout;
pub mod weight_map;

pub use kv_reserve::{KvReservation, PatternRun};
pub use layout::{BankAllocator, CapacityError};
pub use weight_map::{KvSlotReport, MatrixPlacement, ModelMapping};
