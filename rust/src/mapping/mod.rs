//! Model mapping (paper Algorithm 3, §IV): weight placement with
//! multi-head concatenation and even channel/bank distribution, plus
//! KV-cache region reservation (K row-major, V column-major). The
//! `partition` pass splits a model across several devices first
//! (`sched.devices`); each device slice then maps onto its own
//! channel/bank space via `ModelMapping::build_device`.

pub mod kv_reserve;
pub mod layout;
pub mod partition;
pub mod weight_map;

pub use kv_reserve::{KvReservation, PatternRun};
pub use layout::{BankAllocator, CapacityError};
pub use partition::{DevicePartition, DeviceSlice, PartitionStrategy};
pub use weight_map::{KvSlotReport, MatrixPlacement, ModelMapping};
