//! KV-cache region reservation + runtime address computation
//! (paper Algorithm 3 lines 8-14, Fig. 7), partitioned per stream slot.
//!
//! * **Key cache** (row-major, Fig. 7a): token `t`'s head-concatenated
//!   Key vector (d elements) occupies `ceil(d / row_elems)` consecutive
//!   reserved rows in *one* unit; tokens round-robin over units so the
//!   growing context spreads evenly. The q@K^T VMM then reads, per unit,
//!   a short list of full-vector segments — consecutive rows, maximal
//!   locality.
//! * **Value cache** (column-major, Fig. 7b): V's `d` columns round-robin
//!   over units (`cols_pu` columns each); each column owns
//!   `ceil(max_seq / row_elems)` consecutive rows. Writing token `t`
//!   touches one row per owned column (ACT + 1 write + PRE each — no
//!   locality, as the paper notes); the scores@V VMM reads each owned
//!   column as `ceil(ltoken / row_elems)` row segments.
//!
//! **Slots**: serving K concurrent decode streams honestly requires K
//! *disjoint* `max_seq` contexts, so the reservation carries a slot
//! dimension — `k_base[layer][slot][unit]` / `v_base[layer][slot][unit]`
//! — and every address computation takes the stream's slot id. Slot 0 is
//! the single-stream layout; the multi-stream scheduler
//! (`sim::sched::MultiSim`) admits a stream only when a free slot
//! exists and recycles slot ids on retirement. When DRAM rows run out
//! before `max_streams` slots fit, `ModelMapping::build` degrades to
//! fewer slots and reports the shortfall (`mapping::KvSlotReport`)
//! instead of failing.

use super::layout::{BankAllocator, CapacityError, UnitId};
use crate::config::HwConfig;
use crate::dram::RowSegment;
use crate::model::GptModel;
use crate::util::ceil_div;

/// Longest supported row-fill pattern (rows per stored vector/column):
/// covers d_model and context lengths up to 16 * row_elems = 16k.
pub const MAX_PATTERN: usize = 16;

/// Split `elems` into full `row_elems`-sized rows plus a tail. Patterns
/// longer than [`MAX_PATTERN`] are a mapping-time capacity error (the
/// hardware pattern buffer cannot express them); `KvReservation::build`
/// validates both KV patterns up front so the simulator's hot path
/// never hits the overflow at runtime.
fn fill_pattern(elems: u64, row_elems: u64) -> Result<([u32; MAX_PATTERN], u8), CapacityError> {
    if elems > MAX_PATTERN as u64 * row_elems {
        return Err(CapacityError::Pattern { elems, max_elems: MAX_PATTERN as u64 * row_elems });
    }
    Ok(fill_pattern_trusted(elems, row_elems))
}

/// Infallible variant for the simulator hot path: callers rely on the
/// build-time validation above (`debug_assert` documents the contract).
fn fill_pattern_trusted(elems: u64, row_elems: u64) -> ([u32; MAX_PATTERN], u8) {
    let full = (elems / row_elems) as usize;
    let tail = (elems % row_elems) as u32;
    debug_assert!(
        full + (tail > 0) as usize <= MAX_PATTERN,
        "pattern too long ({elems} elems) — must be rejected at mapping build"
    );
    let mut pat = [0u32; MAX_PATTERN];
    for slot in pat.iter_mut().take(full) {
        *slot = row_elems as u32;
    }
    let mut len = full as u8;
    if tail > 0 {
        pat[full] = tail;
        len += 1;
    }
    (pat, len)
}

/// Rows one stream slot reserves per unit over *all* layers (each
/// layer's K region plus V region). The footprint is uniform across
/// units, which is what lets `ModelMapping::build` size the slot count
/// in closed form against the fullest bank's leftover rows instead of
/// retrying the whole placement per candidate count.
pub fn slot_rows_per_unit(model: &GptModel, cfg: &HwConfig, n_units: usize) -> u32 {
    let row_elems = cfg.gddr6.row_elems();
    let d = model.d_model as u64;
    let max_seq = model.max_seq as u64;
    let rows_per_k = ceil_div(d, row_elems) as u32;
    let toks_per_unit = ceil_div(max_seq, n_units as u64) as u32;
    let rows_per_vcol = ceil_div(max_seq, row_elems) as u32;
    let v_cols = super::weight_map::columns_per_unit(d, n_units as u64) as u32;
    model.n_layer as u32 * (toks_per_unit * rows_per_k + v_cols * rows_per_vcol)
}

/// Reserved KV regions for every (layer, stream slot).
#[derive(Clone, Debug)]
pub struct KvReservation {
    /// K region base row per (layer, slot, unit): `k_base[layer][slot][unit]`.
    pub k_base: Vec<Vec<Vec<u32>>>,
    /// V region base row per (layer, slot, unit).
    pub v_base: Vec<Vec<Vec<u32>>>,
    /// Disjoint `max_seq` contexts reserved (= concurrent streams servable).
    pub n_slots: usize,
    pub d_model: u64,
    pub max_seq: u64,
    pub n_units: usize,
    pub banks_per_channel: usize,
    /// Rows per stored Key vector (= ceil(d / row_elems)).
    pub rows_per_k: u32,
    /// Rows per stored Value column (= ceil(max_seq / row_elems)).
    pub rows_per_vcol: u32,
    /// V columns owned per unit.
    pub v_cols_per_unit: u64,
    row_elems: u64,
}

impl KvReservation {
    /// Reserve `n_slots` disjoint per-layer KV contexts. Fails with a
    /// [`CapacityError`] when the rows don't fit (callers may retry with
    /// fewer slots — see `ModelMapping::build`) or when a stored vector
    /// cannot be expressed as a row-fill pattern at all.
    pub fn build(
        model: &GptModel,
        cfg: &HwConfig,
        alloc: &mut BankAllocator,
        n_slots: usize,
    ) -> Result<Self, CapacityError> {
        assert!(n_slots >= 1, "at least one KV slot is required");
        let n_units = alloc.n_units();
        let row_elems = cfg.gddr6.row_elems();
        let d = model.d_model as u64;
        let max_seq = model.max_seq as u64;

        // Validate both runtime row-fill patterns now: the K read pattern
        // (d elements per vector) and the widest V read pattern (max_seq
        // elements per column). Rejecting here turns what used to be a
        // runtime abort into a mapping-build error.
        fill_pattern(d, row_elems)?;
        fill_pattern(max_seq.max(1), row_elems)?;

        let rows_per_k = ceil_div(d, row_elems) as u32;
        let toks_per_unit = ceil_div(max_seq, n_units as u64) as u32;
        let rows_per_vcol = ceil_div(max_seq, row_elems) as u32;
        let v_cols_per_unit = super::weight_map::columns_per_unit(d, n_units as u64);

        let mut k_base = Vec::with_capacity(model.n_layer);
        let mut v_base = Vec::with_capacity(model.n_layer);
        for _layer in 0..model.n_layer {
            let mut k_slots = Vec::with_capacity(n_slots);
            let mut v_slots = Vec::with_capacity(n_slots);
            for _slot in 0..n_slots {
                let mut kb = Vec::with_capacity(n_units);
                let mut vb = Vec::with_capacity(n_units);
                for u in 0..n_units {
                    let unit = alloc.unit(u);
                    kb.push(alloc.alloc(unit, toks_per_unit * rows_per_k)?);
                    vb.push(alloc.alloc(unit, v_cols_per_unit as u32 * rows_per_vcol)?);
                }
                k_slots.push(kb);
                v_slots.push(vb);
            }
            k_base.push(k_slots);
            v_base.push(v_slots);
        }

        Ok(Self {
            k_base,
            v_base,
            n_slots,
            d_model: d,
            max_seq,
            n_units,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            rows_per_k,
            rows_per_vcol,
            v_cols_per_unit,
            row_elems,
        })
    }

    /// Unit that stores token `t`'s Key vector (round-robin).
    pub fn k_unit(&self, t: u64) -> usize {
        (t % self.n_units as u64) as usize
    }

    /// (unit, row segment list) for writing token `t`'s Key vector into
    /// stream slot `slot`.
    pub fn k_write(&self, layer: usize, slot: usize, t: u64) -> (UnitId, Vec<RowSegment>) {
        let u = self.k_unit(t);
        let tok_slot = (t / self.n_units as u64) as u32;
        let base = self.k_base[layer][slot][u] + tok_slot * self.rows_per_k;
        let mut segs = Vec::with_capacity(self.rows_per_k as usize);
        let mut rem = self.d_model;
        for r in 0..self.rows_per_k {
            let elems = rem.min(self.row_elems) as u32;
            segs.push(RowSegment { row: base + r, elems });
            rem -= elems as u64;
        }
        (self.unit_id(u), segs)
    }

    /// Per-unit segment lists for the q@K^T read of slot `slot` at
    /// context `ltoken`.
    pub fn k_read_plan(&self, layer: usize, slot: usize, ltoken: u64) -> Vec<Vec<RowSegment>> {
        let mut plans = vec![Vec::new(); self.n_units];
        self.fill_k_read_plan(layer, slot, ltoken, &mut plans);
        plans
    }

    /// Allocation-free variant: refills `plans` (one entry per unit,
    /// capacities retained) — the simulator hot path.
    pub fn fill_k_read_plan(
        &self,
        layer: usize,
        slot: usize,
        ltoken: u64,
        plans: &mut [Vec<RowSegment>],
    ) {
        assert_eq!(plans.len(), self.n_units);
        for (u, plan) in plans.iter_mut().enumerate() {
            plan.clear();
            // tokens u, u + n_units, ... < ltoken live in consecutive slots
            let owned = if (u as u64) < ltoken {
                ceil_div(ltoken - u as u64, self.n_units as u64)
            } else {
                0
            };
            let base = self.k_base[layer][slot][u];
            for tok_slot in 0..owned {
                let row0 = base + tok_slot as u32 * self.rows_per_k;
                let mut rem = self.d_model;
                for r in 0..self.rows_per_k {
                    let elems = rem.min(self.row_elems) as u32;
                    plan.push(RowSegment { row: row0 + r, elems });
                    rem -= elems as u64;
                }
            }
        }
    }

    /// Tokens whose K vectors unit `u` stores at context `ltoken`.
    pub fn k_owned(&self, u: usize, ltoken: u64) -> u32 {
        if (u as u64) < ltoken {
            ceil_div(ltoken - u as u64, self.n_units as u64) as u32
        } else {
            0
        }
    }

    /// Row-fill pattern of one stored Key vector (e.g. d=1536 ->
    /// [1024, 512]): `full` rows of `row_elems` plus an optional tail.
    pub fn k_read_pattern(&self) -> ([u32; MAX_PATTERN], u8) {
        fill_pattern_trusted(self.d_model, self.row_elems)
    }

    /// Row-fill pattern of one V column read at context `ltoken`.
    /// When a column's reserved rows exceed the rows actually read
    /// (ltoken <= row_elems but max_seq > row_elems) the physical rows
    /// are strided; the cycle cost is identical (all distinct misses).
    pub fn v_read_pattern(&self, ltoken: u64) -> ([u32; MAX_PATTERN], u8) {
        fill_pattern_trusted(ltoken.max(1), self.row_elems)
    }

    /// Scores owned by unit `u` at context `ltoken` (one per stored
    /// token, times heads — heads share the row, segmented accumulation).
    pub fn k_out_elems(&self, u: usize, ltoken: u64, n_head: u64) -> u64 {
        if (u as u64) < ltoken {
            ceil_div(ltoken - u as u64, self.n_units as u64) * n_head
        } else {
            0
        }
    }

    /// (base_row, n_cols, row_stride) for writing token `t`'s Value
    /// elements into unit `u` of stream slot `slot`: one element per
    /// owned column, consecutive rows when the column's row stride is 1
    /// (max_seq <= row_elems), else strided.
    pub fn v_write(&self, layer: usize, slot: usize, t: u64, u: usize) -> (u32, u32, u32) {
        let base = self.v_base[layer][slot][u] + (t / self.row_elems) as u32;
        let n_cols = self.v_cols(u);
        (base, n_cols, self.rows_per_vcol)
    }

    /// Columns of V actually owned by unit `u` (tail units may own fewer).
    pub fn v_cols(&self, u: usize) -> u32 {
        let lo = (u as u64 * self.v_cols_per_unit).min(self.d_model);
        let hi = ((u as u64 + 1) * self.v_cols_per_unit).min(self.d_model);
        (hi - lo) as u32
    }

    /// Per-unit segment lists for the scores@V read of slot `slot` at
    /// context `ltoken`.
    pub fn v_read_plan(&self, layer: usize, slot: usize, ltoken: u64) -> Vec<Vec<RowSegment>> {
        let mut plans = vec![Vec::new(); self.n_units];
        self.fill_v_read_plan(layer, slot, ltoken, &mut plans);
        plans
    }

    /// Allocation-free variant of `v_read_plan` (see `fill_k_read_plan`).
    pub fn fill_v_read_plan(
        &self,
        layer: usize,
        slot: usize,
        ltoken: u64,
        plans: &mut [Vec<RowSegment>],
    ) {
        assert_eq!(plans.len(), self.n_units);
        let rows_touched = ceil_div(ltoken, self.row_elems) as u32;
        for (u, plan) in plans.iter_mut().enumerate() {
            plan.clear();
            let base = self.v_base[layer][slot][u];
            for c in 0..self.v_cols(u) {
                let col_base = base + c * self.rows_per_vcol;
                let mut rem = ltoken;
                for r in 0..rows_touched {
                    let elems = rem.min(self.row_elems) as u32;
                    plan.push(RowSegment { row: col_base + r, elems });
                    rem -= elems as u64;
                }
            }
        }
    }

    fn unit_id(&self, u: usize) -> UnitId {
        UnitId { channel: u / self.banks_per_channel, bank: u % self.banks_per_channel }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::util::prop::check;

    fn kv_slots(model: &str, n_slots: usize) -> KvReservation {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        KvReservation::build(&m, &cfg, &mut alloc, n_slots).unwrap()
    }

    fn kv(model: &str) -> KvReservation {
        kv_slots(model, 1)
    }

    #[test]
    fn k_write_spreads_round_robin() {
        let kv = kv("gpt2-small");
        let (u0, _) = kv.k_write(0, 0, 0);
        let (u1, _) = kv.k_write(0, 0, 1);
        let (u128, s128) = kv.k_write(0, 0, 128);
        assert_ne!(u0, u1);
        assert_eq!(u0, u128); // wraps around 128 units
        // second slot on the same unit is the next reserved row
        let (_, s0) = kv.k_write(0, 0, 0);
        assert_eq!(s128[0].row, s0[0].row + kv.rows_per_k);
    }

    #[test]
    fn k_write_one_row_when_d_fits() {
        let kv = kv("gpt2-small"); // d=768 <= 1024
        let (_, segs) = kv.k_write(0, 0, 5);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].elems, 768);
    }

    #[test]
    fn k_write_two_rows_for_wide_model() {
        let kv = kv("gpt3-xl"); // d=2048 -> 2 rows
        let (_, segs) = kv.k_write(3, 0, 5);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].elems + segs[1].elems, 2048);
    }

    #[test]
    fn k_read_covers_all_tokens() {
        let kv = kv("gpt2-small");
        for ltoken in [1u64, 7, 128, 129, 1000] {
            let plans = kv.k_read_plan(0, 0, ltoken);
            let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
            assert_eq!(total, ltoken * 768, "ltoken={ltoken}");
        }
    }

    #[test]
    fn k_out_elems_total_is_heads_times_tokens() {
        let kv = kv("gpt2-small");
        for ltoken in [1u64, 100, 1024] {
            let total: u64 = (0..kv.n_units).map(|u| kv.k_out_elems(u, ltoken, 12)).sum();
            assert_eq!(total, 12 * ltoken);
        }
    }

    #[test]
    fn v_columns_cover_d_model() {
        let kv = kv("gpt2-large"); // d=1280, 128 units -> 10 cols each
        let total: u64 = (0..kv.n_units).map(|u| kv.v_cols(u) as u64).sum();
        assert_eq!(total, 1280);
    }

    #[test]
    fn v_read_covers_ltoken_per_column() {
        let kv = kv("gpt3-small");
        let plans = kv.v_read_plan(0, 0, 300);
        let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
        assert_eq!(total, 300 * 768);
    }

    #[test]
    fn v_read_multi_row_columns_long_context() {
        let kv = kv("gpt3-xl"); // max_seq=2048 -> 2 rows per column
        assert_eq!(kv.rows_per_vcol, 2);
        let plans = kv.v_read_plan(0, 0, 2000);
        // each owned column contributes 2 segments (1024 + 976)
        let u0 = &plans[0];
        assert_eq!(u0.len() as u64, kv.v_cols(0) as u64 * 2);
    }

    #[test]
    fn regions_do_not_overlap_across_layers() {
        let kv = kv("gpt2-small");
        // layer 1's K base must start after layer 0's K+V regions
        for u in 0..kv.n_units {
            assert!(kv.k_base[1][0][u] > kv.k_base[0][0][u]);
            assert!(kv.v_base[0][0][u] > kv.k_base[0][0][u]);
        }
    }

    #[test]
    fn slots_are_disjoint_same_layer() {
        let kv = kv_slots("gpt2-small", 3);
        assert_eq!(kv.n_slots, 3);
        for u in 0..kv.n_units {
            // Later slots live strictly after earlier slots' regions.
            assert!(kv.k_base[0][1][u] > kv.v_base[0][0][u]);
            assert!(kv.k_base[0][2][u] > kv.v_base[0][1][u]);
        }
    }

    #[test]
    fn slot_addressing_shifts_base_only() {
        // The same (token, layer) write in two slots differs only by the
        // slot region offset — identical shape, disjoint rows.
        let kv = kv_slots("gpt2-small", 2);
        let (u_a, segs_a) = kv.k_write(2, 0, 17);
        let (u_b, segs_b) = kv.k_write(2, 1, 17);
        assert_eq!(u_a, u_b);
        assert_eq!(segs_a.len(), segs_b.len());
        for (a, b) in segs_a.iter().zip(&segs_b) {
            assert_eq!(a.elems, b.elems);
            assert_ne!(a.row, b.row);
        }
    }

    #[test]
    fn pattern_overflow_is_capacity_error_not_panic() {
        // A context longer than MAX_PATTERN rows per V column must fail
        // at mapping build with a Pattern capacity error.
        let mut m = by_name("gpt2-small").unwrap();
        m.max_seq = MAX_PATTERN * 1024 + 1; // 16k rows of 1024 + 1
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        let err = KvReservation::build(&m, &cfg, &mut alloc, 1).unwrap_err();
        match err {
            CapacityError::Pattern { elems, max_elems } => {
                assert_eq!(elems, MAX_PATTERN as u64 * 1024 + 1);
                assert_eq!(max_elems, MAX_PATTERN as u64 * 1024);
            }
            other => panic!("expected Pattern error, got {other:?}"),
        }
    }

    #[test]
    fn prop_k_read_rows_within_reservation() {
        check("k reads stay inside reserved region", 50, |rng| {
            let kv = kv_slots("gpt2-medium", 2);
            let slot = rng.usize_in(0, 2);
            let ltoken = rng.gen_range(1024) + 1;
            let plans = kv.k_read_plan(2, slot, ltoken);
            let toks_per_unit = ceil_div(kv.max_seq, kv.n_units as u64) as u32;
            for (u, plan) in plans.iter().enumerate() {
                let base = kv.k_base[2][slot][u];
                let end = base + toks_per_unit * kv.rows_per_k;
                for s in plan {
                    if s.row < base || s.row >= end {
                        return Err(format!("unit {u} row {} outside [{base},{end})", s.row));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn slot_footprint_matches_actual_allocation() {
        // The closed-form per-slot footprint must equal what one slot
        // actually consumes on a unit (ModelMapping::build relies on
        // this to size the slot count without retrying placements).
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        let kv = KvReservation::build(&m, &cfg, &mut alloc, 1).unwrap();
        let per_slot = slot_rows_per_unit(&m, &cfg, kv.n_units);
        assert_eq!(per_slot, 12 * (8 + 6)); // 12 layers x (K 8 rows + V 6 rows)
        for u in 0..kv.n_units {
            assert_eq!(alloc.used(alloc.unit(u)), per_slot, "unit {u}");
        }
        // Two slots cost exactly twice as much.
        let mut alloc2 = BankAllocator::new(&cfg);
        KvReservation::build(&m, &cfg, &mut alloc2, 2).unwrap();
        assert_eq!(alloc2.used(alloc2.unit(0)), 2 * per_slot);
    }

    #[test]
    fn prop_slot_regions_never_overlap() {
        // Satellite acceptance: across every (layer, slot) pair, the K
        // and V regions of one unit are pairwise disjoint row ranges.
        check("per-slot KV regions disjoint", 20, |rng| {
            let n_slots = rng.usize_in(1, 5);
            let kv = kv_slots("gpt2-small", n_slots);
            let toks_per_unit = ceil_div(kv.max_seq, kv.n_units as u64) as u32;
            let k_rows = toks_per_unit * kv.rows_per_k;
            let v_rows = kv.v_cols_per_unit as u32 * kv.rows_per_vcol;
            let u = rng.usize_in(0, kv.n_units);
            let mut regions: Vec<(u32, u32, String)> = Vec::new();
            for layer in 0..kv.k_base.len() {
                for slot in 0..n_slots {
                    let kb = kv.k_base[layer][slot][u];
                    regions.push((kb, kb + k_rows, format!("K l{layer} s{slot}")));
                    let vb = kv.v_base[layer][slot][u];
                    regions.push((vb, vb + v_rows, format!("V l{layer} s{slot}")));
                }
            }
            regions.sort_by_key(|r| r.0);
            for w in regions.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!(
                        "unit {u}: {} [{}, {}) overlaps {} [{}, {})",
                        w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                    ));
                }
            }
            Ok(())
        });
    }
}
