//! KV-cache region reservation + runtime address computation
//! (paper Algorithm 3 lines 8-14, Fig. 7).
//!
//! * **Key cache** (row-major, Fig. 7a): token `t`'s head-concatenated
//!   Key vector (d elements) occupies `ceil(d / row_elems)` consecutive
//!   reserved rows in *one* unit; tokens round-robin over units so the
//!   growing context spreads evenly. The q@K^T VMM then reads, per unit,
//!   a short list of full-vector segments — consecutive rows, maximal
//!   locality.
//! * **Value cache** (column-major, Fig. 7b): V's `d` columns round-robin
//!   over units (`cols_pu` columns each); each column owns
//!   `ceil(max_seq / row_elems)` consecutive rows. Writing token `t`
//!   touches one row per owned column (ACT + 1 write + PRE each — no
//!   locality, as the paper notes); the scores@V VMM reads each owned
//!   column as `ceil(ltoken / row_elems)` row segments.

use super::layout::{BankAllocator, CapacityError, UnitId};
use crate::config::HwConfig;
use crate::dram::RowSegment;
use crate::model::GptModel;
use crate::util::ceil_div;

/// Longest supported row-fill pattern (rows per stored vector/column):
/// covers d_model and context lengths up to 16 * row_elems = 16k.
pub const MAX_PATTERN: usize = 16;

/// Split `elems` into full `row_elems`-sized rows plus a tail.
fn fill_pattern(elems: u64, row_elems: u64) -> ([u32; MAX_PATTERN], u8) {
    let full = (elems / row_elems) as usize;
    let tail = (elems % row_elems) as u32;
    assert!(full + (tail > 0) as usize <= MAX_PATTERN, "pattern too long ({elems} elems)");
    let mut pat = [0u32; MAX_PATTERN];
    for slot in pat.iter_mut().take(full) {
        *slot = row_elems as u32;
    }
    let mut len = full as u8;
    if tail > 0 {
        pat[full] = tail;
        len += 1;
    }
    (pat, len)
}

/// Reserved KV regions for every layer.
#[derive(Clone, Debug)]
pub struct KvReservation {
    /// K region base row per (layer, unit): `k_base[layer][unit]`.
    pub k_base: Vec<Vec<u32>>,
    /// V region base row per (layer, unit).
    pub v_base: Vec<Vec<u32>>,
    pub d_model: u64,
    pub max_seq: u64,
    pub n_units: usize,
    pub banks_per_channel: usize,
    /// Rows per stored Key vector (= ceil(d / row_elems)).
    pub rows_per_k: u32,
    /// Rows per stored Value column (= ceil(max_seq / row_elems)).
    pub rows_per_vcol: u32,
    /// V columns owned per unit.
    pub v_cols_per_unit: u64,
    row_elems: u64,
}

impl KvReservation {
    pub fn build(
        model: &GptModel,
        cfg: &HwConfig,
        alloc: &mut BankAllocator,
    ) -> Result<Self, CapacityError> {
        let n_units = alloc.n_units();
        let row_elems = cfg.gddr6.row_elems();
        let d = model.d_model as u64;
        let max_seq = model.max_seq as u64;

        let rows_per_k = ceil_div(d, row_elems) as u32;
        let toks_per_unit = ceil_div(max_seq, n_units as u64) as u32;
        let rows_per_vcol = ceil_div(max_seq, row_elems) as u32;
        let v_cols_per_unit = super::weight_map::columns_per_unit(d, n_units as u64);

        let mut k_base = Vec::with_capacity(model.n_layer);
        let mut v_base = Vec::with_capacity(model.n_layer);
        for _layer in 0..model.n_layer {
            let mut kb = Vec::with_capacity(n_units);
            let mut vb = Vec::with_capacity(n_units);
            for u in 0..n_units {
                let unit = alloc.unit(u);
                kb.push(alloc.alloc(unit, toks_per_unit * rows_per_k)?);
                vb.push(alloc.alloc(unit, v_cols_per_unit as u32 * rows_per_vcol)?);
            }
            k_base.push(kb);
            v_base.push(vb);
        }

        Ok(Self {
            k_base,
            v_base,
            d_model: d,
            max_seq,
            n_units,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            rows_per_k,
            rows_per_vcol,
            v_cols_per_unit,
            row_elems,
        })
    }

    /// Unit that stores token `t`'s Key vector (round-robin).
    pub fn k_unit(&self, t: u64) -> usize {
        (t % self.n_units as u64) as usize
    }

    /// (unit, row segment list) for writing token `t`'s Key vector.
    pub fn k_write(&self, layer: usize, t: u64) -> (UnitId, Vec<RowSegment>) {
        let u = self.k_unit(t);
        let slot = (t / self.n_units as u64) as u32;
        let base = self.k_base[layer][u] + slot * self.rows_per_k;
        let mut segs = Vec::with_capacity(self.rows_per_k as usize);
        let mut rem = self.d_model;
        for r in 0..self.rows_per_k {
            let elems = rem.min(self.row_elems) as u32;
            segs.push(RowSegment { row: base + r, elems });
            rem -= elems as u64;
        }
        (self.unit_id(u), segs)
    }

    /// Per-unit segment lists for the q@K^T read at context `ltoken`.
    pub fn k_read_plan(&self, layer: usize, ltoken: u64) -> Vec<Vec<RowSegment>> {
        let mut plans = vec![Vec::new(); self.n_units];
        self.fill_k_read_plan(layer, ltoken, &mut plans);
        plans
    }

    /// Allocation-free variant: refills `plans` (one entry per unit,
    /// capacities retained) — the simulator hot path.
    pub fn fill_k_read_plan(&self, layer: usize, ltoken: u64, plans: &mut [Vec<RowSegment>]) {
        assert_eq!(plans.len(), self.n_units);
        for (u, plan) in plans.iter_mut().enumerate() {
            plan.clear();
            // tokens u, u + n_units, ... < ltoken live in consecutive slots
            let owned = if (u as u64) < ltoken {
                ceil_div(ltoken - u as u64, self.n_units as u64)
            } else {
                0
            };
            let base = self.k_base[layer][u];
            for slot in 0..owned {
                let row0 = base + slot as u32 * self.rows_per_k;
                let mut rem = self.d_model;
                for r in 0..self.rows_per_k {
                    let elems = rem.min(self.row_elems) as u32;
                    plan.push(RowSegment { row: row0 + r, elems });
                    rem -= elems as u64;
                }
            }
        }
    }

    /// Tokens whose K vectors unit `u` stores at context `ltoken`.
    pub fn k_owned(&self, u: usize, ltoken: u64) -> u32 {
        if (u as u64) < ltoken {
            ceil_div(ltoken - u as u64, self.n_units as u64) as u32
        } else {
            0
        }
    }

    /// Row-fill pattern of one stored Key vector (e.g. d=1536 ->
    /// [1024, 512]): `full` rows of `row_elems` plus an optional tail.
    pub fn k_read_pattern(&self) -> ([u32; MAX_PATTERN], u8) {
        fill_pattern(self.d_model, self.row_elems)
    }

    /// Row-fill pattern of one V column read at context `ltoken`.
    /// When a column's reserved rows exceed the rows actually read
    /// (ltoken <= row_elems but max_seq > row_elems) the physical rows
    /// are strided; the cycle cost is identical (all distinct misses).
    pub fn v_read_pattern(&self, ltoken: u64) -> ([u32; MAX_PATTERN], u8) {
        fill_pattern(ltoken.max(1), self.row_elems)
    }

    /// Scores owned by unit `u` at context `ltoken` (one per stored
    /// token, times heads — heads share the row, segmented accumulation).
    pub fn k_out_elems(&self, u: usize, ltoken: u64, n_head: u64) -> u64 {
        if (u as u64) < ltoken {
            ceil_div(ltoken - u as u64, self.n_units as u64) * n_head
        } else {
            0
        }
    }

    /// (base_row, n_rows) for writing token `t`'s Value elements into
    /// unit `u`: one element per owned column, consecutive rows when the
    /// column's row stride is 1 (max_seq <= row_elems), else strided.
    pub fn v_write(&self, layer: usize, t: u64, u: usize) -> (u32, u32, u32) {
        let base = self.v_base[layer][u] + (t / self.row_elems) as u32;
        let n_cols = self.v_cols(u);
        (base, n_cols, self.rows_per_vcol)
    }

    /// Columns of V actually owned by unit `u` (tail units may own fewer).
    pub fn v_cols(&self, u: usize) -> u32 {
        let lo = (u as u64 * self.v_cols_per_unit).min(self.d_model);
        let hi = ((u as u64 + 1) * self.v_cols_per_unit).min(self.d_model);
        (hi - lo) as u32
    }

    /// Per-unit segment lists for the scores@V read at context `ltoken`.
    pub fn v_read_plan(&self, layer: usize, ltoken: u64) -> Vec<Vec<RowSegment>> {
        let mut plans = vec![Vec::new(); self.n_units];
        self.fill_v_read_plan(layer, ltoken, &mut plans);
        plans
    }

    /// Allocation-free variant of `v_read_plan` (see `fill_k_read_plan`).
    pub fn fill_v_read_plan(&self, layer: usize, ltoken: u64, plans: &mut [Vec<RowSegment>]) {
        assert_eq!(plans.len(), self.n_units);
        let rows_touched = ceil_div(ltoken, self.row_elems) as u32;
        for (u, plan) in plans.iter_mut().enumerate() {
            plan.clear();
            let base = self.v_base[layer][u];
            for c in 0..self.v_cols(u) {
                let col_base = base + c * self.rows_per_vcol;
                let mut rem = ltoken;
                for r in 0..rows_touched {
                    let elems = rem.min(self.row_elems) as u32;
                    plan.push(RowSegment { row: col_base + r, elems });
                    rem -= elems as u64;
                }
            }
        }
    }

    fn unit_id(&self, u: usize) -> UnitId {
        UnitId { channel: u / self.banks_per_channel, bank: u % self.banks_per_channel }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::util::prop::check;

    fn kv(model: &str) -> KvReservation {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        KvReservation::build(&m, &cfg, &mut alloc).unwrap()
    }

    #[test]
    fn k_write_spreads_round_robin() {
        let kv = kv("gpt2-small");
        let (u0, _) = kv.k_write(0, 0);
        let (u1, _) = kv.k_write(0, 1);
        let (u128, s128) = kv.k_write(0, 128);
        assert_ne!(u0, u1);
        assert_eq!(u0, u128); // wraps around 128 units
        // second slot on the same unit is the next reserved row
        let (_, s0) = kv.k_write(0, 0);
        assert_eq!(s128[0].row, s0[0].row + kv.rows_per_k);
    }

    #[test]
    fn k_write_one_row_when_d_fits() {
        let kv = kv("gpt2-small"); // d=768 <= 1024
        let (_, segs) = kv.k_write(0, 5);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].elems, 768);
    }

    #[test]
    fn k_write_two_rows_for_wide_model() {
        let kv = kv("gpt3-xl"); // d=2048 -> 2 rows
        let (_, segs) = kv.k_write(3, 5);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].elems + segs[1].elems, 2048);
    }

    #[test]
    fn k_read_covers_all_tokens() {
        let kv = kv("gpt2-small");
        for ltoken in [1u64, 7, 128, 129, 1000] {
            let plans = kv.k_read_plan(0, ltoken);
            let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
            assert_eq!(total, ltoken * 768, "ltoken={ltoken}");
        }
    }

    #[test]
    fn k_out_elems_total_is_heads_times_tokens() {
        let kv = kv("gpt2-small");
        for ltoken in [1u64, 100, 1024] {
            let total: u64 = (0..kv.n_units).map(|u| kv.k_out_elems(u, ltoken, 12)).sum();
            assert_eq!(total, 12 * ltoken);
        }
    }

    #[test]
    fn v_columns_cover_d_model() {
        let kv = kv("gpt2-large"); // d=1280, 128 units -> 10 cols each
        let total: u64 = (0..kv.n_units).map(|u| kv.v_cols(u) as u64).sum();
        assert_eq!(total, 1280);
    }

    #[test]
    fn v_read_covers_ltoken_per_column() {
        let kv = kv("gpt3-small");
        let plans = kv.v_read_plan(0, 300);
        let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
        assert_eq!(total, 300 * 768);
    }

    #[test]
    fn v_read_multi_row_columns_long_context() {
        let kv = kv("gpt3-xl"); // max_seq=2048 -> 2 rows per column
        assert_eq!(kv.rows_per_vcol, 2);
        let plans = kv.v_read_plan(0, 2000);
        // each owned column contributes 2 segments (1024 + 976)
        let u0 = &plans[0];
        assert_eq!(u0.len() as u64, kv.v_cols(0) as u64 * 2);
    }

    #[test]
    fn regions_do_not_overlap_across_layers() {
        let kv = kv("gpt2-small");
        // layer 1's K base must start after layer 0's K+V regions
        for u in 0..kv.n_units {
            assert!(kv.k_base[1][u] > kv.k_base[0][u]);
            assert!(kv.v_base[0][u] > kv.k_base[0][u]);
        }
    }

    #[test]
    fn prop_k_read_rows_within_reservation() {
        check("k reads stay inside reserved region", 50, |rng| {
            let kv = kv("gpt2-medium");
            let ltoken = rng.gen_range(1024) + 1;
            let plans = kv.k_read_plan(2, ltoken);
            let toks_per_unit = ceil_div(kv.max_seq, kv.n_units as u64) as u32;
            for (u, plan) in plans.iter().enumerate() {
                let base = kv.k_base[2][u];
                let end = base + toks_per_unit * kv.rows_per_k;
                for s in plan {
                    if s.row < base || s.row >= end {
                        return Err(format!("unit {u} row {} outside [{base},{end})", s.row));
                    }
                }
            }
            Ok(())
        });
    }
}
