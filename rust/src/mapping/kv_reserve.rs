//! KV-cache region reservation + runtime address computation
//! (paper Algorithm 3 lines 8-14, Fig. 7), organized around *page
//! tables*: physical DRAM reservations are fixed-size **frames**, and a
//! stream's logical token positions resolve to frames through a
//! per-stream page table.
//!
//! * **Key cache** (row-major, Fig. 7a): token `t`'s head-concatenated
//!   Key vector (d elements) occupies `ceil(d / row_elems)` consecutive
//!   reserved rows in *one* unit; tokens round-robin over units so the
//!   growing context spreads evenly. The q@K^T VMM then reads, per unit,
//!   a short list of full-vector segments — consecutive rows, maximal
//!   locality.
//! * **Value cache** (column-major, Fig. 7b): V's `d` columns round-robin
//!   over units (`cols_pu` columns each); each column owns
//!   `ceil(page_tokens / row_elems)` consecutive rows per frame. Writing
//!   token `t` touches one row per owned column (ACT + 1 write + PRE
//!   each — no locality, as the paper notes); the scores@V VMM reads
//!   each owned column as `ceil(span / row_elems)` row segments per
//!   covered frame.
//!
//! **Two granularities, one geometry.**
//!
//! * **Slot mode** (`build`, `page_tokens = None`): the historical
//!   layout. One frame == one full `max_seq` context ("slot"); serving K
//!   concurrent streams reserves K disjoint slots —
//!   `k_base[layer][slot][unit]` / `v_base[layer][slot][unit]` — and
//!   every address computation takes the stream's slot id directly.
//!   Reads are single contiguous regions (`k_read_pattern` /
//!   `v_read_pattern` with a per-slot base row).
//! * **Paged mode** (`build_paged`, `page_tokens = Some(P)`): the same
//!   `[layer][frame][unit]` base arrays, but each frame covers only `P`
//!   tokens (`sched.kv_page_tokens`, rounded up to a multiple of
//!   `n_units` so a token's owning unit is page-invariant, and capped at
//!   the padded `max_seq`). Streams own a *page table* — `pages[j]` is
//!   the physical frame holding logical tokens `[j*P, (j+1)*P)` — and
//!   the address methods take that table instead of a slot id:
//!   `k_write_paged` / `v_write_paged` for stores, and `k_read_runs` /
//!   `v_read_runs` which return **per-page [`PatternRun`] lists** (one
//!   base row + row-fill pattern per covered frame) instead of one
//!   contiguous region. Consecutive runs on the same bank compose
//!   cycle-exactly with the slot-mode sweep when the frames happen to be
//!   contiguous, and pay the honest ACT/PRE row-switch cost when they
//!   are not.
//!
//! With `P = max_seq` (padded) a page table holds exactly one entry and
//! every paged method degenerates to its slot-mode twin — the
//! cycle-identity anchor the scheduler's `kv_paging` equivalence tests
//! pin. Frame pools are sized by `ModelMapping::build` (degrading with a
//! `KvSlotReport` when DRAM rows run short); the multi-stream scheduler
//! (`sim::sched::MultiSim`) owns the free list, the per-stream page
//! tables, on-demand growth, and preemption/eviction on exhaustion.

use super::layout::{BankAllocator, CapacityError, UnitId};
use crate::config::HwConfig;
use crate::dram::RowSegment;
use crate::model::GptModel;
use crate::util::ceil_div;

/// Longest supported row-fill pattern (rows per stored vector/column):
/// covers d_model and context lengths up to 16 * row_elems = 16k.
pub const MAX_PATTERN: usize = 16;

/// Split `elems` into full `row_elems`-sized rows plus a tail. Patterns
/// longer than [`MAX_PATTERN`] are a mapping-time capacity error (the
/// hardware pattern buffer cannot express them); `KvReservation::build`
/// validates both KV patterns up front so the simulator's hot path
/// never hits the overflow at runtime.
fn fill_pattern(elems: u64, row_elems: u64) -> Result<([u32; MAX_PATTERN], u8), CapacityError> {
    if elems > MAX_PATTERN as u64 * row_elems {
        return Err(CapacityError::Pattern { elems, max_elems: MAX_PATTERN as u64 * row_elems });
    }
    Ok(fill_pattern_trusted(elems, row_elems))
}

/// Infallible variant for the simulator hot path: callers rely on the
/// build-time validation above (`debug_assert` documents the contract).
fn fill_pattern_trusted(elems: u64, row_elems: u64) -> ([u32; MAX_PATTERN], u8) {
    let full = (elems / row_elems) as usize;
    let tail = (elems % row_elems) as u32;
    debug_assert!(
        full + (tail > 0) as usize <= MAX_PATTERN,
        "pattern too long ({elems} elems) — must be rejected at mapping build"
    );
    let mut pat = [0u32; MAX_PATTERN];
    for slot in pat.iter_mut().take(full) {
        *slot = row_elems as u32;
    }
    let mut len = full as u8;
    if tail > 0 {
        pat[full] = tail;
        len += 1;
    }
    (pat, len)
}

/// One contiguous KV read on a single bank: `reps` repetitions of a
/// row-fill `pattern` starting at `base_row`. Paged K/V reads are *lists*
/// of these — one run per covered page frame — instead of the slot
/// engine's single `(base_row, reps, pattern)` region. A single-run list
/// is bit-identical in cost to the slot read (the bank's `mac_pattern`
/// is invoked with the same arguments); consecutive runs chain through
/// the bank's `busy_until`/`opened_at` state, paying the honest row
/// ACT/PRE switch cost between frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatternRun {
    /// First reserved row of the frame's region on this bank.
    pub base_row: u32,
    /// Pattern repetitions (stored K vectors, or V columns, in the frame).
    pub reps: u32,
    /// Row-fill pattern of one repetition.
    pub pattern: [u32; MAX_PATTERN],
    /// Live prefix length of `pattern`.
    pub pattern_len: u8,
}

/// Canonical page size: `page_tokens` rounded **up** to a multiple of
/// `n_units` (so `token % n_units` — the owning unit — is the same
/// whether computed globally or page-locally) and capped at `max_seq`
/// padded the same way (a page larger than one full context buys
/// nothing). `page_tokens = max_seq` therefore yields exactly one page
/// per full context — the slot-equivalence configuration.
pub fn round_page_tokens(page_tokens: u64, n_units: usize, max_seq: u64) -> u64 {
    use crate::util::pad_to;
    pad_to(page_tokens.max(1), n_units as u64).min(pad_to(max_seq.max(1), n_units as u64))
}

/// Rows one page frame reserves per unit over *all* layers (each
/// layer's K region plus V region) — the paged analog of
/// [`slot_rows_per_unit`], used by `ModelMapping::build` to size the
/// frame pool in closed form. Note the V region floor: every frame
/// reserves `v_cols_per_unit * ceil(P / row_elems)` V rows, so at small
/// `P` the V share does not shrink below one row per owned column —
/// paging trades that inflation for on-demand growth.
pub fn frame_rows_per_unit(model: &GptModel, cfg: &HwConfig, n_units: usize, page_tokens: u64) -> u32 {
    let row_elems = cfg.gddr6.row_elems();
    let d = model.d_model as u64;
    let p = round_page_tokens(page_tokens, n_units, model.max_seq as u64);
    let rows_per_k = ceil_div(d, row_elems) as u32;
    let toks_per_unit = (p / n_units as u64) as u32;
    let rows_per_vcol = ceil_div(p, row_elems) as u32;
    let v_cols = super::weight_map::columns_per_unit(d, n_units as u64) as u32;
    model.n_layer as u32 * (toks_per_unit * rows_per_k + v_cols * rows_per_vcol)
}

/// Rows one stream slot reserves per unit over *all* layers (each
/// layer's K region plus V region). The footprint is uniform across
/// units, which is what lets `ModelMapping::build` size the slot count
/// in closed form against the fullest bank's leftover rows instead of
/// retrying the whole placement per candidate count.
pub fn slot_rows_per_unit(model: &GptModel, cfg: &HwConfig, n_units: usize) -> u32 {
    let row_elems = cfg.gddr6.row_elems();
    let d = model.d_model as u64;
    let max_seq = model.max_seq as u64;
    let rows_per_k = ceil_div(d, row_elems) as u32;
    let toks_per_unit = ceil_div(max_seq, n_units as u64) as u32;
    let rows_per_vcol = ceil_div(max_seq, row_elems) as u32;
    let v_cols = super::weight_map::columns_per_unit(d, n_units as u64) as u32;
    model.n_layer as u32 * (toks_per_unit * rows_per_k + v_cols * rows_per_vcol)
}

/// Reserved KV regions for every (layer, frame). In slot mode
/// (`page_tokens = None`) a frame is a full `max_seq` context addressed
/// by slot id; in paged mode (`page_tokens = Some(P)`) a frame covers
/// `P` tokens and is addressed through a per-stream page table.
#[derive(Clone, Debug)]
pub struct KvReservation {
    /// K region base row per (layer, frame, unit): `k_base[layer][frame][unit]`.
    pub k_base: Vec<Vec<Vec<u32>>>,
    /// V region base row per (layer, frame, unit).
    pub v_base: Vec<Vec<Vec<u32>>>,
    /// Frames reserved. Slot mode: disjoint `max_seq` contexts
    /// (= concurrent streams servable). Paged mode: pool size in pages.
    pub n_slots: usize,
    pub d_model: u64,
    pub max_seq: u64,
    pub n_units: usize,
    pub banks_per_channel: usize,
    /// Rows per stored Key vector (= ceil(d / row_elems)).
    pub rows_per_k: u32,
    /// Rows per stored Value column (= ceil(tokens-per-frame / row_elems)).
    pub rows_per_vcol: u32,
    /// V columns owned per unit.
    pub v_cols_per_unit: u64,
    /// `None` = slot mode; `Some(P)` = paged mode with `P` tokens per
    /// frame (already rounded via [`round_page_tokens`]).
    pub page_tokens: Option<u64>,
    row_elems: u64,
}

impl KvReservation {
    /// Reserve `n_slots` disjoint per-layer KV contexts. Fails with a
    /// [`CapacityError`] when the rows don't fit (callers may retry with
    /// fewer slots — see `ModelMapping::build`) or when a stored vector
    /// cannot be expressed as a row-fill pattern at all.
    pub fn build(
        model: &GptModel,
        cfg: &HwConfig,
        alloc: &mut BankAllocator,
        n_slots: usize,
    ) -> Result<Self, CapacityError> {
        assert!(n_slots >= 1, "at least one KV slot is required");
        let n_units = alloc.n_units();
        let row_elems = cfg.gddr6.row_elems();
        let d = model.d_model as u64;
        let max_seq = model.max_seq as u64;

        // Validate both runtime row-fill patterns now: the K read pattern
        // (d elements per vector) and the widest V read pattern (max_seq
        // elements per column). Rejecting here turns what used to be a
        // runtime abort into a mapping-build error.
        fill_pattern(d, row_elems)?;
        fill_pattern(max_seq.max(1), row_elems)?;

        let rows_per_k = ceil_div(d, row_elems) as u32;
        let toks_per_unit = ceil_div(max_seq, n_units as u64) as u32;
        let rows_per_vcol = ceil_div(max_seq, row_elems) as u32;
        let v_cols_per_unit = super::weight_map::columns_per_unit(d, n_units as u64);

        let mut k_base = Vec::with_capacity(model.n_layer);
        let mut v_base = Vec::with_capacity(model.n_layer);
        for _layer in 0..model.n_layer {
            let mut k_slots = Vec::with_capacity(n_slots);
            let mut v_slots = Vec::with_capacity(n_slots);
            for _slot in 0..n_slots {
                let mut kb = Vec::with_capacity(n_units);
                let mut vb = Vec::with_capacity(n_units);
                for u in 0..n_units {
                    let unit = alloc.unit(u);
                    kb.push(alloc.alloc(unit, toks_per_unit * rows_per_k)?);
                    vb.push(alloc.alloc(unit, v_cols_per_unit as u32 * rows_per_vcol)?);
                }
                k_slots.push(kb);
                v_slots.push(vb);
            }
            k_base.push(k_slots);
            v_base.push(v_slots);
        }

        Ok(Self {
            k_base,
            v_base,
            n_slots,
            d_model: d,
            max_seq,
            n_units,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            rows_per_k,
            rows_per_vcol,
            v_cols_per_unit,
            page_tokens: None,
            row_elems,
        })
    }

    /// Reserve a pool of `n_frames` page frames of `page_tokens` tokens
    /// each (rounded via [`round_page_tokens`]). The allocation loop is
    /// the same layer -> frame -> unit order as [`build`], so with
    /// `page_tokens = max_seq` and `n_frames = n_slots` every frame gets
    /// the *identical* base rows the slot build would assign — the
    /// foundation of the paging-off cycle-equivalence contract.
    pub fn build_paged(
        model: &GptModel,
        cfg: &HwConfig,
        alloc: &mut BankAllocator,
        n_frames: usize,
        page_tokens: u64,
    ) -> Result<Self, CapacityError> {
        assert!(n_frames >= 1, "at least one KV page frame is required");
        let n_units = alloc.n_units();
        let row_elems = cfg.gddr6.row_elems();
        let d = model.d_model as u64;
        let max_seq = model.max_seq as u64;
        let p = round_page_tokens(page_tokens, n_units, max_seq);

        // Validate both runtime row-fill patterns now (see `build`); the
        // widest V span per frame is one page, not the whole context.
        fill_pattern(d, row_elems)?;
        fill_pattern(p, row_elems)?;

        let rows_per_k = ceil_div(d, row_elems) as u32;
        let toks_per_unit = (p / n_units as u64) as u32; // P is a multiple of n_units
        let rows_per_vcol = ceil_div(p, row_elems) as u32;
        let v_cols_per_unit = super::weight_map::columns_per_unit(d, n_units as u64);

        let mut k_base = Vec::with_capacity(model.n_layer);
        let mut v_base = Vec::with_capacity(model.n_layer);
        for _layer in 0..model.n_layer {
            let mut k_frames = Vec::with_capacity(n_frames);
            let mut v_frames = Vec::with_capacity(n_frames);
            for _frame in 0..n_frames {
                let mut kb = Vec::with_capacity(n_units);
                let mut vb = Vec::with_capacity(n_units);
                for u in 0..n_units {
                    let unit = alloc.unit(u);
                    kb.push(alloc.alloc(unit, toks_per_unit * rows_per_k)?);
                    vb.push(alloc.alloc(unit, v_cols_per_unit as u32 * rows_per_vcol)?);
                }
                k_frames.push(kb);
                v_frames.push(vb);
            }
            k_base.push(k_frames);
            v_base.push(v_frames);
        }

        Ok(Self {
            k_base,
            v_base,
            n_slots: n_frames,
            d_model: d,
            max_seq,
            n_units,
            banks_per_channel: cfg.gddr6.banks_per_channel,
            rows_per_k,
            rows_per_vcol,
            v_cols_per_unit,
            page_tokens: Some(p),
            row_elems,
        })
    }

    /// Page frames a context of `tokens` positions occupies (>= 1, so an
    /// admitted stream can always write its first token). Panics in slot
    /// mode — frame accounting is a paged-mode concept.
    pub fn frames_for(&self, tokens: u64) -> usize {
        let p = self.page_tokens.expect("frames_for on a slot-mode reservation");
        ceil_div(tokens.max(1), p) as usize
    }

    /// Unit that stores token `t`'s Key vector (round-robin).
    pub fn k_unit(&self, t: u64) -> usize {
        (t % self.n_units as u64) as usize
    }

    /// (unit, row segment list) for writing token `t`'s Key vector into
    /// stream slot `slot`.
    pub fn k_write(&self, layer: usize, slot: usize, t: u64) -> (UnitId, Vec<RowSegment>) {
        let u = self.k_unit(t);
        let tok_slot = (t / self.n_units as u64) as u32;
        let base = self.k_base[layer][slot][u] + tok_slot * self.rows_per_k;
        let mut segs = Vec::with_capacity(self.rows_per_k as usize);
        let mut rem = self.d_model;
        for r in 0..self.rows_per_k {
            let elems = rem.min(self.row_elems) as u32;
            segs.push(RowSegment { row: base + r, elems });
            rem -= elems as u64;
        }
        (self.unit_id(u), segs)
    }

    /// Per-unit segment lists for the q@K^T read of slot `slot` at
    /// context `ltoken`.
    pub fn k_read_plan(&self, layer: usize, slot: usize, ltoken: u64) -> Vec<Vec<RowSegment>> {
        let mut plans = vec![Vec::new(); self.n_units];
        self.fill_k_read_plan(layer, slot, ltoken, &mut plans);
        plans
    }

    /// Allocation-free variant: refills `plans` (one entry per unit,
    /// capacities retained) — the simulator hot path.
    pub fn fill_k_read_plan(
        &self,
        layer: usize,
        slot: usize,
        ltoken: u64,
        plans: &mut [Vec<RowSegment>],
    ) {
        assert_eq!(plans.len(), self.n_units);
        for (u, plan) in plans.iter_mut().enumerate() {
            plan.clear();
            // tokens u, u + n_units, ... < ltoken live in consecutive slots
            let owned = if (u as u64) < ltoken {
                ceil_div(ltoken - u as u64, self.n_units as u64)
            } else {
                0
            };
            let base = self.k_base[layer][slot][u];
            for tok_slot in 0..owned {
                let row0 = base + tok_slot as u32 * self.rows_per_k;
                let mut rem = self.d_model;
                for r in 0..self.rows_per_k {
                    let elems = rem.min(self.row_elems) as u32;
                    plan.push(RowSegment { row: row0 + r, elems });
                    rem -= elems as u64;
                }
            }
        }
    }

    /// Tokens whose K vectors unit `u` stores at context `ltoken`.
    pub fn k_owned(&self, u: usize, ltoken: u64) -> u32 {
        if (u as u64) < ltoken {
            ceil_div(ltoken - u as u64, self.n_units as u64) as u32
        } else {
            0
        }
    }

    /// Row-fill pattern of one stored Key vector (e.g. d=1536 ->
    /// [1024, 512]): `full` rows of `row_elems` plus an optional tail.
    pub fn k_read_pattern(&self) -> ([u32; MAX_PATTERN], u8) {
        fill_pattern_trusted(self.d_model, self.row_elems)
    }

    /// Row-fill pattern of one V column read at context `ltoken`.
    /// When a column's reserved rows exceed the rows actually read
    /// (ltoken <= row_elems but max_seq > row_elems) the physical rows
    /// are strided; the cycle cost is identical (all distinct misses).
    pub fn v_read_pattern(&self, ltoken: u64) -> ([u32; MAX_PATTERN], u8) {
        fill_pattern_trusted(ltoken.max(1), self.row_elems)
    }

    /// Scores owned by unit `u` at context `ltoken` (one per stored
    /// token, times heads — heads share the row, segmented accumulation).
    pub fn k_out_elems(&self, u: usize, ltoken: u64, n_head: u64) -> u64 {
        if (u as u64) < ltoken {
            ceil_div(ltoken - u as u64, self.n_units as u64) * n_head
        } else {
            0
        }
    }

    /// (base_row, n_cols, row_stride) for writing token `t`'s Value
    /// elements into unit `u` of stream slot `slot`: one element per
    /// owned column, consecutive rows when the column's row stride is 1
    /// (max_seq <= row_elems), else strided.
    pub fn v_write(&self, layer: usize, slot: usize, t: u64, u: usize) -> (u32, u32, u32) {
        let base = self.v_base[layer][slot][u] + (t / self.row_elems) as u32;
        let n_cols = self.v_cols(u);
        (base, n_cols, self.rows_per_vcol)
    }

    /// Columns of V actually owned by unit `u` (tail units may own fewer).
    pub fn v_cols(&self, u: usize) -> u32 {
        let lo = (u as u64 * self.v_cols_per_unit).min(self.d_model);
        let hi = ((u as u64 + 1) * self.v_cols_per_unit).min(self.d_model);
        (hi - lo) as u32
    }

    /// Per-unit segment lists for the scores@V read of slot `slot` at
    /// context `ltoken`.
    pub fn v_read_plan(&self, layer: usize, slot: usize, ltoken: u64) -> Vec<Vec<RowSegment>> {
        let mut plans = vec![Vec::new(); self.n_units];
        self.fill_v_read_plan(layer, slot, ltoken, &mut plans);
        plans
    }

    /// Allocation-free variant of `v_read_plan` (see `fill_k_read_plan`).
    pub fn fill_v_read_plan(
        &self,
        layer: usize,
        slot: usize,
        ltoken: u64,
        plans: &mut [Vec<RowSegment>],
    ) {
        assert_eq!(plans.len(), self.n_units);
        let rows_touched = ceil_div(ltoken, self.row_elems) as u32;
        for (u, plan) in plans.iter_mut().enumerate() {
            plan.clear();
            let base = self.v_base[layer][slot][u];
            for c in 0..self.v_cols(u) {
                let col_base = base + c * self.rows_per_vcol;
                let mut rem = ltoken;
                for r in 0..rows_touched {
                    let elems = rem.min(self.row_elems) as u32;
                    plan.push(RowSegment { row: col_base + r, elems });
                    rem -= elems as u64;
                }
            }
        }
    }

    /// Paged twin of [`k_write`]: `pages[t / P]` names the physical
    /// frame holding token `t`; within the frame the row math is the
    /// page-local copy of the slot layout (`P` a multiple of `n_units`
    /// keeps the owning unit identical to the global round-robin).
    pub fn k_write_paged(&self, layer: usize, pages: &[u32], t: u64) -> (UnitId, Vec<RowSegment>) {
        let p = self.page_tokens.expect("paged addressing on a slot-mode reservation");
        let frame = pages[(t / p) as usize] as usize;
        let u = self.k_unit(t);
        let tok_slot = ((t % p) / self.n_units as u64) as u32;
        let base = self.k_base[layer][frame][u] + tok_slot * self.rows_per_k;
        let mut segs = Vec::with_capacity(self.rows_per_k as usize);
        let mut rem = self.d_model;
        for r in 0..self.rows_per_k {
            let elems = rem.min(self.row_elems) as u32;
            segs.push(RowSegment { row: base + r, elems });
            rem -= elems as u64;
        }
        (self.unit_id(u), segs)
    }

    /// Paged twin of [`v_write`]: token `t`'s V elements land in row
    /// `(t % P) / row_elems` of each owned column of frame `pages[t/P]`.
    pub fn v_write_paged(&self, layer: usize, pages: &[u32], t: u64, u: usize) -> (u32, u32, u32) {
        let p = self.page_tokens.expect("paged addressing on a slot-mode reservation");
        let frame = pages[(t / p) as usize] as usize;
        let base = self.v_base[layer][frame][u] + ((t % p) / self.row_elems) as u32;
        (base, self.v_cols(u), self.rows_per_vcol)
    }

    /// q@K^T read of a paged context at `ltoken`, for unit `u`: one
    /// [`PatternRun`] per covered page frame (the per-page share of
    /// [`k_owned`] repetitions of [`k_read_pattern`]). With a single
    /// full-context page this is exactly the slot read.
    pub fn k_read_runs(&self, layer: usize, pages: &[u32], ltoken: u64, u: usize) -> Vec<PatternRun> {
        let p = self.page_tokens.expect("paged addressing on a slot-mode reservation");
        let (pattern, pattern_len) = self.k_read_pattern();
        let mut runs = Vec::new();
        for (j, &frame) in pages.iter().enumerate() {
            let lo = j as u64 * p;
            if lo >= ltoken {
                break;
            }
            // tokens u, u + n_units, ... within this page's live span
            let span = (ltoken - lo).min(p);
            if (u as u64) >= span {
                continue;
            }
            let reps = ceil_div(span - u as u64, self.n_units as u64) as u32;
            runs.push(PatternRun { base_row: self.k_base[layer][frame as usize][u], reps, pattern, pattern_len });
        }
        runs
    }

    /// scores@V read of a paged context at `ltoken`, for unit `u`: one
    /// [`PatternRun`] per covered page frame — each owned column
    /// contributes `ceil(span / row_elems)` row segments where `span` is
    /// the page's live token count. With a single full-context page this
    /// is exactly the slot read ([`v_read_pattern`] x [`v_cols`]).
    pub fn v_read_runs(&self, layer: usize, pages: &[u32], ltoken: u64, u: usize) -> Vec<PatternRun> {
        let p = self.page_tokens.expect("paged addressing on a slot-mode reservation");
        let cols = self.v_cols(u);
        let mut runs = Vec::new();
        if cols == 0 {
            return runs;
        }
        for (j, &frame) in pages.iter().enumerate() {
            let lo = j as u64 * p;
            if lo >= ltoken {
                break;
            }
            let span = (ltoken - lo).min(p);
            let (pattern, pattern_len) = fill_pattern_trusted(span, self.row_elems);
            runs.push(PatternRun { base_row: self.v_base[layer][frame as usize][u], reps: cols, pattern, pattern_len });
        }
        runs
    }

    fn unit_id(&self, u: usize) -> UnitId {
        UnitId { channel: u / self.banks_per_channel, bank: u % self.banks_per_channel }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::util::prop::check;

    fn kv_slots(model: &str, n_slots: usize) -> KvReservation {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        KvReservation::build(&m, &cfg, &mut alloc, n_slots).unwrap()
    }

    fn kv(model: &str) -> KvReservation {
        kv_slots(model, 1)
    }

    #[test]
    fn k_write_spreads_round_robin() {
        let kv = kv("gpt2-small");
        let (u0, _) = kv.k_write(0, 0, 0);
        let (u1, _) = kv.k_write(0, 0, 1);
        let (u128, s128) = kv.k_write(0, 0, 128);
        assert_ne!(u0, u1);
        assert_eq!(u0, u128); // wraps around 128 units
        // second slot on the same unit is the next reserved row
        let (_, s0) = kv.k_write(0, 0, 0);
        assert_eq!(s128[0].row, s0[0].row + kv.rows_per_k);
    }

    #[test]
    fn k_write_one_row_when_d_fits() {
        let kv = kv("gpt2-small"); // d=768 <= 1024
        let (_, segs) = kv.k_write(0, 0, 5);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].elems, 768);
    }

    #[test]
    fn k_write_two_rows_for_wide_model() {
        let kv = kv("gpt3-xl"); // d=2048 -> 2 rows
        let (_, segs) = kv.k_write(3, 0, 5);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].elems + segs[1].elems, 2048);
    }

    #[test]
    fn k_read_covers_all_tokens() {
        let kv = kv("gpt2-small");
        for ltoken in [1u64, 7, 128, 129, 1000] {
            let plans = kv.k_read_plan(0, 0, ltoken);
            let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
            assert_eq!(total, ltoken * 768, "ltoken={ltoken}");
        }
    }

    #[test]
    fn k_out_elems_total_is_heads_times_tokens() {
        let kv = kv("gpt2-small");
        for ltoken in [1u64, 100, 1024] {
            let total: u64 = (0..kv.n_units).map(|u| kv.k_out_elems(u, ltoken, 12)).sum();
            assert_eq!(total, 12 * ltoken);
        }
    }

    #[test]
    fn v_columns_cover_d_model() {
        let kv = kv("gpt2-large"); // d=1280, 128 units -> 10 cols each
        let total: u64 = (0..kv.n_units).map(|u| kv.v_cols(u) as u64).sum();
        assert_eq!(total, 1280);
    }

    #[test]
    fn v_read_covers_ltoken_per_column() {
        let kv = kv("gpt3-small");
        let plans = kv.v_read_plan(0, 0, 300);
        let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
        assert_eq!(total, 300 * 768);
    }

    #[test]
    fn v_read_multi_row_columns_long_context() {
        let kv = kv("gpt3-xl"); // max_seq=2048 -> 2 rows per column
        assert_eq!(kv.rows_per_vcol, 2);
        let plans = kv.v_read_plan(0, 0, 2000);
        // each owned column contributes 2 segments (1024 + 976)
        let u0 = &plans[0];
        assert_eq!(u0.len() as u64, kv.v_cols(0) as u64 * 2);
    }

    #[test]
    fn regions_do_not_overlap_across_layers() {
        let kv = kv("gpt2-small");
        // layer 1's K base must start after layer 0's K+V regions
        for u in 0..kv.n_units {
            assert!(kv.k_base[1][0][u] > kv.k_base[0][0][u]);
            assert!(kv.v_base[0][0][u] > kv.k_base[0][0][u]);
        }
    }

    #[test]
    fn slots_are_disjoint_same_layer() {
        let kv = kv_slots("gpt2-small", 3);
        assert_eq!(kv.n_slots, 3);
        for u in 0..kv.n_units {
            // Later slots live strictly after earlier slots' regions.
            assert!(kv.k_base[0][1][u] > kv.v_base[0][0][u]);
            assert!(kv.k_base[0][2][u] > kv.v_base[0][1][u]);
        }
    }

    #[test]
    fn slot_addressing_shifts_base_only() {
        // The same (token, layer) write in two slots differs only by the
        // slot region offset — identical shape, disjoint rows.
        let kv = kv_slots("gpt2-small", 2);
        let (u_a, segs_a) = kv.k_write(2, 0, 17);
        let (u_b, segs_b) = kv.k_write(2, 1, 17);
        assert_eq!(u_a, u_b);
        assert_eq!(segs_a.len(), segs_b.len());
        for (a, b) in segs_a.iter().zip(&segs_b) {
            assert_eq!(a.elems, b.elems);
            assert_ne!(a.row, b.row);
        }
    }

    #[test]
    fn pattern_overflow_is_capacity_error_not_panic() {
        // A context longer than MAX_PATTERN rows per V column must fail
        // at mapping build with a Pattern capacity error.
        let mut m = by_name("gpt2-small").unwrap();
        m.max_seq = MAX_PATTERN * 1024 + 1; // 16k rows of 1024 + 1
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        let err = KvReservation::build(&m, &cfg, &mut alloc, 1).unwrap_err();
        match err {
            CapacityError::Pattern { elems, max_elems } => {
                assert_eq!(elems, MAX_PATTERN as u64 * 1024 + 1);
                assert_eq!(max_elems, MAX_PATTERN as u64 * 1024);
            }
            other => panic!("expected Pattern error, got {other:?}"),
        }
    }

    #[test]
    fn prop_k_read_rows_within_reservation() {
        check("k reads stay inside reserved region", 50, |rng| {
            let kv = kv_slots("gpt2-medium", 2);
            let slot = rng.usize_in(0, 2);
            let ltoken = rng.gen_range(1024) + 1;
            let plans = kv.k_read_plan(2, slot, ltoken);
            let toks_per_unit = ceil_div(kv.max_seq, kv.n_units as u64) as u32;
            for (u, plan) in plans.iter().enumerate() {
                let base = kv.k_base[2][slot][u];
                let end = base + toks_per_unit * kv.rows_per_k;
                for s in plan {
                    if s.row < base || s.row >= end {
                        return Err(format!("unit {u} row {} outside [{base},{end})", s.row));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn slot_footprint_matches_actual_allocation() {
        // The closed-form per-slot footprint must equal what one slot
        // actually consumes on a unit (ModelMapping::build relies on
        // this to size the slot count without retrying placements).
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        let kv = KvReservation::build(&m, &cfg, &mut alloc, 1).unwrap();
        let per_slot = slot_rows_per_unit(&m, &cfg, kv.n_units);
        assert_eq!(per_slot, 12 * (8 + 6)); // 12 layers x (K 8 rows + V 6 rows)
        for u in 0..kv.n_units {
            assert_eq!(alloc.used(alloc.unit(u)), per_slot, "unit {u}");
        }
        // Two slots cost exactly twice as much.
        let mut alloc2 = BankAllocator::new(&cfg);
        KvReservation::build(&m, &cfg, &mut alloc2, 2).unwrap();
        assert_eq!(alloc2.used(alloc2.unit(0)), 2 * per_slot);
    }

    fn kv_paged(model: &str, n_frames: usize, page_tokens: u64) -> KvReservation {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        KvReservation::build_paged(&m, &cfg, &mut alloc, n_frames, page_tokens).unwrap()
    }

    #[test]
    fn round_page_tokens_rounds_and_caps() {
        // Up to a multiple of n_units...
        assert_eq!(round_page_tokens(1, 128, 1024), 128);
        assert_eq!(round_page_tokens(128, 128, 1024), 128);
        assert_eq!(round_page_tokens(129, 128, 1024), 256);
        // ...and capped at the padded full context.
        assert_eq!(round_page_tokens(4096, 128, 1024), 1024);
        assert_eq!(round_page_tokens(u64::MAX / 2, 128, 1000), 1024);
        assert_eq!(round_page_tokens(0, 128, 1024), 128, "0 coerces to one unit round");
    }

    #[test]
    fn full_context_page_is_the_slot_layout() {
        // P = max_seq, n_frames = n_slots: the paged build must assign
        // the *identical* base rows as the slot build, and every paged
        // address method must degenerate to its slot twin. This is the
        // mapping-level half of the kv_paging equivalence contract.
        let slot = kv_slots("gpt2-small", 2);
        let paged = kv_paged("gpt2-small", 2, slot.max_seq);
        assert_eq!(paged.page_tokens, Some(1024));
        assert_eq!(paged.k_base, slot.k_base);
        assert_eq!(paged.v_base, slot.v_base);
        assert_eq!(paged.rows_per_vcol, slot.rows_per_vcol);
        for s in 0..2u32 {
            let pages = [s];
            for t in [0u64, 1, 127, 128, 500] {
                assert_eq!(paged.k_write_paged(3, &pages, t), slot.k_write(3, s as usize, t));
                let u = slot.k_unit(t);
                assert_eq!(paged.v_write_paged(3, &pages, t, u), slot.v_write(3, s as usize, t, u));
            }
            for ltoken in [1u64, 128, 129, 1000] {
                for u in 0..slot.n_units {
                    let runs = paged.k_read_runs(0, &pages, ltoken, u);
                    let owned = slot.k_owned(u, ltoken);
                    if owned == 0 {
                        assert!(runs.is_empty());
                    } else {
                        let (pattern, pattern_len) = slot.k_read_pattern();
                        assert_eq!(
                            runs,
                            vec![PatternRun {
                                base_row: slot.k_base[0][s as usize][u],
                                reps: owned,
                                pattern,
                                pattern_len,
                            }]
                        );
                    }
                    let runs = paged.v_read_runs(0, &pages, ltoken, u);
                    let (pattern, pattern_len) = slot.v_read_pattern(ltoken);
                    assert_eq!(
                        runs,
                        vec![PatternRun {
                            base_row: slot.v_base[0][s as usize][u],
                            reps: slot.v_cols(u),
                            pattern,
                            pattern_len,
                        }]
                    );
                }
            }
        }
    }

    #[test]
    fn paged_reads_cover_all_tokens() {
        // Multi-page read plans account for every stored element exactly
        // once, whatever (shuffled) frames the page table names.
        let kv = kv_paged("gpt2-small", 8, 128);
        let pages = [5u32, 0, 7, 2, 6, 1, 3, 4];
        for ltoken in [1u64, 127, 128, 129, 500, 1000, 1024] {
            let k_total: u64 = (0..kv.n_units)
                .flat_map(|u| kv.k_read_runs(0, &pages, ltoken, u))
                .map(|r| r.reps as u64 * kv.d_model)
                .sum();
            assert_eq!(k_total, ltoken * kv.d_model, "K ltoken={ltoken}");
            let v_total: u64 = (0..kv.n_units)
                .flat_map(|u| kv.v_read_runs(0, &pages, ltoken, u))
                .map(|r| {
                    let span: u64 = r.pattern[..r.pattern_len as usize].iter().map(|&e| e as u64).sum();
                    r.reps as u64 * span
                })
                .sum();
            assert_eq!(v_total, ltoken * kv.d_model, "V ltoken={ltoken}");
        }
    }

    #[test]
    fn paged_writes_stay_inside_their_frame() {
        let kv = kv_paged("gpt2-small", 4, 128);
        let p = kv.page_tokens.unwrap();
        let pages = [3u32, 1, 0, 2];
        let k_rows = (p / kv.n_units as u64) as u32 * kv.rows_per_k;
        for t in [0u64, 127, 128, 300, 511] {
            let frame = pages[(t / p) as usize] as usize;
            let (unit, segs) = kv.k_write_paged(0, &pages, t);
            let u = unit.channel * kv.banks_per_channel + unit.bank;
            let base = kv.k_base[0][frame][u];
            for s in &segs {
                assert!(s.row >= base && s.row < base + k_rows, "t={t} row {}", s.row);
            }
            let (vb, cols, stride) = kv.v_write_paged(0, &pages, t, u);
            assert_eq!(cols, kv.v_cols(u));
            assert_eq!(stride, kv.rows_per_vcol);
            let vbase = kv.v_base[0][frame][u];
            assert!(vb >= vbase && vb < vbase + kv.rows_per_vcol, "t={t}");
        }
    }

    #[test]
    fn frame_footprint_matches_actual_allocation() {
        // Closed-form frame footprint == rows one frame consumes (the
        // paged pool sizing in ModelMapping::build relies on this).
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline();
        let mut alloc = BankAllocator::new(&cfg);
        let kv = KvReservation::build_paged(&m, &cfg, &mut alloc, 1, 128).unwrap();
        let per_frame = frame_rows_per_unit(&m, &cfg, kv.n_units, 128);
        assert_eq!(per_frame, 12 * (1 + 6)); // 12 layers x (K 1 row + V 6 rows)
        for u in 0..kv.n_units {
            assert_eq!(alloc.used(alloc.unit(u)), per_frame, "unit {u}");
        }
        // A full-context frame costs exactly one slot.
        assert_eq!(
            frame_rows_per_unit(&m, &cfg, kv.n_units, m.max_seq as u64),
            slot_rows_per_unit(&m, &cfg, kv.n_units)
        );
    }

    #[test]
    fn frames_for_rounds_up() {
        let kv = kv_paged("gpt2-small", 2, 128);
        assert_eq!(kv.frames_for(0), 1, "an admitted stream needs a first page");
        assert_eq!(kv.frames_for(1), 1);
        assert_eq!(kv.frames_for(128), 1);
        assert_eq!(kv.frames_for(129), 2);
        assert_eq!(kv.frames_for(1024), 8);
    }

    #[test]
    fn prop_slot_regions_never_overlap() {
        // Satellite acceptance: across every (layer, slot) pair, the K
        // and V regions of one unit are pairwise disjoint row ranges.
        check("per-slot KV regions disjoint", 20, |rng| {
            let n_slots = rng.usize_in(1, 5);
            let kv = kv_slots("gpt2-small", n_slots);
            let toks_per_unit = ceil_div(kv.max_seq, kv.n_units as u64) as u32;
            let k_rows = toks_per_unit * kv.rows_per_k;
            let v_rows = kv.v_cols_per_unit as u32 * kv.rows_per_vcol;
            let u = rng.usize_in(0, kv.n_units);
            let mut regions: Vec<(u32, u32, String)> = Vec::new();
            for layer in 0..kv.k_base.len() {
                for slot in 0..n_slots {
                    let kb = kv.k_base[layer][slot][u];
                    regions.push((kb, kb + k_rows, format!("K l{layer} s{slot}")));
                    let vb = kv.v_base[layer][slot][u];
                    regions.push((vb, vb + v_rows, format!("V l{layer} s{slot}")));
                }
            }
            regions.sort_by_key(|r| r.0);
            for w in regions.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!(
                        "unit {u}: {} [{}, {}) overlaps {} [{}, {})",
                        w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                    ));
                }
            }
            Ok(())
        });
    }
}
