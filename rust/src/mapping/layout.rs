//! Bank-row allocator: tracks the next free row of every (channel, bank)
//! unit and hands out contiguous row ranges. All placements are static —
//! PIM-GPT maps the whole model once before serving (paper Fig. 3a).

use crate::config::HwConfig;

/// Identifies one MAC unit = one (channel, bank) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct UnitId {
    pub channel: usize,
    pub bank: usize,
}

/// Row allocator over all units.
#[derive(Clone, Debug)]
pub struct BankAllocator {
    next_row: Vec<u32>,
    rows_per_bank: u32,
    channels: usize,
    banks: usize,
}

/// Why a static placement cannot be realized on the configured DRAM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CapacityError {
    /// A bank ran out of free rows.
    Rows { channel: usize, bank: usize, need: u32, free: u32 },
    /// A stored vector/column needs more rows than the hardware row-fill
    /// pattern supports (`elems > MAX_PATTERN * row_elems`) — the model's
    /// `d_model` or `max_seq` is too large for this row geometry.
    Pattern { elems: u64, max_elems: u64 },
}

impl std::fmt::Display for CapacityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CapacityError::Rows { channel, bank, need, free } => write!(
                f,
                "bank capacity exceeded on ch{channel} bank{bank}: need {need} rows, {free} free"
            ),
            CapacityError::Pattern { elems, max_elems } => write!(
                f,
                "row-fill pattern overflow: {elems} elements per stored vector \
                 exceeds the {max_elems}-element pattern limit"
            ),
        }
    }
}

impl std::error::Error for CapacityError {}

impl BankAllocator {
    pub fn new(cfg: &HwConfig) -> Self {
        let channels = cfg.gddr6.channels;
        let banks = cfg.gddr6.banks_per_channel;
        Self {
            next_row: vec![0; channels * banks],
            rows_per_bank: cfg.gddr6.rows_per_bank() as u32,
            channels,
            banks,
        }
    }

    pub fn n_units(&self) -> usize {
        self.channels * self.banks
    }

    /// Linear unit index -> (channel, bank). Units are numbered
    /// channel-major so consecutive units land on *different banks of the
    /// same channel* first, matching Fig. 6b's distribution.
    pub fn unit(&self, idx: usize) -> UnitId {
        UnitId { channel: idx / self.banks, bank: idx % self.banks }
    }

    fn slot(&self, u: UnitId) -> usize {
        u.channel * self.banks + u.bank
    }

    /// Allocate `rows` consecutive rows on `u`; returns the base row.
    pub fn alloc(&mut self, u: UnitId, rows: u32) -> Result<u32, CapacityError> {
        let slot = self.slot(u);
        let base = self.next_row[slot];
        let free = self.rows_per_bank - base;
        if rows > free {
            return Err(CapacityError::Rows { channel: u.channel, bank: u.bank, need: rows, free });
        }
        self.next_row[slot] += rows;
        Ok(base)
    }

    /// Rows already allocated on `u`.
    pub fn used(&self, u: UnitId) -> u32 {
        self.next_row[self.slot(u)]
    }

    /// Peak fill fraction over all units.
    pub fn max_fill(&self) -> f64 {
        let max = self.next_row.iter().copied().max().unwrap_or(0);
        max as f64 / self.rows_per_bank as f64
    }

    /// Free rows remaining on the fullest unit — the binding constraint
    /// for any further uniform per-unit reservation (KV slot sizing).
    pub fn min_free_rows(&self) -> u32 {
        let max = self.next_row.iter().copied().max().unwrap_or(0);
        self.rows_per_bank - max
    }

    /// Difference between the most- and least-filled unit, in rows —
    /// the balance metric the even distribution optimizes.
    pub fn imbalance_rows(&self) -> u32 {
        let max = self.next_row.iter().copied().max().unwrap_or(0);
        let min = self.next_row.iter().copied().min().unwrap_or(0);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc() -> BankAllocator {
        BankAllocator::new(&HwConfig::paper_baseline())
    }

    #[test]
    fn unit_numbering_is_channel_major() {
        let a = alloc();
        assert_eq!(a.unit(0), UnitId { channel: 0, bank: 0 });
        assert_eq!(a.unit(15), UnitId { channel: 0, bank: 15 });
        assert_eq!(a.unit(16), UnitId { channel: 1, bank: 0 });
        assert_eq!(a.n_units(), 128);
    }

    #[test]
    fn sequential_allocation() {
        let mut a = alloc();
        let u = UnitId { channel: 2, bank: 3 };
        assert_eq!(a.alloc(u, 10).unwrap(), 0);
        assert_eq!(a.alloc(u, 5).unwrap(), 10);
        assert_eq!(a.used(u), 15);
        // other units untouched
        assert_eq!(a.used(UnitId { channel: 2, bank: 4 }), 0);
    }

    #[test]
    fn capacity_enforced() {
        let mut a = alloc();
        let u = UnitId { channel: 0, bank: 0 };
        a.alloc(u, 16384).unwrap();
        let err = a.alloc(u, 1).unwrap_err();
        match err {
            CapacityError::Rows { free, need, .. } => {
                assert_eq!(free, 0);
                assert_eq!(need, 1);
            }
            other => panic!("expected Rows error, got {other:?}"),
        }
    }

    #[test]
    fn imbalance_metric() {
        let mut a = alloc();
        assert_eq!(a.imbalance_rows(), 0);
        a.alloc(UnitId { channel: 0, bank: 0 }, 7).unwrap();
        assert_eq!(a.imbalance_rows(), 7);
    }
}
