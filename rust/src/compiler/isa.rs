//! The PIM-GPT instruction set.
//!
//! Two command streams (paper Fig. 3b): DRAM commands (VMM + KV writes,
//! expanded to ACT/MAC/WR/PRE bursts by the bank state machine) and ASIC
//! commands (arithmetic engines + data movement). Instructions carry
//! explicit dependencies; the scheduler is data-triggered (§III.A).

use crate::asic::AsicOp;
use crate::model::{MatrixId, VmmClass};

/// One instruction.
///
/// KV-touching instructions carry the *stream slot* whose reserved KV
/// region they address (`mapping::KvReservation` partitions the cache
/// per concurrent stream). Programs compile slot-agnostic (slot 0); the
/// slot is a runtime parameter patched in by
/// `ProgramTemplate::instr_at`, exactly like `ltoken`.
///
/// The *pass count* of a prefill chunk (how many consecutive positions
/// one instruction covers, `sim::prefill`) is likewise a runtime
/// parameter — handed to `Resources::issue`, not encoded here — so one
/// compiled program serves decode steps (1 pass) and every chunk size
/// alike. Operand sizes below are always *per pass*.
#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// Broadcast `in_elems` to all channels' GBs, MAC `matrix`, drain
    /// `out_elems`. `parts > 1` means the input exceeded the 2 KB GB and
    /// is streamed in chunks (a PartialSum ASIC op follows). `slot`
    /// selects the KV region for `KCache`/`VCache` reads (0, and
    /// ignored, for weight matrices).
    PimVmm {
        matrix: MatrixId,
        class: VmmClass,
        in_elems: u64,
        out_elems: u64,
        parts: u64,
        slot: usize,
    },
    /// Write token `pos`'s Key vector (row-major) to slot `slot`'s
    /// reserved rows.
    WriteK { layer: usize, slot: usize },
    /// Write token `pos`'s Value elements (column-major) to all units of
    /// slot `slot`'s reserved region.
    WriteV { layer: usize, slot: usize },
    /// Arithmetic on the ASIC computation engines.
    Asic(AsicOp),
}

/// Instruction + dependencies (indices into the program).
#[derive(Clone, Debug)]
pub struct InstrNode {
    pub instr: Instr,
    pub deps: Vec<usize>,
}

/// A compiled decode step.
#[derive(Clone, Debug)]
pub struct Program {
    pub nodes: Vec<InstrNode>,
    /// Context length this step attends over (pos + 1).
    pub ltoken: u64,
    /// Peak SRAM bytes needed by intermediates.
    pub peak_sram_bytes: usize,
}

impl Program {
    /// Count instructions of each broad class (for tests/reports).
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut vmm = 0;
        let mut asic = 0;
        let mut kv = 0;
        for n in &self.nodes {
            match n.instr {
                Instr::PimVmm { .. } => vmm += 1,
                Instr::Asic(_) => asic += 1,
                Instr::WriteK { .. } | Instr::WriteV { .. } => kv += 1,
            }
        }
        (vmm, asic, kv)
    }
}
