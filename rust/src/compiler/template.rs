//! Position-parametric compiled programs and the per-regime cache.
//!
//! A decode step's instruction stream depends on the token position only
//! through the context length `ltoken = pos + 1`, and only in a handful
//! of places: the q@K^T score output, the pre-softmax scale, the softmax
//! itself, the scores@V input (and its GB chunk count), and the partial
//! sum that accumulates those chunks. Everything else — node list,
//! dependency edges, every other operand size — is fixed by the model.
//!
//! The *structure* of the program changes exactly once along a
//! generation: when `n_head * ltoken` first exceeds the 2 KB global
//! buffer, the scores@V VMM becomes chunked and gains a trailing
//! `PartialSum` node. We call the two shapes **position regimes**. A
//! [`ProgramTemplate`] is a program compiled once per regime (at the
//! regime's largest `ltoken`, which also makes the compile-time SRAM
//! check conservative for the whole regime) plus a per-node patch table;
//! [`ProgramTemplate::instr_at`] re-specializes an instruction to any
//! `ltoken` — and to the issuing stream's KV `slot`, since the
//! partitioned KV cache makes every KV read/write slot-addressed — in
//! O(1) with no allocation. The [`ProgramCache`] in front of
//! it is what lets `decode_step` stop rebuilding `DecodeGraph` and
//! re-running `compile()` for every token (≥ 99% hit rate on a 256-token
//! generation; counted in `SimStats::program_cache_{hits,misses}`).
//!
//! Note on the SRAM check: because a template compiles at the regime's
//! *maximum* `ltoken`, a config whose ASIC SRAM only fits short contexts
//! is rejected at the first token of the regime rather than at the exact
//! overflowing position (the per-token seed compiler failed later). All
//! paper configurations fit at full context, so this only affects
//! configs that could not serve the model's `max_seq` anyway.
//!
//! **Prefill chunk programs** (`sim::prefill`): a chunk of `T`
//! consecutive prompt positions executes the decode template of its
//! *last* position — the engine fetches `cache.get` at the chunk's
//! `Chunk::regime_pos()` (its last position), which resolves to the
//! regime [`PosRegime::of_chunk`] describes — with operands
//! specialized by `instr_at(i, ltoken_end, slot)` and issued in
//! matrix-matrix mode (`Resources::issue` receives `passes = T`). The
//! pass count is a runtime parameter exactly like `ltoken` and `slot`:
//! the compiled node list, dependency edges and per-pass operand sizes
//! are identical to the decode program, so the cache needs no extra
//! entries and a 1-position chunk *is* the decode step, bit for bit.
//! The per-position SRAM accounting stays valid because a chunk's
//! positions stream through the same double-buffered windows one after
//! another (`compiler::lower`); only the LM-head logits of the last
//! position are materialized for the host.

use std::rc::Rc;

use super::isa::{Instr, InstrNode, Program};
use super::lower::compile;
use crate::asic::AsicOp;
use crate::config::HwConfig;
use crate::model::{DecodeGraph, GptModel, VmmClass};
use crate::util::ceil_div;
use anyhow::{bail, Result};

/// Structural shape of the decode program at a given position.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PosRegime {
    /// scores@V input (`n_head * ltoken`) exceeds the global buffer, so
    /// the VMM is chunked and followed by a `PartialSum`.
    pub av_chunked: bool,
}

impl PosRegime {
    /// Regime of the decode step at position `pos`.
    pub fn of(model: &GptModel, cfg: &HwConfig, pos: u64) -> Self {
        let ltoken = pos + 1;
        let h = model.n_head as u64;
        Self { av_chunked: h * ltoken > cfg.pim.gb_elems() as u64 }
    }

    /// Regime of a prefill chunk covering positions
    /// `start_pos .. start_pos + len`: the chunk executes one program
    /// compiled for its *last* position (the conservative
    /// representative — a chunk straddling the scores@V boundary runs
    /// chunked-with-partial-sum for all its positions, a slight
    /// overcharge on the pre-boundary ones).
    pub fn of_chunk(model: &GptModel, cfg: &HwConfig, start_pos: u64, len: u64) -> Self {
        Self::of(model, cfg, start_pos + len.max(1) - 1)
    }

    /// Largest `ltoken` this regime covers for `model` — the compile-time
    /// representative (worst case for the SRAM feasibility check).
    pub fn max_ltoken(&self, model: &GptModel, cfg: &HwConfig) -> u64 {
        let h = model.n_head as u64;
        let max_seq = model.max_seq as u64;
        if self.av_chunked {
            max_seq
        } else {
            // Largest ltoken with h * ltoken <= gb_elems.
            (cfg.pim.gb_elems() as u64 / h).clamp(1, max_seq)
        }
    }
}

/// How a node's instruction is re-specialized for a runtime `ltoken`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PatchKind {
    /// q@K^T VMM: `out_elems = n_head * ltoken`.
    ScoreOut,
    /// scores@V VMM: `in_elems = n_head * ltoken`,
    /// `parts = ceil(in_elems / gb_elems)`.
    AttnVIn,
    /// Scale / Softmax over the attention scores: `n = n_head * ltoken`.
    AsicScaled,
    /// PartialSum accumulating the scores@V chunks:
    /// `parts = ceil(n_head * ltoken / gb_elems)`.
    AttnVParts,
    /// PartialSum accumulating the q@K^T chunks (models with
    /// `d_model > gb_elems`): `n = n_head * ltoken`, parts constant.
    ScorePartialN,
}

/// A compiled decode program with its position-dependence factored out.
#[derive(Clone, Debug)]
pub struct ProgramTemplate {
    program: Program,
    /// Parallel to `program.nodes`; `None` = position-independent.
    patch_of: Vec<Option<PatchKind>>,
    n_head: u64,
    gb_elems: u64,
}

impl ProgramTemplate {
    /// Compile the template for `regime` (graph build + lowering happen
    /// once here, then never again for positions inside the regime).
    pub fn build(model: &GptModel, cfg: &HwConfig, regime: PosRegime) -> Result<Self> {
        let lt_ref = regime.max_ltoken(model, cfg);
        let graph = DecodeGraph::build(model, lt_ref - 1);
        let program = compile(&graph, cfg)?;

        let h = model.n_head as u64;
        let gb = cfg.pim.gb_elems() as u64;
        let mut patch_of: Vec<Option<PatchKind>> = vec![None; program.nodes.len()];
        let mut av_nodes: Vec<usize> = Vec::new();
        let mut score_nodes: Vec<usize> = Vec::new();
        for (i, node) in program.nodes.iter().enumerate() {
            let patch = match &node.instr {
                Instr::PimVmm { class: VmmClass::Score, out_elems, .. } => {
                    if *out_elems != h * lt_ref {
                        bail!("score VMM out_elems {out_elems} != n_head*ltoken at node {i}");
                    }
                    score_nodes.push(i);
                    Some(PatchKind::ScoreOut)
                }
                Instr::PimVmm { class: VmmClass::AttnV, in_elems, parts, .. } => {
                    if *in_elems != h * lt_ref || *parts != ceil_div(h * lt_ref, gb) {
                        bail!("attn@V VMM operands unexpected at node {i}");
                    }
                    av_nodes.push(i);
                    Some(PatchKind::AttnVIn)
                }
                Instr::Asic(AsicOp::Scale { n }) | Instr::Asic(AsicOp::Softmax { n, .. }) => {
                    if *n != h * lt_ref {
                        bail!("scaled ASIC op n {n} != n_head*ltoken at node {i}");
                    }
                    Some(PatchKind::AsicScaled)
                }
                Instr::Asic(AsicOp::PartialSum { .. })
                    if node.deps.len() == 1 && av_nodes.contains(&node.deps[0]) =>
                {
                    Some(PatchKind::AttnVParts)
                }
                Instr::Asic(AsicOp::PartialSum { n, .. })
                    if node.deps.len() == 1 && score_nodes.contains(&node.deps[0]) =>
                {
                    if *n != h * lt_ref {
                        bail!("score partial-sum n {n} != n_head*ltoken at node {i}");
                    }
                    Some(PatchKind::ScorePartialN)
                }
                _ => None,
            };
            patch_of[i] = patch;
        }
        Ok(Self { program, patch_of, n_head: h, gb_elems: gb })
    }

    pub fn len(&self) -> usize {
        self.program.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.program.nodes.is_empty()
    }

    pub fn nodes(&self) -> &[InstrNode] {
        &self.program.nodes
    }

    pub fn deps_of(&self, i: usize) -> &[usize] {
        &self.program.nodes[i].deps
    }

    /// Conservative peak SRAM over the whole regime (checked at build).
    pub fn peak_sram_bytes(&self) -> usize {
        self.program.peak_sram_bytes
    }

    /// Instruction `i` specialized to context length `ltoken` and KV
    /// stream slot `slot` — O(1), no allocation (`Instr` holds no heap
    /// data). The slot patch applies to every KV-touching instruction
    /// (KCache/VCache reads, K/V writes): templates are shared across
    /// streams, so the slot — like `ltoken` — is a runtime parameter.
    pub fn instr_at(&self, i: usize, ltoken: u64, slot: usize) -> Instr {
        let mut instr = self.program.nodes[i].instr.clone();
        match &mut instr {
            Instr::PimVmm { matrix, slot: s, .. } if matrix.kind.is_kv_cache() => *s = slot,
            Instr::WriteK { slot: s, .. } | Instr::WriteV { slot: s, .. } => *s = slot,
            _ => {}
        }
        match self.patch_of[i] {
            None => {}
            Some(PatchKind::ScoreOut) => {
                if let Instr::PimVmm { out_elems, .. } = &mut instr {
                    *out_elems = self.n_head * ltoken;
                }
            }
            Some(PatchKind::AttnVIn) => {
                if let Instr::PimVmm { in_elems, parts, .. } = &mut instr {
                    *in_elems = self.n_head * ltoken;
                    *parts = ceil_div(self.n_head * ltoken, self.gb_elems);
                }
            }
            Some(PatchKind::AsicScaled) => match &mut instr {
                Instr::Asic(AsicOp::Scale { n }) | Instr::Asic(AsicOp::Softmax { n, .. }) => {
                    *n = self.n_head * ltoken;
                }
                _ => {}
            },
            Some(PatchKind::AttnVParts) => {
                if let Instr::Asic(AsicOp::PartialSum { parts, .. }) = &mut instr {
                    *parts = ceil_div(self.n_head * ltoken, self.gb_elems);
                }
            }
            Some(PatchKind::ScorePartialN) => {
                if let Instr::Asic(AsicOp::PartialSum { n, .. }) = &mut instr {
                    *n = self.n_head * ltoken;
                }
            }
        }
        instr
    }

    /// Whether node `i` can be **fused across streams**: issued once
    /// with `passes = K` on behalf of K decode streams at the same
    /// position regime, instead of once per stream. True exactly for
    /// the position- and slot-independent nodes — the weight-stationary
    /// VMMs (QKV / attention output / FFN / LM head) and the ASIC ops
    /// whose operand sizes do not scale with the context length. Every
    /// KV-touching instruction (K/V writes, KCache/VCache reads) and
    /// every position-patched node is per-stream: its `slot` or
    /// `ltoken` differs between the fused streams.
    ///
    /// Under **paged KV** (`sched.kv_paging`) the exclusion of KV-cache
    /// reads is load-bearing on its own, not just via the `ltoken`
    /// patch: a KV read resolves through the issuing stream's *page
    /// table* at issue time (`Resources::issue` turns it into per-page
    /// row segments), so two streams at the same `ltoken` still read
    /// different rows. The explicit `is_kv_cache()` check keeps the
    /// predicate correct even for a hypothetical regime where a KV read
    /// escaped position patching — and the shareable set is therefore
    /// *identical* with paging on or off (pinned below), which is what
    /// lets the batched-decode engine share one fused node stream
    /// across both modes.
    pub fn shareable_across_streams(&self, i: usize) -> bool {
        if self.patch_of[i].is_some() {
            return false;
        }
        match &self.program.nodes[i].instr {
            Instr::WriteK { .. } | Instr::WriteV { .. } => false,
            Instr::PimVmm { matrix, .. } => !matrix.kind.is_kv_cache(),
            Instr::Asic(_) => true,
        }
    }

    /// Fully materialize the program at `ltoken`, slot 0 (tests /
    /// tooling; the hot path uses `instr_at` and never allocates).
    pub fn materialize(&self, ltoken: u64) -> Program {
        let mut p = self.program.clone();
        for i in 0..p.nodes.len() {
            p.nodes[i].instr = self.instr_at(i, ltoken, 0);
        }
        p.ltoken = ltoken;
        p
    }
}

/// Per-(model, config) cache of compiled program templates, keyed by
/// position regime. At most one entry per regime ever exists, so a
/// 256-token generation compiles at most twice.
#[derive(Clone, Debug, Default)]
pub struct ProgramCache {
    entries: Vec<(PosRegime, Rc<ProgramTemplate>)>,
    pub hits: u64,
    pub misses: u64,
}

impl ProgramCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Template for decoding at `pos`, compiling on first miss.
    pub fn get(
        &mut self,
        model: &GptModel,
        cfg: &HwConfig,
        pos: u64,
    ) -> Result<Rc<ProgramTemplate>> {
        let regime = PosRegime::of(model, cfg, pos);
        if let Some((_, tpl)) = self.entries.iter().find(|(r, _)| *r == regime) {
            self.hits += 1;
            return Ok(Rc::clone(tpl));
        }
        self.misses += 1;
        let tpl = Rc::new(ProgramTemplate::build(model, cfg, regime)?);
        self.entries.push((regime, Rc::clone(&tpl)));
        Ok(tpl)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 1.0;
        }
        self.hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;

    fn cfg() -> HwConfig {
        HwConfig::paper_baseline()
    }

    /// The template specialized to `ltoken` must equal a fresh compile at
    /// the same position, node for node — the cache is then *exactly* the
    /// seed compiler, amortized.
    #[test]
    fn materialized_matches_fresh_compile() {
        let cfg = cfg();
        // gpt2-small straddles the scores@V chunking boundary (h=12,
        // gb=1024: ltoken 85 is the last unchunked, 86 the first
        // chunked); gpt3-xl (d=2048 > gb) additionally has chunked q@K^T
        // with a position-scaled partial sum.
        for (model, positions) in [
            ("gpt2-small", &[0u64, 1, 42, 84, 85, 86, 100, 511, 1023][..]),
            ("gpt3-xl", &[0u64, 5, 42, 43, 100, 2047][..]),
        ] {
            let m = by_name(model).unwrap();
            for &pos in positions {
                let regime = PosRegime::of(&m, &cfg, pos);
                let tpl = ProgramTemplate::build(&m, &cfg, regime).unwrap();
                let got = tpl.materialize(pos + 1);
                let graph = DecodeGraph::build(&m, pos);
                let want = compile(&graph, &cfg).unwrap();
                assert_eq!(got.nodes.len(), want.nodes.len(), "{model} pos {pos}");
                for (i, (g, w)) in got.nodes.iter().zip(&want.nodes).enumerate() {
                    assert_eq!(g.instr, w.instr, "{model} pos {pos} node {i}");
                    assert_eq!(g.deps, w.deps, "{model} pos {pos} node {i}");
                }
                assert_eq!(got.ltoken, want.ltoken);
            }
        }
    }

    #[test]
    fn regime_boundary_where_expected() {
        let m = by_name("gpt2-small").unwrap(); // h = 12
        let cfg = cfg(); // gb_elems = 1024
        assert!(!PosRegime::of(&m, &cfg, 84).av_chunked); // ltoken 85: 1020
        assert!(PosRegime::of(&m, &cfg, 85).av_chunked); // ltoken 86: 1032
    }

    /// A chunk's regime is its last position's regime — a chunk
    /// straddling the boundary compiles chunked (conservative).
    #[test]
    fn chunk_regime_is_last_positions_regime() {
        let m = by_name("gpt2-small").unwrap();
        let cfg = cfg();
        assert_eq!(PosRegime::of_chunk(&m, &cfg, 0, 32), PosRegime::of(&m, &cfg, 31));
        assert!(!PosRegime::of_chunk(&m, &cfg, 64, 21).av_chunked); // ends at pos 84
        assert!(PosRegime::of_chunk(&m, &cfg, 64, 22).av_chunked); // ends at pos 85
        assert_eq!(PosRegime::of_chunk(&m, &cfg, 7, 0), PosRegime::of(&m, &cfg, 7));
    }

    #[test]
    fn cache_compiles_at_most_once_per_regime() {
        let m = by_name("gpt2-small").unwrap();
        let cfg = cfg();
        let mut cache = ProgramCache::new();
        for pos in 0..256u64 {
            cache.get(&m, &cfg, pos).unwrap();
        }
        assert_eq!(cache.len(), 2); // unchunked + chunked
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 254);
        assert!(cache.hit_rate() > 0.99, "{}", cache.hit_rate());
    }

    #[test]
    fn small_model_single_regime() {
        // gpt-nano: h * max_seq = 4 * 128 = 512 <= 1024 -> never chunked.
        let m = by_name("gpt-nano").unwrap();
        let cfg = cfg();
        let mut cache = ProgramCache::new();
        for pos in 0..(m.max_seq as u64) {
            cache.get(&m, &cfg, pos).unwrap();
        }
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn instr_at_is_patch_only_for_const_nodes() {
        let m = by_name("gpt2-small").unwrap();
        let cfg = cfg();
        let tpl =
            ProgramTemplate::build(&m, &cfg, PosRegime { av_chunked: false }).unwrap();
        // LM head (last node) is position- and slot-independent.
        let last = tpl.len() - 1;
        assert_eq!(tpl.instr_at(last, 1, 0), tpl.instr_at(last, 50, 0));
        assert_eq!(tpl.instr_at(last, 1, 0), tpl.instr_at(last, 1, 3));
    }

    /// A node is shareable across streams iff `instr_at` is invariant
    /// in both `ltoken` and `slot` — the contract batched decode fuses
    /// on. The non-shareable set is exactly the per-layer KV writes
    /// plus every patched node (which includes both KV-cache reads).
    #[test]
    fn shareable_nodes_are_exactly_the_ltoken_and_slot_invariant_ones() {
        let m = by_name("gpt2-small").unwrap();
        let cfg = cfg();
        for regime in [PosRegime { av_chunked: false }, PosRegime { av_chunked: true }] {
            let tpl = ProgramTemplate::build(&m, &cfg, regime).unwrap();
            let mut shareable = 0usize;
            let mut kv_writes = 0usize;
            for i in 0..tpl.len() {
                let instr = tpl.instr_at(i, 5, 1);
                if tpl.shareable_across_streams(i) {
                    shareable += 1;
                    assert_eq!(instr, tpl.instr_at(i, 9, 3), "shareable node {i} varies");
                    match &instr {
                        Instr::WriteK { .. } | Instr::WriteV { .. } => {
                            panic!("KV write node {i} marked shareable")
                        }
                        Instr::PimVmm { matrix, .. } => assert!(!matrix.kind.is_kv_cache()),
                        Instr::Asic(_) => {}
                    }
                } else if let Instr::WriteK { .. } | Instr::WriteV { .. } = instr {
                    kv_writes += 1;
                }
            }
            // Weight VMMs and fixed-size ASIC ops dominate the program.
            assert!(shareable > tpl.len() / 2, "only {shareable}/{} shareable", tpl.len());
            assert_eq!(kv_writes, 2 * m.n_layer, "av_chunked={}", regime.av_chunked);
        }
    }

    /// Pinned: the shareable node set does not depend on the KV
    /// layout. Templates compile from the model and PIM geometry alone;
    /// turning `sched.kv_paging` on must leave both the compiled nodes
    /// and the shareable predicate bit-identical, so batched decode
    /// fuses the same node set in slot and paged mode (the paged
    /// difference lives entirely in issue-time page indirection).
    #[test]
    fn shareable_set_is_identical_with_paging_on_and_off() {
        let m = by_name("gpt2-small").unwrap();
        let off = cfg();
        let mut on = cfg();
        on.sched.kv_paging = true;
        on.sched.kv_page_tokens = 128;
        for regime in [PosRegime { av_chunked: false }, PosRegime { av_chunked: true }] {
            let t_off = ProgramTemplate::build(&m, &off, regime).unwrap();
            let t_on = ProgramTemplate::build(&m, &on, regime).unwrap();
            assert_eq!(t_off.len(), t_on.len());
            for i in 0..t_off.len() {
                assert_eq!(
                    t_off.shareable_across_streams(i),
                    t_on.shareable_across_streams(i),
                    "node {i}, av_chunked={}",
                    regime.av_chunked
                );
                assert_eq!(t_off.instr_at(i, 9, 1), t_on.instr_at(i, 9, 1), "node {i}");
            }
        }
    }

    #[test]
    fn slot_patched_into_every_kv_instruction() {
        use crate::model::MatrixKind;
        let m = by_name("gpt2-small").unwrap();
        let cfg = cfg();
        let tpl =
            ProgramTemplate::build(&m, &cfg, PosRegime { av_chunked: false }).unwrap();
        let mut kv_instrs = 0;
        for i in 0..tpl.len() {
            match tpl.instr_at(i, 10, 2) {
                Instr::WriteK { slot, .. } | Instr::WriteV { slot, .. } => {
                    assert_eq!(slot, 2, "node {i}");
                    kv_instrs += 1;
                }
                Instr::PimVmm { matrix, slot, .. } => {
                    if matrix.kind.is_kv_cache() {
                        assert_eq!(slot, 2, "node {i}");
                        kv_instrs += 1;
                    } else {
                        assert_eq!(slot, 0, "weight VMM node {i} must stay slot 0");
                    }
                }
                Instr::Asic(_) => {}
            }
        }
        // 2 writes + 2 cache reads per layer.
        assert_eq!(kv_instrs, 4 * m.n_layer);
        // And there are weight VMMs in the mix that stayed slot 0.
        let weight_vmms = (0..tpl.len())
            .filter(|&i| matches!(
                tpl.instr_at(i, 10, 2),
                Instr::PimVmm { matrix, .. } if matrix.kind == MatrixKind::Wqkv
            ))
            .count();
        assert_eq!(weight_vmms, m.n_layer);
    }
}
