//! Graph -> instruction lowering.
//!
//! Mostly 1:1, with two hardware-driven transforms:
//!
//! * **GB chunking**: a VMM whose input vector exceeds the 2 KB global
//!   buffer (`gb_elems`) is marked with `parts = ceil(in/gb)` and
//!   followed by an ASIC `PartialSum` that accumulates the per-chunk
//!   partial outputs (paper §III.B / §IV.A(2)). Downstream consumers are
//!   re-pointed at the partial sum.
//! * **SRAM accounting**: every intermediate vector is sized against the
//!   128 KB ASIC SRAM; the peak is recorded and checked (overflow is a
//!   compile error — the hardware has no spill path).
//!
//! The accounting is *per position* and stays valid for prefill chunk
//! programs (`sim::prefill`): a chunk's `T` positions stream through the
//! engines one after another, each reusing the same double-buffered
//! windows, so at no point are two positions' intermediates live
//! together — the chunk multiplies *time* per instruction (`passes` in
//! `Resources::issue`), never SRAM residency.

use super::isa::{Instr, InstrNode, Program};
use crate::asic::AsicOp;
use crate::config::HwConfig;
use crate::model::{DecodeGraph, GraphOp};
use crate::util::ceil_div;
use anyhow::{bail, Result};

/// SRAM streaming window for pipelined elementwise/grouped ASIC ops
/// (double-buffered working set, a quarter of the 128 KB SRAM).
const STREAM_WINDOW_ELEMS: u64 = 16 * 1024;

/// Lower `graph` for the given hardware.
pub fn compile(graph: &DecodeGraph, cfg: &HwConfig) -> Result<Program> {
    let gb_elems = cfg.pim.gb_elems() as u64;
    let sram_cap = cfg.asic.sram_kb * 1024;
    let mut nodes: Vec<InstrNode> = Vec::with_capacity(graph.nodes.len() + 8);
    // graph node index -> instruction index producing its value
    let mut out_of: Vec<usize> = Vec::with_capacity(graph.nodes.len());
    let mut peak_sram = 0usize;

    for gnode in &graph.nodes {
        let deps: Vec<usize> = gnode.deps.iter().map(|&d| out_of[d]).collect();
        let idx = match &gnode.op {
            GraphOp::Vmm { matrix, class, in_elems, out_elems } => {
                // SRAM: input vector + output vector live concurrently.
                // Inputs above the GB size are streamed in double-buffered
                // GB-sized chunks, so only 2 chunks are ever live; outputs
                // consumed by streamable ASIC ops (softmax per head,
                // partial sums) likewise stream through a double buffer —
                // this is what bounds attention-score storage and enables
                // the paper's 8k+ token support (§V.E).
                let live_in = (*in_elems).min(2 * gb_elems);
                let live_out = (*out_elems).min(2 * gb_elems).max(
                    // the LM-head logits are materialized in full for
                    // the host (vocab fits: 50257 * 2 B < 128 KB)
                    if *class == crate::model::VmmClass::LmHead { *out_elems } else { 0 },
                );
                let need = ((live_in + live_out) * 2) as usize;
                peak_sram = peak_sram.max(need);
                if need > sram_cap {
                    bail!(
                        "VMM {matrix:?} intermediates ({need} B) exceed ASIC SRAM ({sram_cap} B)"
                    );
                }
                let parts = ceil_div(*in_elems, gb_elems);
                // Programs compile slot-agnostic: slot 0 here, patched
                // to the issuing stream's slot by `instr_at`.
                let vmm = InstrNode {
                    instr: Instr::PimVmm {
                        matrix: *matrix,
                        class: *class,
                        in_elems: *in_elems,
                        out_elems: *out_elems,
                        parts,
                        slot: 0,
                    },
                    deps,
                };
                nodes.push(vmm);
                let vmm_idx = nodes.len() - 1;
                if parts > 1 {
                    // Chunked input: ASIC accumulates per-chunk partials.
                    nodes.push(InstrNode {
                        instr: Instr::Asic(AsicOp::PartialSum { n: *out_elems, parts }),
                        deps: vec![vmm_idx],
                    });
                    nodes.len() - 1
                } else {
                    vmm_idx
                }
            }
            GraphOp::Asic(op) => {
                // Streamable ops process data through a bounded window
                // (they start on partial inputs — §IV.A(3)); only
                // non-streamable ops hold their full input.
                let live = if op.streamable() {
                    op.live_elems().min(STREAM_WINDOW_ELEMS)
                } else {
                    op.live_elems()
                };
                peak_sram = peak_sram.max((live * 2) as usize);
                nodes.push(InstrNode { instr: Instr::Asic(*op), deps });
                nodes.len() - 1
            }
            GraphOp::WriteK { layer, .. } => {
                nodes.push(InstrNode { instr: Instr::WriteK { layer: *layer, slot: 0 }, deps });
                nodes.len() - 1
            }
            GraphOp::WriteV { layer, .. } => {
                nodes.push(InstrNode { instr: Instr::WriteV { layer: *layer, slot: 0 }, deps });
                nodes.len() - 1
            }
        };
        out_of.push(idx);
    }

    if peak_sram > sram_cap {
        bail!("peak SRAM {peak_sram} B exceeds capacity {sram_cap} B");
    }
    Ok(Program { nodes, ltoken: graph.ltoken, peak_sram_bytes: peak_sram })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::by_name;
    use crate::model::VmmClass;

    fn program(model: &str, pos: u64) -> Program {
        let m = by_name(model).unwrap();
        let g = DecodeGraph::build(&m, pos);
        compile(&g, &HwConfig::paper_baseline()).unwrap()
    }

    #[test]
    fn small_model_short_context_no_chunking() {
        let p = program("gpt2-small", 0);
        for n in &p.nodes {
            if let Instr::PimVmm { parts, class, .. } = &n.instr {
                // fc2 input is 4*768 = 3072 > 1024 -> chunked even here
                if *class != VmmClass::Fc2 {
                    assert_eq!(*parts, 1, "{:?}", n.instr);
                }
            }
        }
    }

    #[test]
    fn fc2_is_gb_chunked_with_partial_sum() {
        let p = program("gpt2-small", 0);
        let mut found = false;
        for (i, n) in p.nodes.iter().enumerate() {
            if let Instr::PimVmm { class: VmmClass::Fc2, parts, in_elems, .. } = &n.instr {
                assert_eq!(*in_elems, 3072);
                assert_eq!(*parts, 3);
                // next instruction must be the partial sum depending on it
                match &p.nodes[i + 1].instr {
                    Instr::Asic(AsicOp::PartialSum { parts: ps, .. }) => assert_eq!(*ps, 3),
                    other => panic!("expected PartialSum after fc2, got {other:?}"),
                }
                assert!(p.nodes[i + 1].deps.contains(&i));
                found = true;
            }
        }
        assert!(found);
    }

    #[test]
    fn long_context_av_is_chunked() {
        // scores @ V at ltoken=1024 with 12 heads: input 12288 > 1024
        let p = program("gpt2-small", 1023);
        let av = p.nodes.iter().find_map(|n| match &n.instr {
            Instr::PimVmm { class: VmmClass::AttnV, parts, in_elems, .. } => Some((*parts, *in_elems)),
            _ => None,
        });
        let (parts, in_elems) = av.unwrap();
        assert_eq!(in_elems, 12 * 1024);
        assert_eq!(parts, 12);
    }

    #[test]
    fn deps_remapped_through_partial_sum() {
        let p = program("gpt2-small", 0);
        // Any consumer of an fc2 VMM must instead depend on its PartialSum.
        for (i, n) in p.nodes.iter().enumerate() {
            if let Instr::PimVmm { class: VmmClass::Fc2, .. } = &n.instr {
                for later in &p.nodes[i + 2..] {
                    assert!(
                        !later.deps.contains(&i),
                        "consumer bypasses partial sum of node {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn instruction_counts() {
        let m = by_name("gpt2-small").unwrap();
        let p = program("gpt2-small", 0);
        let (vmm, _asic, kv) = p.counts();
        assert_eq!(vmm, 6 * m.n_layer + 1);
        assert_eq!(kv, 2 * m.n_layer);
    }

    #[test]
    fn sram_peak_recorded_and_fits() {
        // Largest model's worst intermediate: lm-head in+out
        let p = program("gpt3-xl", 2047);
        assert!(p.peak_sram_bytes > 0);
        assert!(p.peak_sram_bytes <= 128 * 1024, "{}", p.peak_sram_bytes);
    }

    #[test]
    fn deps_stay_topological() {
        let p = program("gpt3-large", 100);
        for (i, n) in p.nodes.iter().enumerate() {
            for &d in &n.deps {
                assert!(d < i);
            }
        }
    }
}
