//! Instruction compiler: lowers the per-token `DecodeGraph` into a
//! dependency-tagged PIM/ASIC instruction stream (paper Fig. 3b).

pub mod isa;
pub mod lower;

pub use isa::{Instr, InstrNode, Program};
pub use lower::compile;
