//! Instruction compiler: lowers the per-token `DecodeGraph` into a
//! dependency-tagged PIM/ASIC instruction stream (paper Fig. 3b), plus
//! the position-parametric program templates and the per-regime cache
//! that amortize compilation across an autoregressive generation.

pub mod isa;
pub mod lower;
pub mod template;

pub use isa::{Instr, InstrNode, Program};
pub use lower::compile;
pub use template::{PosRegime, ProgramCache, ProgramTemplate};
