//! System energy model (paper §V.A "Benchmark Analysis").
//!
//! Components:
//!
//! * **DRAM core** — per-bank IDD-based accounting (`dram::power`):
//!   activation (IDD0 over tRC), refresh (IDD5B), background
//!   (IDD3N busy / IDD2N idle). PIM's key win is that MAC reads consume
//!   row-buffer data *locally*: no IDD4R interface bursts for weights.
//! * **Interface** — IDD4R/IDD4W burst currents are charged only on
//!   actual PIM<->ASIC transfers (GB loads, result drains, KV writes),
//!   cycles derived from bytes moved / channel bandwidth.
//! * **MAC units** — synthesized 149.29 mW per channel's 16 units
//!   (x1.5 routing margin, §V.A), charged over MAC busy cycles.
//! * **ASIC** — 304.59 mW peak while busy; power-gated to a small
//!   leakage fraction when idle (§III.C power gating).


use crate::dram::power::{
    bank_activate_energy, channel_background_energy, channel_refresh_energy, DramEnergy,
};
use crate::dram::TimingCycles;
use crate::sim::Simulator;

/// Idle (power-gated) ASIC power as a fraction of peak.
pub const ASIC_IDLE_FRACTION: f64 = 0.05;

/// Full-system energy breakdown, joules.
#[derive(Clone, Copy, Debug, Default)]
pub struct SystemEnergy {
    pub dram: DramEnergy,
    pub interface_j: f64,
    pub mac_units_j: f64,
    pub asic_j: f64,
}

impl SystemEnergy {
    pub fn total_j(&self) -> f64 {
        self.dram.total_j() + self.interface_j + self.mac_units_j + self.asic_j
    }

    /// Compute energy for a finished simulation run.
    pub fn from_sim(sim: &Simulator) -> Self {
        let cfg = &sim.cfg;
        let t = TimingCycles::from_config(cfg);
        let elapsed = sim.clock();
        let cycle_s = 1e-9 / cfg.gddr6.freq_ghz;

        // DRAM core energy: activations per bank; refresh + background per
        // channel (IDD currents are device-level quantities).
        let mut dram = DramEnergy::default();
        for ch in sim.channels() {
            let mut ch_busy = 0u64;
            let mut ch_refresh = 0u64;
            for b in &ch.banks {
                dram.activate_j += bank_activate_energy(cfg, &t, &b.cmds);
                ch_busy = ch_busy.max(b.cmds.busy_cycles);
                ch_refresh = ch_refresh.max(b.cmds.refresh);
            }
            dram.refresh_j += channel_refresh_energy(cfg, &t, ch_refresh);
            dram.background_j += channel_background_energy(cfg, ch_busy, elapsed);
        }

        // Interface bursts: bytes -> cycles at the channel data rate.
        let per_cycle = cfg.gddr6.channel_bytes_per_cycle();
        let vdd = cfg.gddr6.vdd;
        let idd = &cfg.idd;
        let mut interface_j = 0.0;
        for ch in sim.channels() {
            let rd_cycles = ch.bytes_out as f64 / per_cycle;
            let wr_cycles = ch.bytes_in as f64 / per_cycle;
            interface_j += (idd.idd4r - idd.idd3n) * 1e-3 * vdd * rd_cycles * cycle_s;
            interface_j += (idd.idd4w - idd.idd3n) * 1e-3 * vdd * wr_cycles * cycle_s;
        }

        // MAC units: per-unit share of the synthesized channel power.
        let per_unit_w =
            cfg.pim.mac_power_mw_per_channel * 1e-3 / cfg.gddr6.banks_per_channel as f64;
        let mut mac_units_j = 0.0;
        for ch in sim.channels() {
            for b in &ch.banks {
                mac_units_j += per_unit_w * b.cmds.mac_read_cycles as f64 * cycle_s;
            }
        }

        // ASIC: busy at peak power, idle power-gated.
        let busy = sim.engine().busy_cycles.min(elapsed);
        let idle = elapsed - busy;
        let asic_w = cfg.asic.power_mw * 1e-3;
        let asic_j =
            asic_w * busy as f64 * cycle_s + asic_w * ASIC_IDLE_FRACTION * idle as f64 * cycle_s;

        Self { dram, interface_j, mac_units_j, asic_j }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::model::gpt::by_name;

    fn run(model: &str, tokens: u64) -> (Simulator, SystemEnergy) {
        let mut s = Simulator::new(&by_name(model).unwrap(), &HwConfig::paper_baseline()).unwrap();
        s.generate(tokens).unwrap();
        s.finalize_stats();
        let e = SystemEnergy::from_sim(&s);
        (s, e)
    }

    #[test]
    fn energy_positive_and_dominated_by_dram() {
        let (_, e) = run("gpt2-small", 8);
        assert!(e.total_j() > 0.0);
        // The paper: ASIC contributes a very small fraction of total energy.
        assert!(e.asic_j < 0.2 * e.total_j(), "asic {} of {}", e.asic_j, e.total_j());
        assert!(e.dram.total_j() > 0.3 * e.total_j());
    }

    #[test]
    fn per_token_energy_plausible_millijoules() {
        // PIM-GPT should land in the low-mJ/token range for a 124M model
        // (the entire basis of the 100-1000x energy claims vs ~1 J GPU).
        let (s, e) = run("gpt2-small", 8);
        let per_token = e.total_j() / s.stats.tokens as f64;
        assert!(per_token > 1e-5 && per_token < 2e-2, "{per_token} J/token");
    }

    #[test]
    fn energy_scales_with_model_size() {
        let (_, e_small) = run("gpt2-small", 4);
        let (_, e_med) = run("gpt2-medium", 4);
        assert!(e_med.total_j() > 1.5 * e_small.total_j());
    }

    #[test]
    fn refresh_energy_included() {
        let (_, e) = run("gpt2-small", 8);
        assert!(e.dram.refresh_j > 0.0);
    }
}
