//! GDDR6 DRAM substrate: command set, timing, bank state machine and the
//! IDD-based power model (paper Table I, §V.A).

pub mod bank;
pub mod command;
pub mod power;
pub mod timing;

pub use bank::{Bank, BankStats, RowSegment};
pub use command::CommandCounts;
pub use timing::TimingCycles;
