//! IDD-based DRAM power model (paper §V.A: "we multiply the IDD values
//! consumed during each command with the corresponding latency and VDD,
//! following the standard procedure" — Micron DDR5 addendum / Ghose et al.).
//!
//! IDD currents are *device* (channel) level quantities:
//!
//! * activation: `IDD0 * VDD * tRC` per ACT-PRE pair (IDD0 is defined as
//!   the average device current over one full ACT-PRE cycle) — charged
//!   per bank activation, the marginal unit of PIM row energy;
//! * refresh: `(IDD5B - IDD3N) * VDD * tRFC` per all-bank refresh,
//!   charged once per *channel* refresh event;
//! * background: `IDD3N * VDD` while the channel has any open bank,
//!   `IDD2N * VDD` otherwise — charged per channel over the run;
//! * interface bursts (IDD4R/IDD4W) are charged in `energy::SystemEnergy`
//!   on actual PIM<->ASIC transfer cycles only: PIM's MAC units consume
//!   row-buffer data locally and never pay interface burst energy for
//!   weights — that elimination is the core of the paper's energy claim.

use super::command::CommandCounts;
use super::timing::TimingCycles;
use crate::config::HwConfig;

/// DRAM-core energy breakdown, joules.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DramEnergy {
    pub activate_j: f64,
    pub refresh_j: f64,
    pub background_j: f64,
}

impl DramEnergy {
    pub fn total_j(&self) -> f64 {
        self.activate_j + self.refresh_j + self.background_j
    }

    pub fn merge(&mut self, o: &DramEnergy) {
        self.activate_j += o.activate_j;
        self.refresh_j += o.refresh_j;
        self.background_j += o.background_j;
    }
}

fn cycle_s(cfg: &HwConfig) -> f64 {
    1e-9 / cfg.gddr6.freq_ghz
}

/// Energy of one bank's row activations.
pub fn bank_activate_energy(cfg: &HwConfig, t: &TimingCycles, cmds: &CommandCounts) -> f64 {
    cfg.idd.idd0 * 1e-3 * cfg.gddr6.vdd * (cmds.act * t.trc()) as f64 * cycle_s(cfg)
}

/// Energy of `refreshes` all-bank refresh events on one channel.
pub fn channel_refresh_energy(cfg: &HwConfig, t: &TimingCycles, refreshes: u64) -> f64 {
    (cfg.idd.idd5b - cfg.idd.idd3n).max(0.0) * 1e-3
        * cfg.gddr6.vdd
        * (refreshes * t.trfc) as f64
        * cycle_s(cfg)
}

/// Background energy of one channel over `elapsed` cycles, of which
/// `busy` had at least one bank active.
pub fn channel_background_energy(cfg: &HwConfig, busy: u64, elapsed: u64) -> f64 {
    let busy = busy.min(elapsed);
    let idle = elapsed - busy;
    (cfg.idd.idd3n * busy as f64 + cfg.idd.idd2n * idle as f64)
        * 1e-3
        * cfg.gddr6.vdd
        * cycle_s(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (HwConfig, TimingCycles) {
        let cfg = HwConfig::paper_baseline();
        let t = TimingCycles::from_config(&cfg);
        (cfg, t)
    }

    #[test]
    fn activation_energy_exact() {
        let (cfg, t) = setup();
        let cmds = CommandCounts { act: 100, ..Default::default() };
        let e = bank_activate_energy(&cfg, &t, &cmds);
        // 100 ACTs * tRC(40 ns) * 122 mA * 1.25 V = 610 nJ
        let want = 100.0 * 40e-9 * 122e-3 * 1.25;
        assert!((e - want).abs() / want < 1e-9, "{e} vs {want}");
    }

    #[test]
    fn idle_channel_background_is_idd2n() {
        let (cfg, _) = setup();
        let e = channel_background_energy(&cfg, 0, 1_000_000);
        // 92 mA * 1.25 V * 1 ms
        let want = 92e-3 * 1.25 * 1e-3;
        assert!((e - want).abs() / want < 1e-9);
    }

    #[test]
    fn busy_channel_background_is_idd3n() {
        let (cfg, _) = setup();
        let e = channel_background_energy(&cfg, 1_000_000, 1_000_000);
        let want = 142e-3 * 1.25 * 1e-3;
        assert!((e - want).abs() / want < 1e-9);
    }

    #[test]
    fn refresh_energy_marginal_over_background() {
        let (cfg, t) = setup();
        let e = channel_refresh_energy(&cfg, &t, 10);
        // (277-142) mA * 1.25 V * 10 * 455 ns
        let want = 135e-3 * 1.25 * 10.0 * 455e-9;
        assert!((e - want).abs() / want < 1e-9);
    }

    #[test]
    fn activation_scales_linearly() {
        let (cfg, t) = setup();
        let e1 = bank_activate_energy(&cfg, &t, &CommandCounts { act: 10, ..Default::default() });
        let e2 = bank_activate_energy(&cfg, &t, &CommandCounts { act: 20, ..Default::default() });
        assert!((e2 / e1 - 2.0).abs() < 1e-12);
    }
}
