//! Timing constraints in DRAM clock cycles.
//!
//! The GDDR6 core runs at 1 GHz (Table I) so 1 cycle == 1 ns at the
//! baseline; all latency math inside the simulator is in cycles and is
//! converted to seconds only at the reporting boundary.

use crate::config::HwConfig;

/// Table-I timing constraints converted to cycles.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimingCycles {
    pub trcd: u64,
    pub trp: u64,
    pub tccd: u64,
    pub twr: u64,
    pub trfc: u64,
    pub trefi: u64,
    pub tras: u64,
}

impl TimingCycles {
    pub fn from_config(cfg: &HwConfig) -> Self {
        let f = cfg.gddr6.freq_ghz; // cycles = ns * freq
        let c = |ns: u64| ((ns as f64) * f).round().max(1.0) as u64;
        Self {
            trcd: c(cfg.timing.trcd),
            trp: c(cfg.timing.trp),
            tccd: c(cfg.timing.tccd),
            twr: c(cfg.timing.twr),
            trfc: c(cfg.timing.trfc),
            trefi: c(cfg.timing.trefi),
            tras: c(cfg.timing.tras),
        }
    }

    /// Row cycle time: minimum interval between ACTs to the same bank.
    pub fn trc(&self) -> u64 {
        self.tras + self.trp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_identity_at_1ghz() {
        let t = TimingCycles::from_config(&HwConfig::paper_baseline());
        assert_eq!(t.trcd, 12);
        assert_eq!(t.trp, 12);
        assert_eq!(t.tccd, 1);
        assert_eq!(t.twr, 12);
        assert_eq!(t.trfc, 455);
        assert_eq!(t.trefi, 6825);
        assert_eq!(t.trc(), 40);
    }

    #[test]
    fn scales_with_frequency() {
        let mut cfg = HwConfig::paper_baseline();
        cfg.gddr6.freq_ghz = 2.0;
        let t = TimingCycles::from_config(&cfg);
        assert_eq!(t.trcd, 24);
        assert_eq!(t.tccd, 2);
    }
}
