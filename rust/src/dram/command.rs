//! DRAM command accounting.
//!
//! The simulator does not enqueue individual column commands (that would
//! be ~10^9 objects for a 1024-token run); instead every bank tracks the
//! *counts* and *busy cycles* per command class, which is exactly what the
//! IDD power model consumes. Timing correctness is enforced by the bank
//! state machine when it lays out each command burst.

/// Per-class DRAM command counters (one per bank, merged upward).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommandCounts {
    /// Row activations issued.
    pub act: u64,
    /// Precharges issued.
    pub pre: u64,
    /// Column-read cycles spent feeding the MAC units (tCCD each).
    pub mac_read_cycles: u64,
    /// Column-write cycles (KV write-back).
    pub write_cycles: u64,
    /// Write-recovery waits (tWR) incurred.
    pub write_recoveries: u64,
    /// Refresh commands (tRFC each) — counted at channel level.
    pub refresh: u64,
    /// Cycles the bank spent busy (any command in flight).
    pub busy_cycles: u64,
}

impl CommandCounts {
    pub fn merge(&mut self, other: &CommandCounts) {
        self.act += other.act;
        self.pre += other.pre;
        self.mac_read_cycles += other.mac_read_cycles;
        self.write_cycles += other.write_cycles;
        self.write_recoveries += other.write_recoveries;
        self.refresh += other.refresh;
        self.busy_cycles += other.busy_cycles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_fields() {
        let mut a = CommandCounts { act: 1, pre: 2, mac_read_cycles: 3, ..Default::default() };
        let b = CommandCounts { act: 10, write_cycles: 5, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.act, 11);
        assert_eq!(a.pre, 2);
        assert_eq!(a.mac_read_cycles, 3);
        assert_eq!(a.write_cycles, 5);
    }
}
